//! Integration: every paper figure regenerates and the cross-figure
//! claims (abstract + §5/§6 conclusions) hold across module boundaries.

use cmphx::bench_harness::Table;
use cmphx::calibration as cal;
use cmphx::device::registry;
use cmphx::isa::pass::FmadPolicy;
use cmphx::llm::llamabench::LlamaBench;
use cmphx::llm::quant;
use cmphx::report::figures;

#[test]
fn all_twelve_figures_regenerate() {
    let figs = figures::all_figures();
    assert_eq!(figs.len(), 12, "one per paper table/graph");
    for t in &figs {
        assert!(!t.rows.is_empty(), "{}", t.title);
        let rendered = t.render();
        assert!(rendered.len() > 40);
    }
}

#[test]
fn calibrated_figures_stay_within_tolerance() {
    // Figures with direct paper numbers must reproduce them.
    let checks: &[(Table, f64)] = &[
        (figures::graph_3_1(), 0.12),
        (figures::graph_3_2(), 0.08),
        (figures::graph_3_3(), 0.10),
        (figures::graph_3_4(), 0.06),
        (figures::graph_3_5(), 0.05),
        (figures::graph_ex1(), 0.06),
        (figures::table_1_1(), 0.02),
        (figures::table_1_2(), 0.01),
    ];
    for (t, tol) in checks {
        let worst = t.worst_deviation().expect(&t.title);
        assert!(worst <= *tol, "{}: worst deviation {worst}", t.title);
    }
}

#[test]
fn abstract_headline_claims_hold() {
    // "FP32 floating-point performance exceeds 15 times the original"
    let g31 = figures::graph_3_1();
    let find = |t: &Table, pat: &str, pat2: &str| {
        t.rows
            .iter()
            .find(|r| r.label.contains(pat) && r.label.contains(pat2))
            .map(|r| r.measured)
            .unwrap()
    };
    let restore = find(&g31, "OpenCL", "noFMA") / find(&g31, "OpenCL", "default");
    assert!(restore > 15.0, "{restore}");

    // "inference performance for certain precision levels … surpasses
    // threefold improvements" — our calibrated Q2_K prefill lands at ~2.3×
    // (the paper's own Graph 4-1 number, 231%); the 3× abstract claim is
    // loose even against the paper's body. Assert the calibrated band.
    let bench = LlamaBench::default();
    let dev = registry::cmp170hx();
    let q2_default = bench.run(&dev, &quant::Q2_K, FmadPolicy::Fused).prefill_tps;
    let q2_nofma = bench
        .run(&dev, &quant::Q2_K, FmadPolicy::Decomposed)
        .prefill_tps;
    let speedup = q2_nofma / q2_default;
    assert!(speedup > 2.0 && speedup < 2.7, "{speedup}");
}

#[test]
fn section_6_conclusions_hold() {
    let bench = LlamaBench::default();
    let dev = registry::cmp170hx();
    // "energy efficiency comparable to the A100" for bandwidth-bound duty:
    // within ±2.5× of the theoretical A100-class efficiency in q8 decode
    // and *above* it at default policy.
    let q8 = bench.run(&dev, &quant::Q8_0, FmadPolicy::Fused);
    assert!(q8.tokens_per_watt > q8.theoretical_tokens_per_watt());
    // "not feasible for gaming" proxy: FP32 default is three orders below a
    // healthy card of the same silicon generation.
    let a100 = registry::a100_pcie();
    let crippled = dev.fp32_tflops() * dev.throttle.mult(cmphx::isa::InstClass::Ffma);
    assert!(crippled < a100.fp32_tflops() / 40.0);
}

#[test]
fn figure_generators_are_deterministic() {
    let a = figures::graph_4_1().render();
    let b = figures::graph_4_1().render();
    assert_eq!(a, b);
}

#[test]
fn csv_export_roundtrips_row_counts() {
    for t in figures::all_figures() {
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), t.rows.len() + 1, "{}", t.title);
    }
}
