//! Device models: SM-array specifications, per-class issue rates, and the
//! CMP crippling mechanism (the *throttle unit*).
//!
//! A [`spec::DeviceSpec`] carries everything the timing engine, memory
//! hierarchy and power model need; [`registry`] holds calibrated entries for
//! the CMP 170HX, the A100 reference, the rest of the CMP family (for the
//! market model), and the historical comparison cards from §3.1 (Tesla C870,
//! Tesla P6).

pub mod rates;
pub mod registry;
pub mod spec;
pub mod throttle;

pub use rates::IssueRates;
pub use spec::DeviceSpec;
pub use throttle::ThrottleProfile;
