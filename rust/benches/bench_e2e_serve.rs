//! End-to-end serving benchmark: the full L1→L2→L3 stack under load.
//!
//! Compiles the AOT artifacts, then measures served throughput and latency
//! percentiles at several batch limits — the batching-policy ablation
//! DESIGN.md calls out — plus the simulated CMP 170HX device time for the
//! same token schedule. Requires `make artifacts`.

use std::time::{Duration, Instant};

use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{Server, ServerConfig};
use cmphx::isa::pass::FmadPolicy;
use cmphx::runtime::ArtifactDir;

const REQUESTS: usize = 12;
const TOKENS: usize = 8;

fn run_once(max_batch: usize, step_policy: StepPolicy) -> anyhow::Result<()> {
    let artifacts = ArtifactDir::open(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    )?;
    let config = ServerConfig {
        queue_depth: 64,
        batch: BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(3),
        },
        step_policy,
        fmad: FmadPolicy::Decomposed,
    };
    let server = Server::start(artifacts, config)?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i as i32 + 2)) % 500 + 1).collect();
            server.submit(prompt, TOKENS).unwrap()
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv()?;
        assert!(resp.ok(), "{:?}", resp.error);
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    println!(
        "batch={max_batch:<2} policy={step_policy:?}: {} tok in {wall:.2}s → {:>6.1} tok/s | p50 {:>6.1}ms p99 {:>6.1}ms | sim CMP {:>6.1}ms",
        m.tokens_out,
        m.tokens_out as f64 / wall,
        m.latency_pct(0.5).unwrap_or(0.0) * 1e3,
        m.latency_pct(0.99).unwrap_or(0.0) * 1e3,
        m.simulated_device_s * 1e3,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("== e2e serving: {REQUESTS} requests × {TOKENS} tokens (tiny-qwen over PJRT) ==");
    for max_batch in [1, 2, 4, 8] {
        run_once(max_batch, StepPolicy::RoundRobin)?;
    }
    println!("-- scheduler ablation at batch=4 --");
    run_once(4, StepPolicy::ShortestFirst)?;
    Ok(())
}
