//! Paged KV-cache allocator with VRAM accounting, prefix sharing, and
//! copy-on-write.
//!
//! The CMP 170HX's 8 GB ceiling is the binding constraint of §4.1/§6.2.
//! The old fixed-slot manager reserved worst-case context
//! (`kv_bytes_per_pos × max_ctx`) for every admitted sequence, so a card
//! serving 4k-token contexts with ~1k-token mean generations wasted ~3/4
//! of its KV budget on positions that were never written. [`KvPager`]
//! instead hands out **blocks of N token positions** as a sequence
//! actually grows (vLLM-style paged attention, at the accounting level the
//! simulated deployment needs): admission pins only the prefill window,
//! each decode round grows the sequence by at most one block, and a grow
//! that cannot be satisfied signals the engine to preempt rather than
//! silently over-committing the device.
//!
//! The pager is also **content-aware** (vLLM's block-hash reuse): every
//! block admitted with prompt content carries a *chain hash* of all token
//! positions up to and including the ones it covers, and a per-node
//! prefix index maps chain hash → resident block. [`KvPager::admit_prompt`]
//! matches a new sequence's prompt blocks against the index and **pins**
//! (refcounts) shared blocks instead of allocating fresh ones — identical
//! system-prompt prefixes cost one physical copy, which is another large
//! admission multiplier on an 8 GB card. The first write into a shared
//! block (a decode step growing into a partially-filled prompt tail)
//! triggers **copy-on-write**: the writer gets a private replacement and
//! the shared original stays valid for its other holders.
//! [`KvPager::release`] decrements refcounts and frees a block only when
//! the last holder lets go; the index entry is unregistered at the same
//! moment, so the prefix index can never point at a freed block.
//!
//! [`HostPool`] accounts the host-RAM side of swap-based preemption:
//! evicted sequences whose KV is cheaper to move over the (crippled
//! x1/x4) PCIe link than to recompute park their pages there until
//! resume ([`crate::coordinator::scheduler::choose_preempt`] prices the
//! tradeoff with the §3 PCIe model).
//!
//! Handles are generation-stamped: a released handle — or a handle whose
//! id was recycled by a later admission — is rejected on every operation
//! instead of silently corrupting another sequence's pages.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Handle to one sequence's KV pages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqKv {
    id: usize,
    gen: u64,
}

/// One physical KV block: how many live sequences hold it, and the chain
/// hash it is registered under in the prefix index (`None` for private
/// blocks — decode-written pages, CoW copies, diverged tails).
#[derive(Clone, Copy, Debug, Default)]
struct Block {
    refs: u32,
    hash: Option<u64>,
}

/// One live sequence's page table.
#[derive(Clone, Debug)]
struct SeqAlloc {
    /// Token positions this sequence may write (rounded up into blocks).
    positions: usize,
    /// Physical block ids, in position order. Shared blocks appear in
    /// several sequences' tables at once.
    blocks: Vec<usize>,
}

#[derive(Debug)]
struct PageEntry {
    gen: u64,
    alloc: Option<SeqAlloc>,
}

/// Cumulative prefix-cache counters (monotonic over the pager's life).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Prompt blocks served by pinning an already-resident block.
    pub hit_blocks: u64,
    /// Prompt blocks that had to be allocated fresh.
    pub miss_blocks: u64,
    /// Shared blocks privatized on first write (copy-on-write).
    pub cow_copies: u64,
}

/// Chain hash: FNV-1a folded over the previous chunk's hash and this
/// chunk's token ids. Matching hashes at chunk *k* imply (collisions
/// aside) identical token content over **all** positions `0..=k·N` — the
/// causal-attention condition under which KV pages are interchangeable.
fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf29ce484222325;
    const FNV_PRIME: u64 = 0x100000001b3;
    let mut h = FNV_OFFSET;
    for b in prev.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for t in tokens {
        for b in t.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Chain hashes for every block-sized chunk of a prefill window — the
/// exact keys [`KvPager::admit_prompt`] would probe. Public so the
/// dispatcher can score nodes against the fleet [`PrefixDirectory`]
/// without touching any pager: the window construction is deterministic
/// ([`crate::runtime::ModelRuntime::padded_window`]), so dispatcher and
/// worker compute identical keys from the same prompt.
pub fn window_chain_hashes(window: &[i32], block_positions: usize) -> Vec<u64> {
    let mut hashes = Vec::with_capacity(window.len().div_ceil(block_positions.max(1)));
    let mut prev = 0u64;
    for chunk in window.chunks(block_positions.max(1)) {
        prev = chain_hash(prev, chunk);
        hashes.push(prev);
    }
    hashes
}

/// Paged KV block allocator for one card.
#[derive(Debug)]
pub struct KvPager {
    block_positions: usize,
    bytes_per_pos: u64,
    total_blocks: usize,
    /// Distinct physical blocks with at least one holder.
    allocated: usize,
    active: usize,
    /// Device memory budget and static (weights) usage, bytes.
    vram_bytes: u64,
    weights_bytes: u64,
    /// Physical block table; slots are recycled through `free_slots`.
    blocks: Vec<Block>,
    free_slots: Vec<usize>,
    /// chain hash → resident block id. Entries exist only while the block
    /// has holders (refs ≥ 1) and its content still matches the hash.
    prefix_index: HashMap<u64, usize>,
    entries: Vec<PageEntry>,
    free_ids: Vec<usize>,
    stats: PrefixStats,
}

impl KvPager {
    /// Build a pager over a device with `vram_bytes`, `weights_bytes` of
    /// which are pinned by the model; everything left is carved into
    /// blocks of `block_positions × bytes_per_pos`. Fails when the
    /// geometry cannot yield even one block.
    pub fn new(
        block_positions: usize,
        bytes_per_pos: u64,
        vram_bytes: u64,
        weights_bytes: u64,
    ) -> Result<Self> {
        if block_positions == 0 {
            bail!("KV block size must be at least one position");
        }
        if bytes_per_pos == 0 {
            bail!("KV bytes per position must be nonzero");
        }
        if weights_bytes > vram_bytes {
            bail!("weights ({weights_bytes} bytes) exceed device VRAM ({vram_bytes} bytes)");
        }
        let block_bytes = block_positions as u64 * bytes_per_pos;
        let total_blocks = ((vram_bytes - weights_bytes) / block_bytes) as usize;
        if total_blocks == 0 {
            bail!("no headroom for even one {block_bytes}-byte KV block after weights");
        }
        Ok(KvPager {
            block_positions,
            bytes_per_pos,
            total_blocks,
            allocated: 0,
            active: 0,
            vram_bytes,
            weights_bytes,
            blocks: Vec::new(),
            free_slots: Vec::new(),
            prefix_index: HashMap::new(),
            entries: Vec::new(),
            free_ids: Vec::new(),
            stats: PrefixStats::default(),
        })
    }

    /// Cap the block pool below the VRAM-derived total (a test/ops knob:
    /// force page pressure without faking device specs). Only valid on an
    /// idle pager.
    pub fn limit_blocks(&mut self, cap: usize) -> Result<()> {
        if cap == 0 {
            bail!("KV block budget must be at least one block");
        }
        if self.allocated > 0 {
            bail!("cannot shrink the block pool with live sequences");
        }
        self.total_blocks = self.total_blocks.min(cap);
        Ok(())
    }

    /// Permanently retire up to `n` blocks from the **free** pool — the
    /// VRAM-page-loss fault model. Live sequences are never touched (their
    /// pages are, by definition, the ones still readable); the card just
    /// gets smaller, and the admission gate sees the shrunken capacity
    /// immediately. Returns how many blocks were actually lost, which can
    /// be less than `n` when the free pool is nearly empty.
    pub fn lose_blocks(&mut self, n: usize) -> usize {
        let lose = n.min(self.free_blocks());
        for _ in 0..lose {
            // Retire a concrete free slot when one exists so the id can
            // never be recycled; blocks never materialized in `blocks`
            // are retired by the capacity cut alone.
            self.free_slots.pop();
        }
        self.total_blocks -= lose;
        lose
    }

    /// Blocks needed to hold `positions` token positions (at least one —
    /// every live sequence owns a page).
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.max(1).div_ceil(self.block_positions)
    }

    /// Allocate one physical block with `refs = 1`, registering `hash` in
    /// the prefix index when given (and when the hash is not already
    /// claimed by another resident block).
    fn alloc_block(&mut self, hash: Option<u64>) -> usize {
        let id = match self.free_slots.pop() {
            Some(id) => id,
            None => {
                self.blocks.push(Block::default());
                self.blocks.len() - 1
            }
        };
        // Register the hash only when it is not already claimed — the
        // index maps each chain hash to exactly one resident block.
        let mut registered = None;
        if let Some(h) = hash {
            if let std::collections::hash_map::Entry::Vacant(e) = self.prefix_index.entry(h) {
                e.insert(id);
                registered = Some(h);
            }
        }
        self.blocks[id] = Block { refs: 1, hash: registered };
        self.allocated += 1;
        id
    }

    /// Drop one holder of a physical block; frees it (and unregisters its
    /// hash) when the last holder lets go. Returns true when the block was
    /// actually freed.
    fn unref_block(&mut self, id: usize) -> bool {
        let b = &mut self.blocks[id];
        assert!(b.refs > 0, "refcount underflow on KV block {id}");
        b.refs -= 1;
        if b.refs > 0 {
            return false;
        }
        if let Some(h) = b.hash.take() {
            self.prefix_index.remove(&h);
        }
        self.free_slots.push(id);
        self.allocated -= 1;
        true
    }

    fn new_handle(&mut self, positions: usize, blocks: Vec<usize>) -> SeqKv {
        let id = match self.free_ids.pop() {
            Some(id) => id,
            None => {
                self.entries.push(PageEntry { gen: 0, alloc: None });
                self.entries.len() - 1
            }
        };
        let entry = &mut self.entries[id];
        entry.gen += 1;
        entry.alloc = Some(SeqAlloc { positions: positions.max(1), blocks });
        self.active += 1;
        SeqKv { id, gen: entry.gen }
    }

    /// Admit a sequence holding `positions` positions (the prefill
    /// window) on private, content-less blocks, or `None` when the free
    /// pool cannot cover it. The prefix-blind path — what a disabled
    /// prefix cache uses.
    pub fn admit(&mut self, positions: usize) -> Option<SeqKv> {
        let need = self.blocks_for(positions);
        if need > self.free_blocks() {
            return None;
        }
        let blocks: Vec<usize> = (0..need).map(|_| self.alloc_block(None)).collect();
        Some(self.new_handle(positions, blocks))
    }

    /// Admit a sequence whose prefill window holds exactly `window`
    /// (prompt plus deterministic padding), matching each block-sized
    /// chunk's chain hash against the prefix index. Matched blocks are
    /// **pinned** (refcount bumped) instead of allocated; matching stops
    /// at the first miss (chain hashes make any later hit imply the same
    /// full prefix anyway) and the remaining chunks are allocated fresh
    /// and registered for future admissions — including a trailing
    /// partial chunk, whose content is still deterministic. Returns the
    /// handle and the number of pinned (cache-hit) blocks, or `None` when
    /// the free pool cannot cover the fresh blocks. On `None` nothing is
    /// pinned or allocated.
    pub fn admit_prompt(&mut self, window: &[i32]) -> Option<(SeqKv, usize)> {
        if window.is_empty() {
            return self.admit(0).map(|kv| (kv, 0));
        }
        // Pass 1 (read-only): walk the chain, splitting chunks into a
        // shared prefix run and a fresh tail.
        let hashes = window_chain_hashes(window, self.block_positions);
        let mut pinned: Vec<usize> = Vec::new();
        for h in &hashes {
            match self.prefix_index.get(h) {
                Some(&id) => pinned.push(id),
                None => break,
            }
        }
        let fresh = hashes.len() - pinned.len();
        if fresh > self.free_blocks() {
            return None;
        }
        // Pass 2 (commit): pin the shared run, allocate the tail.
        for &id in &pinned {
            self.blocks[id].refs += 1;
        }
        let hits = pinned.len();
        let mut blocks = pinned;
        for h in &hashes[hits..] {
            blocks.push(self.alloc_block(Some(*h)));
        }
        self.stats.hit_blocks += hits as u64;
        self.stats.miss_blocks += fresh as u64;
        Some((self.new_handle(window.len(), blocks), hits))
    }

    /// Grow a sequence to `positions`. `Ok(true)` when the sequence now
    /// owns every page up to `positions` (including the no-op case);
    /// `Ok(false)` when the free pool cannot cover the growth — the
    /// caller's cue to preempt or stall. Nothing changes on `Ok(false)`.
    /// `Err` marks a coordinator logic bug (stale handle).
    ///
    /// Growth writes positions `cur..positions`, and sequences only ever
    /// append — so the sole block that can be *re*-written is a
    /// partially-filled tail. A shared tail (refs > 1) triggers
    /// **copy-on-write**: the writer takes a private replacement block
    /// (costing one extra page this round) and unpins the original, which
    /// stays valid for its other holders and in the prefix index. A
    /// privately-held hashed tail is simply unregistered, since its
    /// content is about to diverge from the hash.
    pub fn grow(&mut self, seq: SeqKv, positions: usize) -> Result<bool> {
        let (cur, owned) = {
            let a = self.alloc(seq)?;
            (a.positions, a.blocks.len())
        };
        if positions <= cur {
            return Ok(true);
        }
        let tail_written = cur % self.block_positions != 0;
        let tail_id = if tail_written {
            Some(self.entries[seq.id].alloc.as_ref().expect("checked live").blocks[owned - 1])
        } else {
            None
        };
        let cow = tail_id.is_some_and(|id| self.blocks[id].refs > 1);
        let fresh = self.blocks_for(positions) - owned + cow as usize;
        if fresh > self.free_blocks() {
            return Ok(false);
        }
        if let Some(id) = tail_id {
            if cow {
                let copy = self.alloc_block(None);
                self.unref_block(id);
                let alloc = self.entries[seq.id].alloc.as_mut().expect("checked live");
                *alloc.blocks.last_mut().expect("tail exists") = copy;
                self.stats.cow_copies += 1;
            } else if let Some(h) = self.blocks[id].hash.take() {
                self.prefix_index.remove(&h);
            }
        }
        let add = self.blocks_for(positions) - owned;
        let new_blocks: Vec<usize> = (0..add).map(|_| self.alloc_block(None)).collect();
        let alloc = self.entries[seq.id].alloc.as_mut().expect("checked live");
        alloc.blocks.extend(new_blocks);
        alloc.positions = positions;
        Ok(true)
    }

    /// Release a sequence's pages (retirement or preemption); returns the
    /// number of blocks actually freed — shared blocks are only unpinned,
    /// so the count can be less than the sequence held. Stale handles —
    /// double release, or reuse after the id was recycled — are rejected
    /// without touching the accounting.
    pub fn release(&mut self, seq: SeqKv) -> Result<usize> {
        self.alloc(seq)?;
        let entry = &mut self.entries[seq.id];
        let alloc = entry.alloc.take().expect("checked live");
        // Invalidate every outstanding copy of this handle immediately.
        entry.gen += 1;
        let mut freed = 0;
        for &id in &alloc.blocks {
            if self.unref_block(id) {
                freed += 1;
            }
        }
        self.active -= 1;
        self.free_ids.push(seq.id);
        Ok(freed)
    }

    fn alloc(&self, seq: SeqKv) -> Result<&SeqAlloc> {
        let Some(entry) = self.entries.get(seq.id) else {
            bail!("KV handle {} out of range", seq.id);
        };
        if entry.gen != seq.gen || entry.alloc.is_none() {
            bail!("stale KV handle {} (released or recycled)", seq.id);
        }
        Ok(entry.alloc.as_ref().expect("checked above"))
    }

    /// Positions a live sequence currently owns pages for.
    pub fn seq_positions(&self, seq: SeqKv) -> Result<usize> {
        Ok(self.alloc(seq)?.positions)
    }

    /// Blocks a live sequence holds (shared blocks counted once per
    /// holder).
    pub fn seq_blocks(&self, seq: SeqKv) -> Result<usize> {
        Ok(self.alloc(seq)?.blocks.len())
    }

    /// Device bytes backing one sequence's pages, shared blocks included.
    pub fn seq_bytes(&self, seq: SeqKv) -> Result<u64> {
        Ok(self.seq_blocks(seq)? as u64 * self.block_bytes())
    }

    /// Device bytes a swap must actually move: blocks only this sequence
    /// holds. Shared blocks (refs > 1) stay resident for their other
    /// holders when this sequence releases, and a prefix-aware
    /// re-admission pins them again on restore — they never cross the
    /// link.
    pub fn seq_private_bytes(&self, seq: SeqKv) -> Result<u64> {
        let alloc = self.alloc(seq)?;
        let private = alloc
            .blocks
            .iter()
            .filter(|&&id| self.blocks[id].refs == 1)
            .count();
        Ok(private as u64 * self.block_bytes())
    }

    /// How many of a sequence's first `first` blocks (its prompt window)
    /// other live sequences also hold. Those blocks survive this
    /// sequence's release and would be prefix-cache hits on a
    /// recompute-resume — the eviction chooser uses this to price the
    /// recompute side with the same credit the resume path applies.
    pub fn seq_shared_blocks(&self, seq: SeqKv, first: usize) -> Result<usize> {
        let alloc = self.alloc(seq)?;
        Ok(alloc
            .blocks
            .iter()
            .take(first)
            .filter(|&&id| self.blocks[id].refs > 1)
            .count())
    }

    /// How many new sequences of `positions` the free pool could admit
    /// right now — the admission gate of continuous batching. Counts
    /// fresh allocations only, so it is conservative for prompts whose
    /// prefixes are resident (those pin instead of allocating).
    pub fn admissible(&self, positions: usize) -> usize {
        self.free_blocks() / self.blocks_for(positions)
    }

    /// Read-only probe: how many leading blocks of `window` are resident
    /// right now (the hit count [`KvPager::admit_prompt`] would report).
    /// Nothing is pinned — the prefix-aware admission gate uses this to
    /// discount a queued prompt's page bill before deciding to pop it,
    /// and a stale answer only costs a conservative decision, never
    /// correctness (admission re-walks the index under the same lock).
    pub fn resident_prefix_blocks(&self, window: &[i32]) -> usize {
        window_chain_hashes(window, self.block_positions)
            .iter()
            .take_while(|h| self.prefix_index.contains_key(h))
            .count()
    }

    /// Every chain hash currently registered in the prefix index — the
    /// node's published view in the fleet [`PrefixDirectory`]. A snapshot:
    /// by the time a route lands the set may have shrunk (eviction), which
    /// is why admission re-checks and a stale hit degrades to a miss.
    pub fn index_hashes(&self) -> Vec<u64> {
        self.prefix_index.keys().copied().collect()
    }

    pub fn free_blocks(&self) -> usize {
        self.total_blocks - self.allocated
    }

    /// Distinct physical blocks in use (shared blocks counted once).
    pub fn used_blocks(&self) -> usize {
        self.allocated
    }

    pub fn capacity_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Token positions per block.
    pub fn block_positions(&self) -> usize {
        self.block_positions
    }

    /// The longest single sequence the whole pool could hold.
    pub fn max_positions(&self) -> usize {
        self.total_blocks * self.block_positions
    }

    /// Live sequences holding pages.
    pub fn active_seqs(&self) -> usize {
        self.active
    }

    /// Cumulative prefix-cache counters.
    pub fn prefix_stats(&self) -> PrefixStats {
        self.stats
    }

    fn block_bytes(&self) -> u64 {
        self.block_positions as u64 * self.bytes_per_pos
    }

    /// Bytes currently resident (weights + distinct allocated pages —
    /// sharing means this can be far below the sum of per-sequence
    /// footprints).
    pub fn resident_bytes(&self) -> u64 {
        self.weights_bytes + self.allocated as u64 * self.block_bytes()
    }

    /// Headroom to the VRAM budget.
    pub fn headroom_bytes(&self) -> u64 {
        self.vram_bytes - self.resident_bytes()
    }

    /// What the replaced fixed-slot allocator would have admitted over the
    /// same VRAM: worst-case reservation of `max_ctx` positions per
    /// sequence. Kept as the paged-vs-fixed comparison baseline for
    /// benches and acceptance tests.
    pub fn fixed_slot_capacity(&self, max_ctx: usize) -> usize {
        let per_slot = self.bytes_per_pos * max_ctx.max(1) as u64;
        ((self.vram_bytes - self.weights_bytes) / per_slot) as usize
    }

    #[cfg(test)]
    fn block_refs(&self, id: usize) -> u32 {
        self.blocks[id].refs
    }

    #[cfg(test)]
    fn seq_block_ids(&self, seq: SeqKv) -> Vec<usize> {
        self.alloc(seq).expect("live handle").blocks.clone()
    }

    #[cfg(test)]
    fn index_entries(&self) -> Vec<usize> {
        self.prefix_index.values().copied().collect()
    }
}

/// Host-RAM pool for swap-based preemption: evicted sequences whose KV is
/// cheaper to move over PCIe than to recompute park their pages here
/// until resume. Pure byte accounting — in the simulated deployment the
/// "pages" are the sequence's retained [`crate::runtime::DecodeState`].
#[derive(Clone, Copy, Debug)]
pub struct HostPool {
    capacity: u64,
    used: u64,
}

impl HostPool {
    pub fn new(capacity_bytes: u64) -> Self {
        HostPool { capacity: capacity_bytes, used: 0 }
    }

    /// Reserve `bytes` for a swapped-out sequence; false when the pool
    /// cannot hold it (the caller falls back to drop-and-recompute).
    pub fn try_reserve(&mut self, bytes: u64) -> bool {
        if self.used + bytes > self.capacity {
            return false;
        }
        self.used += bytes;
        true
    }

    /// Return a swapped sequence's bytes (resume or terminal failure).
    pub fn release(&mut self, bytes: u64) {
        debug_assert!(bytes <= self.used, "host pool release underflow");
        self.used = self.used.saturating_sub(bytes);
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity
    }
}

/// Fleet-level chain-hash prefix directory: each node periodically
/// publishes the chain hashes its [`KvPager`] holds resident, and the
/// dispatcher scores candidate nodes by how deep a new prompt's hash
/// chain matches — prefix-affine routing sends a request to the card
/// already holding its prefix instead of re-prefilling it elsewhere.
///
/// The directory is deliberately a *hint*, not a lease: entries can
/// outlive eviction between a publish and the route that read it. That
/// is safe by construction — the worker's [`KvPager::admit_prompt`]
/// re-walks its own live index under its own lock, so a stale hit simply
/// admits with fewer (or zero) pinned blocks: a plain miss and a full
/// prefill, never an error. Nothing in the data plane trusts the
/// directory.
#[derive(Debug)]
pub struct PrefixDirectory {
    published: std::sync::Mutex<Vec<std::collections::HashSet<u64>>>,
}

impl PrefixDirectory {
    pub fn new(nodes: usize) -> Self {
        PrefixDirectory {
            published: std::sync::Mutex::new(vec![std::collections::HashSet::new(); nodes]),
        }
    }

    /// Replace `node`'s published set with a fresh snapshot
    /// ([`KvPager::index_hashes`]). Full replacement, not a merge —
    /// evicted chains must disappear, or the directory would only ever
    /// grow staler.
    pub fn publish(&self, node: usize, hashes: Vec<u64>) {
        let mut p = self.published.lock().unwrap();
        if let Some(set) = p.get_mut(node) {
            set.clear();
            set.extend(hashes);
        }
    }

    /// Drop a dead node's entries immediately — its VRAM is gone, so
    /// routing toward its published chains would be pure loss.
    pub fn clear(&self, node: usize) {
        let mut p = self.published.lock().unwrap();
        if let Some(set) = p.get_mut(node) {
            set.clear();
        }
    }

    /// Per-node matched-prefix depth for one prompt's hash chain: how
    /// many *leading* hashes each node has published. Matching stops at
    /// the first gap, mirroring [`KvPager::admit_prompt`] — a resident
    /// block behind a missing one is unreachable prefix-wise.
    pub fn match_depths(&self, hashes: &[u64]) -> Vec<usize> {
        let p = self.published.lock().unwrap();
        p.iter()
            .map(|set| hashes.iter().take_while(|h| set.contains(h)).count())
            .collect()
    }

    /// Nodes the directory tracks.
    pub fn nodes(&self) -> usize {
        self.published.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    /// 4-position blocks of 1 KiB/pos over 8 MiB with 1 MiB of weights:
    /// (8 - 1) MiB / 4 KiB = 1792 blocks.
    fn pager() -> KvPager {
        KvPager::new(4, 1 << 10, 8 << 20, 1 << 20).unwrap()
    }

    #[test]
    fn admit_grow_release_cycle_tracks_blocks() {
        let mut p = pager();
        assert_eq!(p.capacity_blocks(), 1792);
        let a = p.admit(6).unwrap(); // 2 blocks
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.active_seqs(), 1);
        // growth inside the last owned block allocates nothing
        assert!(p.grow(a, 7).unwrap());
        assert!(p.grow(a, 8).unwrap());
        assert_eq!(p.used_blocks(), 2);
        // crossing the block boundary allocates exactly one block
        assert!(p.grow(a, 9).unwrap());
        assert_eq!(p.used_blocks(), 3);
        // shrinking requests are no-ops
        assert!(p.grow(a, 2).unwrap());
        assert_eq!(p.seq_positions(a).unwrap(), 9);
        assert_eq!(p.release(a).unwrap(), 3);
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.active_seqs(), 0);
    }

    #[test]
    fn grow_past_the_pool_fails_without_side_effects() {
        let mut p = pager();
        let a = p.admit(4).unwrap();
        let hog = p.admit(1792 * 4 - 4).unwrap(); // everything else
        assert_eq!(p.free_blocks(), 0);
        assert!(!p.grow(a, 5).unwrap(), "no pages left");
        assert_eq!(p.seq_positions(a).unwrap(), 4, "failed grow must not move");
        assert_eq!(p.used_blocks(), 1792);
        p.release(hog).unwrap();
        assert!(p.grow(a, 5).unwrap(), "freed pages make growth succeed");
        p.release(a).unwrap();
    }

    #[test]
    fn stale_handles_are_rejected_without_corrupting_accounting() {
        let mut p = pager();
        let a = p.admit(4).unwrap();
        let b = p.admit(4).unwrap();
        p.release(a).unwrap();
        let err = p.release(a).unwrap_err().to_string();
        assert!(err.contains("stale"), "{err}");
        assert_eq!(p.used_blocks(), 1);
        // the id is recycled by the next admission; the old handle must
        // still be dead even though the slot is live again
        let c = p.admit(4).unwrap();
        assert!(p.grow(a, 8).is_err());
        assert!(p.release(a).is_err());
        assert_eq!(p.used_blocks(), 2);
        // out-of-range ids are rejected too
        let bogus = SeqKv { id: 999, gen: 1 };
        assert!(p.release(bogus).unwrap_err().to_string().contains("out of range"));
        p.release(b).unwrap();
        p.release(c).unwrap();
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn rejects_impossible_geometries() {
        // weights alone overflow the card
        assert!(KvPager::new(4, 1 << 10, 1 << 20, 2 << 20).is_err());
        // headroom smaller than one block
        assert!(KvPager::new(1024, 1 << 20, (1 << 30) + 1, 1 << 30).is_err());
        // degenerate parameters
        assert!(KvPager::new(0, 1 << 10, 8 << 20, 0).is_err());
        assert!(KvPager::new(4, 0, 8 << 20, 0).is_err());
    }

    #[test]
    fn vram_accounting_tracks_pages() {
        let mut p = pager();
        assert_eq!(p.resident_bytes(), 1 << 20);
        let a = p.admit(5).unwrap(); // 2 blocks of 4 KiB
        assert_eq!(p.resident_bytes(), (1 << 20) + 2 * (4 << 10));
        assert_eq!(p.seq_bytes(a).unwrap(), 2 * (4 << 10));
        p.release(a).unwrap();
        assert_eq!(p.headroom_bytes(), (8 << 20) - (1 << 20));
    }

    #[test]
    fn limit_blocks_caps_the_pool() {
        let mut p = pager();
        p.limit_blocks(3).unwrap();
        assert_eq!(p.capacity_blocks(), 3);
        assert_eq!(p.max_positions(), 12);
        assert_eq!(p.admissible(4), 3);
        let a = p.admit(12).unwrap();
        assert!(p.admit(1).is_none());
        assert!(p.limit_blocks(2).is_err(), "cannot shrink under live pages");
        assert!(p.limit_blocks(0).is_err());
        p.release(a).unwrap();
        // a cap above the total is a no-op
        p.limit_blocks(usize::MAX).unwrap();
        assert_eq!(p.capacity_blocks(), 3);
    }

    #[test]
    fn lose_blocks_shrinks_only_the_free_pool() {
        let mut p = pager();
        p.limit_blocks(10).unwrap();
        let a = p.admit(12).unwrap(); // 3 blocks live
        assert_eq!(p.free_blocks(), 7);
        // a VRAM fault burns 4 free pages: capacity shrinks, the live
        // sequence is untouched
        assert_eq!(p.lose_blocks(4), 4);
        assert_eq!(p.capacity_blocks(), 6);
        assert_eq!(p.free_blocks(), 3);
        assert_eq!(p.seq_positions(a).unwrap(), 12);
        assert!(p.grow(a, 16).unwrap(), "survivors can still grow");
        // losses clamp to the free pool — live pages are never taken
        assert_eq!(p.lose_blocks(100), 2);
        assert_eq!(p.capacity_blocks(), 4);
        assert_eq!(p.free_blocks(), 0);
        assert_eq!(p.lose_blocks(1), 0, "nothing free left to lose");
        // released pages come back into the (smaller) pool and recycle
        assert_eq!(p.release(a).unwrap(), 4);
        assert_eq!(p.free_blocks(), 4);
        let b = p.admit(16).unwrap();
        assert_eq!(p.used_blocks(), 4);
        p.release(b).unwrap();
        assert_eq!(p.used_blocks() + p.free_blocks(), p.capacity_blocks());
    }

    #[test]
    fn paged_admits_strictly_more_than_fixed_slots_at_long_context() {
        // The §4.1 accounting on a CMP 170HX: Qwen2.5-1.5B KV bytes/pos
        // (2 · 28 layers · 2 kv_heads · 128 head_dim · f16 = 28672 B) on
        // an 8 GB card with ~2 GB of q8_0 weights, serving 4096-token
        // contexts whose mean sequence (prompt + generation) is 1024
        // positions — context 4× the mean, the acceptance operating point.
        let mut p = KvPager::new(16, 28_672, 8 << 30, 2 << 30).unwrap();
        let max_ctx = 4096;
        let mean_seq = 1024;
        let fixed = p.fixed_slot_capacity(max_ctx);
        let paged = p.admissible(mean_seq);
        assert!(fixed > 0);
        assert!(
            paged > fixed,
            "paged {paged} must beat fixed-slot {fixed} at equal VRAM"
        );
        // ~4× is the arithmetic expectation when reservations are 4× the
        // mean; block rounding costs a little
        assert!(paged >= 3 * fixed, "paged {paged} vs fixed {fixed}");
        // and the pager actually delivers that concurrency within budget
        let held: Vec<SeqKv> = (0..paged).map(|_| p.admit(mean_seq).unwrap()).collect();
        assert!(p.resident_bytes() <= 8 << 30);
        assert_eq!(p.active_seqs(), paged);
        for h in held {
            p.release(h).unwrap();
        }
    }

    /// A padded prefill window: `shared` common tokens then `salt`-unique
    /// filler up to `len` (models a shared system prompt + per-user tail).
    fn window(shared: usize, len: usize, salt: i32) -> Vec<i32> {
        (0..len)
            .map(|i| if i < shared { i as i32 + 1 } else { salt * 10_000 + i as i32 })
            .collect()
    }

    #[test]
    fn identical_prompts_share_every_block() {
        let mut p = pager(); // 4-position blocks
        let w = window(8, 8, 0); // two full blocks
        let (a, hits_a) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits_a, 0);
        assert_eq!(p.used_blocks(), 2);
        let (b, hits_b) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits_b, 2, "the second identical prompt pins both blocks");
        assert_eq!(p.used_blocks(), 2, "no new physical blocks");
        assert_eq!(p.seq_block_ids(a), p.seq_block_ids(b));
        assert_eq!(p.prefix_stats(), PrefixStats { hit_blocks: 2, miss_blocks: 2, cow_copies: 0 });
        // releases unpin; the last holder frees
        assert_eq!(p.release(a).unwrap(), 0, "shared blocks survive the first release");
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.release(b).unwrap(), 2);
        assert_eq!(p.used_blocks(), 0);
        assert!(p.index_entries().is_empty(), "freed blocks leave the index");
    }

    #[test]
    fn shared_prefix_pins_only_the_common_run() {
        let mut p = pager();
        // 12-position windows sharing the first 8 positions (2 of 3 blocks)
        let (a, _) = p.admit_prompt(&window(8, 12, 1)).unwrap();
        let (b, hits) = p.admit_prompt(&window(8, 12, 2)).unwrap();
        assert_eq!(hits, 2);
        assert_eq!(p.used_blocks(), 4, "3 + 1 fresh tail, not 6");
        let (ia, ib) = (p.seq_block_ids(a), p.seq_block_ids(b));
        assert_eq!(&ia[..2], &ib[..2]);
        assert_ne!(ia[2], ib[2]);
        assert_eq!(p.block_refs(ia[0]), 2);
        assert_eq!(p.block_refs(ia[2]), 1);
        // the eviction chooser's survivability probe: 2 of a's 3 blocks
        // (and both of its first 2, the "prompt window") are shared
        assert_eq!(p.seq_shared_blocks(a, 3).unwrap(), 2);
        assert_eq!(p.seq_shared_blocks(a, 1).unwrap(), 1);
        // …so a swap of `a` moves only its private tail block
        assert_eq!(p.seq_private_bytes(a).unwrap(), 4 << 10);
        assert_eq!(p.seq_bytes(a).unwrap(), 3 * (4 << 10));
        p.release(b).unwrap();
        assert_eq!(p.seq_shared_blocks(a, 3).unwrap(), 0, "sole holder shares nothing");
        assert_eq!(p.seq_private_bytes(a).unwrap(), p.seq_bytes(a).unwrap());
        p.release(a).unwrap();
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn growing_into_a_shared_tail_copies_on_write() {
        let mut p = pager();
        // 6-position windows: one full block + a shared partial tail
        let w = window(6, 6, 0);
        let (a, _) = p.admit_prompt(&w).unwrap();
        let (b, hits) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits, 2, "the deterministic partial tail is shareable too");
        assert_eq!(p.used_blocks(), 2);
        let tail = p.seq_block_ids(a)[1];
        assert_eq!(p.block_refs(tail), 2);
        // a's first decode write lands inside the shared tail → CoW
        assert!(p.grow(a, 7).unwrap());
        assert_eq!(p.prefix_stats().cow_copies, 1);
        assert_eq!(p.used_blocks(), 3, "one private replacement allocated");
        let a_tail = p.seq_block_ids(a)[1];
        assert_ne!(a_tail, tail, "writer got a private copy");
        assert_eq!(p.block_refs(tail), 1, "b still holds the original");
        assert_eq!(p.seq_block_ids(b)[1], tail);
        // the original stays registered: a third identical prompt re-pins it
        let (c, hits_c) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits_c, 2);
        assert_eq!(p.block_refs(tail), 2);
        // a sole-holder hashed tail is unregistered (not copied) on write
        p.release(c).unwrap();
        assert!(p.grow(b, 8).unwrap());
        assert_eq!(p.prefix_stats().cow_copies, 1, "no copy when refs == 1");
        let (_, hits_d) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits_d, 1, "the diverged tail no longer matches");
        p.release(a).unwrap();
        p.release(b).unwrap();
    }

    #[test]
    fn cow_respects_the_free_pool() {
        let mut p = pager();
        p.limit_blocks(3).unwrap();
        let w = window(6, 6, 0);
        let (a, _) = p.admit_prompt(&w).unwrap();
        let (b, _) = p.admit_prompt(&w).unwrap(); // pins both of a's blocks
        let hog = p.admit(1).unwrap(); // takes the last free block
        assert_eq!(p.free_blocks(), 0);
        // a's first write needs a CoW replacement block that does not
        // exist: the grow must refuse and change nothing.
        let before = p.seq_block_ids(a);
        assert!(!p.grow(a, 7).unwrap());
        assert_eq!(p.seq_block_ids(a), before);
        assert_eq!(p.seq_positions(a).unwrap(), 6);
        assert_eq!(p.prefix_stats().cow_copies, 0);
        p.release(hog).unwrap();
        assert!(p.grow(a, 7).unwrap(), "freed pages make the CoW succeed");
        assert_eq!(p.prefix_stats().cow_copies, 1);
        assert_eq!(p.seq_positions(b).unwrap(), 6, "the other holder is untouched");
        p.release(a).unwrap();
        p.release(b).unwrap();
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn prefix_cached_admission_hits_the_acceptance_multiplier() {
        // The ISSUE 5 acceptance point: Qwen2.5-1.5B q8_0 on a CMP 170HX
        // (8 GiB, 1,625,610,592 bytes of weights → 15181 16-position
        // blocks), ctx 4096, 1024-position mean sequences, all sharing a
        // 512-position system prompt. The paged baseline admits
        // ⌊15181/64⌋ = 237; with prefix sharing the 32 prompt blocks are
        // resident once and each later admission allocates only its 32
        // private blocks: 1 + ⌊(15181 − 64)/32⌋ = 473 — ≥ 1.5× (≈2×) the
        // PR 3 baseline. Recorded as `serve_prefix_cache` in
        // BENCH_sim_throughput.json.
        use crate::device::registry;
        use crate::llm::model::ModelDesc;
        use crate::llm::quant;
        let model = ModelDesc::qwen25_15b();
        let dev = registry::cmp170hx();
        let mut p = KvPager::new(
            16,
            model.kv_bytes_per_pos(),
            dev.mem.capacity_bytes,
            model.weight_bytes(&quant::Q8_0),
        )
        .unwrap();
        let (mean_seq, shared) = (1024usize, 512usize);
        let baseline = p.admissible(mean_seq);
        assert_eq!(baseline, 237, "the PR 3 serve_concurrency operating point");
        let mut held = Vec::new();
        while let Some((kv, _)) = p.admit_prompt(&window(shared, mean_seq, held.len() as i32)) {
            held.push(kv);
        }
        let shared_blocks = shared / 16;
        let per_seq = mean_seq / 16;
        let analytic = 1 + (p.capacity_blocks() - per_seq) / (per_seq - shared_blocks);
        assert_eq!(held.len(), analytic, "admission must match the analytic point");
        assert_eq!(held.len(), 473);
        assert!(
            held.len() as f64 >= 1.5 * baseline as f64,
            "prefix-cached {} vs paged {baseline}",
            held.len()
        );
        assert!(p.resident_bytes() <= dev.mem.capacity_bytes);
        for kv in held {
            p.release(kv).unwrap();
        }
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn host_pool_reserves_and_releases() {
        let mut pool = HostPool::new(100);
        assert!(pool.try_reserve(60));
        assert!(!pool.try_reserve(50), "over-capacity reservation refused");
        assert!(pool.try_reserve(40));
        assert_eq!(pool.used_bytes(), 100);
        pool.release(60);
        assert_eq!(pool.used_bytes(), 40);
        assert!(pool.try_reserve(60));
        assert_eq!(pool.capacity_bytes(), 100);
    }

    #[test]
    fn prop_host_pool_conserves_bytes_under_faulty_swap_interleavings() {
        // Shadow-model property for the swap path's host-RAM accounting:
        // random interleavings of swap-out (reserve), swap-in (release),
        // and *failed* swap-in (the fault injector corrupts the parked
        // pages; the worker releases the reservation exactly once and
        // falls back to recompute). Invariants after every step: used
        // bytes equal the sum of outstanding reservations (bytes
        // conserved, no double-free), used never exceeds capacity, and a
        // refused reservation changes nothing.
        forall(0xFA117, 200, |rng: &mut Rng| {
            let capacity = rng.range(1, 1 << 20);
            let mut pool = HostPool::new(capacity);
            let mut outstanding: Vec<u64> = Vec::new(); // shadow reservations
            for _ in 0..120 {
                match rng.below(3) {
                    0 => {
                        // swap-out: park a sequence's private KV bytes
                        let bytes = rng.range(0, capacity + capacity / 4);
                        let before = pool.used_bytes();
                        if pool.try_reserve(bytes) {
                            outstanding.push(bytes);
                        } else {
                            assert!(before + bytes > capacity, "refusal must mean overflow");
                            assert_eq!(pool.used_bytes(), before, "refused reserve moved bytes");
                        }
                    }
                    1 => {
                        // swap-in: the resume path restores and releases
                        if let Some(i) =
                            (!outstanding.is_empty()).then(|| rng.below(outstanding.len() as u64))
                        {
                            pool.release(outstanding.swap_remove(i as usize));
                        }
                    }
                    _ => {
                        // failed swap-in: the reservation is released once
                        // (never twice) and the sequence recomputes; from
                        // the pool's view this is indistinguishable from a
                        // clean swap-in, which is exactly the invariant —
                        // the fault path must not invent or leak bytes.
                        if let Some(i) =
                            (!outstanding.is_empty()).then(|| rng.below(outstanding.len() as u64))
                        {
                            pool.release(outstanding.swap_remove(i as usize));
                        }
                    }
                }
                let expect: u64 = outstanding.iter().sum();
                assert_eq!(pool.used_bytes(), expect, "pool drifted from shadow ledger");
                assert!(pool.used_bytes() <= pool.capacity_bytes());
            }
            for bytes in outstanding.drain(..) {
                pool.release(bytes);
            }
            assert_eq!(pool.used_bytes(), 0, "draining all reservations must zero the pool");
        });
    }

    #[test]
    fn prop_pages_always_partition_the_budget() {
        // Port of the fixed-slot allocator's never-leaks property to
        // random admit/grow/preempt/resume interleavings: live
        // allocations plus the free pool always partition the block
        // budget, and resident bytes never exceed VRAM.
        forall(0x9A6ED, 150, |rng: &mut Rng| {
            let bp = rng.range(1, 8) as usize;
            let total = rng.range(2, 40) as usize;
            let bytes_per_pos = 64u64;
            let block_bytes = bp as u64 * bytes_per_pos;
            let weights = 1u64 << 10;
            let vram = weights + total as u64 * block_bytes + rng.below(block_bytes);
            let mut p = KvPager::new(bp, bytes_per_pos, vram, weights).unwrap();
            assert_eq!(p.capacity_blocks(), total);
            // (handle, positions) shadow model; parked holds preempted
            // sequences' positions awaiting resume
            let mut held: Vec<(SeqKv, usize)> = Vec::new();
            let mut parked: Vec<usize> = Vec::new();
            for _ in 0..96 {
                match rng.below(4) {
                    0 => {
                        // admit a fresh sequence
                        let pos = rng.range(1, 4 * bp as u64) as usize;
                        match p.admit(pos) {
                            Some(h) => held.push((h, pos)),
                            None => assert!(p.free_blocks() < pos.div_ceil(bp)),
                        }
                    }
                    1 => {
                        // grow a live sequence (a decode round)
                        if let Some(i) =
                            (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                        {
                            let target = held[i].1 + rng.range(0, 2 * bp as u64) as usize;
                            let before = p.used_blocks();
                            if p.grow(held[i].0, target).unwrap() {
                                held[i].1 = held[i].1.max(target);
                            } else {
                                assert_eq!(p.used_blocks(), before, "failed grow moved");
                            }
                        }
                    }
                    2 => {
                        // preempt: KV dropped, sequence parked for resume
                        if let Some(i) =
                            (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                        {
                            let (h, pos) = held.swap_remove(i);
                            let freed = p.release(h).unwrap();
                            assert_eq!(freed, pos.max(1).div_ceil(bp));
                            assert!(p.release(h).is_err(), "double release must fail");
                            parked.push(pos);
                        }
                    }
                    _ => {
                        // resume: re-admit at the parked length (the
                        // recompute path re-grows to where it left off)
                        if let Some(i) =
                            (!parked.is_empty()).then(|| rng.below(parked.len() as u64) as usize)
                        {
                            let pos = parked[i];
                            if let Some(h) = p.admit(pos) {
                                parked.swap_remove(i);
                                held.push((h, pos));
                            } else {
                                assert!(p.free_blocks() < pos.max(1).div_ceil(bp));
                            }
                        }
                    }
                }
                // invariants after every step
                let expect: usize = held.iter().map(|&(_, pos)| pos.max(1).div_ceil(bp)).sum();
                assert_eq!(p.used_blocks(), expect);
                assert_eq!(p.used_blocks() + p.free_blocks(), p.capacity_blocks());
                assert!(p.resident_bytes() <= vram);
                assert_eq!(p.active_seqs(), held.len());
                assert_eq!(p.admissible(bp), p.free_blocks());
            }
            for (h, _) in held {
                p.release(h).unwrap();
            }
            assert_eq!(p.used_blocks(), 0);
        });
    }

    #[test]
    fn prop_shared_prefix_refcounts_and_index_never_dangle() {
        // The ISSUE 5 release-path property: random interleavings of
        // shared-prefix admit / CoW grow / release against a shadow model
        // of per-sequence block tables. After every step: each block's
        // refcount equals the number of live holders (so it can never
        // underflow), the prefix index only points at blocks with live
        // holders (never at a freed block), distinct-held-blocks equals
        // the pager's used count, and used + free partitions the budget.
        forall(0xC0FFEE, 120, |rng: &mut Rng| {
            let bp = rng.range(1, 6) as usize;
            let total = rng.range(4, 48) as usize;
            let weights = 1u64 << 10;
            let vram = weights + total as u64 * (bp as u64 * 64);
            let mut p = KvPager::new(bp, 64, vram, weights).unwrap();
            // a small pool of prompt families: windows share a prefix
            // within a family, so admissions pin each other's blocks
            let families: Vec<(usize, usize)> = (0..3)
                .map(|_| {
                    let len = rng.range(1, 4 * bp as u64) as usize;
                    (rng.range(0, len as u64 + 1) as usize, len)
                })
                .collect();
            let mut held: Vec<(SeqKv, Vec<usize>, usize)> = Vec::new(); // handle, shadow blocks, positions
            for _ in 0..80 {
                match rng.below(4) {
                    0 | 1 => {
                        // admit from a random family with a random salt
                        // (small salt range → frequent identical prompts)
                        let (shared, len) = *rng.pick(&families);
                        let salt = rng.range(0, 3) as i32;
                        let w = window(shared, len, salt);
                        let free_before = p.free_blocks();
                        if let Some((h, hits)) = p.admit_prompt(&w) {
                            let ids = p.seq_block_ids(h);
                            assert_eq!(ids.len(), len.max(1).div_ceil(bp));
                            assert!(hits <= ids.len());
                            assert_eq!(free_before - p.free_blocks(), ids.len() - hits);
                            held.push((h, ids, len));
                        } else {
                            assert!(p.free_blocks() < len.max(1).div_ceil(bp));
                        }
                    }
                    2 => {
                        // grow (may CoW a shared tail)
                        if let Some(i) =
                            (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                        {
                            let target = held[i].2 + rng.range(0, 2 * bp as u64) as usize;
                            if p.grow(held[i].0, target).unwrap() {
                                held[i].2 = held[i].2.max(target);
                                held[i].1 = p.seq_block_ids(held[i].0);
                            }
                        }
                    }
                    _ => {
                        // release a random holder
                        if let Some(i) =
                            (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                        {
                            let (h, _, _) = held.swap_remove(i);
                            p.release(h).unwrap();
                            assert!(p.release(h).is_err(), "double release must fail");
                        }
                    }
                }
                // shadow-model invariants
                let mut refs: std::collections::HashMap<usize, u32> =
                    std::collections::HashMap::new();
                for (_, ids, _) in &held {
                    for &id in ids {
                        *refs.entry(id).or_default() += 1;
                    }
                }
                for (&id, &expect) in &refs {
                    assert_eq!(p.block_refs(id), expect, "refcount drifted on block {id}");
                }
                assert_eq!(p.used_blocks(), refs.len(), "distinct held blocks == used");
                assert_eq!(p.used_blocks() + p.free_blocks(), p.capacity_blocks());
                for id in p.index_entries() {
                    assert!(
                        refs.contains_key(&id),
                        "prefix index points at freed block {id}"
                    );
                }
            }
            for (h, _, _) in held {
                p.release(h).unwrap();
            }
            assert_eq!(p.used_blocks(), 0);
            assert!(p.index_entries().is_empty());
        });
    }

    #[test]
    fn directory_scores_matched_prefix_depth_per_node() {
        let mut p0 = pager();
        let mut p1 = pager();
        // node 0 holds the 8-shared family; node 1 holds a disjoint one
        let (a, _) = p0.admit_prompt(&window(8, 12, 1)).unwrap();
        let (b, _) = p1.admit_prompt(&window(0, 12, 9)).unwrap();
        let dir = PrefixDirectory::new(2);
        assert_eq!(dir.nodes(), 2);
        dir.publish(0, p0.index_hashes());
        dir.publish(1, p1.index_hashes());
        // a sibling of node 0's family matches its 2 shared blocks there
        // and nothing on node 1
        let w = window(8, 12, 2);
        let hashes = window_chain_hashes(&w, p0.block_positions());
        assert_eq!(dir.match_depths(&hashes), vec![2, 0]);
        // the exact resident prompt matches all 3 of its blocks
        let exact = window_chain_hashes(&window(8, 12, 1), p0.block_positions());
        assert_eq!(dir.match_depths(&exact), vec![3, 0]);
        // and the probe agrees with what admission would report
        assert_eq!(p0.resident_prefix_blocks(&w), 2);
        assert_eq!(p1.resident_prefix_blocks(&w), 0);
        // clearing a dead node zeroes its depths without touching others
        dir.clear(0);
        assert_eq!(dir.match_depths(&exact), vec![0, 0]);
        p0.release(a).unwrap();
        p1.release(b).unwrap();
    }

    #[test]
    fn stale_directory_entry_degrades_to_a_plain_miss() {
        // The dispatcher/directory race: node 0 publishes its resident
        // chains, then evicts them (release drops the last refs) before
        // the affinity-routed request lands. The route was taken on a
        // stale entry — admission must degrade to a plain miss
        // (re-prefill), never error, and the directory heals on the next
        // publish.
        let mut p = pager();
        let w = window(8, 8, 0);
        let (a, _) = p.admit_prompt(&w).unwrap();
        let dir = PrefixDirectory::new(1);
        dir.publish(0, p.index_hashes());
        let hashes = window_chain_hashes(&w, p.block_positions());
        assert_eq!(dir.match_depths(&hashes), vec![2], "published while resident");
        // evict between publish and dispatch
        p.release(a).unwrap();
        assert_eq!(
            dir.match_depths(&hashes),
            vec![2],
            "directory is a stale hint by design"
        );
        assert_eq!(p.resident_prefix_blocks(&w), 0, "the pager knows better");
        // the routed request admits anyway: zero hits, fresh pages, no error
        let (b, hits) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits, 0, "stale hit must become a plain miss");
        assert_eq!(p.used_blocks(), 2);
        // republish reflects reality again
        dir.publish(0, p.index_hashes());
        assert_eq!(dir.match_depths(&hashes), vec![2]);
        p.release(b).unwrap();
        dir.publish(0, p.index_hashes());
        assert_eq!(dir.match_depths(&hashes), vec![0]);
    }

    #[test]
    fn prop_two_node_fabric_directory_and_pools_never_dangle() {
        // The fabric-wide extension of the shared-prefix property: two
        // pagers (cards), one fleet PrefixDirectory, one shared HostPool.
        // Random interleavings of affinity-routed admit / CoW grow /
        // swap-out / cross-node migration (swap-in on the *other* card) /
        // release, with publishes interleaved at random (so the directory
        // is routinely stale). Invariants after every step: each pager's
        // index never points at a freed block, directory depths never
        // exceed the published snapshot's truth at publish time (checked
        // by re-publishing and comparing), the shared host pool's bytes
        // equal the outstanding parked reservations, and admitting via a
        // stale directory route never errors.
        forall(0xFAB51C, 100, |rng: &mut Rng| {
            let bp = rng.range(1, 6) as usize;
            let total = rng.range(6, 40) as usize;
            let weights = 1u64 << 10;
            let vram = weights + total as u64 * (bp as u64 * 64);
            let mut pagers = [
                KvPager::new(bp, 64, vram, weights).unwrap(),
                KvPager::new(bp, 64, vram, weights).unwrap(),
            ];
            let dir = PrefixDirectory::new(2);
            let mut host = HostPool::new(rng.range(1, 1 << 16));
            // live: (node, handle, shadow ids, positions); parked: (home
            // node at swap time, reserved bytes, family, len, salt)
            let mut live: Vec<(usize, SeqKv, Vec<usize>, usize)> = Vec::new();
            let mut parked: Vec<(usize, u64, usize, usize, i32)> = Vec::new();
            let families: Vec<(usize, usize)> = (0..3)
                .map(|_| {
                    let len = rng.range(1, 4 * bp as u64) as usize;
                    (rng.range(0, len as u64 + 1) as usize, len)
                })
                .collect();
            for _ in 0..80 {
                match rng.below(6) {
                    0 | 1 => {
                        // affinity-routed admit: pick the node with the
                        // deeper published match (possibly stale)
                        let fi = rng.below(families.len() as u64) as usize;
                        let (shared, len) = families[fi];
                        let salt = rng.range(0, 3) as i32;
                        let w = window(shared, len, salt);
                        let depths = dir.match_depths(&window_chain_hashes(&w, bp));
                        let node = if depths[1] > depths[0] { 1 } else { 0 };
                        if let Some((h, hits)) = pagers[node].admit_prompt(&w) {
                            // stale routes degrade: hits bounded by what
                            // is actually resident, never an error
                            assert!(hits <= len.max(1).div_ceil(bp));
                            let ids = pagers[node].seq_block_ids(h);
                            live.push((node, h, ids, len));
                        }
                    }
                    2 => {
                        // grow (may CoW)
                        if let Some(i) =
                            (!live.is_empty()).then(|| rng.below(live.len() as u64) as usize)
                        {
                            let target = live[i].3 + rng.range(0, 2 * bp as u64) as usize;
                            let node = live[i].0;
                            if pagers[node].grow(live[i].1, target).unwrap() {
                                live[i].3 = live[i].3.max(target);
                                live[i].2 = pagers[node].seq_block_ids(live[i].1);
                            }
                        }
                    }
                    3 => {
                        // swap-out: park a live sequence's private bytes
                        // in the shared host pool
                        if let Some(i) =
                            (!live.is_empty()).then(|| rng.below(live.len() as u64) as usize)
                        {
                            let (node, h, len) = (live[i].0, live[i].1, live[i].3);
                            let bytes = pagers[node].seq_private_bytes(h).unwrap();
                            if host.try_reserve(bytes) {
                                live.swap_remove(i);
                                pagers[node].release(h).unwrap();
                                let fi = rng.below(families.len() as u64) as usize;
                                let (shared, _) = families[fi];
                                parked.push((node, bytes, shared.min(len), len, 0));
                            }
                        }
                    }
                    4 => {
                        // migrate/resume: restore a parked sequence onto a
                        // random card — possibly NOT its home (the
                        // cross-node path); the host reservation is
                        // released exactly once either way
                        if let Some(i) =
                            (!parked.is_empty()).then(|| rng.below(parked.len() as u64) as usize)
                        {
                            let (_, bytes, shared, len, salt) = parked[i];
                            let dst = rng.below(2) as usize;
                            let w = window(shared, len, salt);
                            if let Some((h, _)) = pagers[dst].admit_prompt(&w) {
                                parked.swap_remove(i);
                                host.release(bytes);
                                let ids = pagers[dst].seq_block_ids(h);
                                live.push((dst, h, ids, len));
                            }
                        }
                    }
                    _ => {
                        // release, or republish a random node's snapshot
                        if rng.below(2) == 0 {
                            let node = rng.below(2) as usize;
                            dir.publish(node, pagers[node].index_hashes());
                        } else if let Some(i) =
                            (!live.is_empty()).then(|| rng.below(live.len() as u64) as usize)
                        {
                            let (node, h, _, _) = live.swap_remove(i);
                            pagers[node].release(h).unwrap();
                        }
                    }
                }
                // invariants: per-node index integrity + shared-pool
                // byte conservation
                for (node, pager) in pagers.iter().enumerate() {
                    let mut refs: std::collections::HashMap<usize, u32> =
                        std::collections::HashMap::new();
                    for (n, _, ids, _) in &live {
                        if *n == node {
                            for &id in ids {
                                *refs.entry(id).or_default() += 1;
                            }
                        }
                    }
                    for (&id, &expect) in &refs {
                        assert_eq!(pager.block_refs(id), expect, "node {node} refcount drift");
                    }
                    assert_eq!(pager.used_blocks(), refs.len());
                    for id in pager.index_entries() {
                        assert!(
                            refs.contains_key(&id),
                            "node {node} index points at freed block {id}"
                        );
                    }
                }
                let expect: u64 = parked.iter().map(|&(_, b, _, _, _)| b).sum();
                assert_eq!(host.used_bytes(), expect, "host pool drifted from parked ledger");
                assert!(host.used_bytes() <= host.capacity_bytes());
            }
            for (node, h, _, _) in live {
                pagers[node].release(h).unwrap();
            }
            for (_, bytes, _, _, _) in parked {
                host.release(bytes);
            }
            assert_eq!(host.used_bytes(), 0);
            assert_eq!(pagers[0].used_blocks() + pagers[1].used_blocks(), 0);
        });
    }
}
