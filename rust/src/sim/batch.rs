//! Batched sweeps: fan `kernels × devices × config` across worker threads.
//!
//! Every paper figure, calibration sweep, and fleet-weighting pass is a
//! dense grid of independent `simulate` calls. This module runs such grids
//! across `std::thread` workers with **deterministic result ordering**: the
//! output vector is always job-major then device-major, bit-identical to
//! running [`simulate_lowered`] sequentially in that order (each grid cell
//! is a pure function of its inputs, so parallelism cannot reorder or
//! perturb the floating-point math *within* a cell, and cells never
//! interact).
//!
//! Use [`sweep`] when every kernel shares one [`SimConfig`]; use
//! [`run_jobs`] when each kernel carries its own config (the llama-bench
//! grid, where MMQ and cuBLAS cells sustain different issue efficiencies).

use crate::device::DeviceSpec;
use crate::sim::engine::{simulate_lowered, KernelTiming, SimConfig};
use crate::sim::lowered::LoweredKernel;

/// One work item of a sweep: a pre-lowered kernel plus the engine config it
/// should be simulated under.
#[derive(Clone, Copy, Debug)]
pub struct SweepJob<'a> {
    pub kernel: &'a LoweredKernel,
    pub cfg: SimConfig,
}

/// Upper bound on worker threads; beyond this the per-cell work (a few µs)
/// is dwarfed by spawn/join overhead.
const MAX_WORKERS: usize = 16;

/// Below this many cells the sweep runs inline: spawning/joining OS threads
/// costs more than simulating a handful of cells does, and the small sweeps
/// (graph_3_5's 4 bars, a 2-device fleet weighting) must not get slower
/// than the sequential loops they replaced.
const SEQUENTIAL_CUTOFF: usize = 32;

fn worker_count(cells: usize) -> usize {
    if cells < SEQUENTIAL_CUTOFF {
        return 1;
    }
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    hw.min(MAX_WORKERS).min(cells).max(1)
}

/// Run `jobs × devices`, returning timings in job-major order:
/// `out[j * devices.len() + d]` is `jobs[j]` on `devices[d]`. Results are
/// bit-identical to the equivalent sequential loop.
pub fn run_jobs(jobs: &[SweepJob<'_>], devices: &[DeviceSpec]) -> Vec<KernelTiming> {
    let nd = devices.len();
    let cells = jobs.len() * nd;
    if cells == 0 {
        return Vec::new();
    }
    let workers = worker_count(cells);
    let mut out: Vec<Option<KernelTiming>> = Vec::with_capacity(cells);
    out.resize_with(cells, || None);

    if workers == 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            let job = &jobs[i / nd];
            *slot = Some(simulate_lowered(job.kernel, &devices[i % nd], &job.cfg));
        }
    } else {
        // Contiguous chunks of the flat grid per worker: disjoint &mut
        // slices, no locks, deterministic placement.
        let chunk = cells.div_ceil(workers);
        std::thread::scope(|s| {
            for (w, slots) in out.chunks_mut(chunk).enumerate() {
                let base = w * chunk;
                s.spawn(move || {
                    for (off, slot) in slots.iter_mut().enumerate() {
                        let i = base + off;
                        let job = &jobs[i / nd];
                        *slot = Some(simulate_lowered(job.kernel, &devices[i % nd], &job.cfg));
                    }
                });
            }
        });
    }
    out.into_iter().map(|t| t.expect("every cell simulated")).collect()
}

/// Run arbitrary `(job, device)` pairs — the heterogeneous-fleet shape
/// where every card simulates its own kernel build (e.g. a per-node fmad
/// policy, so no dense `jobs × devices` grid exists). Output order matches
/// `pairs` and is bit-identical to the equivalent sequential loop.
pub fn run_pairs(pairs: &[(SweepJob<'_>, &DeviceSpec)]) -> Vec<KernelTiming> {
    if pairs.is_empty() {
        return Vec::new();
    }
    let workers = worker_count(pairs.len());
    if workers == 1 {
        return pairs
            .iter()
            .map(|(job, dev)| simulate_lowered(job.kernel, dev, &job.cfg))
            .collect();
    }
    let mut out: Vec<Option<KernelTiming>> = Vec::with_capacity(pairs.len());
    out.resize_with(pairs.len(), || None);
    let chunk = pairs.len().div_ceil(workers);
    std::thread::scope(|s| {
        for (w, slots) in out.chunks_mut(chunk).enumerate() {
            let base = w * chunk;
            s.spawn(move || {
                for (off, slot) in slots.iter_mut().enumerate() {
                    let (job, dev) = &pairs[base + off];
                    *slot = Some(simulate_lowered(job.kernel, dev, &job.cfg));
                }
            });
        }
    });
    out.into_iter().map(|t| t.expect("every pair simulated")).collect()
}

/// Run `kernels × devices` under one shared config, kernel-major order:
/// `out[k * devices.len() + d]`.
pub fn sweep(
    kernels: &[LoweredKernel],
    devices: &[DeviceSpec],
    cfg: &SimConfig,
) -> Vec<KernelTiming> {
    let jobs: Vec<SweepJob<'_>> = kernels
        .iter()
        .map(|k| SweepJob { kernel: k, cfg: *cfg })
        .collect();
    run_jobs(&jobs, devices)
}

/// Convenience: one device, many (kernel, config) jobs.
pub fn run_jobs_on(jobs: &[SweepJob<'_>], dev: &DeviceSpec) -> Vec<KernelTiming> {
    run_jobs(jobs, std::slice::from_ref(dev))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::registry;
    use crate::isa::class::InstClass::*;
    use crate::isa::ir::{Kernel, MemPattern, Stmt, Traffic};
    use crate::testutil::{forall, Rng};

    fn assert_bit_identical(a: &KernelTiming, b: &KernelTiming) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.time_s.to_bits(), b.time_s.to_bits());
        assert_eq!(a.compute_time_s.to_bits(), b.compute_time_s.to_bits());
        assert_eq!(a.memory_time_s.to_bits(), b.memory_time_s.to_bits());
        assert_eq!(a.power_w.to_bits(), b.power_w.to_bits());
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.dvfs_derate.to_bits(), b.dvfs_derate.to_bits());
        assert_eq!(a.flops, b.flops);
        assert_eq!(a.iops, b.iops);
        assert_eq!(a.bytes.to_bits(), b.bytes.to_bits());
        assert_eq!(a.pipe_times.len(), b.pipe_times.len());
        for ((ka, va), (kb, vb)) in a.pipe_times.iter().zip(b.pipe_times.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(va.to_bits(), vb.to_bits());
        }
    }

    fn gen_kernel(rng: &mut Rng, i: usize) -> Kernel {
        let classes = [Ffma, Fmul, Fadd, Hfma2, Imad, Dp4a, Ldg, Stg];
        let mut body = Vec::new();
        for _ in 0..rng.range(1, 5) {
            body.push(Stmt::op(*rng.pick(&classes), rng.range(1, 256)));
        }
        Kernel::new(format!("k{i}"), rng.range(1 << 10, 1 << 22), 256)
            .with_body(body)
            .with_traffic(Traffic {
                read_bytes: rng.range(0, 1 << 30),
                write_bytes: rng.range(0, 1 << 28),
                pattern: MemPattern::Coalesced,
                l2_hit_rate: rng.f64_range(0.0, 0.8),
            })
    }

    #[test]
    fn empty_sweep_is_empty() {
        assert!(sweep(&[], &[registry::cmp170hx()], &SimConfig::default()).is_empty());
        assert!(run_jobs(&[], &[]).is_empty());
    }

    #[test]
    fn ordering_is_kernel_major_then_device() {
        let kernels: Vec<LoweredKernel> = (0..3)
            .map(|i| {
                LoweredKernel::lower(
                    &Kernel::new(format!("k{i}"), 1 << 16, 256)
                        .with_body(vec![Stmt::op(Fmul, 8)]),
                )
            })
            .collect();
        let devices = [registry::cmp170hx(), registry::a100_pcie()];
        let out = sweep(&kernels, &devices, &SimConfig::default());
        assert_eq!(out.len(), 6);
        for (k, kern) in kernels.iter().enumerate() {
            for d in 0..devices.len() {
                assert_eq!(out[k * devices.len() + d].name, kern.name);
            }
        }
    }

    #[test]
    fn prop_batch_is_bit_identical_to_sequential() {
        // The acceptance property: for arbitrary kernel/device/config
        // grids, the threaded sweep returns exactly the timings — same
        // values, same order — as the sequential reference loop.
        forall(0xBA7C4, 40, |rng: &mut Rng| {
            // Kernel counts straddle SEQUENTIAL_CUTOFF so both the inline
            // and the threaded paths are exercised.
            let kernels: Vec<LoweredKernel> = (0..rng.range(2, 24) as usize)
                .map(|i| LoweredKernel::lower(&gen_kernel(rng, i)))
                .collect();
            let devices: Vec<crate::device::DeviceSpec> = vec![
                registry::cmp170hx(),
                registry::a100_pcie(),
                registry::cmp170hx_x16(),
            ][..rng.range(1, 3) as usize]
                .to_vec();
            let jobs: Vec<SweepJob<'_>> = kernels
                .iter()
                .map(|k| SweepJob {
                    kernel: k,
                    cfg: SimConfig {
                        issue_efficiency: rng.f64_range(0.3, 1.0),
                        overlap: rng.f64_range(0.0, 1.0),
                        ..Default::default()
                    },
                })
                .collect();
            let batched = run_jobs(&jobs, &devices);
            let mut sequential = Vec::new();
            for job in &jobs {
                for dev in &devices {
                    sequential.push(simulate_lowered(job.kernel, dev, &job.cfg));
                }
            }
            assert_eq!(batched.len(), sequential.len());
            for (a, b) in batched.iter().zip(sequential.iter()) {
                assert_bit_identical(a, b);
            }
        });
    }

    #[test]
    fn prop_run_pairs_matches_sequential_and_grid() {
        // Pairs drawn from a jobs × devices grid must reproduce the
        // run_jobs cells bit-for-bit, in pair order, across both the inline
        // and the threaded paths.
        forall(0xFA172, 30, |rng: &mut Rng| {
            let kernels: Vec<LoweredKernel> = (0..rng.range(1, 20) as usize)
                .map(|i| LoweredKernel::lower(&gen_kernel(rng, i)))
                .collect();
            let devices = [registry::cmp170hx(), registry::cmp90hx(), registry::a100_pcie()];
            let jobs: Vec<SweepJob<'_>> = kernels
                .iter()
                .map(|k| SweepJob {
                    kernel: k,
                    cfg: SimConfig {
                        issue_efficiency: rng.f64_range(0.3, 1.0),
                        ..Default::default()
                    },
                })
                .collect();
            let pairs: Vec<(SweepJob<'_>, &crate::device::DeviceSpec)> = jobs
                .iter()
                .flat_map(|j| devices.iter().map(move |d| (*j, d)))
                .collect();
            let paired = run_pairs(&pairs);
            let grid = run_jobs(&jobs, &devices);
            assert_eq!(paired.len(), grid.len());
            for (a, b) in paired.iter().zip(grid.iter()) {
                assert_bit_identical(a, b);
            }
        });
    }

    #[test]
    fn run_pairs_empty_is_empty() {
        assert!(run_pairs(&[]).is_empty());
    }

    #[test]
    fn run_jobs_on_single_device() {
        let lk = LoweredKernel::lower(
            &Kernel::new("k", 1 << 16, 256).with_body(vec![Stmt::op(Imad, 32)]),
        );
        let jobs = [
            SweepJob { kernel: &lk, cfg: SimConfig::default() },
            SweepJob {
                kernel: &lk,
                cfg: SimConfig { issue_efficiency: 0.5, ..Default::default() },
            },
        ];
        let out = run_jobs_on(&jobs, &registry::cmp170hx());
        assert_eq!(out.len(), 2);
        // Half the issue efficiency → strictly slower compute.
        assert!(out[1].compute_time_s > out[0].compute_time_s);
    }
}
