//! ggml quantization formats (the six the paper benchmarks).
//!
//! Each format carries its storage cost and the instruction character of
//! its CUDA matmul kernels. Bits-per-weight figures are the ggml block
//! layouts: q8_0 = 32 weights + 1 f16 scale per block (34 B / 32 = 8.5
//! bpw); k-quants use 256-weight super-blocks with nested scales.

use crate::isa::ir::KernelSource;

/// One ggml quantization format.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantFormat {
    pub name: &'static str,
    /// Effective bits per weight including scales/mins.
    pub bits_per_weight_x1000: u32,
    /// ggml block size (weights per scale block).
    pub block: u32,
    /// Where the matmul kernels come from: `Lib` (cuBLAS) for float
    /// formats, `Jit` (MMQ/MMVQ) for quantized — the fmad boundary.
    pub source: KernelSource,
    /// Fused fp32 scale/accumulate ops per block in the prefill (MMQ)
    /// kernel — the crippled/restorable fraction.
    pub scale_fmas_per_block: f64,
    /// Integer unpack ops (shifts/masks/adds) per block in MMQ.
    pub unpack_iops_per_block: f64,
    /// Fraction of decode (MMVQ) multiply-accumulates that run as fp32
    /// FFMA rather than DP4A (super-block scale application, partial sums).
    pub decode_float_frac: f64,
}

impl QuantFormat {
    pub fn bits_per_weight(&self) -> f64 {
        self.bits_per_weight_x1000 as f64 / 1000.0
    }

    /// Bytes to store `params` weights in this format.
    pub fn bytes_for(&self, params: u64) -> u64 {
        (params as f64 * self.bits_per_weight() / 8.0) as u64
    }

    /// Is this a k-quant (256-weight super-blocks)?
    pub fn is_kquant(&self) -> bool {
        self.block == 256
    }

    /// The float formats route through cuBLAS — fmad-immune.
    pub fn fmad_immune(&self) -> bool {
        self.source == KernelSource::Lib
    }
}

/// f32 — full precision; GEMM via cuBLAS (Lib).
pub const F32: QuantFormat = QuantFormat {
    name: "f32",
    bits_per_weight_x1000: 32_000,
    block: 1,
    source: KernelSource::Lib,
    scale_fmas_per_block: 0.0,
    unpack_iops_per_block: 0.0,
    decode_float_frac: 1.0, // SGEMV: all-FFMA (crippled, and Lib: unfixable)
};

/// f16 — half precision; GEMM via cuBLAS HGEMM fallback (Lib).
pub const F16: QuantFormat = QuantFormat {
    name: "f16",
    bits_per_weight_x1000: 16_000,
    block: 1,
    source: KernelSource::Lib,
    scale_fmas_per_block: 0.0,
    unpack_iops_per_block: 0.0,
    decode_float_frac: 0.0, // HGEMV on the (uncrippled) scalar-half pipe
};

/// q8_0 — 32-weight blocks, one f16 scale.
pub const Q8_0: QuantFormat = QuantFormat {
    name: "q8_0",
    bits_per_weight_x1000: 8_500,
    block: 32,
    source: KernelSource::Jit,
    scale_fmas_per_block: 0.35,
    unpack_iops_per_block: 4.0,
    decode_float_frac: 0.22,
};

/// q6_k — 256-weight super-blocks, 16 6-bit sub-scales.
pub const Q6_K: QuantFormat = QuantFormat {
    name: "q6_k",
    bits_per_weight_x1000: 6_562,
    block: 256,
    source: KernelSource::Jit,
    scale_fmas_per_block: 4.5,
    unpack_iops_per_block: 48.0,
    decode_float_frac: 0.20,
};

/// q4_k_m — 256-weight super-blocks, 4-bit weights, 6-bit scales/mins.
pub const Q4_K_M: QuantFormat = QuantFormat {
    name: "q4_k_m",
    bits_per_weight_x1000: 4_850,
    block: 256,
    source: KernelSource::Jit,
    scale_fmas_per_block: 6.0,
    unpack_iops_per_block: 56.0,
    decode_float_frac: 0.18,
};

/// q2_k — 256-weight super-blocks, 2-bit weights, two-level scale tree:
/// the most dequant math per weight of the six.
pub const Q2_K: QuantFormat = QuantFormat {
    name: "q2_k",
    bits_per_weight_x1000: 2_625,
    block: 256,
    source: KernelSource::Jit,
    scale_fmas_per_block: 10.0,
    unpack_iops_per_block: 72.0,
    decode_float_frac: 0.14,
};

/// The six formats in the paper's graph order.
pub const ALL: &[QuantFormat] = &[F32, F16, Q8_0, Q6_K, Q4_K_M, Q2_K];

/// Look up a format by name.
pub fn by_name(name: &str) -> Option<QuantFormat> {
    ALL.iter().copied().find(|q| q.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bpw_values_match_ggml_layouts() {
        assert_eq!(F32.bits_per_weight(), 32.0);
        assert_eq!(F16.bits_per_weight(), 16.0);
        assert_eq!(Q8_0.bits_per_weight(), 8.5); // (32 + 2 bytes)/32 × 8
        assert!((Q6_K.bits_per_weight() - 6.5625).abs() < 0.01);
        assert!((Q2_K.bits_per_weight() - 2.625).abs() < 0.01);
    }

    #[test]
    fn qwen_f32_does_not_fit_in_8gb_but_f16_does() {
        // §4.1: the 1.5B model was chosen so all layers fit in 8 GB VRAM.
        // (f32 weights are 6.2 GB — they fit, barely, with little room for
        // context; f16 and below are comfortable.)
        let params: u64 = 1_540_000_000;
        assert!(F32.bytes_for(params) > 6_000_000_000);
        assert!(F16.bytes_for(params) < 3_200_000_000);
        assert!(Q2_K.bytes_for(params) < 600_000_000);
    }

    #[test]
    fn scale_math_grows_as_quantization_deepens() {
        // The mechanism behind Graph 4-1's noFMA speedup ordering: per
        // weight, q2_k has the most crippled-class work.
        let per_weight = |q: &QuantFormat| q.scale_fmas_per_block / q.block as f64;
        assert!(per_weight(&Q2_K) > per_weight(&Q4_K_M));
        assert!(per_weight(&Q4_K_M) > per_weight(&Q6_K));
        assert!(per_weight(&Q6_K) > per_weight(&Q8_0));
    }

    #[test]
    fn float_formats_are_fmad_immune() {
        assert!(F32.fmad_immune() && F16.fmad_immune());
        assert!(!Q8_0.fmad_immune() && !Q2_K.fmad_immune());
    }

    #[test]
    fn kquants_use_superblocks() {
        assert!(Q6_K.is_kquant() && Q4_K_M.is_kquant() && Q2_K.is_kquant());
        assert!(!Q8_0.is_kquant());
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("q4_k_m").unwrap().name, "q4_k_m");
        assert!(by_name("q3_k").is_none());
    }
}
