//! Memory-bandwidth benchmark (Graph 3-5) — OpenCL-Benchmark's memory
//! section: coalesced read, coalesced write, misaligned read, misaligned
//! write, on a buffer far larger than L2.

use crate::device::DeviceSpec;
use crate::isa::class::InstClass;
use crate::isa::ir::{Kernel, MemPattern, Stmt, Traffic};
use crate::sim::{batch, simulate_lowered, LoweredKernel, SimConfig};

use super::ToolResult;

/// 2 GiB test buffer (OpenCL-Benchmark scales to VRAM; 2 GiB ≫ 8 MiB L2).
const BYTES: u64 = 2 << 30;
const ELEM: u64 = 4;

/// Direction of the streaming kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Read,
    Write,
}

impl Dir {
    pub fn name(self) -> &'static str {
        match self {
            Dir::Read => "read",
            Dir::Write => "write",
        }
    }
}

/// Build the streaming kernel for a direction/pattern.
pub fn kernel(dir: Dir, pattern: MemPattern) -> Kernel {
    let threads = BYTES / ELEM;
    let (read, write, body) = match dir {
        Dir::Read => (
            BYTES,
            0,
            // reads reduced into a register to defeat dead-code elimination
            vec![Stmt::op(InstClass::Ldg, 1), Stmt::op(InstClass::Iadd, 1)],
        ),
        Dir::Write => (0, BYTES, vec![Stmt::op(InstClass::Stg, 1)]),
    };
    Kernel::new(
        format!("membench.{}.{:?}", dir.name(), pattern),
        threads,
        256,
    )
    .with_body(body)
    .with_traffic(Traffic {
        read_bytes: read,
        write_bytes: write,
        pattern,
        l2_hit_rate: 0.0,
    })
}

/// The one place a membench ToolResult label/timing pair is assembled —
/// shared by the single-case and batched paths so labels cannot drift.
fn tool_result(dir: Dir, pattern: MemPattern, timing: crate::sim::KernelTiming) -> ToolResult {
    ToolResult {
        tool: "opencl-benchmark/mem",
        case: format!("{} {:?}", dir.name(), pattern),
        timing,
    }
}

/// Run one (direction, pattern) case.
pub fn run(dev: &DeviceSpec, dir: Dir, pattern: MemPattern) -> ToolResult {
    let lk = LoweredKernel::lower(&kernel(dir, pattern));
    let timing = simulate_lowered(&lk, dev, &SimConfig::default());
    tool_result(dir, pattern, timing)
}

/// The four bars of Graph 3-5, lowered once each and simulated as one
/// batched sweep.
pub fn graph_3_5(dev: &DeviceSpec) -> Vec<ToolResult> {
    let cases = [
        (Dir::Read, MemPattern::Coalesced),
        (Dir::Write, MemPattern::Coalesced),
        (Dir::Read, MemPattern::Misaligned),
        (Dir::Write, MemPattern::Misaligned),
    ];
    let lowered: Vec<LoweredKernel> = cases
        .iter()
        .map(|&(dir, pattern)| LoweredKernel::lower(&kernel(dir, pattern)))
        .collect();
    let timings = batch::sweep(&lowered, std::slice::from_ref(dev), &SimConfig::default());
    cases
        .iter()
        .zip(timings)
        .map(|(&(dir, pattern), timing)| tool_result(dir, pattern, timing))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibration as cal;
    use crate::device::registry;

    #[test]
    fn coalesced_read_matches_graph_3_5() {
        let dev = registry::cmp170hx();
        let g = run(&dev, Dir::Read, MemPattern::Coalesced).gbps();
        assert!(cal::check(&cal::MEMBW_COALESCED_GBPS, g), "{g}");
    }

    #[test]
    fn bandwidth_fully_retained_vs_a100() {
        // The paper's pivotal claim: CMP bandwidth ≈ 96% of A100's.
        let cmp = run(&registry::cmp170hx(), Dir::Read, MemPattern::Coalesced).gbps();
        let a100 = run(&registry::a100_pcie(), Dir::Read, MemPattern::Coalesced).gbps();
        let ratio = cmp / a100;
        assert!(ratio > 0.94 && ratio < 0.98, "{ratio}");
    }

    #[test]
    fn misaligned_pays_a_heavy_penalty() {
        let dev = registry::cmp170hx();
        let co = run(&dev, Dir::Read, MemPattern::Coalesced).gbps();
        let mis = run(&dev, Dir::Read, MemPattern::Misaligned).gbps();
        assert!(mis / co < 0.6, "misaligned {mis} vs coalesced {co}");
    }

    #[test]
    fn all_graph_bars_are_memory_bound() {
        for r in graph_3_5(&registry::cmp170hx()) {
            assert!(r.timing.memory_bound(), "{}", r.case);
        }
    }

    #[test]
    fn fmad_policy_is_irrelevant_to_bandwidth() {
        use crate::isa::pass::{apply_fmad, FmadPolicy};
        let k = kernel(Dir::Read, MemPattern::Coalesced);
        let rewritten = apply_fmad(&k, FmadPolicy::Decomposed);
        assert_eq!(k.body, rewritten.body);
    }
}
