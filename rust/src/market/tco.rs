//! Reuse-value and fleet-planning models (§6.2: "most suitable for…
//! community edge nodes that prioritize cost and service latency").

use crate::device::DeviceSpec;
use crate::isa::pass::FmadPolicy;
use crate::llm::llamabench::LlamaBench;
use crate::llm::quant::QuantFormat;

/// Dollars-per-throughput value of a card in a given duty.
#[derive(Clone, Debug)]
pub struct ReuseValue {
    pub device: &'static str,
    pub price_usd: f64,
    /// $ per restored FP32 TFLOPS (after the fmad workaround).
    pub usd_per_tflop_fp32: f64,
    /// $ per decode token/s on the given quant.
    pub usd_per_decode_tps: f64,
    /// Annual energy cost at a duty cycle, USD.
    pub energy_usd_per_year: f64,
    /// Decode throughput used for the ratio.
    pub decode_tps: f64,
}

/// Electricity price assumption for edge deployments, $/kWh.
pub const USD_PER_KWH: f64 = 0.12;

/// Value of a device for quantized-LLM edge serving.
pub fn reuse_value(
    dev: &DeviceSpec,
    quant: &QuantFormat,
    policy: FmadPolicy,
    duty_cycle: f64,
) -> ReuseValue {
    let bench = LlamaBench::default();
    let r = bench.run(dev, quant, policy);
    let fp32 = crate::bench::openclbench::peak_fp32(dev, policy).tflops();
    let kwh_year = dev.tdp_w * duty_cycle * 24.0 * 365.0 / 1000.0;
    ReuseValue {
        device: dev.name,
        price_usd: dev.price_usd,
        usd_per_tflop_fp32: dev.price_usd / fp32,
        usd_per_decode_tps: dev.price_usd / r.decode_tps,
        energy_usd_per_year: kwh_year * USD_PER_KWH,
        decode_tps: r.decode_tps,
    }
}

/// A sized fleet meeting a throughput target.
#[derive(Clone, Debug)]
pub struct FleetPlan {
    pub device: &'static str,
    pub cards: u32,
    pub capex_usd: f64,
    pub power_w: f64,
    pub decode_tps_total: f64,
}

/// Size a fleet of `dev` from a **measured** per-card serving throughput —
/// a fleet-engine node's `Metrics::sim_tokens_per_sec`, or a real
/// deployment's observed rate — rather than the modeled single-card
/// estimate. This is what the continuous-batching coordinator feeds back
/// into the §6.2 economics: sizing consumes what the fleet actually
/// sustained under its admission policy, not a standalone tg128 peak.
pub fn fleet_for_measured_throughput(
    dev: &DeviceSpec,
    measured_tps_per_card: f64,
    target_tps: f64,
) -> FleetPlan {
    assert!(
        measured_tps_per_card > 0.0,
        "measured throughput must be positive"
    );
    let cards = (target_tps / measured_tps_per_card).ceil().max(1.0) as u32;
    FleetPlan {
        device: dev.name,
        cards,
        capex_usd: cards as f64 * dev.price_usd,
        power_w: cards as f64 * dev.tdp_w,
        decode_tps_total: cards as f64 * measured_tps_per_card,
    }
}

/// How many cards of `dev` are needed to serve `target_tps` of decode
/// throughput on `quant`, and what that costs — the modeled-estimate
/// convenience over [`fleet_for_measured_throughput`].
pub fn fleet_for_throughput(
    dev: &DeviceSpec,
    quant: &QuantFormat,
    policy: FmadPolicy,
    target_tps: f64,
) -> FleetPlan {
    let bench = LlamaBench::default();
    let per_card = bench.run(dev, quant, policy).decode_tps;
    fleet_for_measured_throughput(dev, per_card, target_tps)
}

/// §6.2's headline question, answered from measured serving metrics: how
/// many `dev` cards replace one A100 for decode serving, and at what
/// capital and energy cost.
#[derive(Clone, Debug)]
pub struct Replacement {
    pub device: &'static str,
    /// Cards of `dev` needed to match one A100's measured throughput.
    pub cards_per_a100: u32,
    /// Replacement-fleet capex over A100 capex (< 1 ⇒ the reuse pencils).
    pub capex_ratio: f64,
    /// Replacement-fleet wall power over A100 wall power.
    pub power_ratio: f64,
    /// Joules per token of `dev` over joules per token of the A100
    /// (> 1 ⇒ the recycled fleet pays an energy premium per token).
    pub energy_per_token_ratio: f64,
}

/// Compare a measured `(tokens/s, watts)` operating point of `dev` against
/// a measured A100 operating point. Throughputs and powers come from the
/// fleet engine's per-node metrics (or `LlamaBench` rows for a pure-model
/// answer).
pub fn a100_replacement(
    dev: &DeviceSpec,
    measured_tps: f64,
    measured_w: f64,
    a100_tps: f64,
    a100_w: f64,
) -> Replacement {
    assert!(measured_tps > 0.0 && a100_tps > 0.0);
    let a100 = crate::device::registry::a100_pcie();
    let cards = (a100_tps / measured_tps).ceil().max(1.0) as u32;
    Replacement {
        device: dev.name,
        cards_per_a100: cards,
        capex_ratio: (cards as f64 * dev.price_usd) / a100.price_usd,
        power_ratio: (cards as f64 * measured_w) / a100_w,
        energy_per_token_ratio: (measured_w / measured_tps) / (a100_w / a100_tps),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::registry;
    use crate::llm::quant;

    #[test]
    fn restored_cmp_is_cheap_flops() {
        // Second-hand 170HX (~$400 in 2024, but we use the paper's $4500
        // 2021 ASP) — even at ASP, restored FP32 costs less per TFLOP than
        // the crippled card by ~16×.
        let dev = registry::cmp170hx();
        let crippled = reuse_value(&dev, &quant::Q8_0, FmadPolicy::Fused, 1.0);
        let restored = reuse_value(&dev, &quant::Q8_0, FmadPolicy::Decomposed, 1.0);
        assert!(crippled.usd_per_tflop_fp32 / restored.usd_per_tflop_fp32 > 15.0);
    }

    #[test]
    fn cmp_beats_a100_on_capex_per_decode_tps() {
        // The §6.2 argument: for bandwidth-bound decode, a $4500 CMP gives
        // a large fraction of a $10k A100's decode rate.
        let cmp = reuse_value(
            &registry::cmp170hx(),
            &quant::Q4_K_M,
            FmadPolicy::Decomposed,
            1.0,
        );
        let a100 = reuse_value(
            &registry::a100_pcie(),
            &quant::Q4_K_M,
            FmadPolicy::Fused,
            1.0,
        );
        assert!(
            cmp.usd_per_decode_tps < a100.usd_per_decode_tps,
            "cmp {} vs a100 {}",
            cmp.usd_per_decode_tps,
            a100.usd_per_decode_tps
        );
    }

    #[test]
    fn fleet_meets_target() {
        let dev = registry::cmp170hx();
        let plan = fleet_for_throughput(&dev, &quant::Q4_K_M, FmadPolicy::Decomposed, 2000.0);
        assert!(plan.decode_tps_total >= 2000.0);
        assert!(plan.cards >= 2);
        assert!((plan.capex_usd - plan.cards as f64 * dev.price_usd).abs() < 1e-9);
    }

    #[test]
    fn measured_sizing_matches_modeled_sizing_at_the_model_point() {
        // Feeding the modeled per-card rate through the measured-throughput
        // path must reproduce fleet_for_throughput exactly.
        let dev = registry::cmp170hx();
        let per_card = LlamaBench::default()
            .run(&dev, &quant::Q4_K_M, FmadPolicy::Decomposed)
            .decode_tps;
        let modeled =
            fleet_for_throughput(&dev, &quant::Q4_K_M, FmadPolicy::Decomposed, 2000.0);
        let measured = fleet_for_measured_throughput(&dev, per_card, 2000.0);
        assert_eq!(modeled.cards, measured.cards);
        assert_eq!(modeled.capex_usd, measured.capex_usd);
        assert_eq!(
            modeled.decode_tps_total.to_bits(),
            measured.decode_tps_total.to_bits()
        );
    }

    #[test]
    fn measured_sizing_reflects_serving_degradation() {
        // A fleet that measures below the tg128 peak needs more cards —
        // exactly what the single-card estimate used to hide.
        let dev = registry::cmp170hx();
        let peak = fleet_for_measured_throughput(&dev, 500.0, 2000.0);
        let degraded = fleet_for_measured_throughput(&dev, 350.0, 2000.0);
        assert_eq!(peak.cards, 4);
        assert_eq!(degraded.cards, 6);
        assert!(degraded.capex_usd > peak.capex_usd);
    }

    #[test]
    fn a100_replacement_counts_cards_and_energy() {
        let dev = registry::cmp170hx();
        // A card at 1/3 the A100 rate → 3 cards, and a 2× J/token premium
        // when it burns 2/3 the power at 1/3 the rate.
        let r = a100_replacement(&dev, 100.0, 200.0, 300.0, 300.0);
        assert_eq!(r.cards_per_a100, 3);
        assert!((r.power_ratio - 2.0).abs() < 1e-12);
        assert!((r.energy_per_token_ratio - 2.0).abs() < 1e-12);
        // capex: 3 × $4500 vs $10k
        assert!((r.capex_ratio - 1.35).abs() < 1e-12);
    }

    #[test]
    fn single_card_fleet_for_tiny_target() {
        let dev = registry::cmp170hx();
        let plan = fleet_for_throughput(&dev, &quant::Q2_K, FmadPolicy::Decomposed, 1.0);
        assert_eq!(plan.cards, 1);
    }

    #[test]
    fn energy_cost_scales_with_duty() {
        let dev = registry::cmp170hx();
        let full = reuse_value(&dev, &quant::Q8_0, FmadPolicy::Fused, 1.0);
        let half = reuse_value(&dev, &quant::Q8_0, FmadPolicy::Fused, 0.5);
        assert!((full.energy_usd_per_year / half.energy_usd_per_year - 2.0).abs() < 1e-9);
    }
}
