//! Overload acceptance: open-loop arrival streams pushed through and past
//! the fleet's latency knee, comparing the admission-control arm against
//! the reactive-only `--no-admission-control` ablation on bit-identical
//! traffic.
//!
//! The headline assertions run on the pure discrete-event fleet model
//! (`cmphx::load::sim`) so they hold in every environment — thousands of
//! requests, no artifacts, no wall clock. One end-to-end test replays a
//! plan against the real coordinator and skips (with a note on stderr)
//! when the AOT artifacts or PJRT are missing.

use cmphx::faults::{FaultEvent, FaultKind, FaultPlan};
use cmphx::load::{
    capacity_rps, simulate, sweep, ArrivalPlan, ArrivalProcess, NodeModel, SimConfig,
    WorkloadShape,
};
use cmphx::qos::TenantId;
use cmphx::testutil::assert_close;

mod common;

const SEED: u64 = 0x10AD_CAFE;

/// Two CMP 170HX-like cards, three equal-weight tenants, one shared
/// 500 ms contract — the fleet every assertion below runs against.
fn fleet() -> SimConfig {
    SimConfig::uniform(2, NodeModel::cmp170hx_like(), 3, Some(0.5))
}

fn shape() -> WorkloadShape {
    WorkloadShape {
        tenants: 3,
        prompt_len: 32,
        shared_prefix_len: 16,
        families: 4,
        max_tokens: 8,
    }
}

fn plan(seed: u64) -> ArrivalPlan {
    ArrivalPlan::seeded(ArrivalProcess::Poisson { rps: 40.0 }, seed, 30.0, &shape())
}

/// Rescale a plan so its offered rate is `rho` × fleet capacity.
fn at_rho(base: &ArrivalPlan, cfg: &SimConfig, rho: f64) -> ArrivalPlan {
    base.scaled(rho * capacity_rps(base, cfg) / base.offered_rps())
}

#[test]
fn past_the_knee_admission_control_beats_the_reactive_arm() {
    let cfg = fleet();
    let base = plan(SEED);
    for rho in [1.5, 2.0] {
        let hot = at_rho(&base, &cfg, rho);
        let ac = simulate(&hot, &cfg);
        let bare = simulate(&hot, &cfg.without_admission());

        // The ablation must exhibit congestion collapse: a large share of
        // its offered load either fails at dispatch after queueing (the
        // reactive deadline gate) or burns full service on answers that
        // land past their contract — served-late waste.
        assert!(
            bare.deadline_misses + bare.served_late > bare.offered / 4,
            "rho={rho}: the reactive arm must collapse into a miss storm: {bare:?}"
        );
        assert!(bare.served_late > 0, "rho={rho}: collapse includes served waste");

        // The AC arm sheds at submit instead, and converts that refused
        // load into strictly more useful work from the same stream.
        assert!(ac.shed_admission > 0, "rho={rho}: overload must engage the controller");
        assert!(
            ac.goodput_tokens > bare.goodput_tokens,
            "rho={rho}: AC goodput must win: {} vs {}",
            ac.goodput_tokens,
            bare.goodput_tokens
        );
        assert!(
            ac.slo_attainment() > bare.slo_attainment(),
            "rho={rho}: AC attainment must win: {:?} vs {:?}",
            ac.slo_attainment(),
            bare.slo_attainment()
        );
        // Shedding also buys energy efficiency: fewer joules spent on
        // tokens nobody can use.
        assert!(
            ac.goodput_tokens_per_joule > bare.goodput_tokens_per_joule,
            "rho={rho}: useful tokens per joule: {} vs {}",
            ac.goodput_tokens_per_joule,
            bare.goodput_tokens_per_joule
        );
    }
}

#[test]
fn below_the_knee_both_arms_serve_bit_identical_tokens() {
    let cfg = fleet();
    let cool = at_rho(&plan(SEED), &cfg, 0.6);
    let ac = simulate(&cool, &cfg);
    let bare = simulate(&cool, &cfg.without_admission());
    assert_eq!(ac.shed_admission, 0, "no shedding below the knee");
    assert_eq!(ac.deadline_misses, 0);
    assert_eq!(bare.deadline_misses, 0);
    assert_eq!(
        ac.served, bare.served,
        "admission control must be a no-op below the knee: same requests, same tokens"
    );
    assert_eq!(ac, bare, "the whole report coincides when the controller never fires");
    assert_eq!(ac.slo_attainment(), Some(1.0));
}

#[test]
fn same_seed_reproduces_identical_curves_including_under_chaos() {
    let calm = fleet();
    let chaos = SimConfig {
        chaos: Some(FaultPlan::seeded(SEED ^ 0xFA17, 2, 64, 0.08)),
        ..calm.clone()
    };
    let mults = [0.5, 1.0, 1.5, 2.0];
    for cfg in [&calm, &chaos] {
        let a = sweep(&plan(SEED), &mults, cfg);
        let b = sweep(&plan(SEED), &mults, cfg);
        assert_eq!(a, b, "same seed, same curve — fingerprints and all");
    }
    let a = sweep(&plan(SEED), &mults, &chaos);
    let c = sweep(&plan(SEED + 1), &mults, &chaos);
    assert_ne!(a, c, "a different arrival seed must change the curve");
    // Chaos that provably bites — one card dies on its first round —
    // must perturb the curve it composes with, and still replay exactly.
    let lethal = SimConfig {
        chaos: Some(FaultPlan::script(vec![FaultEvent {
            node: 0,
            round: 0,
            kind: FaultKind::NodeDeath,
        }])),
        ..calm.clone()
    };
    let hot = at_rho(&plan(SEED), &calm, 1.0);
    assert_ne!(
        simulate(&hot, &lethal),
        simulate(&hot, &calm),
        "a dead card must show up in the curve"
    );
    assert_eq!(simulate(&hot, &lethal), simulate(&hot, &lethal));
}

#[test]
fn every_arrival_process_is_seed_deterministic_and_rate_faithful() {
    let processes = [
        ArrivalProcess::Poisson { rps: 25.0 },
        ArrivalProcess::Mmpp {
            base_rps: 10.0,
            burst_rps: 40.0,
            mean_dwell_s: 1.0,
        },
        ArrivalProcess::Diurnal {
            mean_rps: 25.0,
            swing: 0.5,
            period_s: 20.0,
        },
    ];
    for p in processes {
        let a = ArrivalPlan::seeded(p, SEED, 200.0, &shape());
        let b = ArrivalPlan::seeded(p, SEED, 200.0, &shape());
        assert_eq!(a, b, "{}: same seed, same stream", p.name());
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(
            a.fingerprint(),
            ArrivalPlan::seeded(p, SEED + 1, 200.0, &shape()).fingerprint(),
            "{}: different seed, different stream",
            p.name()
        );
        // Long-window empirical rate converges on the nominal rate.
        assert_close(a.len() as f64 / 200.0, p.nominal_rps(), 0.10);
    }
}

#[test]
fn trace_replay_preserves_per_tenant_submission_order() {
    use cmphx::load::Arrival;
    // A captured trace with interleaved tenants and a same-instant tie:
    // replay must sort globally by time while each tenant's own sequence
    // keeps its original relative order (stable sort).
    let trace = vec![
        Arrival { at_s: 2.0, tenant: TenantId(0), prompt: vec![10], max_tokens: 1 },
        Arrival { at_s: 1.0, tenant: TenantId(1), prompt: vec![20], max_tokens: 1 },
        Arrival { at_s: 2.0, tenant: TenantId(1), prompt: vec![21], max_tokens: 1 },
        Arrival { at_s: 0.5, tenant: TenantId(0), prompt: vec![11], max_tokens: 1 },
        Arrival { at_s: 2.0, tenant: TenantId(0), prompt: vec![12], max_tokens: 1 },
    ];
    let plan = ArrivalPlan::replay(trace);
    let times: Vec<f64> = plan.arrivals.iter().map(|a| a.at_s).collect();
    assert_eq!(times, vec![0.5, 1.0, 2.0, 2.0, 2.0]);
    let t0: Vec<i32> = plan
        .arrivals
        .iter()
        .filter(|a| a.tenant == TenantId(0))
        .map(|a| a.prompt[0])
        .collect();
    assert_eq!(t0, vec![11, 10, 12], "tenant 0's ties keep trace order");
    assert_eq!(plan.tenant_span(), 2);
}

/// End-to-end arm: the same open-loop plan against the real coordinator,
/// with a per-tenant SLO contract in the registry. Skips without the AOT
/// artifacts. Kept deliberately below the knee — the point here is that
/// the production path honors the contract wiring (SLO-stamped deadlines,
/// attainment metrics, submit-time admission), not the overload physics,
/// which the pure-model tests above pin at scale.
#[test]
fn live_server_serves_a_contracted_open_loop_plan() {
    use std::time::Duration;

    use cmphx::coordinator::batcher::BatchPolicy;
    use cmphx::coordinator::{Server, ServerConfig};
    use cmphx::load::drive;
    use cmphx::qos::TenantSpec;

    let Some(dir) = common::artifact_dir() else { return };
    let mut cfg = ServerConfig {
        queue_depth: 64,
        batch: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(10),
            ..BatchPolicy::default()
        },
        ..ServerConfig::default()
    };
    let mut gold = TenantSpec::new("gold", 2.0);
    gold.slo_ms = Some(30_000.0); // generous: this test is below the knee
    cfg.qos.tenants = vec![gold, TenantSpec::new("free", 1.0)];
    let server = Server::start(dir, cfg).expect("server start");
    let gold_id = server.tenant_id("gold").unwrap();

    let mut plan = ArrivalPlan::seeded(
        ArrivalProcess::Poisson { rps: 4.0 },
        SEED,
        4.0,
        &WorkloadShape { tenants: 2, ..shape() },
    );
    plan.arrivals.truncate(8);
    // The generator draws from a 32k vocab; fold into the tiny test
    // model's id space (family structure survives — the map is 1:1 on
    // the ids that actually occur far more often than not).
    for a in &mut plan.arrivals {
        for t in &mut a.prompt {
            *t = (*t % 500) + 1;
        }
    }
    let out = drive(&server, &plan, 0.05);
    assert_eq!(out.submit_rejected, 0, "below the knee nothing is refused at the door");
    assert_eq!(out.completed(), plan.len(), "every arrival completes within its contract");
    let gold_offered = plan.arrivals.iter().filter(|a| a.tenant == gold_id).count();
    let m = server.shutdown();
    assert_eq!(m.slo_eligible as usize, gold_offered, "only the contracted tenant is scored");
    assert_eq!(m.slo_met, m.slo_eligible, "a generous contract is met by everything served");
    assert_eq!(m.admission_sheds, 0);
}
