//! OpenCL-Benchmark port (ProjectPhysX).
//!
//! Unlike mixbench's intensity sweep, OpenCL-Benchmark launches dedicated
//! *peak-rate* kernels per precision: a huge grid of threads doing nothing
//! but chained math on register values, sized so memory traffic is
//! negligible. Its launch pressure is the best of the paper's tools — §3.2
//! and §3.4 both note its results land slightly above the CUDA tools.
//!
//! The paper's noFMA variant patches the kernel source with
//! `#pragma OPENCL FP_CONTRACT OFF` + an `fma()` macro override (Table 2-8);
//! here that's [`FmadPolicy::Decomposed`] through the same pass.

use crate::device::DeviceSpec;
use crate::isa::class::InstClass;
use crate::isa::ir::{Kernel, Stmt, Traffic};
use crate::isa::pass::{apply_fmad, FmadPolicy};
use crate::sim::{simulate_lowered, LoweredKernel, SimConfig};

use super::{Precision, ToolResult};

/// OpenCL-Benchmark's compute kernels sustain ~98% of peak issue (long
/// independent chains, no loop-carried dependence).
const OPENCL_ISSUE_EFF: f64 = 0.98;
/// Grid: 16M work-items × 512 chained ops each.
const ITEMS: u64 = 16 * 1024 * 1024;
const CHAIN: u64 = 512;
const BLOCK: u32 = 256;

fn sim_config() -> SimConfig {
    SimConfig {
        issue_efficiency: OPENCL_ISSUE_EFF,
        ..Default::default()
    }
}

fn fused_class(precision: Precision) -> InstClass {
    match precision {
        Precision::Fp32 => InstClass::Ffma,
        Precision::Fp16Half2 => InstClass::Hfma2,
        Precision::Fp16Scalar => InstClass::Hfma,
        Precision::Fp64 => InstClass::Dfma,
        Precision::Int32 => InstClass::Imad,
        Precision::Int8 => InstClass::Dp4a,
    }
}

/// The peak-rate kernel: one element in, CHAIN fused ops, one element out.
pub fn kernel(precision: Precision) -> Kernel {
    let class = fused_class(precision);
    let bytes = match precision {
        Precision::Fp16Half2 | Precision::Fp16Scalar => 2,
        Precision::Fp64 => 8,
        _ => 4,
    };
    Kernel::new(
        format!("openclbench.{}", precision.name()),
        ITEMS,
        BLOCK,
    )
    .with_body(vec![
        Stmt::op(InstClass::Ldg, 1),
        Stmt::looped(CHAIN, vec![Stmt::op(class, 1)]),
        Stmt::op(InstClass::Stg, 1),
    ])
    .with_traffic(Traffic::coalesced(ITEMS * bytes, ITEMS * bytes))
}

/// Lower the peak kernel for one precision at one fmad policy — reusable
/// across devices via [`crate::sim::simulate_lowered`] / [`crate::sim::batch`].
pub fn lowered(precision: Precision, policy: FmadPolicy) -> LoweredKernel {
    LoweredKernel::lower(&apply_fmad(&kernel(precision), policy))
}

/// Run the peak kernel for one precision at one fmad policy.
pub fn peak(dev: &DeviceSpec, precision: Precision, policy: FmadPolicy) -> ToolResult {
    ToolResult {
        tool: "opencl-benchmark",
        case: format!("{} {}", precision.name(), policy.name()),
        timing: simulate_lowered(&lowered(precision, policy), dev, &sim_config()),
    }
}

/// Convenience wrappers used throughout the crate and examples.
pub fn peak_fp32(dev: &DeviceSpec, policy: FmadPolicy) -> crate::sim::KernelTiming {
    peak(dev, Precision::Fp32, policy).timing
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibration as cal;
    use crate::device::registry;

    #[test]
    fn fp32_default_matches_graph_3_1() {
        let dev = registry::cmp170hx();
        let t = peak(&dev, Precision::Fp32, FmadPolicy::Fused).tflops();
        assert!(cal::check(&cal::FP32_DEFAULT_TFLOPS, t), "{t}");
    }

    #[test]
    fn fp32_nofma_matches_graph_3_1() {
        let dev = registry::cmp170hx();
        let t = peak(&dev, Precision::Fp32, FmadPolicy::Decomposed).tflops();
        assert!(cal::check(&cal::FP32_NOFMA_TFLOPS, t), "{t}");
    }

    #[test]
    fn fp16_half2_matches_graph_3_2() {
        let dev = registry::cmp170hx();
        let t = peak(&dev, Precision::Fp16Half2, FmadPolicy::Fused).tflops();
        assert!(cal::check(&cal::FP16_HALF2_TFLOPS, t), "{t}");
    }

    #[test]
    fn fp64_matches_graph_3_3_both_policies() {
        let dev = registry::cmp170hx();
        let def = peak(&dev, Precision::Fp64, FmadPolicy::Fused).tflops();
        let nofma = peak(&dev, Precision::Fp64, FmadPolicy::Decomposed).tflops();
        assert!(cal::check(&cal::FP64_DEFAULT_TFLOPS, def), "{def}");
        assert!(cal::check(&cal::FP64_NOFMA_TFLOPS, nofma), "{nofma}");
    }

    #[test]
    fn int32_matches_graph_3_4() {
        let dev = registry::cmp170hx();
        let t = peak(&dev, Precision::Int32, FmadPolicy::Fused).tiops();
        assert!(cal::check(&cal::INT32_OPENCL_TIOPS, t), "{t}");
    }

    #[test]
    fn int8_matches_graph_ex1() {
        let dev = registry::cmp170hx();
        let t = peak(&dev, Precision::Int8, FmadPolicy::Fused).tiops();
        assert!(cal::check(&cal::INT8_OPENCL_TIOPS, t), "{t}");
    }

    #[test]
    fn opencl_beats_cuda_mixbench_slightly() {
        // §3.2/§3.4: "OpenCL-based benchmarks show slightly higher
        // performance than CUDA-based ones".
        use crate::bench::mixbench;
        let dev = registry::cmp170hx();
        for precision in [Precision::Fp32, Precision::Int32] {
            let ocl = peak(&dev, precision, FmadPolicy::Decomposed);
            let cuda = mixbench::peak(&dev, precision, FmadPolicy::Decomposed);
            let (a, b) = if precision.integer() {
                (ocl.tiops(), cuda.tiops())
            } else {
                (ocl.tflops(), cuda.tflops())
            };
            assert!(a > b, "{}: opencl {a} vs cuda {b}", precision.name());
            assert!(a / b < 1.15, "gap should be slight: {a} vs {b}");
        }
    }

    #[test]
    fn a100_reference_peaks() {
        let dev = registry::a100_pcie();
        let t = peak(&dev, Precision::Fp32, FmadPolicy::Fused).timing;
        // DVFS-capped below the 19.5 ideal but must clear 15.
        assert!(t.tflops() > 15.0, "{}", t.tflops());
    }
}
