//! Per-round fleet time-series samples.
//!
//! Two shapes: a [`SeriesPoint`] per (node, round) — the worker snapshots
//! its queue depth, pager page tiers, host-pool occupancy, and simulated
//! power draw once per engine round — and a [`DispatchPoint`] per
//! dispatch-stage drain tick, carrying the WFQ tenant-deficit counters
//! and the router's outstanding-work snapshot. Both are stamped on
//! simulated/logical clocks only, exported as `series`/`dispatch` JSONL
//! lines and as Chrome counter tracks (`ph:"C"`), so "what was the fleet
//! doing at round R when the card died" has a recorded answer.

/// One node's gauges at one engine round.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SeriesPoint {
    pub node: usize,
    pub round: u64,
    /// The node's simulated clock at the sample, seconds.
    pub sim_s: f64,
    /// Requests waiting on the node's bounded work queue.
    pub queue_depth: usize,
    /// Sequences in the decode set.
    pub live_seqs: usize,
    /// This node's sequences in the shared park lot.
    pub parked_seqs: usize,
    /// KV blocks with live holders (the pinned tier).
    pub pinned_blocks: usize,
    /// Refcount-zero blocks retained by the radix tree.
    pub cached_blocks: usize,
    /// Truly-free blocks (allocatable without reclaim).
    pub free_blocks: usize,
    /// Fleet host-pool bytes in use (swap-parked sequences).
    pub host_pool_bytes: u64,
    /// Simulated draw this round, watts (0 when the card idled).
    pub watts: f64,
}

/// The dispatch stage's sample at one drain tick: fairness and routing
/// state that lives queue-side, not on any node.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DispatchPoint {
    /// The dispatch loop's drain counter (its logical clock).
    pub tick: u64,
    /// Requests waiting in the admission queue.
    pub queued: usize,
    /// Per-tenant DRR deficit counters, lane order (empty on the FIFO
    /// ablation arm).
    pub lane_deficits: Vec<f64>,
    /// Per-node outstanding work units from the router.
    pub outstanding: Vec<u64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_zeroed() {
        let p = SeriesPoint::default();
        assert_eq!(p.queue_depth, 0);
        assert_eq!(p.watts, 0.0);
        let d = DispatchPoint::default();
        assert!(d.lane_deficits.is_empty());
        assert!(d.outstanding.is_empty());
    }
}
