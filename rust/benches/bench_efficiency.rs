//! `cargo bench` target regenerating Graph 4-3 — decode tokens/s/W.
//!
//! Prints the figure table (measured vs paper where the paper reports a
//! number) and times the figure generation itself with the mini-criterion
//! harness (the sweep is the L3 hot path the §Perf pass optimizes).

use cmphx::bench_harness::time_fn;
use cmphx::report::figures;

fn main() {
    let table = figures::graph_4_3();
    print!("{}", table.render());
    if let Some(worst) = table.worst_deviation() {
        println!("worst deviation vs paper: {:+.1}%", worst * 100.0);
    }
    let stats = time_fn(1, 5, || {
        std::hint::black_box(figures::graph_4_3());
    });
    println!(
        "figure generation: mean {:.3} ms (σ {:.3} ms, {} samples)\n",
        stats.mean_s * 1e3,
        stats.stddev_s * 1e3,
        stats.samples
    );
}
