//! The fleet serving engine: a shared admission queue feeding N per-card
//! continuous-batching workers over paged KV.
//!
//! Life of a request: client → bounded queue → dispatch stage (the
//! [`Fleet`] router picks a card, failing over past dead workers) → that
//! node's worker joins the request into its decode round as soon as the
//! KV pager can hold its prefill window (vLLM-style continuous batching —
//! no stop-the-world batch windows), prefills it, and interleaves decode
//! steps per [`scheduler::plan_round_into`], growing the sequence's KV
//! pages block-by-block, until the sequence hits its target → reply on
//! the request's channel. When a round cannot allocate growth pages, the
//! engine preempts the longest-remaining sequence
//! ([`scheduler::plan_eviction`]): its KV is dropped and the request is
//! parked on the waiting queue, to resume later by recomputing prefill
//! and replaying its generated tokens (greedy decode is deterministic, so
//! the replay reconstructs the identical state). Failures are contained
//! per request; a dropped reply receiver is a cancellation.
//!
//! Every node owns its own [`ModelRuntime`], [`KvPager`] sized to its
//! card's VRAM, [`Metrics`], and a simulated device-time/energy overlay
//! calibrated per card (any mix of registry [`DeviceSpec`]s), so a
//! heterogeneous fleet — a 170HX next to a 90HX — reports fleet-wide
//! tokens/s and tokens/joule.

use std::collections::VecDeque;
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SendError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::device::{registry, DeviceSpec};
use crate::isa::pass::FmadPolicy;
use crate::llm::llamabench::{BenchResult, LlamaBench};
use crate::llm::model::ModelDesc;
use crate::llm::quant;
use crate::runtime::{ArtifactDir, DecodeState, ModelRuntime};

use super::batcher::BatchPolicy;
use super::kv::{KvPager, SeqKv};
use super::metrics::{FleetMetrics, Metrics};
use super::request::{GenRequest, GenResponse};
use super::router::{Fleet, Node, RoutePolicy};
use super::scheduler::{plan_admission, plan_eviction, plan_round_into, SeqView, StepPolicy};

/// One card of the serving fleet: the simulated device identity and the
/// fmad policy its deployment would run.
#[derive(Clone, Debug)]
pub struct NodeConfig {
    pub device: DeviceSpec,
    pub fmad: FmadPolicy,
}

impl NodeConfig {
    pub fn new(device: DeviceSpec, fmad: FmadPolicy) -> Self {
        NodeConfig { device, fmad }
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bound of **each** engine queue: the shared dispatch queue and every
    /// node's own queue (so a fleet buffers up to `(1 + nodes) ×
    /// queue_depth` requests, plus one in the dispatcher's hand, before
    /// `submit` sheds load).
    pub queue_depth: usize,
    /// Per-node admission policy (concurrency cap, cold-start gather, KV
    /// page size, preemption).
    pub batch: BatchPolicy,
    pub step_policy: StepPolicy,
    /// fmad policy of the default single-node deployment (and of nodes
    /// added via the CLI); explicit [`NodeConfig`]s carry their own.
    pub fmad: FmadPolicy,
    /// Dispatch-stage routing policy across the fleet.
    pub route: RoutePolicy,
    /// The fleet. Empty = one CMP 170HX (the single-card path, unchanged
    /// in behaviour and per-request results).
    pub nodes: Vec<NodeConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_depth: 64,
            batch: BatchPolicy::default(),
            step_policy: StepPolicy::RoundRobin,
            fmad: FmadPolicy::Decomposed,
            route: RoutePolicy::WeightedThroughput,
            nodes: Vec::new(),
        }
    }
}

/// Client handle: submit requests, read metrics, shut down.
pub struct ServerHandle {
    tx: Option<SyncSender<GenRequest>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    node_names: Vec<&'static str>,
    node_metrics: Vec<Arc<Mutex<Metrics>>>,
    next_id: std::sync::atomic::AtomicU64,
}

/// Simulated per-token device time and power for one node's overlay.
#[derive(Clone, Copy, Debug)]
struct Overlay {
    prefill_s_per_token: f64,
    decode_s_per_token: f64,
    /// Prefill is compute-saturated, so the DVFS governor pins the board at
    /// its envelope — [`crate::power::PowerModel::board_power`] clips
    /// saturated activity to TDP, which is what we charge per prefill
    /// second.
    prefill_w: f64,
    /// Decode power from the §4.4 calibrated residency model.
    decode_w: f64,
}

impl Overlay {
    /// Overlay for one node serving the paper's Qwen2.5-1.5B in q8_0 — the
    /// workload §6.2 recommends — from its calibrated bench row.
    fn from_row(row: &BenchResult, dev: &DeviceSpec) -> Overlay {
        Overlay {
            prefill_s_per_token: 1.0 / row.prefill_tps,
            decode_s_per_token: 1.0 / row.decode_tps,
            prefill_w: dev.tdp_w,
            decode_w: row.decode_power_w,
        }
    }
}

/// Reject artifact geometries the admission path cannot serve: a runtime
/// with `prefill_t > max_ctx` has no decode budget at all (and the old
/// `max_ctx - prefill_t` subtraction panicked on it at admit time).
pub(crate) fn validate_window(max_ctx: usize, prefill_t: usize) -> Result<()> {
    if prefill_t > max_ctx {
        anyhow::bail!("runtime window invalid: prefill_t {prefill_t} exceeds max_ctx {max_ctx}");
    }
    Ok(())
}

/// Decode-token budget left after the prefill window. Saturating, so even
/// a geometry that slipped past [`validate_window`] yields a clean
/// zero-budget rejection at admit time instead of a usize underflow panic.
pub(crate) fn admission_budget(max_ctx: usize, prefill_t: usize) -> usize {
    max_ctx.saturating_sub(prefill_t)
}

/// The serving engine.
pub struct Server;

impl Server {
    /// Start the fleet over an artifact directory: one runtime-owning
    /// worker per node plus the dispatch stage. Compilation happens on the
    /// worker threads; `start` returns once every node is live (or the
    /// first error is known).
    pub fn start(artifacts: ArtifactDir, config: ServerConfig) -> Result<ServerHandle> {
        let model = ModelDesc::qwen25_15b();
        let nodes: Vec<NodeConfig> = if config.nodes.is_empty() {
            vec![NodeConfig::new(registry::cmp170hx(), config.fmad)]
        } else {
            config.nodes.clone()
        };

        // One calibrated bench row per node: overlay rates, routing weight,
        // and decode power all come from a single batched sweep.
        let bench = LlamaBench { model, ..Default::default() };
        let cells: Vec<(DeviceSpec, FmadPolicy)> =
            nodes.iter().map(|n| (n.device.clone(), n.fmad)).collect();
        let rows = bench.run_nodes(&cells, &quant::Q8_0);

        let fleet = Arc::new(Mutex::new(Fleet::new(
            nodes
                .iter()
                .zip(&rows)
                .map(|(n, r)| Node {
                    name: n.device.name,
                    weight: r.decode_tps,
                    outstanding: 0,
                    assigned: 0,
                    healthy: true,
                })
                .collect(),
            config.route,
        )));

        let queue_depth = config.queue_depth.max(1);
        let weights_bytes = model.weight_bytes(&quant::Q8_0);
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(nodes.len());
        let mut worker_txs: Vec<SyncSender<GenRequest>> = Vec::with_capacity(nodes.len());
        let mut workers = Vec::with_capacity(nodes.len());
        let mut node_metrics = Vec::with_capacity(nodes.len());
        let node_names: Vec<&'static str> = nodes.iter().map(|n| n.device.name).collect();

        for (i, (node, row)) in nodes.iter().zip(&rows).enumerate() {
            let (wtx, wrx) = sync_channel::<GenRequest>(queue_depth);
            worker_txs.push(wtx);
            let metrics = Arc::new(Mutex::new(Metrics::new()));
            node_metrics.push(Arc::clone(&metrics));

            let overlay = Overlay::from_row(row, &node.device);
            let vram_bytes = node.device.mem.capacity_bytes;
            let artifacts = artifacts.clone();
            let ready = ready_tx.clone();
            let fleet = Arc::clone(&fleet);
            let policy = config.batch;
            let step_policy = config.step_policy;

            let worker = std::thread::Builder::new()
                .name(format!("cmphx-node{i}"))
                .spawn(move || {
                    let runtime = match ModelRuntime::load(&artifacts) {
                        Ok(rt) => rt,
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    // The window geometry is validated at startup so admit
                    // never sees an inverted (prefill_t > max_ctx) config.
                    if let Err(e) =
                        validate_window(runtime.config.max_ctx, runtime.config.prefill_t)
                    {
                        let _ = ready.send(Err(e));
                        return;
                    }
                    // Paged KV sized against this node's own VRAM: weights
                    // are pinned, everything else is carved into blocks of
                    // `kv_block_positions` token positions of the serving
                    // model (the binding 8 GB ceiling for the 170HX).
                    let mut pager = match KvPager::new(
                        policy.block_positions(),
                        model.kv_bytes_per_pos(),
                        vram_bytes,
                        weights_bytes,
                    ) {
                        Ok(p) => p,
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    if let Some(cap) = policy.kv_block_budget {
                        if let Err(e) = pager.limit_blocks(cap) {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    }
                    // The pool must hold at least one prefill window plus
                    // one decode position, or admission could never make
                    // progress and the engine would spin.
                    if pager.max_positions() < runtime.config.prefill_t + 1 {
                        let _ = ready.send(Err(anyhow::anyhow!(
                            "KV budget of {} blocks × {} positions cannot hold one \
                             prefill window ({} tokens) plus a decode step",
                            pager.capacity_blocks(),
                            pager.block_positions(),
                            runtime.config.prefill_t,
                        )));
                        return;
                    }
                    let _ = ready.send(Ok(()));
                    worker_loop(NodeWorker {
                        node: i,
                        runtime,
                        rx: wrx,
                        policy,
                        step_policy,
                        overlay,
                        pager,
                        metrics,
                        fleet,
                    });
                })?;
            workers.push(worker);
        }
        drop(ready_tx);
        for _ in 0..nodes.len() {
            ready_rx.recv()??;
        }

        // Dispatch stage: the Fleet's routing policy IS the fan-out.
        let (tx, rx) = sync_channel::<GenRequest>(queue_depth);
        let fleet_d = Arc::clone(&fleet);
        let metrics_d: Vec<Arc<Mutex<Metrics>>> =
            node_metrics.iter().map(Arc::clone).collect();
        let dispatcher = std::thread::Builder::new()
            .name("cmphx-dispatch".into())
            .spawn(move || {
                while let Ok(req) = rx.recv() {
                    dispatch(req, &fleet_d, &worker_txs, &metrics_d);
                }
                // Dropping worker_txs here closes every node queue; the
                // workers drain what was already routed, then exit.
            })?;

        Ok(ServerHandle {
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            workers,
            node_names,
            node_metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }
}

/// Route one request to a live worker, failing over past dead ones. A
/// failed send marks the node unhealthy — it stays excluded from routing
/// for the server's lifetime (the old behaviour left it in the fleet, so
/// the router kept feeding a dead card while healthy ones idled) — and the
/// request is rerouted to the next healthy node. Only when no healthy node
/// remains is the request failed.
fn dispatch(
    req: GenRequest,
    fleet: &Mutex<Fleet>,
    worker_txs: &[SyncSender<GenRequest>],
    metrics: &[Arc<Mutex<Metrics>>],
) {
    let mut req = req;
    loop {
        let idx = fleet.lock().unwrap().route();
        let Err(SendError(failed)) = worker_txs[idx].send(req) else {
            return;
        };
        let any_healthy = {
            let mut f = fleet.lock().unwrap();
            // the failed send never reached a worker: uncount it, then
            // exclude the dead node
            f.complete(idx);
            f.mark_unhealthy(idx);
            f.healthy_count() > 0
        };
        if any_healthy {
            req = failed;
            continue;
        }
        // Every worker is gone: fail the request instead of wedging.
        let queue_s = failed.enqueued.elapsed().as_secs_f64();
        metrics[idx].lock().unwrap().record_response(queue_s, 0, false);
        let _ = failed.reply.send(empty_response(
            failed.id,
            idx,
            queue_s,
            Some("node worker unavailable".into()),
        ));
        return;
    }
}

impl ServerHandle {
    /// Submit a generation request; returns the response receiver. Errors
    /// when `max_tokens` is zero (nothing to generate — the old path
    /// silently produced one token and counted it in throughput), when the
    /// queue is full (backpressure), or when the server is stopped.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_tokens: usize,
    ) -> Result<Receiver<GenResponse>> {
        if max_tokens == 0 {
            anyhow::bail!("max_tokens must be at least 1 (zero-token requests are rejected)");
        }
        let (reply, rx) = std::sync::mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req = GenRequest {
            id,
            prompt,
            max_tokens,
            reply,
            enqueued: Instant::now(),
        };
        let tx = self.tx.as_ref().ok_or_else(|| anyhow::anyhow!("server stopped"))?;
        match tx.try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => anyhow::bail!("queue full (backpressure)"),
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("server stopped"),
        }
    }

    /// Fleet-wide metrics snapshot (all nodes merged).
    pub fn metrics(&self) -> Metrics {
        self.fleet_metrics().total()
    }

    /// Per-node metrics snapshot.
    pub fn fleet_metrics(&self) -> FleetMetrics {
        FleetMetrics {
            nodes: self
                .node_names
                .iter()
                .zip(&self.node_metrics)
                .map(|(name, m)| (*name, m.lock().unwrap().clone()))
                .collect(),
        }
    }

    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Stop accepting requests, drain, and join the fleet.
    pub fn shutdown(mut self) -> Metrics {
        self.stop();
        self.metrics()
    }

    /// Like [`ServerHandle::shutdown`], keeping per-node attribution.
    pub fn shutdown_fleet(mut self) -> FleetMetrics {
        self.stop();
        self.fleet_metrics()
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Everything one node's continuous-batching loop owns.
struct NodeWorker {
    node: usize,
    runtime: ModelRuntime,
    rx: Receiver<GenRequest>,
    policy: BatchPolicy,
    step_policy: StepPolicy,
    overlay: Overlay,
    pager: KvPager,
    metrics: Arc<Mutex<Metrics>>,
    fleet: Arc<Mutex<Fleet>>,
}

/// One in-flight sequence.
struct Live {
    req: GenRequest,
    state: DecodeState,
    kv: SeqKv,
    tokens: Vec<i32>,
    queue_s: f64,
    prefill_s: f64,
    /// Wall decode seconds accumulated before the last (re)join — preempted
    /// stretches are summed here, the current stretch in `decode_started`.
    decode_s: f64,
    sim_s: f64,
    sim_j: f64,
    preemptions: u64,
    failed: Option<String>,
    decode_started: Instant,
}

impl Live {
    fn target(&self) -> usize {
        if self.failed.is_some() {
            self.tokens.len()
        } else {
            self.req.max_tokens
        }
    }

    fn done(&self) -> bool {
        self.tokens.len() >= self.target()
    }
}

/// A preempted sequence parked off-device: its KV pages are gone;
/// everything needed to recompute the state on resume rides along.
struct Preempted {
    req: GenRequest,
    tokens: Vec<i32>,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
    sim_s: f64,
    sim_j: f64,
    preemptions: u64,
    /// When the sequence was evicted — parked time is queueing time, and
    /// the client-observed latency must include it.
    parked_at: Instant,
}

impl Preempted {
    /// Accumulated queue seconds including the current parked stretch.
    fn queue_s_now(&self) -> f64 {
        self.queue_s + self.parked_at.elapsed().as_secs_f64()
    }
}

/// What happened when a parked sequence tried to re-enter decode.
enum Resumed {
    Joined,
    /// Not enough free pages right now — parked again, retry next round.
    NoPages(Preempted),
    /// Terminal failure (recompute failed, or the pool can never hold it);
    /// the request was answered.
    Failed,
}

fn worker_loop(mut w: NodeWorker) {
    let mut live: Vec<Live> = Vec::new();
    let mut waiting: VecDeque<Preempted> = VecDeque::new();
    // Round-planning buffers reused across the engine's lifetime: planning
    // a round allocates nothing after the first.
    let mut views: Vec<SeqView> = Vec::new();
    let mut plan: Vec<usize> = Vec::new();
    let mut stalled: Vec<usize> = Vec::new();
    let mut open = true;

    while open || !live.is_empty() || !waiting.is_empty() {
        let prefill_t = w.runtime.config.prefill_t;
        // --- admission (page-join): fill headroom, never stall decode.
        //     Preempted sequences resume before new arrivals join. ---
        let mut want = plan_admission(&w.policy, live.len(), w.pager.admissible(prefill_t));
        while want > 0 {
            let Some(parked) = waiting.pop_front() else { break };
            match resume(&mut w, parked, &mut live) {
                Resumed::Joined => want -= 1,
                Resumed::NoPages(parked) => {
                    if live.is_empty() {
                        // Nothing holds pages yet the resume cannot fit:
                        // the pool can never hold this sequence. Fail it
                        // terminally rather than spinning forever.
                        let queue_s = parked.queue_s_now();
                        reject(
                            &mut w,
                            &parked.req,
                            "KV pool cannot hold the resumed sequence".into(),
                            queue_s,
                        );
                    } else {
                        waiting.push_front(parked);
                        break;
                    }
                }
                Resumed::Failed => {}
            }
        }
        // A resume re-admits its full replay length — usually more pages
        // than the one prefill window `want` was budgeted on — so refresh
        // the headroom before admitting new arrivals. Without this, the
        // arrival loop pops a queued request into a terminal page-overload
        // reject that plan_admission exists to prevent.
        want = want.min(plan_admission(&w.policy, live.len(), w.pager.admissible(prefill_t)));
        if open && want > 0 {
            if live.is_empty() && waiting.is_empty() {
                // Idle engine: block for the first arrival, then gather up
                // to `max_wait` of company for the cold-start round.
                match w.rx.recv() {
                    Ok(req) => {
                        if admit(&mut w, req, &mut live) {
                            want -= 1;
                        }
                        let deadline = Instant::now() + w.policy.max_wait;
                        while want > 0 {
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            match w.rx.recv_timeout(deadline - now) {
                                Ok(req) => {
                                    if admit(&mut w, req, &mut live) {
                                        want -= 1;
                                    }
                                }
                                Err(RecvTimeoutError::Timeout) => break,
                                Err(RecvTimeoutError::Disconnected) => {
                                    open = false;
                                    break;
                                }
                            }
                        }
                    }
                    Err(_) => open = false,
                }
            } else {
                // Busy engine: non-blocking joins — the continuous part.
                while want > 0 {
                    match w.rx.try_recv() {
                        Ok(req) => {
                            if admit(&mut w, req, &mut live) {
                                want -= 1;
                            }
                        }
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
        }
        if live.is_empty() {
            continue;
        }

        // Sequences already done (a max_tokens == 1 request is complete
        // straight out of prefill) retire *before* pressure resolution —
        // their pages must not inflate the shortfall and preempt or fail
        // a peer that would fit once they free.
        retire_done(&mut w, &mut live);
        if live.is_empty() {
            continue;
        }

        // --- plan one decode round, resolving KV page pressure: every
        //     planned sequence must own the page its next token writes
        //     before any device work happens ---
        loop {
            views.clear();
            views.extend(live.iter().enumerate().map(|(i, l)| SeqView {
                seq: i,
                generated: l.tokens.len(),
                target: l.target(),
            }));
            plan_round_into(w.step_policy, &views, &mut plan);
            if plan.is_empty() {
                break;
            }
            stalled.clear();
            for &idx in &plan {
                let l = &live[idx];
                let grown = w
                    .pager
                    .grow(l.kv, l.state.pos + 1)
                    .expect("live sequences hold valid KV handles");
                if !grown {
                    stalled.push(idx);
                }
            }
            if stalled.is_empty() {
                break;
            }
            // Page pressure. The victim is the longest-remaining sequence
            // — evicting the work furthest from completion frees the most
            // future page demand and never throws away a nearly-done
            // sequence.
            let victim = plan_eviction(&views).expect("non-empty plan has an active seq");
            if w.policy.preempt && live.len() > 1 {
                let evicted = live.swap_remove(victim);
                preempt(&mut w, evicted, &mut waiting);
                continue; // replan against the freed pages
            }
            if stalled.len() == plan.len() {
                // Nothing can advance and no retirement will ever free a
                // page (preemption disabled, or this is the last
                // sequence): fail the victim to restore liveness.
                let mut evicted = live.swap_remove(victim);
                evicted.failed = Some(format!(
                    "KV pages exhausted ({} of {} blocks free) and preemption {}",
                    w.pager.free_blocks(),
                    w.pager.capacity_blocks(),
                    if w.policy.preempt {
                        "cannot help (no other sequence to evict)"
                    } else {
                        "is disabled"
                    },
                ));
                retire(&mut w, evicted);
                continue;
            }
            // Partial pressure without preemption: the stalled sequences
            // sit this round out (they retry when a peer retires and frees
            // pages); everyone else steps.
            plan.retain(|idx| !stalled.contains(idx));
            break;
        }

        // --- one decode round across the planned set ---
        if !plan.is_empty() {
            w.metrics.lock().unwrap().record_batch(plan.len());
            for &idx in &plan {
                let l = &mut live[idx];
                let token = *l.tokens.last().unwrap();
                match w.runtime.decode(&mut l.state, token) {
                    Ok(()) => {
                        l.tokens.push(l.state.argmax());
                        l.sim_s += w.overlay.decode_s_per_token;
                        l.sim_j += w.overlay.decode_s_per_token * w.overlay.decode_w;
                    }
                    Err(e) => l.failed = Some(format!("decode failed: {e}")),
                }
            }
        }

        // --- retire finished sequences; their pages free for the next
        //     round's admissions and resumes ---
        retire_done(&mut w, &mut live);
    }
}

/// Retire every done sequence in the live set; their pages free
/// immediately for admissions, resumes, and peers' growth.
fn retire_done(w: &mut NodeWorker, live: &mut Vec<Live>) {
    let mut i = 0;
    while i < live.len() {
        if !live[i].done() {
            i += 1;
            continue;
        }
        let l = live.swap_remove(i);
        retire(w, l);
    }
}

/// Admit one routed request: window checks, KV pages for the prefill
/// window, prefill. Returns true when the request joined the in-flight
/// set.
fn admit(w: &mut NodeWorker, req: GenRequest, live: &mut Vec<Live>) -> bool {
    let cfg = w.runtime.config;
    let queue_s = req.enqueued.elapsed().as_secs_f64();
    if req.max_tokens == 0 {
        // submit() rejects these at the API; a zero-token request built by
        // any other path is answered as an empty success without touching
        // decode (and without polluting throughput metrics with a token).
        w.metrics.lock().unwrap().record_response(queue_s, 0, true);
        w.fleet.lock().unwrap().complete(w.node);
        let _ = req.reply.send(empty_response(req.id, w.node, queue_s, None));
        return false;
    }
    let budget = admission_budget(cfg.max_ctx, cfg.prefill_t);
    if req.prompt.len() > cfg.prefill_t || req.max_tokens > budget {
        let msg = format!(
            "request exceeds window (prompt {} > {} or tokens {} > {})",
            req.prompt.len(),
            cfg.prefill_t,
            req.max_tokens,
            budget
        );
        reject(w, &req, msg, queue_s);
        return false;
    }
    // The sequence must fit this card's page pool even running alone, or
    // admission would loop forever growing toward pages that don't exist.
    let final_positions = cfg.prefill_t + req.max_tokens - 1;
    if w.pager.blocks_for(final_positions) > w.pager.capacity_blocks() {
        let msg = format!(
            "request needs {} KV blocks at full length but the card has {}",
            w.pager.blocks_for(final_positions),
            w.pager.capacity_blocks()
        );
        reject(w, &req, msg, queue_s);
        return false;
    }
    let Some(kv) = w.pager.admit(cfg.prefill_t) else {
        reject(w, &req, "no KV pages (overload)".into(), queue_s);
        return false;
    };
    let t0 = Instant::now();
    match w.runtime.prefill_padded(&req.prompt) {
        Ok(state) => {
            let prefill_s = t0.elapsed().as_secs_f64();
            let sim_s = w.overlay.prefill_s_per_token * cfg.prefill_t as f64;
            let sim_j = sim_s * w.overlay.prefill_w;
            let first = state.argmax();
            live.push(Live {
                req,
                state,
                kv,
                tokens: vec![first],
                queue_s,
                prefill_s,
                decode_s: 0.0,
                sim_s,
                sim_j,
                preemptions: 0,
                failed: None,
                decode_started: Instant::now(),
            });
            true
        }
        Err(e) => {
            w.pager.release(kv).expect("releasing the just-admitted pages");
            reject(w, &req, format!("prefill failed: {e}"), queue_s);
            false
        }
    }
}

/// Evict one in-flight sequence under page pressure: drop its KV, park the
/// request on the waiting queue. Resume recomputes prefill and replays the
/// tokens generated so far — greedy decode is deterministic, so the replay
/// reconstructs the identical state (vLLM's recompute-on-resume).
fn preempt(w: &mut NodeWorker, l: Live, waiting: &mut VecDeque<Preempted>) {
    w.pager.release(l.kv).expect("page accounting");
    w.metrics.lock().unwrap().preemptions += 1;
    waiting.push_back(Preempted {
        decode_s: l.decode_s + l.decode_started.elapsed().as_secs_f64(),
        req: l.req,
        tokens: l.tokens,
        queue_s: l.queue_s,
        prefill_s: l.prefill_s,
        sim_s: l.sim_s,
        sim_j: l.sim_j,
        preemptions: l.preemptions + 1,
        parked_at: Instant::now(),
    });
}

/// Re-enter a preempted sequence: re-admit its pages (the full replay
/// length up front, so the resume cannot itself be preempted mid-replay),
/// recompute prefill, replay the generated tokens, rejoin the live set.
fn resume(w: &mut NodeWorker, p: Preempted, live: &mut Vec<Live>) -> Resumed {
    let cfg = w.runtime.config;
    let Some(kv) = w.pager.admit(cfg.prefill_t) else {
        return Resumed::NoPages(p);
    };
    let resume_positions = cfg.prefill_t + p.tokens.len().saturating_sub(1);
    if !w.pager.grow(kv, resume_positions).expect("just-admitted KV handle") {
        w.pager.release(kv).expect("releasing the just-admitted pages");
        return Resumed::NoPages(p);
    }
    // The parked stretch ends here: from now on the request is either
    // recomputing (prefill/decode wall time) or terminally answered.
    let queue_s = p.queue_s_now();
    let t0 = Instant::now();
    let mut state = match w.runtime.prefill_padded(&p.req.prompt) {
        Ok(s) => s,
        Err(e) => {
            w.pager.release(kv).expect("page accounting");
            reject(w, &p.req, format!("resume prefill failed: {e}"), queue_s);
            return Resumed::Failed;
        }
    };
    for &tok in p.tokens.iter().take(p.tokens.len() - 1) {
        if let Err(e) = w.runtime.decode(&mut state, tok) {
            w.pager.release(kv).expect("page accounting");
            reject(w, &p.req, format!("resume replay failed: {e}"), queue_s);
            return Resumed::Failed;
        }
    }
    let recompute_wall_s = t0.elapsed().as_secs_f64();
    // Simulated cost of the recompute — all of it wasted work, bought by
    // the headroom the earlier eviction created.
    let replay_steps = (p.tokens.len() - 1) as f64;
    let wasted_s = w.overlay.prefill_s_per_token * cfg.prefill_t as f64
        + w.overlay.decode_s_per_token * replay_steps;
    let wasted_j = w.overlay.prefill_s_per_token * cfg.prefill_t as f64 * w.overlay.prefill_w
        + w.overlay.decode_s_per_token * replay_steps * w.overlay.decode_w;
    {
        let mut m = w.metrics.lock().unwrap();
        m.resumes += 1;
        m.wasted_prefill_s += wasted_s;
    }
    live.push(Live {
        req: p.req,
        state,
        kv,
        tokens: p.tokens,
        queue_s,
        prefill_s: p.prefill_s + recompute_wall_s,
        decode_s: p.decode_s,
        sim_s: p.sim_s + wasted_s,
        sim_j: p.sim_j + wasted_j,
        preemptions: p.preemptions,
        failed: None,
        decode_started: Instant::now(),
    });
    Resumed::Joined
}

/// Retire one finished (or failed) sequence: release its pages, account
/// metrics, tell the router, reply.
fn retire(w: &mut NodeWorker, l: Live) {
    w.pager.release(l.kv).expect("page accounting");
    let decode_s = l.decode_s + l.decode_started.elapsed().as_secs_f64();
    let ok = l.failed.is_none();
    let resp = GenResponse {
        id: l.req.id,
        tokens: l.tokens,
        error: l.failed,
        queue_s: l.queue_s,
        prefill_s: l.prefill_s,
        decode_s,
        simulated_device_s: l.sim_s,
        preemptions: l.preemptions,
        node: w.node,
    };
    {
        let mut m = w.metrics.lock().unwrap();
        m.wall_prefill_s += l.prefill_s;
        m.wall_decode_s += decode_s;
        m.simulated_device_s += l.sim_s;
        m.simulated_energy_j += l.sim_j;
        m.record_response(resp.latency_s(), resp.tokens.len(), ok);
    }
    w.fleet.lock().unwrap().complete(w.node);
    // dropped receiver = cancelled; ignore send failure
    let _ = l.req.reply.send(resp);
}

/// Reply with a terminal error for a request that holds no pages.
fn reject(w: &mut NodeWorker, req: &GenRequest, error: String, queue_s: f64) {
    w.metrics.lock().unwrap().record_response(queue_s, 0, false);
    w.fleet.lock().unwrap().complete(w.node);
    let _ = req.reply.send(empty_response(req.id, w.node, queue_s, Some(error)));
}

/// A terminal no-tokens reply (a rejection, or a zero-token empty
/// success) — the one place the "nothing was generated" response shape
/// lives.
fn empty_response(id: u64, node: usize, queue_s: f64, error: Option<String>) -> GenResponse {
    GenResponse {
        id,
        tokens: vec![],
        error,
        queue_s,
        prefill_s: 0.0,
        decode_s: 0.0,
        simulated_device_s: 0.0,
        preemptions: 0,
        node,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stub_handle(tx: SyncSender<GenRequest>) -> ServerHandle {
        ServerHandle {
            tx: Some(tx),
            dispatcher: None,
            workers: Vec::new(),
            node_names: vec!["stub"],
            node_metrics: vec![Arc::new(Mutex::new(Metrics::new()))],
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    fn dummy_request(id: u64) -> (GenRequest, Receiver<GenResponse>) {
        let (reply, rx) = std::sync::mpsc::channel();
        let req = GenRequest {
            id,
            prompt: vec![1, 2, 3],
            max_tokens: 2,
            reply,
            enqueued: Instant::now(),
        };
        (req, rx)
    }

    #[test]
    fn zero_token_requests_are_rejected_at_submit() {
        // Regression: `max_tokens == 0` used to be floored to one token in
        // the decode loop, silently generating output and counting it in
        // throughput metrics.
        let (tx, rx) = sync_channel::<GenRequest>(4);
        let handle = stub_handle(tx);
        let err = handle.submit(vec![1, 2], 0).unwrap_err().to_string();
        assert!(err.contains("max_tokens"), "{err}");
        assert!(rx.try_recv().is_err(), "nothing may reach the queue");
        // a normal request still flows
        let _reply = handle.submit(vec![1, 2], 3).unwrap();
        assert_eq!(rx.try_recv().unwrap().max_tokens, 3);
    }

    #[test]
    fn window_validation_rejects_inverted_geometry() {
        assert!(validate_window(64, 16).is_ok());
        assert!(validate_window(64, 64).is_ok());
        let err = validate_window(16, 64).unwrap_err().to_string();
        assert!(err.contains("prefill_t"), "{err}");
    }

    #[test]
    fn admission_budget_saturates_instead_of_panicking() {
        assert_eq!(admission_budget(64, 16), 48);
        // Regression: the old `max_ctx - prefill_t` underflowed (panicked)
        // on a runtime configured with prefill_t > max_ctx.
        assert_eq!(admission_budget(16, 64), 0);
        assert_eq!(admission_budget(64, 64), 0);
    }

    #[test]
    fn dispatch_reroutes_off_dead_workers_and_excludes_them() {
        // Node 0's worker is torn down (its queue receiver dropped);
        // node 1 is alive.
        let fleet = Mutex::new(Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin));
        let (tx0, rx0) = sync_channel::<GenRequest>(8);
        let (tx1, rx1) = sync_channel::<GenRequest>(8);
        drop(rx0);
        let txs = vec![tx0, tx1];
        let metrics = vec![
            Arc::new(Mutex::new(Metrics::new())),
            Arc::new(Mutex::new(Metrics::new())),
        ];
        // Round-robin picks node 0 first; the failed send must mark it
        // unhealthy and reroute the same request to node 1 (regression:
        // the request was failed and the dead node kept taking traffic).
        let (req, reply) = dummy_request(1);
        dispatch(req, &fleet, &txs, &metrics);
        assert_eq!(rx1.try_recv().unwrap().id, 1, "request must be rerouted");
        assert!(reply.try_recv().is_err(), "request must not be failed");
        {
            let f = fleet.lock().unwrap();
            assert_eq!(f.healthy_count(), 1);
            assert_eq!(f.nodes[0].outstanding, 0, "failed send must be uncounted");
            assert_eq!(f.nodes[1].outstanding, 1);
        }
        // The dead node stays excluded: every later request lands on the
        // healthy card while it idles — no more routing to the dead one.
        let mut replies = Vec::new();
        for id in 2..6 {
            let (req, reply) = dummy_request(id);
            dispatch(req, &fleet, &txs, &metrics);
            replies.push(reply);
        }
        let got: Vec<u64> = rx1.try_iter().map(|r| r.id).collect();
        assert_eq!(got, vec![2, 3, 4, 5]);
        assert_eq!(fleet.lock().unwrap().nodes[0].assigned, 1);
        assert!(replies.iter().all(|r| r.try_recv().is_err()));
    }

    #[test]
    fn dispatch_fails_the_request_only_when_no_healthy_node_remains() {
        let fleet = Mutex::new(Fleet::uniform(1, 1.0, RoutePolicy::RoundRobin));
        let (tx0, rx0) = sync_channel::<GenRequest>(1);
        drop(rx0);
        let metrics = vec![Arc::new(Mutex::new(Metrics::new()))];
        let (req, reply) = dummy_request(9);
        dispatch(req, &fleet, &[tx0], &metrics);
        let resp = reply.try_recv().unwrap();
        assert!(!resp.ok());
        assert!(resp.error.as_deref().unwrap().contains("unavailable"));
        assert_eq!(fleet.lock().unwrap().healthy_count(), 0);
        assert_eq!(metrics[0].lock().unwrap().errors, 1);
    }
}
