//! Launch geometry / wave quantization.
//!
//! Small grids underutilize the SM array: a launch of `blocks` thread blocks
//! executes in ⌈blocks / (SMs × blocks_per_sm)⌉ waves, and the last wave may
//! run partially empty. The paper's CUDA-vs-OpenCL gaps (Graphs 3-1/3-4:
//! "mixbench's 1024 compute iters … may not fully stress the GPU") are
//! modeled via the tools' launch pressure feeding this quantization.

/// Occupancy description of one launch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Occupancy {
    pub blocks: u64,
    pub blocks_per_sm: u32,
    pub sms: u32,
}

impl Occupancy {
    pub fn new(blocks: u64, block_threads: u32, sms: u32, max_threads_per_sm: u32) -> Self {
        let blocks_per_sm = (max_threads_per_sm / block_threads.max(1)).max(1);
        Occupancy {
            blocks,
            blocks_per_sm,
            sms,
        }
    }

    /// Concurrent blocks the device can hold.
    pub fn concurrent_blocks(&self) -> u64 {
        self.sms as u64 * self.blocks_per_sm as u64
    }

    /// Full + partial waves for this launch.
    pub fn waves(&self) -> u64 {
        self.blocks.div_ceil(self.concurrent_blocks().max(1))
    }

    /// Utilization of the last wave (1.0 when the grid tiles evenly).
    pub fn tail_utilization(&self) -> f64 {
        let cap = self.concurrent_blocks().max(1);
        let rem = self.blocks % cap;
        if rem == 0 {
            1.0
        } else {
            rem as f64 / cap as f64
        }
    }

    /// Effective slowdown factor from wave quantization: ideal time assumes
    /// perfect spreading; real time is `waves` quantized. For large grids
    /// this tends to 1.
    pub fn quantization_factor(&self) -> f64 {
        if self.blocks == 0 {
            return 1.0;
        }
        let ideal_waves = self.blocks as f64 / self.concurrent_blocks() as f64;
        self.waves() as f64 / ideal_waves.max(f64::MIN_POSITIVE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, forall, Rng};

    #[test]
    fn exact_tiling_has_no_penalty() {
        // 70 SMs × 8 blocks/SM = 560 concurrent; 1120 blocks = 2 full waves.
        let o = Occupancy::new(1120, 256, 70, 2048);
        assert_eq!(o.waves(), 2);
        assert_close(o.quantization_factor(), 1.0, 1e-12);
        assert_close(o.tail_utilization(), 1.0, 1e-12);
    }

    #[test]
    fn single_block_wastes_the_device() {
        let o = Occupancy::new(1, 256, 70, 2048);
        assert_eq!(o.waves(), 1);
        assert!(o.quantization_factor() > 500.0);
    }

    #[test]
    fn tail_wave_partial_utilization() {
        let o = Occupancy::new(561, 256, 70, 2048);
        assert_eq!(o.waves(), 2);
        assert!(o.tail_utilization() < 0.01);
    }

    #[test]
    fn prop_quantization_at_least_one_and_shrinks_with_scale() {
        forall(0x0CC, 300, |rng: &mut Rng| {
            let sms = rng.range(1, 128) as u32;
            let blocks = rng.range(1, 1 << 20);
            let o = Occupancy::new(blocks, 256, sms, 2048);
            let q = o.quantization_factor();
            assert!(q >= 1.0 - 1e-9, "quantization can only slow down: {q}");
            // 64× more blocks → factor no worse.
            let o2 = Occupancy::new(blocks * 64, 256, sms, 2048);
            assert!(o2.quantization_factor() <= q + 1e-9);
        });
    }
}
