"""AOT artifact tests: the HLO text round-trips and the goldens are
reproducible.

Loading back through the same xla_client the Rust side wraps
(HloModule text → parse → compile on the CPU PJRT client) is exercised on
the Rust side in rust/tests/; here we check the emission contract.
"""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build_artifacts(str(out), seed=0)
    return str(out), manifest


def test_manifest_lists_all_entries(artifacts):
    out, manifest = artifacts
    expected = {"prefill", "decode", "mixbench_fused", "mixbench_nofma", "qmatmul"}
    assert set(manifest["entries"]) == expected
    for e in manifest["entries"].values():
        assert os.path.exists(os.path.join(out, e["file"]))
        assert e["bytes"] > 1000


def test_hlo_text_is_parseable_hlo(artifacts):
    out, manifest = artifacts
    for name, e in manifest["entries"].items():
        with open(os.path.join(out, e["file"])) as f:
            head = f.read(4096)
        assert head.startswith("HloModule"), name
        assert "ENTRY" in head or "entry_computation_layout" in head


def test_no_large_constant_elision(artifacts):
    # The model weights are baked into prefill/decode: the `{...}` marker
    # would mean the text cannot round-trip.
    out, _ = artifacts
    for name in ("prefill", "decode"):
        with open(os.path.join(out, f"{name}.hlo.txt")) as f:
            assert "{...}" not in f.read(), name


def test_goldens_are_reproducible(artifacts, tmp_path):
    out, _ = artifacts
    with open(os.path.join(out, "goldens.json")) as f:
        g1 = json.load(f)
    out2 = tmp_path / "again"
    aot.build_artifacts(str(out2), seed=0)
    with open(out2 / "goldens.json") as f:
        g2 = json.load(f)
    assert g1["greedy_tokens"] == g2["greedy_tokens"]
    assert g1["prefill_last_logits"] == g2["prefill_last_logits"]
    assert g1["mixbench"]["fused_head"] == g2["mixbench"]["fused_head"]


def test_goldens_expose_the_fmad_divergence(artifacts):
    out, _ = artifacts
    with open(os.path.join(out, "goldens.json")) as f:
        g = json.load(f)
    # fused and decomposed mixbench outputs genuinely differ (the golden
    # inputs sit in the chaotic regime, which amplifies the single rounding
    # difference)...
    assert g["mixbench"]["max_divergence"] > 0.0
    # ...but both stay on the bounded attractor of t ← t² + y.
    assert g["mixbench"]["max_divergence"] < 4.0


def test_different_seed_changes_weights(tmp_path):
    a = aot.build_artifacts(str(tmp_path / "a"), seed=0)
    b = aot.build_artifacts(str(tmp_path / "b"), seed=1)
    ga = json.load(open(tmp_path / "a" / "goldens.json"))
    gb = json.load(open(tmp_path / "b" / "goldens.json"))
    assert ga["prefill_last_logits"] != gb["prefill_last_logits"]
    assert a["entries"].keys() == b["entries"].keys()
