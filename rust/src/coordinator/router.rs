//! Fleet router: spread requests across multiple (simulated) cards.
//!
//! §6.2 imagines community edge nodes built from recycled CMP cards; a
//! node with several cards needs a router. Policies:
//! - [`RoutePolicy::RoundRobin`] — classic;
//! - [`RoutePolicy::LeastLoaded`] — by outstanding work;
//! - [`RoutePolicy::WeightedThroughput`] — by each card's decode tokens/s
//!   (heterogeneous fleets: a 170HX next to a 90HX).
//!
//! Health is more than a bool: a node readmitted by
//! [`Fleet::mark_healthy`] can be put on **probation** (see
//! [`Fleet::set_probation_rounds`]) — it serves probe requests one at a
//! time until it has passed the configured number, and a failure during
//! probation re-quarantines it. That stops a flapping salvage card from
//! oscillating in and out of full routing. The router also keeps the
//! MTTR ledger: per-node downtime from the moment a card left routing to
//! the moment it was fully trusted again.

use std::time::Instant;

use crate::device::DeviceSpec;
use crate::isa::pass::FmadPolicy;
use crate::llm::llamabench::LlamaBench;
use crate::llm::quant::QuantFormat;

/// One routed card.
#[derive(Clone, Debug)]
pub struct Node {
    pub name: &'static str,
    /// Decode throughput weight (tokens/s on the serving quant).
    pub weight: f64,
    /// Outstanding queued work units.
    pub outstanding: u64,
    /// Cumulative assigned requests.
    pub assigned: u64,
    /// Routable. The dispatch stage clears this when the node's worker is
    /// gone (its queue rejected a send), excluding it from future routing
    /// — the old behaviour kept selecting the dead card forever while
    /// healthy ones idled.
    pub healthy: bool,
    /// Probe serves still owed before this node is fully trusted. While
    /// nonzero the node is routable only when idle (one probe in flight
    /// at a time); a failed probe re-quarantines it.
    pub probation: u64,
    /// When the current incident started (the node left full routing).
    pub down_since: Option<Instant>,
    /// Total downtime over *closed* incidents, seconds — the MTTR
    /// numerator. An incident closes when the node is fully trusted
    /// again (probation passed, or immediate readmission).
    pub downtime_s: f64,
    /// Closed incidents — the MTTR denominator.
    pub recoveries: u64,
    /// Times this node left routing (deaths + operator drains).
    pub faults: u64,
}

impl Node {
    pub fn new(name: &'static str, weight: f64) -> Self {
        Node {
            name,
            weight,
            outstanding: 0,
            assigned: 0,
            healthy: true,
            probation: 0,
            down_since: None,
            downtime_s: 0.0,
            recoveries: 0,
            faults: 0,
        }
    }

    /// Fully trusted: healthy with no probation owed.
    pub fn trusted(&self) -> bool {
        self.healthy && self.probation == 0
    }
}

/// Routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    WeightedThroughput,
}

/// A fleet of cards plus a routing cursor.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub nodes: Vec<Node>,
    policy: RoutePolicy,
    cursor: usize,
    /// Probe serves a readmitted node owes before full trust. `0` (the
    /// default) preserves the legacy immediate readmission.
    probation_rounds: u64,
    /// Peak multiplier a full prefix match applies to a node's
    /// effective throughput in [`Fleet::route_affine`]. The default 2.0
    /// reproduces the PR 7 fixed bonus; values ≤ 1.0 disable the depth
    /// term entirely (plain policy).
    affinity_bonus: f64,
}

impl Fleet {
    /// Build a fleet directly from pre-weighted nodes — the serving engine
    /// computes weights once from its per-node calibrated bench rows and
    /// hands them over, making the router the actual dispatch stage rather
    /// than a standalone index-picker.
    pub fn new(nodes: Vec<Node>, policy: RoutePolicy) -> Self {
        Fleet {
            nodes,
            policy,
            cursor: 0,
            probation_rounds: 0,
            affinity_bonus: 2.0,
        }
    }

    /// Build a fleet from device specs, weighting by simulated decode
    /// throughput on `quant` at `policy`'s fmad setting. The weighting
    /// kernels are lowered once and swept across the whole fleet as one
    /// batched [`crate::sim::batch`] run — fleet size no longer multiplies
    /// IR walks.
    pub fn from_devices(
        devices: &[DeviceSpec],
        quant: &QuantFormat,
        fmad: FmadPolicy,
        policy: RoutePolicy,
    ) -> Self {
        let bench = LlamaBench::default();
        let nodes = devices
            .iter()
            .zip(bench.run_across(devices, quant, fmad))
            .map(|(d, r)| Node::new(d.name, r.decode_tps))
            .collect();
        Fleet::new(nodes, policy)
    }

    /// Uniform fleet of `n` identical nodes (tests/benches).
    pub fn uniform(n: usize, weight: f64, policy: RoutePolicy) -> Self {
        Fleet::new((0..n).map(|_| Node::new("node", weight)).collect(), policy)
    }

    /// Arm quarantine/probation: a node readmitted by
    /// [`Fleet::mark_healthy`] must pass this many probe serves (one at a
    /// time) before it is fully trusted again.
    pub fn set_probation_rounds(&mut self, rounds: u64) {
        self.probation_rounds = rounds;
    }

    /// Route one request; returns the node index. Unhealthy nodes are
    /// skipped while at least one healthy node remains; a fully-unhealthy
    /// fleet degrades to routing across all nodes (standalone callers keep
    /// working — the dispatch stage checks [`Fleet::healthy_count`] itself
    /// and fails requests instead of sending them to the dead).
    pub fn route(&mut self) -> usize {
        assert!(!self.nodes.is_empty(), "empty fleet");
        // Trust ladder: prefer nodes that are trusted or idle-on-probation
        // (a probation node takes one probe at a time); fall back to any
        // healthy node (every survivor is a busy probationer); a fully
        // unhealthy fleet degrades to all nodes.
        let probing = |n: &Node| n.healthy && (n.probation == 0 || n.outstanding == 0);
        let tier = if self.nodes.iter().any(probing) {
            0
        } else if self.healthy_count() > 0 {
            1
        } else {
            2
        };
        let eligible = move |n: &Node| match tier {
            0 => n.healthy && (n.probation == 0 || n.outstanding == 0),
            1 => n.healthy,
            _ => true,
        };
        let idx = match self.policy {
            RoutePolicy::RoundRobin => loop {
                let i = self.cursor % self.nodes.len();
                self.cursor += 1;
                if eligible(&self.nodes[i]) {
                    break i;
                }
            },
            RoutePolicy::LeastLoaded => self
                .nodes
                .iter()
                .enumerate()
                .filter(|&(_, n)| eligible(n))
                .min_by_key(|(_, n)| n.outstanding)
                .map(|(i, _)| i)
                .unwrap(),
            RoutePolicy::WeightedThroughput => {
                // pick the node with the lowest normalized load
                // (outstanding / weight) — deterministic weighted fairness.
                self.nodes
                    .iter()
                    .enumerate()
                    .filter(|&(_, n)| eligible(n))
                    .min_by(|(_, a), (_, b)| {
                        let la = (a.outstanding as f64 + 1.0) / a.weight.max(1e-9);
                        let lb = (b.outstanding as f64 + 1.0) / b.weight.max(1e-9);
                        la.partial_cmp(&lb).unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap()
            }
        };
        self.nodes[idx].outstanding += 1;
        self.nodes[idx].assigned += 1;
        idx
    }

    /// Set the peak affinity multiplier ([`Fleet::route_affine`]'s
    /// `--affinity-bonus`). A full prefix match scales a node's
    /// effective throughput by this factor; partial matches interpolate
    /// linearly. Values ≤ 1.0 degrade `route_affine` to the plain
    /// policy (the bonus term becomes constant, so the depth signal
    /// carries zero weight — the knob's own ablation arm).
    pub fn set_affinity_bonus(&mut self, bonus: f64) {
        self.affinity_bonus = bonus;
    }

    /// Route one request with **prefix affinity**: `depths[i]` is node
    /// i's matched-prefix depth for this prompt (blocks of the prompt's
    /// chain already resident there — pinned or warm-but-idle cached,
    /// per the fleet [`crate::coordinator::kv::PrefixDirectory`]).
    /// Eligibility walks the same trust ladder as [`Fleet::route`];
    /// among eligible nodes the pick maximizes `(1 + (bonus − 1) ·
    /// depth/best_depth) · weight / (outstanding + 1)` — the depth term
    /// is normalized against the best match in the fleet, so a full
    /// prefix hit scales a node's effective throughput by at most the
    /// configured [`Fleet::set_affinity_bonus`] (default 2×). Bounding
    /// the bonus is what keeps the fleet balanced: with raw depths a
    /// warm node's score dwarfs the load term and every shared-prefix
    /// arrival piles onto the first card that served one, while the
    /// bounded form lets distinct prompt families spread out and then
    /// stick to their holders. With no depth anywhere — or a bonus ≤
    /// 1.0 — `route()` is called instead, preserving non-affine
    /// policies verbatim (the `--no-affinity` ablation and prefix-less
    /// traffic take the identical path).
    pub fn route_affine(&mut self, depths: &[usize]) -> usize {
        assert!(!self.nodes.is_empty(), "empty fleet");
        assert_eq!(depths.len(), self.nodes.len(), "one depth per node");
        if self.affinity_bonus <= 1.0 || depths.iter().all(|&d| d == 0) {
            return self.route();
        }
        let gain = self.affinity_bonus - 1.0;
        let best_depth = depths.iter().copied().max().unwrap().max(1) as f64;
        let probing = |n: &Node| n.healthy && (n.probation == 0 || n.outstanding == 0);
        let tier = if self.nodes.iter().any(probing) {
            0
        } else if self.healthy_count() > 0 {
            1
        } else {
            2
        };
        let eligible = move |n: &Node| match tier {
            0 => n.healthy && (n.probation == 0 || n.outstanding == 0),
            1 => n.healthy,
            _ => true,
        };
        let idx = self
            .nodes
            .iter()
            .enumerate()
            .filter(|&(_, n)| eligible(n))
            .max_by(|(ia, a), (ib, b)| {
                let sa = (1.0 + gain * depths[*ia] as f64 / best_depth) * a.weight.max(1e-9)
                    / (a.outstanding as f64 + 1.0);
                let sb = (1.0 + gain * depths[*ib] as f64 / best_depth) * b.weight.max(1e-9)
                    / (b.outstanding as f64 + 1.0);
                // ties go to the lower index: max_by keeps the *last*
                // max, so order Greater only on a strict win
                sa.partial_cmp(&sb).unwrap().then(std::cmp::Ordering::Greater)
            })
            .map(|(i, _)| i)
            .unwrap();
        self.nodes[idx].outstanding += 1;
        self.nodes[idx].assigned += 1;
        idx
    }

    /// Mark one unit of work complete on a node.
    pub fn complete(&mut self, idx: usize) {
        assert!(self.nodes[idx].outstanding > 0, "complete on idle node");
        self.nodes[idx].outstanding -= 1;
    }

    /// Exclude a node from routing — its worker is gone or an operator
    /// drained it. Reversed by [`Fleet::mark_healthy`]. Opens the node's
    /// MTTR incident clock (idempotent: re-marking a down node does not
    /// reset it).
    pub fn mark_unhealthy(&mut self, idx: usize) {
        let n = &mut self.nodes[idx];
        if n.healthy || n.down_since.is_none() {
            n.faults += n.healthy as u64;
            if n.down_since.is_none() {
                n.down_since = Some(Instant::now());
            }
        }
        n.healthy = false;
        n.probation = 0;
    }

    /// Restore a node to the routable set — the recovery hook the old
    /// router lacked (an excluded node stayed excluded for the server's
    /// lifetime even after its worker came back or an operator replaced
    /// the card). With probation armed the node re-enters quarantined:
    /// it serves probes one at a time and is fully trusted (incident
    /// closed, MTTR recorded) only after passing them all; with the
    /// default of zero rounds the dispatch stage resumes routing to it
    /// immediately.
    pub fn mark_healthy(&mut self, idx: usize) {
        let rounds = self.probation_rounds;
        let n = &mut self.nodes[idx];
        if n.healthy {
            return;
        }
        n.healthy = true;
        n.probation = rounds;
        if rounds == 0 {
            self.close_incident(idx);
        }
    }

    /// Report a served request's outcome for probation tracking: a
    /// passing probe works the node toward full trust, a failing one
    /// re-quarantines it on the spot. No-op for trusted nodes.
    pub fn note_result(&mut self, idx: usize, ok: bool) {
        if self.nodes[idx].probation == 0 {
            return;
        }
        if ok {
            self.nodes[idx].probation -= 1;
            if self.nodes[idx].probation == 0 {
                self.close_incident(idx);
            }
        } else {
            self.nodes[idx].healthy = false;
            self.nodes[idx].probation = 0;
            // the original incident clock keeps running
        }
    }

    fn close_incident(&mut self, idx: usize) {
        let n = &mut self.nodes[idx];
        if let Some(start) = n.down_since.take() {
            n.downtime_s += start.elapsed().as_secs_f64();
            n.recoveries += 1;
        }
    }

    /// Mean time to recovery across closed incidents, seconds. `None`
    /// until at least one node has fully recovered.
    pub fn mttr_s(&self) -> Option<f64> {
        let recoveries: u64 = self.nodes.iter().map(|n| n.recoveries).sum();
        if recoveries == 0 {
            return None;
        }
        let downtime: f64 = self.nodes.iter().map(|n| n.downtime_s).sum();
        Some(downtime / recoveries as f64)
    }

    /// Move one queued unit of work from `from` to `to` — the router-side
    /// bookkeeping of a work steal. The request was routed (and counted)
    /// onto `from` but will be served (and completed) by `to`.
    pub fn reassign(&mut self, from: usize, to: usize) {
        assert!(self.nodes[from].outstanding > 0, "reassign from an idle node");
        self.nodes[from].outstanding -= 1;
        self.nodes[from].assigned -= 1;
        self.nodes[to].outstanding += 1;
        self.nodes[to].assigned += 1;
    }

    /// Nodes still eligible for routing.
    pub fn healthy_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.healthy).count()
    }

    pub fn total_assigned(&self) -> u64 {
        self.nodes.iter().map(|n| n.assigned).sum()
    }

    /// Per-node outstanding work units — the routing state the trace
    /// journal's dispatch samples carry
    /// ([`crate::obsv::DispatchPoint::outstanding`]).
    pub fn outstanding_snapshot(&self) -> Vec<u64> {
        self.nodes.iter().map(|n| n.outstanding).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    #[test]
    fn round_robin_cycles() {
        let mut f = Fleet::uniform(3, 1.0, RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| f.route()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_fills_idle_nodes_first() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::LeastLoaded);
        let a = f.route();
        let b = f.route();
        assert_ne!(a, b);
        f.complete(a);
        assert_eq!(f.route(), a);
    }

    #[test]
    fn weighted_routing_respects_throughput_ratios() {
        // node 0 twice as fast → gets ~2/3 of a long stream.
        let mut f = Fleet::new(
            vec![node("fast", 200.0), node("slow", 100.0)],
            RoutePolicy::WeightedThroughput,
        );
        // steady state: each node drains work at its own speed
        let mut service = [0.0f64; 2];
        for _ in 0..3000 {
            let _ = f.route();
            for (i, s) in service.iter_mut().enumerate() {
                *s += f.nodes[i].weight / 300.0;
                while *s >= 1.0 && f.nodes[i].outstanding > 0 {
                    f.complete(i);
                    *s -= 1.0;
                }
            }
        }
        let fast = f.nodes[0].assigned as f64;
        let slow = f.nodes[1].assigned as f64;
        let ratio = fast / slow;
        assert!(ratio > 1.6 && ratio < 2.5, "{ratio}");
    }

    fn node(name: &'static str, weight: f64) -> Node {
        Node::new(name, weight)
    }

    #[test]
    fn weighted_routing_starves_zero_weight_nodes() {
        // A dead card (zero measured throughput) must not attract traffic:
        // its normalized load is effectively infinite.
        let mut f = Fleet::new(
            vec![node("dead", 0.0), node("live", 100.0)],
            RoutePolicy::WeightedThroughput,
        );
        for _ in 0..50 {
            assert_eq!(f.route(), 1);
        }
        assert_eq!(f.nodes[0].assigned, 0);
        assert_eq!(f.nodes[1].assigned, 50);
    }

    #[test]
    fn weighted_all_zero_weight_fleet_still_routes() {
        // Degenerate fleet: every weight zero. The epsilon guard keeps the
        // load metric finite, so routing degrades to least-loaded instead
        // of panicking on a NaN comparison.
        let mut f = Fleet::new(
            vec![node("a", 0.0), node("b", 0.0)],
            RoutePolicy::WeightedThroughput,
        );
        for _ in 0..4 {
            let i = f.route();
            assert!(i < 2);
        }
        assert_eq!(f.total_assigned(), 4);
        assert_eq!(f.nodes[0].assigned, 2);
        assert_eq!(f.nodes[1].assigned, 2);
    }

    #[test]
    fn weighted_single_node_fleet_routes_everything_to_it() {
        let mut f = Fleet::uniform(1, 5.0, RoutePolicy::WeightedThroughput);
        for _ in 0..10 {
            assert_eq!(f.route(), 0);
        }
        assert_eq!(f.nodes[0].assigned, 10);
        assert_eq!(f.nodes[0].outstanding, 10);
    }

    #[test]
    #[should_panic(expected = "empty fleet")]
    fn empty_fleet_route_panics() {
        let mut f = Fleet::uniform(0, 1.0, RoutePolicy::WeightedThroughput);
        let _ = f.route();
    }

    #[test]
    fn heterogeneous_fleet_from_registry() {
        use crate::device::registry;
        use crate::llm::quant;
        let f = Fleet::from_devices(
            &[registry::cmp170hx(), registry::cmp170hx_x16()],
            &quant::Q4_K_M,
            FmadPolicy::Decomposed,
            RoutePolicy::WeightedThroughput,
        );
        assert_eq!(f.nodes.len(), 2);
        // the x16 mod lowers readback overhead → strictly faster decode
        assert!(f.nodes[1].weight > f.nodes[0].weight);
    }

    #[test]
    fn unhealthy_nodes_are_excluded_from_every_policy() {
        for policy in [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
            RoutePolicy::WeightedThroughput,
        ] {
            let mut f = Fleet::uniform(3, 1.0, policy);
            f.mark_unhealthy(1);
            assert_eq!(f.healthy_count(), 2);
            for _ in 0..12 {
                let i = f.route();
                assert_ne!(i, 1, "{policy:?} routed to a dead node");
            }
            assert_eq!(f.nodes[1].assigned, 0);
        }
    }

    #[test]
    fn round_robin_keeps_cycling_the_survivors() {
        let mut f = Fleet::uniform(3, 1.0, RoutePolicy::RoundRobin);
        f.mark_unhealthy(0);
        let picks: Vec<usize> = (0..4).map(|_| f.route()).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
    }

    #[test]
    fn fully_unhealthy_fleet_degrades_instead_of_hanging() {
        // route() must not spin or panic when every node is dead; the
        // dispatch stage guards on healthy_count() before trusting it.
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        f.mark_unhealthy(0);
        f.mark_unhealthy(1);
        assert_eq!(f.healthy_count(), 0);
        let i = f.route();
        assert!(i < 2);
    }

    #[test]
    fn recovered_nodes_rejoin_routing() {
        // Regression: there was no mark_healthy — a node excluded once
        // stayed excluded forever, so a fleet that lost and regained a
        // card kept idling it.
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        f.mark_unhealthy(1);
        for _ in 0..4 {
            assert_eq!(f.route(), 0);
        }
        f.mark_healthy(1);
        assert_eq!(f.healthy_count(), 2);
        let picks: Vec<usize> = (0..4).map(|_| f.route()).collect();
        assert!(picks.contains(&1), "recovered node must serve again: {picks:?}");
    }

    #[test]
    fn probation_serves_one_probe_at_a_time() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::LeastLoaded);
        f.set_probation_rounds(2);
        f.mark_unhealthy(1);
        f.mark_healthy(1);
        assert_eq!(f.nodes[1].probation, 2, "readmission starts quarantined");
        // the probationer is idle, so it is eligible — but once it holds
        // one probe, everything else goes to the trusted node.
        let mut got_probe = false;
        for _ in 0..6 {
            let i = f.route();
            if i == 1 {
                assert!(!got_probe, "a second request reached a busy probationer");
                got_probe = true;
            }
        }
        assert!(got_probe, "an idle probationer must receive its probe");
        assert_eq!(f.nodes[1].outstanding, 1);
    }

    #[test]
    fn passing_probes_restore_full_trust_and_record_mttr() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        f.set_probation_rounds(2);
        assert_eq!(f.mttr_s(), None, "no incidents yet");
        f.mark_unhealthy(1);
        assert_eq!(f.nodes[1].faults, 1);
        f.mark_healthy(1);
        assert_eq!(f.mttr_s(), None, "quarantine holds the incident open");
        for _ in 0..2 {
            let i = f.route();
            f.complete(i);
            f.note_result(i, true);
        }
        // node 1's probe may not have routed yet depending on the cursor;
        // drive until both probes pass.
        while f.nodes[1].probation > 0 {
            let i = f.route();
            f.complete(i);
            f.note_result(i, true);
        }
        assert!(f.nodes[1].trusted());
        assert_eq!(f.nodes[1].recoveries, 1);
        let mttr = f.mttr_s().expect("closed incident must record MTTR");
        assert!(mttr >= 0.0);
    }

    #[test]
    fn a_failed_probe_requarantines_the_node() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::LeastLoaded);
        f.set_probation_rounds(3);
        f.mark_unhealthy(1);
        f.mark_healthy(1);
        // route until the probationer holds its probe
        while f.nodes[1].outstanding == 0 {
            f.route();
        }
        f.complete(1);
        f.note_result(1, false);
        assert!(!f.nodes[1].healthy, "a flapping card goes straight back out");
        assert_eq!(f.nodes[1].recoveries, 0, "the incident never closed");
        assert_eq!(f.mttr_s(), None);
        // and the fleet keeps serving on the survivor
        for _ in 0..4 {
            assert_eq!(f.route(), 0);
        }
    }

    #[test]
    fn zero_probation_preserves_immediate_readmission() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        f.mark_unhealthy(1);
        f.mark_healthy(1);
        assert!(f.nodes[1].trusted(), "legacy behaviour: trusted on readmit");
        assert_eq!(f.nodes[1].recoveries, 1, "the incident closed at readmission");
        assert!(f.mttr_s().is_some());
    }

    #[test]
    fn note_result_is_a_noop_for_trusted_nodes() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        f.note_result(0, false);
        assert!(f.nodes[0].trusted(), "failures on trusted nodes are the worker's call");
    }

    #[test]
    fn remarking_a_down_node_does_not_reset_its_incident_clock() {
        let mut f = Fleet::uniform(1, 1.0, RoutePolicy::RoundRobin);
        f.mark_unhealthy(0);
        let started = f.nodes[0].down_since.expect("incident opened");
        f.mark_unhealthy(0);
        assert_eq!(f.nodes[0].down_since, Some(started));
        assert_eq!(f.nodes[0].faults, 1, "one incident, not two");
    }

    #[test]
    fn reassign_moves_outstanding_and_assigned() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        assert_eq!(f.route(), 0);
        assert_eq!(f.route(), 1);
        assert_eq!(f.route(), 0);
        // node 1 steals one of node 0's queued requests
        f.reassign(0, 1);
        assert_eq!(f.nodes[0].outstanding, 1);
        assert_eq!(f.nodes[1].outstanding, 2);
        assert_eq!(f.nodes[0].assigned, 1);
        assert_eq!(f.nodes[1].assigned, 2);
        assert_eq!(f.total_assigned(), 3, "steals conserve the request count");
        // the thief completes the stolen work
        f.complete(1);
        f.complete(1);
        assert_eq!(f.nodes[1].outstanding, 0);
    }

    #[test]
    #[should_panic(expected = "reassign from an idle node")]
    fn reassign_from_an_idle_node_panics() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        f.reassign(0, 1);
    }

    #[test]
    fn affine_routing_prefers_the_prefix_holder() {
        let mut f = Fleet::uniform(2, 100.0, RoutePolicy::WeightedThroughput);
        // Node 1 holds the full prefix (normalized bonus 2×); node 0 holds
        // none. The holder wins while 2/(o1+1) beats 1/(o0+1), ties shed
        // to the lower index: 1 (200 vs 100), 0 (100 vs 100 tie), 1 (100
        // vs 50), 1 (66.7 vs 50), 0 (50 vs 50 tie) — a bounded 2:1 tilt
        // toward the holder, never a pile-on.
        let picks: Vec<usize> = (0..5).map(|_| f.route_affine(&[0, 4])).collect();
        assert_eq!(picks, vec![1, 0, 1, 1, 0]);
        assert_eq!(f.nodes[1].outstanding, 3);
        assert_eq!(f.nodes[0].outstanding, 2);
    }

    #[test]
    fn affinity_bonus_one_degrades_to_the_plain_policy() {
        // 8d regression: with the bonus at 1.0 the depth term is
        // constant, so even a full prefix match must not perturb the
        // configured policy — identical picks to depth-blind routing.
        let mut affine = Fleet::uniform(3, 1.0, RoutePolicy::RoundRobin);
        affine.set_affinity_bonus(1.0);
        let mut plain = Fleet::uniform(3, 1.0, RoutePolicy::RoundRobin);
        for _ in 0..6 {
            assert_eq!(affine.route_affine(&[0, 7, 2]), plain.route());
        }
        // the same degradation holds for the weighted policy
        let mut w = Fleet::new(
            vec![node("fast", 200.0), node("slow", 100.0)],
            RoutePolicy::WeightedThroughput,
        );
        w.set_affinity_bonus(1.0);
        assert_eq!(w.route_affine(&[0, 9]), 0, "full match on slow cannot win at 1.0");
    }

    #[test]
    fn affinity_bonus_scales_the_tilt_toward_the_holder() {
        // A 3× bonus keeps the holder ahead one pick longer than the
        // default 2×: scores 3/(o+1) vs 1/(o+1) give 1 1 0 1 … instead
        // of 1 0 1 1 0 — still bounded, never a pile-on.
        let mut f = Fleet::uniform(2, 100.0, RoutePolicy::WeightedThroughput);
        f.set_affinity_bonus(3.0);
        let picks: Vec<usize> = (0..4).map(|_| f.route_affine(&[0, 4])).collect();
        assert_eq!(picks, vec![1, 1, 0, 1]);
    }

    #[test]
    fn affine_routing_with_no_depth_reduces_to_the_plain_policy() {
        // All-zero depths must preserve the configured policy exactly —
        // the --no-affinity ablation and prefix-less traffic take the
        // identical path.
        let mut f = Fleet::uniform(3, 1.0, RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| f.route_affine(&[0, 0, 0])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        let mut w = Fleet::new(
            vec![node("fast", 200.0), node("slow", 100.0)],
            RoutePolicy::WeightedThroughput,
        );
        let a = w.route_affine(&[0, 0]);
        assert_eq!(a, 0, "zero depths fall back to weighted throughput");
    }

    #[test]
    fn affine_routing_skips_unhealthy_prefix_holders() {
        let mut f = Fleet::uniform(2, 1.0, RoutePolicy::WeightedThroughput);
        f.mark_unhealthy(1);
        // the prefix lives on the dead card; affinity must not resurrect it
        for _ in 0..4 {
            assert_eq!(f.route_affine(&[0, 8]), 0);
        }
        assert_eq!(f.nodes[1].assigned, 0);
    }

    #[test]
    fn affine_routing_breaks_ties_to_the_lowest_index() {
        let mut f = Fleet::uniform(3, 1.0, RoutePolicy::WeightedThroughput);
        assert_eq!(f.route_affine(&[2, 2, 2]), 0);
        // node 0 now carries one unit; equal depths send the next to 1
        assert_eq!(f.route_affine(&[2, 2, 2]), 1);
    }

    #[test]
    fn prop_routing_conserves_requests() {
        // Every request lands on exactly one node; totals match.
        forall(0x40B7E, 200, |rng: &mut Rng| {
            let n = rng.range(1, 6) as usize;
            let policy = *rng.pick(&[
                RoutePolicy::RoundRobin,
                RoutePolicy::LeastLoaded,
                RoutePolicy::WeightedThroughput,
            ]);
            let mut f = Fleet::uniform(n, 1.0, policy);
            let total = rng.range(1, 200);
            for _ in 0..total {
                let i = f.route();
                assert!(i < n);
                if rng.chance(0.6) {
                    f.complete(i);
                }
            }
            assert_eq!(f.total_assigned(), total);
            let sum: u64 = f.nodes.iter().map(|x| x.assigned).sum();
            assert_eq!(sum, total);
        });
    }
}
