//! Adaptive admission control: fail fast *before* prefill is wasted.
//!
//! The fleet's pre-existing overload defense is reactive — a request that
//! outlives its wall-clock deadline is failed at dispatch, after it
//! queued and after earlier doomed requests burned card time. Past the
//! latency knee that policy collapses: every admitted request pushes the
//! backlog further over everyone's SLO, the cards stay saturated serving
//! answers nobody can use in time, and goodput falls while energy burn
//! holds at full draw (congestion collapse).
//!
//! [`AdmissionCtl`] makes the decision at **submit** instead, from a
//! prediction the dispatcher can already compute: backlog ahead of the
//! request (queue depth × calibrated per-request service estimate from
//! the node overlays — the same signals `obsv::series` samples) plus the
//! request's own service demand. If the predicted completion violates the
//! tenant's SLO contract, the request is shed immediately with an error —
//! the client can retry elsewhere, and the card's next seconds go to a
//! request that can still win.
//!
//! Shedding escalates down a **brownout ladder** with hysteresis rather
//! than flapping on a point estimate: consecutive doomed predictions trip
//! the level up (shedding spreads from certainly-doomed requests to
//! near-SLO requests of the lightest-weight tenants first, mirroring how
//! the PR 6 degradation ladder sheds over-rate tenants), and a calm
//! streak cools it back down. The controller is pure state — no clocks,
//! no randomness — so the same decision sequence replays bit-identically,
//! which is what lets open-loop overload curves be seed-reproducible.

/// Tuning for [`AdmissionCtl`]. Defaults are deliberately gentle: no
/// headroom inflation and a ladder that needs a sustained doomed streak
/// to escalate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmissionConfig {
    /// Multiplier on the predicted completion before comparing against
    /// the SLO (`> 1.0` sheds earlier, buying safety margin for
    /// estimation error).
    pub headroom: f64,
    /// Consecutive doomed verdicts before the brownout level steps up.
    pub trip_decisions: u32,
    /// Consecutive clean verdicts before it steps back down.
    pub cool_decisions: u32,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            headroom: 1.0,
            trip_decisions: 4,
            cool_decisions: 16,
        }
    }
}

/// One admission decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Admit,
    /// Shed now, before any prefill; carries the brownout level that made
    /// the call (0 = only certainly-doomed requests are shed).
    Shed { level: u8 },
}

/// Deterministic admission controller with a hysteretic brownout ladder.
///
/// Level 0 sheds only requests whose *own* predicted completion already
/// violates their SLO. Each level `L ≥ 1` additionally sheds requests
/// from the lightest `25·L` % of tenants (by fair-share weight rank) once
/// their prediction crosses `(1 − 0.2·L)` of the SLO — shedding the
/// cheapest traffic early to pull the backlog back under the knee before
/// heavier tenants start missing.
#[derive(Clone, Debug)]
pub struct AdmissionCtl {
    cfg: AdmissionConfig,
    level: u8,
    hot_streak: u32,
    calm_streak: u32,
    /// Requests shed across the controller's lifetime.
    pub sheds: u64,
    /// Requests admitted across the controller's lifetime.
    pub admits: u64,
}

impl AdmissionCtl {
    /// Top of the brownout ladder.
    pub const MAX_LEVEL: u8 = 3;

    pub fn new(cfg: AdmissionConfig) -> Self {
        assert!(cfg.headroom > 0.0 && cfg.headroom.is_finite(), "bad headroom");
        assert!(cfg.trip_decisions > 0 && cfg.cool_decisions > 0, "zero streaks flap");
        AdmissionCtl {
            cfg,
            level: 0,
            hot_streak: 0,
            calm_streak: 0,
            sheds: 0,
            admits: 0,
        }
    }

    /// Current brownout level (0 = normal operation).
    pub fn level(&self) -> u8 {
        self.level
    }

    /// Decide one request. `predicted_s` is backlog-ahead plus own
    /// service; `slo_s` the tenant's contract (None = no contract, always
    /// admitted — there is nothing to protect); `weight_rank` the
    /// tenant's fair-share weight rank in `[0, 1]` (0 = lightest tenant,
    /// 1 = heaviest).
    pub fn decide(&mut self, predicted_s: f64, slo_s: Option<f64>, weight_rank: f64) -> Verdict {
        let slo = match slo_s {
            Some(s) => s,
            None => {
                self.admits += 1;
                return Verdict::Admit;
            }
        };
        let inflated = predicted_s * self.cfg.headroom;
        let doomed = inflated > slo;
        if doomed {
            self.hot_streak += 1;
            self.calm_streak = 0;
            if self.hot_streak >= self.cfg.trip_decisions {
                self.hot_streak = 0;
                if self.level < Self::MAX_LEVEL {
                    self.level += 1;
                }
            }
        } else {
            self.calm_streak += 1;
            self.hot_streak = 0;
            if self.calm_streak >= self.cfg.cool_decisions {
                self.calm_streak = 0;
                if self.level > 0 {
                    self.level -= 1;
                }
            }
        }
        let l = f64::from(self.level);
        let brownout = self.level > 0 && weight_rank < 0.25 * l && inflated > slo * (1.0 - 0.2 * l);
        if doomed || brownout {
            self.sheds += 1;
            Verdict::Shed { level: self.level }
        } else {
            self.admits += 1;
            Verdict::Admit
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> AdmissionCtl {
        AdmissionCtl::new(AdmissionConfig::default())
    }

    #[test]
    fn healthy_predictions_admit_and_doomed_shed_at_level_zero() {
        let mut c = ctl();
        assert_eq!(c.decide(0.5, Some(1.0), 0.0), Verdict::Admit);
        assert_eq!(c.decide(1.5, Some(1.0), 1.0), Verdict::Shed { level: 0 });
        assert_eq!(c.level(), 0, "one doomed request does not trip the ladder");
        assert_eq!((c.admits, c.sheds), (1, 1));
    }

    #[test]
    fn requests_without_a_contract_are_never_shed() {
        let mut c = ctl();
        for _ in 0..100 {
            assert_eq!(c.decide(1e9, None, 0.0), Verdict::Admit);
        }
        assert_eq!(c.level(), 0, "contract-less traffic cannot escalate the ladder");
    }

    #[test]
    fn sustained_doom_trips_the_ladder_and_calm_cools_it() {
        let cfg = AdmissionConfig {
            headroom: 1.0,
            trip_decisions: 3,
            cool_decisions: 4,
        };
        let mut c = AdmissionCtl::new(cfg);
        for _ in 0..3 {
            c.decide(2.0, Some(1.0), 1.0);
        }
        assert_eq!(c.level(), 1, "three consecutive doomed verdicts trip level 1");
        for _ in 0..6 {
            c.decide(2.0, Some(1.0), 1.0);
        }
        assert_eq!(c.level(), 3, "and the ladder saturates at MAX_LEVEL");
        for _ in 0..30 {
            c.decide(2.0, Some(1.0), 1.0);
        }
        assert_eq!(c.level(), AdmissionCtl::MAX_LEVEL);
        for _ in 0..12 {
            c.decide(0.1, Some(1.0), 1.0);
        }
        assert_eq!(c.level(), 0, "twelve calm verdicts walk all three levels back down");
    }

    #[test]
    fn brownout_sheds_light_tenants_near_the_slo_but_not_heavy_ones() {
        let cfg = AdmissionConfig {
            headroom: 1.0,
            trip_decisions: 2,
            cool_decisions: 100,
        };
        let mut c = AdmissionCtl::new(cfg);
        c.decide(2.0, Some(1.0), 1.0);
        c.decide(2.0, Some(1.0), 1.0);
        assert_eq!(c.level(), 1);
        // 0.9 of SLO: above the level-1 brownout threshold (0.8·SLO)
        assert_eq!(
            c.decide(0.9, Some(1.0), 0.0),
            Verdict::Shed { level: 1 },
            "lightest tenant sheds near the SLO under brownout"
        );
        assert_eq!(
            c.decide(0.9, Some(1.0), 0.9),
            Verdict::Admit,
            "a heavy tenant with the same prediction stays admitted"
        );
        assert_eq!(
            c.decide(0.5, Some(1.0), 0.0),
            Verdict::Admit,
            "even the lightest tenant keeps comfortably-in-SLO traffic"
        );
    }

    #[test]
    fn mixed_traffic_does_not_flap_the_ladder() {
        // alternating doomed/clean never builds a streak, so the level
        // stays put — the hysteresis working as intended
        let mut c = ctl();
        for _ in 0..50 {
            c.decide(2.0, Some(1.0), 1.0);
            c.decide(0.2, Some(1.0), 1.0);
        }
        assert_eq!(c.level(), 0);
    }

    #[test]
    fn identical_decision_sequences_replay_identically() {
        let run = || {
            let mut c = ctl();
            (0..200)
                .map(|i| {
                    let p = (i % 7) as f64 * 0.3;
                    c.decide(p, Some(1.0), (i % 5) as f64 / 4.0)
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "pure state machine: no clocks, no randomness");
    }

    #[test]
    fn headroom_sheds_earlier() {
        let mut tight = AdmissionCtl::new(AdmissionConfig {
            headroom: 1.25,
            ..AdmissionConfig::default()
        });
        let mut loose = ctl();
        // 0.9 of SLO: fine without headroom, doomed with 1.25×
        assert_eq!(loose.decide(0.9, Some(1.0), 1.0), Verdict::Admit);
        assert_eq!(tight.decide(0.9, Some(1.0), 1.0), Verdict::Shed { level: 0 });
    }
}
