#!/usr/bin/env bash
# Local tier-1 gate: build, test, lint.
#
# Usage: scripts/check.sh [--no-clippy]
#
# Mirrors the ROADMAP tier-1 verify (`cargo build --release && cargo test
# -q`) and adds rustfmt drift detection plus clippy with warnings denied.
# Run from anywhere; the script cd's to the repo root.

set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install a Rust toolchain to run the tier-1 gate" >&2
    exit 1
fi

# Formatting first: cheapest check, and drift must fail loudly (CI installs
# the rustfmt component, so the warning branch only fires on bare local
# toolchains).
if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --all -- --check"
    cargo fmt --all -- --check
else
    echo "warning: rustfmt not installed; skipping format gate" >&2
fi

echo "==> cargo build --release --all-targets"
# --all-targets so benches and examples (which cargo test skips) cannot rot
cargo build --release --all-targets

echo "==> cargo test -q"
cargo test -q

if [[ "${1:-}" != "--no-clippy" ]]; then
    if cargo clippy --version >/dev/null 2>&1; then
        echo "==> cargo clippy --all-targets -- -D warnings"
        cargo clippy --all-targets -- -D warnings
    else
        echo "warning: clippy not installed; skipping lint step" >&2
    fi
fi

echo "tier-1 gate passed"
