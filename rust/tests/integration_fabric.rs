//! Fleet KV-fabric integration: prefix-affine routing against its
//! ablation, live migration of a parked sequence onto an idle peer card
//! (bit-identical tokens), swap–decode overlap accounting, and the chaos
//! case where the migration *target* dies after claiming foreign work.
//!
//! Every test skips (passes vacuously, with a note on stderr) when the
//! AOT artifacts are missing or PJRT is unavailable (the vendored stub xla
//! crate) — environments that cannot run the runtime at all.

use std::time::Duration;

use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{GenResponse, NodeConfig, RoutePolicy, Server, ServerConfig, ServerHandle};
use cmphx::device::registry;
use cmphx::faults::{FaultEvent, FaultKind, FaultPlan};
use cmphx::isa::pass::FmadPolicy;
mod common;
use common::artifact_dir;

fn artifact_prefill_t(dir: &cmphx::runtime::ArtifactDir) -> usize {
    cmphx::runtime::goldens::config_usize(dir, "prefill_t").unwrap()
}

/// Two identical 170HX nodes, round-robin fleet policy.
fn fleet2(max_batch: usize) -> ServerConfig {
    ServerConfig {
        queue_depth: 32,
        batch: BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(200),
            ..BatchPolicy::default()
        },
        step_policy: StepPolicy::RoundRobin,
        fmad: FmadPolicy::Decomposed,
        route: RoutePolicy::RoundRobin,
        nodes: vec![
            NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
            NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        ],
        ..Default::default()
    }
}

fn start(cfg: ServerConfig) -> Option<ServerHandle> {
    Some(Server::start(artifact_dir()?, cfg).unwrap())
}

/// Submit one prompt and wait for its response.
fn serve_one(server: &ServerHandle, prompt: Vec<i32>, tokens: usize) -> GenResponse {
    server
        .submit(prompt, tokens)
        .unwrap()
        .recv_timeout(Duration::from_secs(240))
        .unwrap()
}

#[test]
fn affine_routing_reuses_the_warm_card_and_the_ablation_spreads() {
    // Serially repeated identical prompts: the first lands by round-robin
    // on node 0, which publishes the prompt's chain hashes while decoding
    // it; every later dispatch sees the directory entry and routes back to
    // the warm card. The --no-affinity arm keeps alternating. Stealing is
    // off so routing alone decides placement.
    let prompt = vec![5, 9, 13, 2, 8, 1, 30, 44];
    let mut cfg = fleet2(2);
    cfg.qos.steal = false;
    let Some(server) = start(cfg) else { return };
    for i in 0..3 {
        let r = serve_one(&server, prompt.clone(), 6);
        assert!(r.ok(), "{:?}", r.error);
        assert_eq!(r.node, 0, "request {i} must stay on the warm card");
    }
    let fm = server.shutdown_fleet();
    assert!(
        fm.total().affine_routes >= 2,
        "repeat prompts must route affine (got {})",
        fm.total().affine_routes
    );
    assert_eq!(fm.nodes[1].1.requests, 0, "the cold card must stay idle");
    assert!(fm.total().prefix_hits >= 1, "the warm card must reuse its pages");

    let mut cfg = fleet2(2);
    cfg.qos.steal = false;
    cfg.affinity = false;
    let Some(server) = start(cfg) else { return };
    for _ in 0..3 {
        let r = serve_one(&server, prompt.clone(), 6);
        assert!(r.ok(), "{:?}", r.error);
    }
    let fm = server.shutdown_fleet();
    assert_eq!(fm.total().affine_routes, 0, "the ablation must never route affine");
    assert!(
        fm.nodes[1].1.requests >= 1,
        "plain round-robin must spread identical prompts"
    );
}

/// The migration workload: three distinct prompts, 24 tokens each,
/// round-robin → node 0 serves two concurrently under a page budget that
/// cannot hold both at peak, node 1 serves one. Node 0 parks one of its
/// pair under pressure (swapping its pages to the shared host pool);
/// node 1 finishes first, goes idle, finds nothing to steal, and claims
/// the parked sequence — restoring the host-resident pages over its own
/// link and decoding to completion.
fn migration_config(prefill_t: usize) -> ServerConfig {
    const LONG: usize = 24;
    let mut cfg = fleet2(2);
    // Routing must stay plain round-robin so the 2-vs-1 split is fixed.
    cfg.affinity = false;
    cfg.batch.kv_block_positions = 1;
    cfg.batch.kv_block_budget = Some((2 * prefill_t + 12).max(prefill_t + LONG));
    cfg.batch.swap = true;
    cfg
}

fn migration_prompts() -> [Vec<i32>; 3] {
    [
        vec![3, 1, 4, 1, 5, 9, 2, 6],
        vec![2, 7, 1, 8, 2, 8, 1, 8],
        vec![1, 6, 1, 8, 0, 3, 3, 9],
    ]
}

#[test]
fn a_migrated_sequence_completes_bit_identically_on_the_thief_card() {
    let Some(dir) = artifact_dir() else { return };
    let prefill_t = artifact_prefill_t(&dir);
    let prompts = migration_prompts();

    // Reference: the same prompts served without page pressure.
    let Some(reference) = start(fleet2(4)) else { return };
    let expected: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| {
            let r = serve_one(&reference, p.clone(), 24);
            assert!(r.ok(), "{:?}", r.error);
            r.tokens
        })
        .collect();
    drop(reference);

    let Some(server) = start(migration_config(prefill_t)) else { return };
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| server.submit(p.clone(), 24).unwrap())
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(240)).unwrap();
        assert!(r.ok(), "request {i}: {:?}", r.error);
        assert_eq!(
            r.tokens, expected[i],
            "request {i}: a migrated/parked sequence must replay bit-identically"
        );
    }
    let fm = server.shutdown_fleet();
    let m = fm.total();
    assert_eq!(m.errors, 0);
    assert_eq!(m.lost_seqs, 0);
    assert!(m.preemptions >= 1, "the page budget must have evicted someone");
    assert!(
        m.migrations >= 1,
        "the idle card must have claimed the parked sequence (migrations={})",
        m.migrations
    );
    assert!(m.swap_outs >= 1, "the eviction must have swapped to the host pool");
    assert_eq!(m.swap_ins, m.swap_outs, "every parked page set must come back");
    // Swap–decode overlap: the ledger splits every transfer into the part
    // hidden under a decode round and the stalled tail — conserving the
    // total — and a swap-out next to surviving decodes always hides some.
    assert!(
        (m.swap_overlapped_s + m.swap_stalled_s - m.swap_transfer_s).abs() < 1e-9,
        "overlap split must conserve transfer time"
    );
    assert!(m.swap_overlapped_s > 0.0, "swap DMA must overlap the decode round");
    assert!(
        m.swap_stalled_s < m.swap_transfer_s,
        "with overlap on, the stalled tail must be strictly below the serial charge"
    );
}

#[test]
fn a_dying_migration_target_loses_no_sequences() {
    // Chaos arm: the card that claims the parked sequence dies while
    // serving it. The death path rescues its live set (the migrated
    // sequence included) back through the dispatch stage onto the
    // survivor, which replays it bit-identically — zero lost sequences,
    // every response delivered.
    let Some(dir) = artifact_dir() else { return };
    let prefill_t = artifact_prefill_t(&dir);
    let prompts = migration_prompts();

    let Some(reference) = start(fleet2(4)) else { return };
    let expected: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| {
            let r = serve_one(&reference, p.clone(), 24);
            assert!(r.ok(), "{:?}", r.error);
            r.tokens
        })
        .collect();
    drop(reference);

    // Node 1 serves its single routed request (~24 rounds), claims the
    // parked sequence from node 0's pair, and the script kills it a few
    // rounds into serving the claim — while node 0 is still busy.
    let mut cfg = migration_config(prefill_t);
    cfg.faults = Some(FaultPlan::script(vec![FaultEvent {
        node: 1,
        round: 28,
        kind: FaultKind::NodeDeath,
    }]));
    let Some(server) = start(cfg) else { return };
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| server.submit(p.clone(), 24).unwrap())
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(240)).unwrap();
        assert!(r.ok(), "request {i} lost to the target's death: {:?}", r.error);
        assert_eq!(
            r.tokens, expected[i],
            "request {i}: rescue after a failed migration must stay bit-identical"
        );
    }
    let fm = server.shutdown_fleet();
    let m = fm.total();
    assert_eq!(m.errors, 0, "zero dropped responses");
    assert_eq!(m.lost_seqs, 0, "the dead target may lose nothing");
    assert_eq!(m.requests, 3, "every request retires exactly once");
    assert!(
        m.rescued_seqs >= 1,
        "the dead card's in-hand work must ride the rescue path"
    );
}
