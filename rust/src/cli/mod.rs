//! Hand-rolled CLI (no `clap` in the offline crate set).
//!
//! ```text
//! cmphx specs [device]          spec sheets (Tables 2-1…2-5)
//! cmphx bench <suite>           fp32|fp16|fp64|int32|int8|membw|pcie|all
//! cmphx llama-bench [device]    Graphs 4-1/4-2/4-3 grid
//! cmphx market                  Tables 1-1/1-2 + reuse value
//! cmphx report                  every figure, with paper deviations
//! cmphx targets                 calibration target check
//! cmphx serve [--requests N]    end-to-end PJRT serving demo
//! ```

pub mod args;
pub mod commands;

pub use args::Args;
pub use commands::run;
