//! Compile-time stub of the `xla-rs` PJRT bindings.
//!
//! The real crate links against the `xla_extension` shared library, which
//! this build image does not ship. This stub reproduces the API surface the
//! repository uses so the crate (and everything downstream of
//! `cmphx::runtime`) typechecks and builds; every operation that would
//! touch PJRT returns [`Error::Unavailable`] at runtime. Integration tests
//! that need a live PJRT client skip/fail exactly as they do on any machine
//! without artifacts, and the simulation substrate — which never touches
//! PJRT — is unaffected.

use std::fmt;
use std::path::Path;

/// Stub error: always "PJRT unavailable".
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: xla_extension is not available in this build (stub xla crate)"
            ),
        }
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error::Unavailable(what.to_string()))
}

/// Element types the repository references.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S8,
    S32,
    S64,
    F32,
    F64,
}

/// Marker for scalar types storable in a [`Literal`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i8 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side literal (stub: carries no data).
#[derive(Debug, Clone, Default)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a slice (stub: shape/data dropped).
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal::default()
    }

    /// Build a rank-0 literal (stub).
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal::default()
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal), Error> {
        unavailable("Literal::to_tuple3")
    }
}

/// Parsed HLO module proto (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer (stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_constructors_are_usable_at_compile_time() {
        let _ = Literal::vec1(&[1.0f32, 2.0]);
        let _ = Literal::scalar(3i32);
        assert!(Literal::default().to_vec::<f32>().is_err());
    }
}
