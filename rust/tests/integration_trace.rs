//! Observability integration: per-request span tracing over the live
//! engine. A scripted node death mid-decode must leave a flight-recorder
//! dump for the killed card, and the exported journal must reconstruct a
//! rescued request's full lifecycle — queued → dispatched → admitted →
//! rescued → requeued → replayed/retired — with the per-phase seconds of
//! every retired span summing to its end-to-end simulated latency.
//!
//! Every test skips (passes vacuously, with a note on stderr) when the
//! AOT artifacts are missing or PJRT is unavailable (the vendored stub xla
//! crate). Byte-identical determinism of the exporters is pinned by the
//! seeded scripted-tracer tests in `cmphx::obsv::export` — the live
//! engine's wall-clock interleaving reorders drains, which the canonical
//! `(node, seq)` sort absorbs per node but not across submission races.

use std::collections::HashSet;
use std::time::Duration;

use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{
    GenResponse, NodeConfig, RoutePolicy, Server, ServerConfig, ServerHandle,
};
use cmphx::device::registry;
use cmphx::faults::{FaultEvent, FaultKind, FaultPlan};
use cmphx::isa::pass::FmadPolicy;
use cmphx::obsv::{chrome_trace, journal_jsonl, lifecycle_slices, parse_journal, SpanKind};
mod common;
use common::artifact_dir;

/// Two identical 170HX nodes, round-robin routing, stealing off, span
/// tracing armed.
fn traced_config(faults: Option<FaultPlan>) -> ServerConfig {
    let mut cfg = ServerConfig {
        queue_depth: 32,
        batch: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(20),
            ..BatchPolicy::default()
        },
        step_policy: StepPolicy::RoundRobin,
        fmad: FmadPolicy::Decomposed,
        route: RoutePolicy::RoundRobin,
        nodes: vec![
            NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
            NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        ],
        trace: true,
        ..Default::default()
    };
    cfg.qos.steal = false;
    cfg.faults = faults;
    cfg
}

fn start(cfg: ServerConfig) -> Option<ServerHandle> {
    Some(Server::start(artifact_dir()?, cfg).unwrap())
}

fn kill_node0() -> FaultPlan {
    FaultPlan::script(vec![FaultEvent { node: 0, round: 3, kind: FaultKind::NodeDeath }])
}

fn run_workload(server: &ServerHandle, n: usize, tokens: usize) -> Vec<GenResponse> {
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i as i32 + 2)) % 500 + 1).collect();
            server.submit(prompt, tokens).unwrap()
        })
        .collect();
    rxs.into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(240)).unwrap())
        .collect()
}

#[test]
fn a_chaos_death_dumps_the_flight_recorder_and_journals_the_rescue() {
    let Some(server) = start(traced_config(Some(kill_node0()))) else { return };
    let responses = run_workload(&server, 6, 12);
    for (i, r) in responses.iter().enumerate() {
        assert!(r.ok(), "request {i} lost to the death: {:?}", r.error);
        // the response carries its trace id and the phase ledger the
        // journal's retired span was built from
        assert_eq!(r.trace.0, r.id, "trace ids are request ids");
        assert_eq!(
            r.ledger.device_s(),
            r.simulated_device_s,
            "the ledger is the simulated device time, phase-split"
        );
    }
    let tracer = server.tracer();
    let fm = server.shutdown_fleet();
    assert!(fm.total().rescued_seqs >= 1, "the death must have rescued work");

    let snap = tracer.snapshot();
    assert!(
        snap.dumps.iter().any(|d| d.node == 0 && d.reason == "node death"),
        "the killed card must leave a flight-recorder dump: {:?}",
        snap.dumps.iter().map(|d| (d.node, d.reason.clone())).collect::<Vec<_>>()
    );

    // the JSONL journal parses back and re-exports byte-identically
    let text = journal_jsonl(&snap);
    let parsed = parse_journal(&text).expect("every journal line is well-formed");
    assert_eq!(journal_jsonl(&parsed), text, "export → parse → export is the identity");

    // the Chrome view is loadable-shaped and carries lifecycle slices
    let chrome = chrome_trace(&snap);
    assert!(chrome.starts_with("{\"traceEvents\":["));
    assert!(chrome.contains("\"ph\":\"X\""), "per-phase slices present");
    assert!(chrome.contains("\"name\":\"rescued\""), "the rescue shows as an instant");

    // reconstruct a rescued request's lifecycle from the journal alone
    let all_events: Vec<_> =
        snap.events.iter().chain(snap.dumps.iter().flat_map(|d| d.events.iter())).collect();
    let rescued_id = all_events
        .iter()
        .find(|e| matches!(e.kind, SpanKind::Rescued { .. }))
        .expect("a rescued span exists")
        .trace;
    let kinds: HashSet<&str> =
        all_events.iter().filter(|e| e.trace == rescued_id).map(|e| e.kind.name()).collect();
    for need in ["queued", "dispatched", "rescued", "requeued", "admitted", "retired"] {
        assert!(kinds.contains(need), "rescued lifecycle is missing {need:?}: {kinds:?}");
    }

    // every retired span's per-phase slices sum to its end-to-end
    // simulated latency (queue + device seconds), ending at the stamp
    let mut retired = 0;
    for e in &all_events {
        if let SpanKind::Retired { queue_s, ledger, .. } = &e.kind {
            retired += 1;
            let slices = lifecycle_slices(*queue_s, ledger, e.sim_s);
            let total: f64 = slices.iter().map(|s| s.dur_s).sum();
            assert!(
                (total - (queue_s + ledger.device_s())).abs() < 1e-9,
                "phase seconds must sum to end-to-end sim latency"
            );
            let last = slices.last().expect("a served request has nonzero phases");
            assert!((last.start_s + last.dur_s - e.sim_s).abs() < 1e-9);
        }
    }
    assert_eq!(retired, 6, "every request retires exactly once in the journal");

    // the per-round fleet time-series covered both cards
    assert!(snap.series.iter().any(|p| p.node == 0));
    assert!(snap.series.iter().any(|p| p.node == 1));
    assert!(!snap.dispatch.is_empty(), "dispatch-stage samples present");
}

#[test]
fn the_disabled_tracer_retains_nothing_on_the_same_workload() {
    // The tracing-off arm of the overhead ablation: same fleet, same
    // chaos, trace off — the snapshot must be empty and goodput whole.
    let mut cfg = traced_config(Some(kill_node0()));
    cfg.trace = false;
    let Some(server) = start(cfg) else { return };
    let responses = run_workload(&server, 6, 12);
    assert!(responses.iter().all(|r| r.ok()));
    let tracer = server.tracer();
    server.shutdown_fleet();
    let snap = tracer.snapshot();
    assert!(snap.events.is_empty() && snap.dumps.is_empty() && snap.series.is_empty());
}
