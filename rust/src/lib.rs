//! # cmphx — crippled-GPU co-design study platform
//!
//! Reproduction of *"Exploration of Cryptocurrency Mining-Specific GPUs in AI
//! Applications: A Case Study of CMP 170HX"* (CS.AR 2025).
//!
//! The library models an Ampere-class GPU whose fused-multiply-add
//! instruction classes are throttled by a hardware limiter (the NVIDIA CMP
//! 170HX crippling mechanism), implements the community `-fmad=false`
//! workaround as a real compiler pass over a small kernel IR, ports the
//! paper's benchmark workloads (mixbench, OpenCL-Benchmark, GPU-Burn,
//! PyTorch GEMM, llama-bench over Qwen2.5-1.5B in six ggml quant formats),
//! and serves a real AOT-compiled tiny-Qwen model through a threaded
//! coordinator backed by the PJRT CPU client.
//!
//! Layer map (see DESIGN.md):
//! - **L3 (this crate)** — simulator substrate + serving coordinator + CLI.
//!   The simulation hot path is a *lower-once / simulate-many* pipeline:
//!   [`isa::InstMix`] is a fixed array indexed by instruction-class
//!   discriminant (O(1) counts, incrementally-maintained FLOP/IOP/fused
//!   aggregates); [`sim::LoweredKernel`] caches one IR walk per kernel; and
//!   [`sim::batch`] fans `kernels × devices × configs` sweeps across worker
//!   threads with results bit-identical to (and ordered like) the
//!   sequential loop. Single one-shot calls use [`sim::simulate`]; anything
//!   sweep-shaped — bench-port intensity sweeps, the llama-bench
//!   quant × policy grid, figure regeneration, fleet weighting — lowers
//!   once and goes through [`sim::simulate_lowered`] / [`sim::batch`].
//! - **L2 (python/compile/model.py)** — JAX tiny-Qwen prefill/decode,
//!   AOT-lowered once to `artifacts/*.hlo.txt`.
//! - **L1 (python/compile/kernels/)** — Pallas kernels (mixbench chain,
//!   q8_0 quantized matmul, GQA decode attention) with pure-jnp oracles.
//!
//! Quick tour (`no_run` only because rustdoc's test binary misses the
//! xla_extension rpath in this offline image; the same assertion runs in
//! `bench::openclbench::tests` and `report::figures::tests`):
//! ```no_run
//! use cmphx::device::registry;
//! use cmphx::bench::openclbench;
//! use cmphx::isa::pass::FmadPolicy;
//!
//! let dev = registry::cmp170hx();
//! let crippled = openclbench::peak_fp32(&dev, FmadPolicy::Fused).tflops();
//! let restored = openclbench::peak_fp32(&dev, FmadPolicy::Decomposed).tflops();
//! assert!(restored / crippled > 15.0); // the paper's headline
//! ```

pub mod bench;
pub mod bench_harness;
pub mod calibration;
pub mod cli;
pub mod coordinator;
pub mod device;
pub mod faults;
pub mod isa;
pub mod llm;
pub mod load;
pub mod market;
pub mod memhier;
pub mod obsv;
pub mod power;
pub mod qos;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod testutil;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
