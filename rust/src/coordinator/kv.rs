//! Paged KV-cache allocator with VRAM accounting.
//!
//! The CMP 170HX's 8 GB ceiling is the binding constraint of §4.1/§6.2.
//! The old fixed-slot manager reserved worst-case context
//! (`kv_bytes_per_pos × max_ctx`) for every admitted sequence, so a card
//! serving 4k-token contexts with ~1k-token mean generations wasted ~3/4
//! of its KV budget on positions that were never written. [`KvPager`]
//! instead hands out **blocks of N token positions** as a sequence
//! actually grows (vLLM-style paged attention, at the accounting level the
//! simulated deployment needs): admission pins only the prefill window,
//! each decode round grows the sequence by at most one block, and a grow
//! that cannot be satisfied signals the engine to preempt (drop the KV,
//! requeue, recompute on resume) rather than silently over-committing the
//! device.
//!
//! Handles are generation-stamped: a released handle — or a handle whose
//! id was recycled by a later admission — is rejected on every operation
//! instead of silently corrupting another sequence's pages.

use anyhow::{bail, Result};

/// Handle to one sequence's KV pages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqKv {
    id: usize,
    gen: u64,
}

/// One live sequence's page-table summary.
#[derive(Clone, Copy, Debug)]
struct SeqAlloc {
    /// Token positions this sequence may write (rounded up into `blocks`).
    positions: usize,
    /// Blocks currently owned.
    blocks: usize,
}

#[derive(Debug)]
struct PageEntry {
    gen: u64,
    alloc: Option<SeqAlloc>,
}

/// Paged KV block allocator for one card.
#[derive(Debug)]
pub struct KvPager {
    block_positions: usize,
    bytes_per_pos: u64,
    total_blocks: usize,
    used_blocks: usize,
    active: usize,
    /// Device memory budget and static (weights) usage, bytes.
    vram_bytes: u64,
    weights_bytes: u64,
    entries: Vec<PageEntry>,
    free_ids: Vec<usize>,
}

impl KvPager {
    /// Build a pager over a device with `vram_bytes`, `weights_bytes` of
    /// which are pinned by the model; everything left is carved into
    /// blocks of `block_positions × bytes_per_pos`. Fails when the
    /// geometry cannot yield even one block.
    pub fn new(
        block_positions: usize,
        bytes_per_pos: u64,
        vram_bytes: u64,
        weights_bytes: u64,
    ) -> Result<Self> {
        if block_positions == 0 {
            bail!("KV block size must be at least one position");
        }
        if bytes_per_pos == 0 {
            bail!("KV bytes per position must be nonzero");
        }
        if weights_bytes > vram_bytes {
            bail!("weights ({weights_bytes} bytes) exceed device VRAM ({vram_bytes} bytes)");
        }
        let block_bytes = block_positions as u64 * bytes_per_pos;
        let total_blocks = ((vram_bytes - weights_bytes) / block_bytes) as usize;
        if total_blocks == 0 {
            bail!("no headroom for even one {block_bytes}-byte KV block after weights");
        }
        Ok(KvPager {
            block_positions,
            bytes_per_pos,
            total_blocks,
            used_blocks: 0,
            active: 0,
            vram_bytes,
            weights_bytes,
            entries: Vec::new(),
            free_ids: Vec::new(),
        })
    }

    /// Cap the block pool below the VRAM-derived total (a test/ops knob:
    /// force page pressure without faking device specs). Only valid on an
    /// idle pager.
    pub fn limit_blocks(&mut self, cap: usize) -> Result<()> {
        if cap == 0 {
            bail!("KV block budget must be at least one block");
        }
        if self.used_blocks > 0 {
            bail!("cannot shrink the block pool with live sequences");
        }
        self.total_blocks = self.total_blocks.min(cap);
        Ok(())
    }

    /// Blocks needed to hold `positions` token positions (at least one —
    /// every live sequence owns a page).
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.max(1).div_ceil(self.block_positions)
    }

    /// Admit a sequence holding `positions` positions (the prefill
    /// window), or `None` when the free pool cannot cover it.
    pub fn admit(&mut self, positions: usize) -> Option<SeqKv> {
        let need = self.blocks_for(positions);
        if need > self.free_blocks() {
            return None;
        }
        let id = match self.free_ids.pop() {
            Some(id) => id,
            None => {
                self.entries.push(PageEntry { gen: 0, alloc: None });
                self.entries.len() - 1
            }
        };
        let entry = &mut self.entries[id];
        entry.gen += 1;
        entry.alloc = Some(SeqAlloc {
            positions: positions.max(1),
            blocks: need,
        });
        let gen = entry.gen;
        self.used_blocks += need;
        self.active += 1;
        Some(SeqKv { id, gen })
    }

    /// Grow a sequence to `positions`. `Ok(true)` when the sequence now
    /// owns every page up to `positions` (including the no-op case);
    /// `Ok(false)` when the free pool cannot cover the growth — the
    /// caller's cue to preempt or stall. Nothing changes on `Ok(false)`.
    /// `Err` marks a coordinator logic bug (stale handle).
    pub fn grow(&mut self, seq: SeqKv, positions: usize) -> Result<bool> {
        let cur = self.alloc(seq)?;
        if positions <= cur.positions {
            return Ok(true);
        }
        let need = self.blocks_for(positions) - cur.blocks;
        if need > self.free_blocks() {
            return Ok(false);
        }
        let alloc = self.entries[seq.id].alloc.as_mut().expect("checked live");
        alloc.blocks += need;
        alloc.positions = positions;
        self.used_blocks += need;
        Ok(true)
    }

    /// Release a sequence's pages (retirement or preemption); returns the
    /// number of blocks freed. Stale handles — double release, or reuse
    /// after the id was recycled — are rejected without touching the
    /// accounting.
    pub fn release(&mut self, seq: SeqKv) -> Result<usize> {
        let cur = self.alloc(seq)?;
        let entry = &mut self.entries[seq.id];
        entry.alloc = None;
        // Invalidate every outstanding copy of this handle immediately.
        entry.gen += 1;
        self.used_blocks -= cur.blocks;
        self.active -= 1;
        self.free_ids.push(seq.id);
        Ok(cur.blocks)
    }

    fn alloc(&self, seq: SeqKv) -> Result<SeqAlloc> {
        let Some(entry) = self.entries.get(seq.id) else {
            bail!("KV handle {} out of range", seq.id);
        };
        if entry.gen != seq.gen || entry.alloc.is_none() {
            bail!("stale KV handle {} (released or recycled)", seq.id);
        }
        Ok(entry.alloc.expect("checked above"))
    }

    /// Positions a live sequence currently owns pages for.
    pub fn seq_positions(&self, seq: SeqKv) -> Result<usize> {
        Ok(self.alloc(seq)?.positions)
    }

    /// How many new sequences of `positions` the free pool could admit
    /// right now — the admission gate of continuous batching.
    pub fn admissible(&self, positions: usize) -> usize {
        self.free_blocks() / self.blocks_for(positions)
    }

    pub fn free_blocks(&self) -> usize {
        self.total_blocks - self.used_blocks
    }

    pub fn used_blocks(&self) -> usize {
        self.used_blocks
    }

    pub fn capacity_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Token positions per block.
    pub fn block_positions(&self) -> usize {
        self.block_positions
    }

    /// The longest single sequence the whole pool could hold.
    pub fn max_positions(&self) -> usize {
        self.total_blocks * self.block_positions
    }

    /// Live sequences holding pages.
    pub fn active_seqs(&self) -> usize {
        self.active
    }

    fn block_bytes(&self) -> u64 {
        self.block_positions as u64 * self.bytes_per_pos
    }

    /// Bytes currently resident (weights + allocated pages).
    pub fn resident_bytes(&self) -> u64 {
        self.weights_bytes + self.used_blocks as u64 * self.block_bytes()
    }

    /// Headroom to the VRAM budget.
    pub fn headroom_bytes(&self) -> u64 {
        self.vram_bytes - self.resident_bytes()
    }

    /// What the replaced fixed-slot allocator would have admitted over the
    /// same VRAM: worst-case reservation of `max_ctx` positions per
    /// sequence. Kept as the paged-vs-fixed comparison baseline for
    /// benches and acceptance tests.
    pub fn fixed_slot_capacity(&self, max_ctx: usize) -> usize {
        let per_slot = self.bytes_per_pos * max_ctx.max(1) as u64;
        ((self.vram_bytes - self.weights_bytes) / per_slot) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    /// 4-position blocks of 1 KiB/pos over 8 MiB with 1 MiB of weights:
    /// (8 - 1) MiB / 4 KiB = 1792 blocks.
    fn pager() -> KvPager {
        KvPager::new(4, 1 << 10, 8 << 20, 1 << 20).unwrap()
    }

    #[test]
    fn admit_grow_release_cycle_tracks_blocks() {
        let mut p = pager();
        assert_eq!(p.capacity_blocks(), 1792);
        let a = p.admit(6).unwrap(); // 2 blocks
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.active_seqs(), 1);
        // growth inside the last owned block allocates nothing
        assert!(p.grow(a, 7).unwrap());
        assert!(p.grow(a, 8).unwrap());
        assert_eq!(p.used_blocks(), 2);
        // crossing the block boundary allocates exactly one block
        assert!(p.grow(a, 9).unwrap());
        assert_eq!(p.used_blocks(), 3);
        // shrinking requests are no-ops
        assert!(p.grow(a, 2).unwrap());
        assert_eq!(p.seq_positions(a).unwrap(), 9);
        assert_eq!(p.release(a).unwrap(), 3);
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.active_seqs(), 0);
    }

    #[test]
    fn grow_past_the_pool_fails_without_side_effects() {
        let mut p = pager();
        let a = p.admit(4).unwrap();
        let hog = p.admit(1792 * 4 - 4).unwrap(); // everything else
        assert_eq!(p.free_blocks(), 0);
        assert!(!p.grow(a, 5).unwrap(), "no pages left");
        assert_eq!(p.seq_positions(a).unwrap(), 4, "failed grow must not move");
        assert_eq!(p.used_blocks(), 1792);
        p.release(hog).unwrap();
        assert!(p.grow(a, 5).unwrap(), "freed pages make growth succeed");
        p.release(a).unwrap();
    }

    #[test]
    fn stale_handles_are_rejected_without_corrupting_accounting() {
        let mut p = pager();
        let a = p.admit(4).unwrap();
        let b = p.admit(4).unwrap();
        p.release(a).unwrap();
        let err = p.release(a).unwrap_err().to_string();
        assert!(err.contains("stale"), "{err}");
        assert_eq!(p.used_blocks(), 1);
        // the id is recycled by the next admission; the old handle must
        // still be dead even though the slot is live again
        let c = p.admit(4).unwrap();
        assert!(p.grow(a, 8).is_err());
        assert!(p.release(a).is_err());
        assert_eq!(p.used_blocks(), 2);
        // out-of-range ids are rejected too
        let bogus = SeqKv { id: 999, gen: 1 };
        assert!(p.release(bogus).unwrap_err().to_string().contains("out of range"));
        p.release(b).unwrap();
        p.release(c).unwrap();
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn rejects_impossible_geometries() {
        // weights alone overflow the card
        assert!(KvPager::new(4, 1 << 10, 1 << 20, 2 << 20).is_err());
        // headroom smaller than one block
        assert!(KvPager::new(1024, 1 << 20, (1 << 30) + 1, 1 << 30).is_err());
        // degenerate parameters
        assert!(KvPager::new(0, 1 << 10, 8 << 20, 0).is_err());
        assert!(KvPager::new(4, 0, 8 << 20, 0).is_err());
    }

    #[test]
    fn vram_accounting_tracks_pages() {
        let mut p = pager();
        assert_eq!(p.resident_bytes(), 1 << 20);
        let a = p.admit(5).unwrap(); // 2 blocks of 4 KiB
        assert_eq!(p.resident_bytes(), (1 << 20) + 2 * (4 << 10));
        p.release(a).unwrap();
        assert_eq!(p.headroom_bytes(), (8 << 20) - (1 << 20));
    }

    #[test]
    fn limit_blocks_caps_the_pool() {
        let mut p = pager();
        p.limit_blocks(3).unwrap();
        assert_eq!(p.capacity_blocks(), 3);
        assert_eq!(p.max_positions(), 12);
        assert_eq!(p.admissible(4), 3);
        let a = p.admit(12).unwrap();
        assert!(p.admit(1).is_none());
        assert!(p.limit_blocks(2).is_err(), "cannot shrink under live pages");
        assert!(p.limit_blocks(0).is_err());
        p.release(a).unwrap();
        // a cap above the total is a no-op
        p.limit_blocks(usize::MAX).unwrap();
        assert_eq!(p.capacity_blocks(), 3);
    }

    #[test]
    fn paged_admits_strictly_more_than_fixed_slots_at_long_context() {
        // The §4.1 accounting on a CMP 170HX: Qwen2.5-1.5B KV bytes/pos
        // (2 · 28 layers · 2 kv_heads · 128 head_dim · f16 = 28672 B) on
        // an 8 GB card with ~2 GB of q8_0 weights, serving 4096-token
        // contexts whose mean sequence (prompt + generation) is 1024
        // positions — context 4× the mean, the acceptance operating point.
        let mut p = KvPager::new(16, 28_672, 8 << 30, 2 << 30).unwrap();
        let max_ctx = 4096;
        let mean_seq = 1024;
        let fixed = p.fixed_slot_capacity(max_ctx);
        let paged = p.admissible(mean_seq);
        assert!(fixed > 0);
        assert!(
            paged > fixed,
            "paged {paged} must beat fixed-slot {fixed} at equal VRAM"
        );
        // ~4× is the arithmetic expectation when reservations are 4× the
        // mean; block rounding costs a little
        assert!(paged >= 3 * fixed, "paged {paged} vs fixed {fixed}");
        // and the pager actually delivers that concurrency within budget
        let held: Vec<SeqKv> = (0..paged).map(|_| p.admit(mean_seq).unwrap()).collect();
        assert!(p.resident_bytes() <= 8 << 30);
        assert_eq!(p.active_seqs(), paged);
        for h in held {
            p.release(h).unwrap();
        }
    }

    #[test]
    fn prop_pages_always_partition_the_budget() {
        // Port of the fixed-slot allocator's never-leaks property to
        // random admit/grow/preempt/resume interleavings: live
        // allocations plus the free pool always partition the block
        // budget, and resident bytes never exceed VRAM.
        forall(0x9A6ED, 150, |rng: &mut Rng| {
            let bp = rng.range(1, 8) as usize;
            let total = rng.range(2, 40) as usize;
            let bytes_per_pos = 64u64;
            let block_bytes = bp as u64 * bytes_per_pos;
            let weights = 1u64 << 10;
            let vram = weights + total as u64 * block_bytes + rng.below(block_bytes);
            let mut p = KvPager::new(bp, bytes_per_pos, vram, weights).unwrap();
            assert_eq!(p.capacity_blocks(), total);
            // (handle, positions) shadow model; parked holds preempted
            // sequences' positions awaiting resume
            let mut held: Vec<(SeqKv, usize)> = Vec::new();
            let mut parked: Vec<usize> = Vec::new();
            for _ in 0..96 {
                match rng.below(4) {
                    0 => {
                        // admit a fresh sequence
                        let pos = rng.range(1, 4 * bp as u64) as usize;
                        match p.admit(pos) {
                            Some(h) => held.push((h, pos)),
                            None => assert!(p.free_blocks() < pos.div_ceil(bp)),
                        }
                    }
                    1 => {
                        // grow a live sequence (a decode round)
                        if let Some(i) =
                            (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                        {
                            let target = held[i].1 + rng.range(0, 2 * bp as u64) as usize;
                            let before = p.used_blocks();
                            if p.grow(held[i].0, target).unwrap() {
                                held[i].1 = held[i].1.max(target);
                            } else {
                                assert_eq!(p.used_blocks(), before, "failed grow moved");
                            }
                        }
                    }
                    2 => {
                        // preempt: KV dropped, sequence parked for resume
                        if let Some(i) =
                            (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                        {
                            let (h, pos) = held.swap_remove(i);
                            let freed = p.release(h).unwrap();
                            assert_eq!(freed, pos.max(1).div_ceil(bp));
                            assert!(p.release(h).is_err(), "double release must fail");
                            parked.push(pos);
                        }
                    }
                    _ => {
                        // resume: re-admit at the parked length (the
                        // recompute path re-grows to where it left off)
                        if let Some(i) =
                            (!parked.is_empty()).then(|| rng.below(parked.len() as u64) as usize)
                        {
                            let pos = parked[i];
                            if let Some(h) = p.admit(pos) {
                                parked.swap_remove(i);
                                held.push((h, pos));
                            } else {
                                assert!(p.free_blocks() < pos.max(1).div_ceil(bp));
                            }
                        }
                    }
                }
                // invariants after every step
                let expect: usize = held.iter().map(|&(_, pos)| pos.max(1).div_ceil(bp)).sum();
                assert_eq!(p.used_blocks(), expect);
                assert_eq!(p.used_blocks() + p.free_blocks(), p.capacity_blocks());
                assert!(p.resident_bytes() <= vram);
                assert_eq!(p.active_seqs(), held.len());
                assert_eq!(p.admissible(bp), p.free_blocks());
            }
            for (h, _) in held {
                p.release(h).unwrap();
            }
            assert_eq!(p.used_blocks(), 0);
        });
    }
}
