//! Deterministic fault injection and self-healing policy for the fleet.
//!
//! The paper's recycled-card economics (§5/§6.2) put worn mining boards —
//! x1 risers, no ECC, tired fans — under production load, so failure is a
//! scheduled input here, not an exception path. This module owns the three
//! pieces the serving engine composes:
//!
//! - [`plan`] — a seed-driven [`FaultPlan`]: a script of [`FaultEvent`]s
//!   (card death mid-decode, transient stall, PCIe link downgrade, VRAM
//!   page loss, host-pool swap-in failure, thermal throttle) keyed to a
//!   node's engine round. Same seed, same script, always — chaos runs
//!   reproduce exactly.
//! - [`injector`] — the shared [`FaultInjector`] workers poll once per
//!   engine round; it advances each node's round clock and hands back the
//!   faults due, so injection is deterministic per (seed, node, round)
//!   and independent of wall-clock timing.
//! - [`recovery`] — the [`RecoveryPolicy`] knobs for the self-healing
//!   half: in-flight rescue on node death, bounded retry with exponential
//!   backoff, per-request wall-clock deadlines, and the probation rounds
//!   a flapping card must pass before routing trusts it again.
//!
//! Faults that do not kill a card feed the worker's [`Degrade`] ladder
//! instead of a binary healthy/dead bit: a downgraded link disables swap
//! (the PCIe price that justified it is gone), a thermal throttle sheds
//! tenants already over their rate budget, and VRAM page loss shrinks the
//! admission budget to match the surviving pool.

pub mod injector;
pub mod plan;
pub mod recovery;

pub use injector::FaultInjector;
pub use plan::{FaultEvent, FaultKind, FaultPlan};
pub use recovery::{backoff_delay, RecoveryPolicy};

/// Per-worker degradation state — the ladder a faulted card descends
/// instead of flipping straight to dead. All effects are engine-visible
/// (admission, swap choice, overlay pricing) and none are terminal.
#[derive(Clone, Debug, Default)]
pub struct Degrade {
    /// Swap preemption is off (the link no longer earns its round trip).
    pub swap_disabled: bool,
    /// Decode rounds left to skip entirely (a wedged driver, recovering).
    pub stall_rounds: u64,
    /// Simulated-decode slowdown while throttled (≥ 1.0 when active).
    pub throttle_factor: f64,
    /// Rounds of throttle remaining; 0 = full speed.
    pub throttle_rounds: u64,
    /// KV blocks permanently lost to bad VRAM pages.
    pub lost_blocks: usize,
}

impl Degrade {
    /// Is the thermal ladder step active this round?
    pub fn throttled(&self) -> bool {
        self.throttle_rounds > 0
    }

    /// Multiplier on the overlay's decode seconds-per-token this round.
    pub fn decode_factor(&self) -> f64 {
        if self.throttled() {
            self.throttle_factor.max(1.0)
        } else {
            1.0
        }
    }

    /// Advance one engine round: throttle windows expire on their own.
    pub fn tick_round(&mut self) {
        self.throttle_rounds = self.throttle_rounds.saturating_sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degrade_default_is_a_healthy_card() {
        let d = Degrade::default();
        assert!(!d.swap_disabled);
        assert!(!d.throttled());
        assert_eq!(d.decode_factor(), 1.0);
        assert_eq!(d.stall_rounds, 0);
        assert_eq!(d.lost_blocks, 0);
    }

    #[test]
    fn throttle_expires_after_its_window() {
        let mut d = Degrade { throttle_factor: 3.0, throttle_rounds: 2, ..Degrade::default() };
        assert!(d.throttled());
        assert_eq!(d.decode_factor(), 3.0);
        d.tick_round();
        assert_eq!(d.decode_factor(), 3.0, "round two still throttled");
        d.tick_round();
        assert!(!d.throttled(), "window spent");
        assert_eq!(d.decode_factor(), 1.0);
        d.tick_round(); // must not underflow
    }

    #[test]
    fn decode_factor_never_speeds_the_card_up() {
        let d = Degrade { throttle_factor: 0.25, throttle_rounds: 5, ..Degrade::default() };
        assert_eq!(d.decode_factor(), 1.0, "a throttle below 1.0 clamps to no-op");
    }
}
