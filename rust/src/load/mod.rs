//! Open-loop load harness: seeded arrival generation, per-tenant SLO
//! admission control, and offered-load sweeps through the latency knee.
//!
//! Every bench the repo had before this module was *closed-loop* — the
//! next request waited for the previous answer, so the harness itself
//! throttled to whatever the fleet could serve and the latency knee was
//! invisible. Production traffic is **open-loop**: arrivals come on
//! their own clock whether or not the fleet keeps up, and the paper's
//! viability claim for salvage mining cards lives exactly on that curve —
//! offered load vs goodput, tail latency, SLO attainment, and
//! tokens-per-joule, through and past saturation.
//!
//! The module mirrors the [`crate::faults`] design: everything is a pure
//! seeded data structure on the simulated clock, so the same seed yields
//! a bit-identical arrival stream and bit-identical curves.
//!
//! - [`arrivals`] — seeded arrival processes (Poisson, MMPP bursts,
//!   diurnal) and trace replay, with multi-tenant shared-prefix prompt
//!   structure; an [`ArrivalPlan`] is data, like a `FaultPlan`.
//! - [`admission`] — [`AdmissionCtl`], the deterministic submit-time
//!   admission controller with a hysteretic brownout ladder; threaded
//!   into the live dispatcher (`serve --no-admission-control` ablates).
//! - [`sim`] — a discrete-event fleet model over the calibrated overlay
//!   constants; [`sweep`] produces the offered-load knee curves that the
//!   `serve_openloop` bench row and the acceptance tests pin.
//! - [`harness`] — replays a plan against a *real* [`crate::coordinator`]
//!   server, open-loop, for artifact-gated end-to-end runs.

pub mod admission;
pub mod arrivals;
pub mod harness;
pub mod sim;

pub use admission::{AdmissionConfig, AdmissionCtl, Verdict};
pub use arrivals::{Arrival, ArrivalPlan, ArrivalProcess, WorkloadShape};
pub use harness::{drive, DriveOutcome};
pub use sim::{capacity_rps, simulate, sweep, CurvePoint, NodeModel, SimConfig, SimReport};
pub(crate) use sim::weight_ranks;
