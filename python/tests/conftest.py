import jax

# f64 must be real: the fused-FMA oracle emulates single-rounding FMA in f64.
jax.config.update("jax_enable_x64", True)
