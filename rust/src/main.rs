//! `cmphx` — leader entrypoint.
//!
//! See `cmphx help` (cli::commands::HELP) for the command surface. The
//! binary is self-contained once `make artifacts` has produced the AOT
//! HLO bundle; Python never runs on the request path.

use cmphx::cli::{run, Args};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    match run(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
