"""Pallas port of the mixbench hot loop (Graph 3-1's kernel).

The CUDA original runs, per thread, ``c`` fused multiply-adds on a register
value between one global load and one store. TPU adaptation (DESIGN.md
§Hardware-Adaptation): instead of a warp per element, each grid program owns
a VMEM-resident block of the vector and runs the chain on the whole block —
the VPU is the analog of the CUDA core array, and the HBM↔VMEM schedule that
CUDA expresses with thread-block tiling is a ``BlockSpec``.

Two variants mirror the ``-fmad`` policy:
- ``fused``       — single-rounding FMA semantics (f64 emulation);
- ``decomposed``  — explicit MUL then ADD, double rounding (``-fmad=false``).

The numerics of the two variants genuinely differ, exactly as they do on
silicon; python/tests asserts both against their oracles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _kernel(x_ref, y_ref, o_ref, *, iters: int, fused: bool):
    t = x_ref[...]
    y = y_ref[...]

    # Rounding is pinned with `lax.reduce_precision` — the one rounding op
    # XLA treats as semantically opaque. Everything softer gets undone:
    # optimization barriers are dropped by the Pallas interpreter, and the
    # algebraic simplifier legally collapses f64-detour converts back to
    # f32 ops, which LLVM then re-contracts into FMA — silently undoing
    # `-fmad=false`. Both variants compute the exact product in f64
    # (f32×f32 is exact there); the only difference is whether the product
    # is rounded to f32 precision *before* the add — precisely the FFMA vs
    # FMUL+FADD distinction the CMP limiter keys on.
    def round32(v):
        return jax.lax.reduce_precision(v, exponent_bits=8, mantissa_bits=23)

    if fused:

        def body(_, acc):
            acc64 = acc.astype(jnp.float64)
            s = acc64 * acc64 + y.astype(jnp.float64)
            return round32(s).astype(jnp.float32)

    else:

        def body(_, acc):
            acc64 = acc.astype(jnp.float64)
            m = round32(acc64 * acc64)  # the FMUL's rounding
            return round32(m + y.astype(jnp.float64)).astype(jnp.float32)

    o_ref[...] = jax.lax.fori_loop(0, iters, body, t)


@functools.partial(jax.jit, static_argnames=("iters", "fused"))
def mixbench(x, y, iters: int = 64, fused: bool = True):
    """Run the mixbench chain over a 1-D f32 vector.

    ``len(x)`` must be a multiple of ``BLOCK`` (pad at the call site).
    """
    (n,) = x.shape
    assert n % BLOCK == 0, f"n={n} must be a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    return pl.pallas_call(
        functools.partial(_kernel, iters=iters, fused=fused),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=True,
    )(x, y)
