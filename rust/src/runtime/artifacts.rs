//! Artifact directory handling: locate, validate and compile HLO entries.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// A validated artifact directory (`make artifacts` output).
#[derive(Clone, Debug)]
pub struct ArtifactDir {
    pub root: PathBuf,
}

/// Entries `make artifacts` is contracted to produce.
pub const REQUIRED: &[&str] = &[
    "prefill.hlo.txt",
    "decode.hlo.txt",
    "mixbench_fused.hlo.txt",
    "mixbench_nofma.hlo.txt",
    "qmatmul.hlo.txt",
    "goldens.json",
    "manifest.json",
];

impl ArtifactDir {
    /// Open and validate an artifact directory.
    pub fn open(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        if !root.is_dir() {
            bail!(
                "artifact directory {} does not exist — run `make artifacts`",
                root.display()
            );
        }
        for f in REQUIRED {
            if !root.join(f).is_file() {
                bail!(
                    "artifact {} missing from {} — rerun `make artifacts`",
                    f,
                    root.display()
                );
            }
        }
        Ok(ArtifactDir { root })
    }

    /// Locate the artifact dir: `$CMPHX_ARTIFACTS` or `./artifacts`.
    pub fn discover() -> Result<Self> {
        let root = std::env::var("CMPHX_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(root)
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    /// Load + compile one HLO entry on a PJRT client.
    pub fn compile(
        &self,
        client: &xla::PjRtClient,
        name: &str,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.path(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_rejects_missing_dir() {
        assert!(ArtifactDir::open("/nonexistent/artifacts").is_err());
    }

    #[test]
    fn open_rejects_incomplete_dir() {
        let tmp = std::env::temp_dir().join("cmphx-incomplete-artifacts");
        let _ = std::fs::create_dir_all(&tmp);
        std::fs::write(tmp.join("prefill.hlo.txt"), "HloModule x").unwrap();
        let err = ArtifactDir::open(&tmp).unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn required_list_covers_the_contract() {
        assert!(REQUIRED.contains(&"prefill.hlo.txt"));
        assert!(REQUIRED.contains(&"decode.hlo.txt"));
        assert!(REQUIRED.contains(&"goldens.json"));
    }
}
