//! Admission policy for the continuous-batching engine.
//!
//! This module used to own a stop-the-world window batcher (gather requests
//! under a (size, wait) window, then serve that batch to completion). The
//! fleet engine replaced that loop with **continuous batching** — sequences
//! join the decode round whenever KV pages free — so the batcher is
//! reduced to the admission-policy value type consumed by
//! [`crate::coordinator::scheduler::plan_admission`] (the page-join step)
//! and by the engine's cold-start gather. The paged-KV refactor grew it
//! the page-allocator knobs: block size, the preempt-and-requeue switch,
//! and an optional block budget for forcing page pressure in tests.

use std::time::Duration;

use super::kv::ReclaimPolicy;

/// Admission policy for a node's continuous-batching engine.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Concurrency cap: the most sequences that may share one card's
    /// decode round (bounded further by free KV pages at admission time).
    pub max_batch: usize,
    /// Cold-start gather window: how long an idle engine waits for company
    /// after the first request arrives before prefilling the round. Once
    /// the engine is busy, admission is non-blocking — arrivals join the
    /// next round immediately.
    pub max_wait: Duration,
    /// KV page size in token positions: sequences allocate VRAM in blocks
    /// of this many positions as they grow, instead of reserving
    /// worst-case context up front. vLLM's default block of 16 positions
    /// carries over well to the 8 GB cards.
    pub kv_block_positions: usize,
    /// Preempt-and-requeue: when a decode round cannot allocate growth
    /// pages, evict the longest-remaining sequence back to the waiting
    /// queue (KV dropped, prefill recomputed on resume) so short requests
    /// keep completing. With this off, starved sequences stall until a
    /// peer retires — and fail terminally if nothing ever will.
    pub preempt: bool,
    /// Optional cap on the node's KV block pool, below what its VRAM
    /// would allow. `None` (the default) uses every free byte; tests and
    /// capacity experiments pin this to force page pressure.
    pub kv_block_budget: Option<usize>,
    /// Waiting-queue aging: once a preempted sequence has sat parked for
    /// this many engine rounds, the worker stops admitting new arrivals
    /// until it resumes (reserving freed pages for the replay), and the
    /// resumed sequence is shielded from re-eviction — so sustained short
    /// traffic can no longer park a long sequence indefinitely (the PR 3
    /// waiting-queue starvation follow-up). `0` ages immediately.
    pub aging_rounds: u64,
    /// Prefix sharing: admission chain-hashes the prompt window's blocks
    /// and pins already-resident blocks (copy-on-write on first write)
    /// instead of allocating — identical system prompts cost one physical
    /// copy. On by default; `--no-prefix-cache` gives the ablation arm.
    pub prefix_cache: bool,
    /// Reclaimable KV retention: blocks reaching refcount zero stay in the
    /// radix tree as cache (reclaimed lazily under allocation pressure)
    /// so a returning user re-pins their history instead of re-prefilling
    /// it. On by default; `--no-kv-cache` gives the refcount-zero-frees
    /// ablation arm (the PR 5/7 behaviour). Only meaningful with
    /// `prefix_cache` on.
    pub kv_retention: bool,
    /// Cached-tier reclaim victim selection (`--reclaim-policy`):
    /// strict LRU, or depth-aware — break toward deep private tail
    /// chunks so shallow shared system-prefix blocks survive pressure
    /// longest. LRU stays the default baseline.
    pub reclaim: ReclaimPolicy,
    /// Migration hysteresis, age half: a foreign parked sequence is
    /// claimable only after it has sat parked this many engine rounds —
    /// younger entries are ones their owner is likely to resume next
    /// round, and grabbing them pays two PCIe transfers for nothing.
    /// (The other half of the gate is owner queue depth, checked live.)
    pub migrate_min_age: u64,
    /// Swap-based preemption: when evicting a victim, compare the §3 PCIe
    /// round-trip cost of its KV pages at this card's link width against
    /// the overlay-priced recompute and park the pages in host RAM when
    /// the transfer is cheaper. Off by default (`--swap` enables): the
    /// stock drop-and-replay path stays the baseline.
    pub swap: bool,
    /// Host-RAM budget for swapped-out KV pages, bytes. A victim whose
    /// footprint does not fit falls back to drop-and-recompute.
    pub host_pool_bytes: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            kv_block_positions: 16,
            preempt: true,
            kv_block_budget: None,
            aging_rounds: 16,
            prefix_cache: true,
            kv_retention: true,
            reclaim: ReclaimPolicy::Lru,
            migrate_min_age: 2,
            swap: false,
            host_pool_bytes: 1 << 30,
        }
    }
}

impl BatchPolicy {
    /// The concurrency cap with a floor of one sequence — a zero cap would
    /// make an engine that can never admit anything.
    pub fn concurrency(&self) -> usize {
        self.max_batch.max(1)
    }

    /// The KV page size with a floor of one position — a zero block would
    /// make a pager that can never hold anything.
    pub fn block_positions(&self) -> usize {
        self.kv_block_positions.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_is_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.max_wait > Duration::ZERO);
        assert_eq!(p.concurrency(), p.max_batch);
        assert!(p.kv_block_positions >= 1);
        assert!(p.preempt, "preemption is the default — starvation is not");
        assert!(p.kv_block_budget.is_none());
        assert!(p.aging_rounds > 0, "parked sequences age after a bounded wait");
        assert!(p.prefix_cache, "prefix sharing is the default — it only saves pages");
        assert!(p.kv_retention, "radix-tree retention is the default serving mode");
        assert_eq!(p.reclaim, ReclaimPolicy::Lru, "LRU reclaim stays the baseline");
        assert!(p.migrate_min_age > 0, "claims defer at least one round");
        assert!(!p.swap, "swap preemption is opt-in; drop-and-replay stays the baseline");
        assert!(p.host_pool_bytes > 0, "an armed swap path needs host headroom");
    }

    #[test]
    fn zero_cap_is_floored_to_one() {
        let p = BatchPolicy {
            max_batch: 0,
            ..BatchPolicy::default()
        };
        assert_eq!(p.concurrency(), 1);
    }

    #[test]
    fn zero_block_is_floored_to_one_position() {
        let p = BatchPolicy {
            kv_block_positions: 0,
            ..BatchPolicy::default()
        };
        assert_eq!(p.block_positions(), 1);
    }
}
