//! FluidX3D-style lattice-Boltzmann workload (§6.2's second reuse case:
//! "memory bandwidth-intensive applications in cost-sensitive industrial
//! simulations (e.g., FluidX3D)").
//!
//! D3Q19 LBM stream-collide: per cell per step, 19 f32 populations are
//! read and written (152 B of traffic) against ~350 FLOPs of collision
//! math — operational intensity ≈ 2.3 flops/byte, far left of the ridge:
//! bandwidth-bound on every modern GPU, which is exactly why a CMP 170HX
//! keeps up with an A100 here. FluidX3D reports MLUPs (mega lattice
//! updates per second).

use crate::device::DeviceSpec;
use crate::isa::class::InstClass;
use crate::isa::ir::{Kernel, Stmt, Traffic};
use crate::isa::pass::{apply_fmad, FmadPolicy};
use crate::sim::{simulate_lowered, LoweredKernel, SimConfig};

/// D3Q19 lattice constants.
pub const Q: u64 = 19;
pub const BYTES_PER_CELL: u64 = 2 * Q * 4; // read + write all populations
/// Collision math per cell (BGK with common optimizations): ~350 FLOPs,
/// roughly half fused.
pub const FMA_PER_CELL: u64 = 110;
pub const MULADD_PER_CELL: u64 = 130;

/// One stream-collide step over an `n³` cube.
pub fn step_kernel(n: u64) -> Kernel {
    let cells = n * n * n;
    Kernel::new(format!("lbm.d3q19.{n}^3"), cells, 256)
        .with_body(vec![
            Stmt::op(InstClass::Ldg, Q),
            Stmt::op(InstClass::Ffma, FMA_PER_CELL),
            Stmt::op(InstClass::Fmul, MULADD_PER_CELL / 2),
            Stmt::op(InstClass::Fadd, MULADD_PER_CELL / 2),
            Stmt::op(InstClass::Stg, Q),
        ])
        .with_traffic(Traffic::coalesced(cells * Q * 4, cells * Q * 4))
}

/// Simulate one step; returns (MLUPs, memory_bound).
pub fn mlups(dev: &DeviceSpec, n: u64, policy: FmadPolicy) -> (f64, bool) {
    let lk = LoweredKernel::lower(&apply_fmad(&step_kernel(n), policy));
    let t = simulate_lowered(&lk, dev, &SimConfig::default());
    let cells = (n * n * n) as f64;
    (cells / t.time_s / 1e6, t.memory_bound())
}

/// Largest cube that fits in VRAM (FluidX3D needs ~2× the lattice for
/// auxiliary fields; 8 GB caps around 330³).
pub fn max_cube(dev: &DeviceSpec) -> u64 {
    let bytes_per_cell = Q * 4 * 2; // populations + aux
    let mut n = 16;
    while (n + 16) * (n + 16) * (n + 16) * bytes_per_cell <= dev.mem.capacity_bytes {
        n += 16;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::registry;

    #[test]
    fn lbm_is_bandwidth_bound_everywhere() {
        // Even on the crippled card the collision math hides behind the
        // 152 B/cell of traffic — on the *default* build it tips compute-
        // bound though, which is the §6.2 caveat for running unpatched.
        let cmp = registry::cmp170hx();
        let (_, nofma_bound) = mlups(&cmp, 256, FmadPolicy::Decomposed);
        assert!(nofma_bound, "noFMA LBM must be memory-bound");
        let a100 = registry::a100_pcie();
        let (_, a100_bound) = mlups(&a100, 256, FmadPolicy::Fused);
        assert!(a100_bound);
    }

    #[test]
    fn restored_cmp_matches_a100_within_bandwidth_ratio() {
        // The §6.2 claim, quantified: MLUPs ratio ≈ bandwidth ratio (0.96).
        let cmp = mlups(&registry::cmp170hx(), 256, FmadPolicy::Decomposed).0;
        let a100 = mlups(&registry::a100_pcie(), 256, FmadPolicy::Fused).0;
        let ratio = cmp / a100;
        assert!(ratio > 0.93 && ratio < 1.0, "{ratio}");
    }

    #[test]
    fn default_build_cripples_lbm() {
        // Without the fmad rebuild, the 110 FFMA/cell hit the 1/32 wall
        // and the card falls well behind its own bandwidth.
        let cmp = registry::cmp170hx();
        let crippled = mlups(&cmp, 256, FmadPolicy::Fused).0;
        let restored = mlups(&cmp, 256, FmadPolicy::Decomposed).0;
        assert!(restored / crippled > 4.0, "{restored} vs {crippled}");
    }

    #[test]
    fn mlups_scale_is_plausible() {
        // 1314 GB/s effective / 152 B per cell ≈ 8.6 GLUPs upper bound.
        let (m, _) = mlups(&registry::cmp170hx(), 256, FmadPolicy::Decomposed);
        assert!(m > 5_000.0 && m < 9_000.0, "{m}");
    }

    #[test]
    fn max_cube_respects_vram() {
        let n = max_cube(&registry::cmp170hx());
        // 368³ × 152 B ≈ 7.6 GB of the 8 GiB card
        assert!(n >= 336 && n <= 384, "{n}");
        assert!(max_cube(&registry::a100_pcie()) > n);
    }
}
