//! Pure discrete-event open-loop fleet simulator.
//!
//! The overload acceptance tests need to push a fleet 1.5× past its
//! capacity and compare admission-control policies on *bit-identical*
//! arrival streams — thousands of requests, in CI, with or without the
//! AOT artifacts present. This module runs that experiment on a pure
//! M/G/k-style model of the fleet instead of the threaded engine: each
//! node is its calibrated service rate (seconds per prefill/decode token,
//! watts per phase — the same quantities the per-card overlays carry),
//! requests route to the least-backlogged live card, and the only clock
//! is the arrival stream's simulated clock. No threads, no wall time, no
//! randomness outside the seeded [`ArrivalPlan`] and
//! [`crate::faults::FaultPlan`] — so [`simulate`] is a *function*:
//! same inputs, same [`SimReport`], byte for byte, which is what lets a
//! knee curve be asserted equal across runs and across chaos replays.
//!
//! The control plane mirrors the real dispatcher's overload behavior:
//! - **Deadline gate** (always on, like `--deadline-ms` / per-tenant
//!   SLOs): a request whose backlog already exceeds its SLO when its turn
//!   comes is failed at dispatch without service — the reactive defense.
//! - **Admission control** (the [`super::AdmissionCtl`] arm): the same
//!   prediction is made at *submit* from backlog + own service demand,
//!   and doomed requests are shed before any card time is spent. Served-
//!   but-late requests are the waste the reactive arm cannot avoid: they
//!   burn full service and energy for tokens that miss their contract.
//! - **Chaos**: a seeded fault plan fires on each node's service-round
//!   clock — deaths remove the card, stalls freeze its backlog forward,
//!   throttles stretch its service times, page losses and swap failures
//!   charge re-prefill penalties — composing overload with the PR 6
//!   fault model deterministically.
//!
//! [`sweep`] runs one plan across a ladder of load multipliers
//! ([`ArrivalPlan::scaled`]) and returns the offered-load vs
//! goodput/latency/attainment/energy curve the `serve_openloop` bench row
//! records.

use std::collections::VecDeque;

use super::admission::{AdmissionConfig, AdmissionCtl, Verdict};
use super::arrivals::{token_fingerprint, ArrivalPlan};
use crate::faults::{FaultKind, FaultPlan};

/// One card's calibrated service model — the overlay quantities the real
/// dispatcher estimates from (§4 device model).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeModel {
    pub prefill_s_per_token: f64,
    pub decode_s_per_token: f64,
    pub prefill_w: f64,
    pub decode_w: f64,
}

impl NodeModel {
    /// A CMP 170HX-like serving profile: compute-starved prefill at the
    /// TDP envelope, HBM2e-fed decode at the §4.4 measured draw.
    pub fn cmp170hx_like() -> Self {
        NodeModel {
            prefill_s_per_token: 2.0e-4,
            decode_s_per_token: 2.0e-3,
            prefill_w: 250.0,
            decode_w: 75.0,
        }
    }

    /// Base service seconds for one request on this card, unthrottled.
    pub fn service_s(&self, prompt_len: usize, max_tokens: usize) -> f64 {
        prompt_len as f64 * self.prefill_s_per_token + max_tokens as f64 * self.decode_s_per_token
    }
}

/// The simulated fleet and its overload policy.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub nodes: Vec<NodeModel>,
    /// Per-tenant SLO contract, seconds (index = tenant id; `None` = no
    /// contract, never shed, never counted for attainment).
    pub slo_s: Vec<Option<f64>>,
    /// Per-tenant fair-share weights (brownout shed order).
    pub weights: Vec<f64>,
    /// `Some` = the admission-control arm; `None` = the reactive-only
    /// `--no-admission-control` ablation.
    pub admission: Option<AdmissionConfig>,
    /// Optional seeded chaos script, fired on service-round clocks.
    pub chaos: Option<FaultPlan>,
    /// Simulated seconds one `TransientStall` round freezes a card for
    /// (also scales the link/swap fault penalties).
    pub stall_unit_s: f64,
}

impl SimConfig {
    /// A homogeneous fleet with one shared SLO across equal-weight
    /// tenants and admission control at defaults.
    pub fn uniform(nodes: usize, model: NodeModel, tenants: usize, slo_s: Option<f64>) -> Self {
        assert!(nodes > 0 && tenants > 0);
        SimConfig {
            nodes: vec![model; nodes],
            slo_s: vec![slo_s; tenants],
            weights: vec![1.0; tenants],
            admission: Some(AdmissionConfig::default()),
            chaos: None,
            stall_unit_s: 0.05,
        }
    }

    /// The same config with the admission controller removed (ablation).
    pub fn without_admission(&self) -> Self {
        SimConfig {
            admission: None,
            ..self.clone()
        }
    }
}

/// Outcome of one open-loop run. Derives `PartialEq` so same-seed
/// reproducibility is a single assert over the whole report, fingerprints
/// included.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimReport {
    /// Requests offered by the arrival stream.
    pub offered: usize,
    /// Requests served to completion (tokens delivered, timely or not).
    pub completed: usize,
    /// Completed requests that finished past their tenant's SLO — served
    /// waste: full service and energy for unusable answers.
    pub served_late: usize,
    /// Requests shed at submit by the admission controller.
    pub shed_admission: usize,
    /// Requests failed at dispatch because their backlog already exceeded
    /// their SLO (the reactive deadline gate).
    pub deadline_misses: usize,
    /// Requests lost because no live node remained.
    pub lost_no_node: usize,
    /// Requests whose tenant carries an SLO contract.
    pub slo_eligible: usize,
    /// SLO-eligible requests that completed within their contract.
    pub slo_met: usize,
    /// Tokens that count: SLO-met requests plus contract-less completions.
    pub goodput_tokens: u64,
    /// `goodput_tokens` over the stream's horizon (last completion).
    pub goodput_tps: f64,
    /// Simulated energy spent, joules — including the waste on late
    /// completions.
    pub energy_j: f64,
    /// Useful tokens per joule: `goodput_tokens / energy_j`.
    pub goodput_tokens_per_joule: f64,
    /// Completion-latency percentiles over completed requests, seconds.
    pub p50_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
    /// Most requests simultaneously accepted-but-unfinished.
    pub peak_queue: usize,
    /// In-flight requests at the last arrival instant.
    pub final_queue: usize,
    /// Largest backlog any routed request saw ahead of it, seconds.
    pub peak_backlog_s: f64,
    /// `(arrival index, served-token fingerprint)` for every completed
    /// request, in service order — the bit-identity witness for the
    /// below-knee equivalence of policy arms.
    pub served: Vec<(u64, u64)>,
}

impl SimReport {
    /// Fraction of SLO-eligible requests that met their contract.
    pub fn slo_attainment(&self) -> Option<f64> {
        if self.slo_eligible == 0 {
            None
        } else {
            Some(self.slo_met as f64 / self.slo_eligible as f64)
        }
    }
}

/// One point of an offered-load sweep.
#[derive(Clone, Debug, PartialEq)]
pub struct CurvePoint {
    /// Load multiplier applied to the base plan.
    pub multiplier: f64,
    /// Realized offered rate at this multiplier, requests/s.
    pub offered_rps: f64,
    pub report: SimReport,
}

/// Aggregate service capacity for the plan's mean request shape,
/// requests/second — the knee's natural x-axis unit.
pub fn capacity_rps(plan: &ArrivalPlan, cfg: &SimConfig) -> f64 {
    if plan.is_empty() {
        return 0.0;
    }
    let n = plan.len() as f64;
    let mean_prompt = plan.arrivals.iter().map(|a| a.prompt.len()).sum::<usize>() as f64 / n;
    let mean_tokens = plan.arrivals.iter().map(|a| a.max_tokens).sum::<usize>() as f64 / n;
    cfg.nodes
        .iter()
        .map(|m| {
            let svc = mean_prompt * m.prefill_s_per_token + mean_tokens * m.decode_s_per_token;
            if svc > 0.0 {
                1.0 / svc
            } else {
                0.0
            }
        })
        .sum()
}

/// Tenant weight ranks in `[0, 1]`: 0 = strictly lightest, 1 = heaviest.
/// A lone tenant ranks 1.0 so brownout levels never shed the only
/// customer's near-SLO traffic.
pub(crate) fn weight_ranks(weights: &[f64]) -> Vec<f64> {
    if weights.len() <= 1 {
        return vec![1.0; weights.len()];
    }
    let denom = (weights.len() - 1) as f64;
    weights
        .iter()
        .map(|&w| weights.iter().filter(|&&o| o < w).count() as f64 / denom)
        .collect()
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run one open-loop stream against the fleet model. Pure: same
/// `(plan, cfg)` → same report, bit for bit.
pub fn simulate(plan: &ArrivalPlan, cfg: &SimConfig) -> SimReport {
    assert!(!cfg.nodes.is_empty(), "simulating an empty fleet");
    let n = cfg.nodes.len();
    let mut free_at = vec![0.0_f64; n];
    let mut served_rounds = vec![0_u64; n];
    let mut alive = vec![true; n];
    // (slowdown factor, service rounds it still applies to)
    let mut throttle = vec![(1.0_f64, 0_u64); n];
    // one-shot re-work (page loss, swap corruption) charged to the
    // node's next served request
    let mut penalty_s = vec![0.0_f64; n];
    let mut faults: Vec<VecDeque<(u64, FaultKind)>> = (0..n)
        .map(|node| match &cfg.chaos {
            Some(plan) => plan.for_node(node).into(),
            None => VecDeque::new(),
        })
        .collect();
    let ranks = weight_ranks(&cfg.weights);
    let mut ctl = cfg.admission.map(AdmissionCtl::new);

    let mut report = SimReport {
        offered: plan.len(),
        ..SimReport::default()
    };
    let mut inflight: Vec<f64> = Vec::new();
    let mut latencies: Vec<f64> = Vec::new();
    let mut horizon = 0.0_f64;

    for (idx, a) in plan.arrivals.iter().enumerate() {
        let t = a.at_s;
        horizon = horizon.max(t);
        inflight.retain(|&done| done > t);
        // chaos due on each node's service-round clock fires before
        // routing sees the fleet
        for node in 0..n {
            loop {
                match faults[node].front() {
                    Some(&(round, _)) if round <= served_rounds[node] => {}
                    _ => break,
                }
                let (_, kind) = faults[node].pop_front().expect("front checked");
                match kind {
                    FaultKind::NodeDeath => alive[node] = false,
                    FaultKind::TransientStall { rounds } => {
                        free_at[node] = free_at[node].max(t) + rounds as f64 * cfg.stall_unit_s;
                    }
                    FaultKind::ThermalThrottle { factor, rounds } if rounds > 0 => {
                        throttle[node] = (factor.max(1.0), rounds);
                    }
                    FaultKind::ThermalThrottle { .. } => {}
                    FaultKind::LinkDowngrade { .. } | FaultKind::SwapInFailure => {
                        penalty_s[node] += 0.5 * cfg.stall_unit_s;
                    }
                    FaultKind::VramPageLoss { blocks } => {
                        penalty_s[node] += blocks as f64 * 8.0 * cfg.nodes[node].prefill_s_per_token;
                    }
                }
            }
        }

        let slo = cfg.slo_s.get(a.tenant.0).copied().flatten();
        if slo.is_some() {
            report.slo_eligible += 1;
        }

        // least-backlog routing over live cards (ties → lowest index)
        let mut best: Option<(usize, f64)> = None;
        for node in 0..n {
            if !alive[node] {
                continue;
            }
            let backlog = (free_at[node] - t).max(0.0);
            let better = match best {
                None => true,
                Some((_, b)) => backlog < b,
            };
            if better {
                best = Some((node, backlog));
            }
        }
        let Some((node, backlog)) = best else {
            report.lost_no_node += 1;
            continue;
        };
        report.peak_backlog_s = report.peak_backlog_s.max(backlog);

        let (tf, throttle_left) = throttle[node];
        let model = cfg.nodes[node];
        let penalty = penalty_s[node];
        let svc = model.service_s(a.prompt.len(), a.max_tokens) * tf + penalty;

        // submit-time admission: shed before any service is spent
        if let Some(ctl) = ctl.as_mut() {
            if let Verdict::Shed { .. } =
                ctl.decide(backlog + svc, slo, ranks.get(a.tenant.0).copied().unwrap_or(1.0))
            {
                report.shed_admission += 1;
                continue;
            }
        }
        // the dispatcher's reactive deadline gate: stale work fails
        // before prefill, but only after it already queued
        if let Some(s) = slo {
            if backlog >= s {
                report.deadline_misses += 1;
                continue;
            }
        }

        // serve
        penalty_s[node] = 0.0;
        let done = t + backlog + svc;
        free_at[node] = done;
        served_rounds[node] += 1;
        if throttle_left > 0 {
            throttle[node] = if throttle_left == 1 { (1.0, 0) } else { (tf, throttle_left - 1) };
        }
        horizon = horizon.max(done);
        inflight.push(done);
        report.peak_queue = report.peak_queue.max(inflight.len());

        let latency = done - t;
        latencies.push(latency);
        report.completed += 1;
        report.served.push((idx as u64, token_fingerprint(&a.prompt, a.max_tokens)));
        report.energy_j += (a.prompt.len() as f64 * model.prefill_s_per_token * tf + penalty)
            * model.prefill_w
            + a.max_tokens as f64 * model.decode_s_per_token * tf * model.decode_w;
        match slo {
            Some(s) if latency <= s => {
                report.slo_met += 1;
                report.goodput_tokens += a.max_tokens as u64;
            }
            Some(_) => report.served_late += 1,
            None => report.goodput_tokens += a.max_tokens as u64,
        }
    }

    report.final_queue = inflight.len();
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    report.p50_s = pct(&latencies, 0.5);
    report.p99_s = pct(&latencies, 0.99);
    report.p999_s = pct(&latencies, 0.999);
    if horizon > 0.0 {
        report.goodput_tps = report.goodput_tokens as f64 / horizon;
    }
    if report.energy_j > 0.0 {
        report.goodput_tokens_per_joule = report.goodput_tokens as f64 / report.energy_j;
    }
    report
}

/// Sweep one plan across load multipliers: the knee curve. Every point
/// serves the same requests on a compressed clock, each with a fresh
/// admission controller.
pub fn sweep(plan: &ArrivalPlan, multipliers: &[f64], cfg: &SimConfig) -> Vec<CurvePoint> {
    multipliers
        .iter()
        .map(|&m| {
            let scaled = plan.scaled(m);
            let offered_rps = scaled.offered_rps();
            CurvePoint {
                multiplier: m,
                offered_rps,
                report: simulate(&scaled, cfg),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{FaultEvent, FaultPlan};
    use crate::load::arrivals::{ArrivalProcess, WorkloadShape};

    fn base_plan(seed: u64) -> ArrivalPlan {
        // ~0.8 s of service demand per second offered against a 2-card
        // fleet: comfortably below the knee
        ArrivalPlan::seeded(
            ArrivalProcess::Poisson { rps: 30.0 },
            seed,
            30.0,
            &WorkloadShape {
                tenants: 2,
                prompt_len: 32,
                shared_prefix_len: 16,
                families: 2,
                max_tokens: 8,
            },
        )
    }

    fn fleet(slo_s: Option<f64>) -> SimConfig {
        SimConfig::uniform(2, NodeModel::cmp170hx_like(), 2, slo_s)
    }

    #[test]
    fn below_the_knee_everything_meets_its_contract() {
        let plan = base_plan(0xFEED);
        let cfg = fleet(Some(2.0));
        assert!(plan.offered_rps() < 0.7 * capacity_rps(&plan, &cfg), "stays under the knee");
        let r = simulate(&plan, &cfg);
        assert_eq!(r.shed_admission, 0, "no shedding below the knee");
        assert_eq!(r.deadline_misses, 0);
        assert_eq!(r.lost_no_node, 0);
        assert_eq!(r.completed, r.offered);
        assert_eq!(r.slo_attainment(), Some(1.0));
        assert_eq!(r.served.len(), r.offered);
        assert!(r.goodput_tokens > 0 && r.energy_j > 0.0);
        assert!(r.p50_s <= r.p99_s && r.p99_s <= r.p999_s);
    }

    #[test]
    fn same_seed_reproduces_the_whole_report_bit_identically() {
        let cfg = fleet(Some(1.0));
        let chaos = SimConfig {
            chaos: Some(FaultPlan::seeded(0xBAD, 2, 40, 0.1)),
            ..cfg.clone()
        };
        for c in [&cfg, &chaos] {
            let a = simulate(&base_plan(0x5EED), c);
            let b = simulate(&base_plan(0x5EED), c);
            assert_eq!(a, b, "simulate is a pure function of (plan, cfg)");
        }
        let s1 = sweep(&base_plan(0x5EED), &[0.5, 1.0, 1.8], &chaos);
        let s2 = sweep(&base_plan(0x5EED), &[0.5, 1.0, 1.8], &chaos);
        assert_eq!(s1, s2, "curves replay bit-identically under chaos");
        let other = simulate(&base_plan(0x5EEE), &cfg);
        assert_ne!(simulate(&base_plan(0x5EED), &cfg).served, other.served);
    }

    #[test]
    fn admission_control_wins_past_the_knee() {
        let plan = base_plan(0xA3);
        let cfg = fleet(Some(0.5));
        let hot = plan.scaled(2.0 * capacity_rps(&plan, &cfg) / plan.offered_rps());
        let ac = simulate(&hot, &cfg);
        let bare = simulate(&hot, &cfg.without_admission());
        assert!(ac.shed_admission > 0, "overload must engage the controller");
        assert!(
            bare.deadline_misses + bare.served_late > bare.offered / 4,
            "the reactive arm collapses into a miss storm: {bare:?}"
        );
        assert!(ac.goodput_tokens > bare.goodput_tokens, "{ac:?} vs {bare:?}");
        assert!(ac.slo_attainment() > bare.slo_attainment());
    }

    #[test]
    fn node_death_falls_back_and_total_death_loses() {
        let plan = base_plan(7);
        let cfg = fleet(Some(5.0));
        let one_dead = SimConfig {
            chaos: Some(FaultPlan::script(vec![FaultEvent {
                node: 0,
                round: 0,
                kind: FaultKind::NodeDeath,
            }])),
            ..cfg.clone()
        };
        let r = simulate(&plan, &one_dead);
        assert_eq!(r.lost_no_node, 0, "the survivor absorbs everything");
        assert!(r.completed + r.deadline_misses + r.shed_admission == r.offered);
        let all_dead = SimConfig {
            chaos: Some(FaultPlan::script(
                (0..2)
                    .map(|node| FaultEvent { node, round: 0, kind: FaultKind::NodeDeath })
                    .collect(),
            )),
            ..cfg
        };
        let r = simulate(&plan, &all_dead);
        assert_eq!(r.lost_no_node, r.offered, "a dead fleet serves nothing");
        assert_eq!(r.completed, 0);
    }

    #[test]
    fn chaos_stretches_the_tail_but_stays_deterministic() {
        let plan = base_plan(0xC0);
        let calm = fleet(Some(4.0));
        let stormy = SimConfig {
            chaos: Some(FaultPlan::script(vec![
                FaultEvent {
                    node: 0,
                    round: 5,
                    kind: FaultKind::TransientStall { rounds: 4 },
                },
                FaultEvent {
                    node: 1,
                    round: 5,
                    kind: FaultKind::ThermalThrottle { factor: 3.0, rounds: 20 },
                },
                FaultEvent {
                    node: 0,
                    round: 10,
                    kind: FaultKind::VramPageLoss { blocks: 2 },
                },
            ])),
            ..calm.clone()
        };
        let base = simulate(&plan, &calm);
        let hit = simulate(&plan, &stormy);
        assert!(hit.p999_s > base.p999_s, "faults must cost tail latency");
        assert_eq!(simulate(&plan, &stormy), hit);
    }

    #[test]
    fn weight_ranks_order_lightest_to_heaviest() {
        assert_eq!(weight_ranks(&[1.0]), vec![1.0], "a lone tenant is never brownout bait");
        let r = weight_ranks(&[1.0, 3.0, 2.0]);
        assert_eq!(r, vec![0.0, 1.0, 0.5]);
        let equal = weight_ranks(&[2.0, 2.0]);
        assert_eq!(equal, vec![0.0, 0.0], "equal weights tie at the bottom");
    }

    #[test]
    fn capacity_scales_with_fleet_size() {
        let plan = base_plan(1);
        let one = SimConfig::uniform(1, NodeModel::cmp170hx_like(), 1, None);
        let four = SimConfig::uniform(4, NodeModel::cmp170hx_like(), 1, None);
        let c1 = capacity_rps(&plan, &one);
        crate::testutil::assert_close(capacity_rps(&plan, &four), 4.0 * c1, 1e-12);
        assert!(c1 > 0.0);
    }
}
