//! Instruction classes and execution pipes.
//!
//! Classes are the granularity at which (a) the device prices throughput and
//! (b) the CMP limiter throttles. Pipes group classes that contend for the
//! same issue/execution resources inside an SM.

/// Scalar element type of an arithmetic instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F16,
    F32,
    F64,
    I32,
    I8,
}

impl DType {
    /// Bytes per scalar element.
    pub fn bytes(self) -> u64 {
        match self {
            DType::F16 => 2,
            DType::F32 => 4,
            DType::F64 => 8,
            DType::I32 => 4,
            DType::I8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F16 => "f16",
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I32 => "i32",
            DType::I8 => "i8",
        }
    }
}

/// Execution pipe inside an SM. Classes sharing a pipe serialize against
/// each other; distinct pipes overlap (the timing engine takes the max).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pipe {
    /// FP32 / scalar-FP16 / INT32 cores (the "CUDA core" pipe on GA100 —
    /// fp32 and int32 issue on shared dispatch ports).
    Core,
    /// Dedicated FP64 units.
    Fp64,
    /// Packed-half (half2) vector pipe — on GA100 this is the 4×-rate
    /// non-tensor FP16 path.
    Half2,
    /// Tensor cores (present but unusable on CMP 170HX per the paper: no
    /// driver support is exposed; modeled for the A100 reference).
    Tensor,
    /// Load/store units — memory instructions; actual transfer time is
    /// modeled by [`crate::memhier`], but LSU issue slots still contend.
    Lsu,
}

/// Instruction classes priced by the device model. `*Fma` variants are the
/// fused classes the CMP limiter throttles; the unfused `*Mul`/`*Add`
/// variants are what the `-fmad=false` pass emits instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum InstClass {
    // fp32 scalar
    Ffma,
    Fmul,
    Fadd,
    // fp64 scalar
    Dfma,
    Dmul,
    Dadd,
    // packed fp16 (2-wide SIMD within a lane)
    Hfma2,
    Hmul2,
    Hadd2,
    // scalar fp16 (issues on the Core pipe at half rate — no dual issue;
    // this is the PyTorch/GPU-Burn path that only reaches ~6.3 TFLOPS)
    Hfma,
    Hmul,
    Hadd,
    // int32
    Imad,
    Imul,
    Iadd,
    // int8 4-wide dot-product-accumulate
    Dp4a,
    // tensor-core HMMA (A100 reference device only)
    HmmaF16,
    // transcendental / special function
    Mufu,
    // memory
    Ldg,
    Stg,
}

/// All classes, for registry/table iteration. Ordered by discriminant so
/// that `ALL_CLASSES[c.index()] == c` — the array-backed
/// [`crate::isa::InstMix`] relies on this correspondence.
pub const ALL_CLASSES: &[InstClass] = &[
    InstClass::Ffma,
    InstClass::Fmul,
    InstClass::Fadd,
    InstClass::Dfma,
    InstClass::Dmul,
    InstClass::Dadd,
    InstClass::Hfma2,
    InstClass::Hmul2,
    InstClass::Hadd2,
    InstClass::Hfma,
    InstClass::Hmul,
    InstClass::Hadd,
    InstClass::Imad,
    InstClass::Imul,
    InstClass::Iadd,
    InstClass::Dp4a,
    InstClass::HmmaF16,
    InstClass::Mufu,
    InstClass::Ldg,
    InstClass::Stg,
];

/// Number of instruction classes — the dimension of the flat count array
/// inside [`crate::isa::InstMix`].
pub const N_CLASSES: usize = ALL_CLASSES.len();

/// All execution pipes, ordered by discriminant (`Pipe::index` order).
pub const ALL_PIPES: &[Pipe] = &[Pipe::Core, Pipe::Fp64, Pipe::Half2, Pipe::Tensor, Pipe::Lsu];

/// Number of execution pipes — the dimension of per-pipe accumulators in
/// the timing engine.
pub const N_PIPES: usize = ALL_PIPES.len();

impl Pipe {
    /// Dense index of this pipe (discriminant order; matches [`ALL_PIPES`]).
    pub const fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Pipe::Core => "core",
            Pipe::Fp64 => "fp64",
            Pipe::Half2 => "half2",
            Pipe::Tensor => "tensor",
            Pipe::Lsu => "lsu",
        }
    }
}

impl InstClass {
    /// Dense index of this class (discriminant order; matches
    /// [`ALL_CLASSES`]). O(1) — the array-mix lookup key.
    pub const fn index(self) -> usize {
        self as usize
    }

    /// Pipe this class issues on.
    pub fn pipe(self) -> Pipe {
        use InstClass::*;
        match self {
            Ffma | Fmul | Fadd | Hfma | Hmul | Hadd | Imad | Imul | Iadd | Dp4a | Mufu => {
                Pipe::Core
            }
            Dfma | Dmul | Dadd => Pipe::Fp64,
            Hfma2 | Hmul2 | Hadd2 => Pipe::Half2,
            HmmaF16 => Pipe::Tensor,
            Ldg | Stg => Pipe::Lsu,
        }
    }

    /// Floating-point operations contributed per instruction (0 for int/mem).
    /// FMA counts as 2 (mul + add), packed-half doubles per lane width, and
    /// one HMMA warp-instruction covers a 16×16×16 MMA fragment slice worth
    /// 512 FLOPs (the convention the rate table prices).
    pub fn flops(self) -> u64 {
        use InstClass::*;
        match self {
            Ffma | Dfma | Hfma => 2,
            Fmul | Fadd | Dmul | Dadd | Hmul | Hadd => 1,
            Hfma2 => 4,
            Hmul2 | Hadd2 => 2,
            HmmaF16 => 512,
            Mufu => 1,
            _ => 0,
        }
    }

    /// Relative dynamic energy per op (FLOP or IOP) versus a scalar fp32
    /// FLOP. Narrower datapaths burn less; the fp64 path burns about twice;
    /// tensor cores amortize control over a whole MMA fragment. These
    /// weights are what let a 250 W card sustain ~49 TFLOPS of packed-half
    /// (Graph 3-2) while FP32 DVFS-caps near 19.5 on the A100.
    pub fn energy_weight(self) -> f64 {
        use InstClass::*;
        match self {
            Dfma | Dmul | Dadd => 2.0,
            Hfma2 | Hmul2 | Hadd2 | Hfma | Hmul | Hadd => 0.2,
            Imad | Imul | Iadd => 0.8,
            Dp4a => 0.25,
            HmmaF16 => 0.08,
            _ => 1.0,
        }
    }

    /// Integer operations contributed per instruction.
    pub fn iops(self) -> u64 {
        use InstClass::*;
        match self {
            Imad => 2,
            Imul | Iadd => 1,
            // dp4a: 4 multiplies + 4 adds (incl. accumulate) per instruction
            // — the convention OpenCL-Benchmark uses when reporting TIOPs.
            Dp4a => 8,
            _ => 0,
        }
    }

    /// Is this a fused multiply-add class (the limiter's trigger set)?
    pub fn is_fused(self) -> bool {
        matches!(
            self,
            InstClass::Ffma | InstClass::Dfma | InstClass::Hfma | InstClass::Hfma2
        )
    }

    /// The unfused (mul, add) pair the `-fmad=false` pass decomposes a fused
    /// class into; `None` for non-fused classes.
    pub fn decomposed(self) -> Option<(InstClass, InstClass)> {
        match self {
            InstClass::Ffma => Some((InstClass::Fmul, InstClass::Fadd)),
            InstClass::Dfma => Some((InstClass::Dmul, InstClass::Dadd)),
            InstClass::Hfma => Some((InstClass::Hmul, InstClass::Hadd)),
            InstClass::Hfma2 => Some((InstClass::Hmul2, InstClass::Hadd2)),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        use InstClass::*;
        match self {
            Ffma => "FFMA",
            Fmul => "FMUL",
            Fadd => "FADD",
            Dfma => "DFMA",
            Dmul => "DMUL",
            Dadd => "DADD",
            Hfma2 => "HFMA2",
            Hmul2 => "HMUL2",
            Hadd2 => "HADD2",
            Hfma => "HFMA",
            Hmul => "HMUL",
            Hadd => "HADD",
            Imad => "IMAD",
            Imul => "IMUL",
            Iadd => "IADD",
            Dp4a => "DP4A",
            HmmaF16 => "HMMA.F16",
            Mufu => "MUFU",
            Ldg => "LDG",
            Stg => "STG",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fused_classes_decompose_to_same_pipe_and_flops() {
        for &c in ALL_CLASSES {
            if let Some((m, a)) = c.decomposed() {
                assert!(c.is_fused());
                // Decomposition preserves total FLOPs (2 per fused op) and
                // stays on the same pipe — the pass changes instruction
                // count, never where the work runs.
                assert_eq!(m.flops() + a.flops(), c.flops());
                assert_eq!(m.pipe(), c.pipe());
                assert_eq!(a.pipe(), c.pipe());
                assert!(!m.is_fused() && !a.is_fused());
            }
        }
    }

    #[test]
    fn exactly_four_fused_classes() {
        let fused: Vec<_> = ALL_CLASSES.iter().filter(|c| c.is_fused()).collect();
        assert_eq!(fused.len(), 4);
    }

    #[test]
    fn decomposition_preserves_energy_weight() {
        for &c in ALL_CLASSES {
            if let Some((m, a)) = c.decomposed() {
                assert_eq!(m.energy_weight(), c.energy_weight());
                assert_eq!(a.energy_weight(), c.energy_weight());
            }
        }
    }

    #[test]
    fn hmma_prices_a_fragment() {
        assert_eq!(InstClass::HmmaF16.flops(), 512);
    }

    #[test]
    fn memory_classes_have_no_flops() {
        assert_eq!(InstClass::Ldg.flops(), 0);
        assert_eq!(InstClass::Stg.flops(), 0);
        assert_eq!(InstClass::Ldg.pipe(), Pipe::Lsu);
    }

    #[test]
    fn dp4a_counts_eight_iops() {
        assert_eq!(InstClass::Dp4a.iops(), 8);
        assert_eq!(InstClass::Imad.iops(), 2);
    }

    #[test]
    fn all_classes_is_in_discriminant_order() {
        // The array-backed InstMix indexes by discriminant; ALL_CLASSES must
        // enumerate exactly that order with no gaps or duplicates.
        assert_eq!(ALL_CLASSES.len(), N_CLASSES);
        for (i, &c) in ALL_CLASSES.iter().enumerate() {
            assert_eq!(c.index(), i, "{} out of order", c.name());
        }
    }

    #[test]
    fn all_pipes_is_in_discriminant_order() {
        assert_eq!(ALL_PIPES.len(), N_PIPES);
        for (i, &p) in ALL_PIPES.iter().enumerate() {
            assert_eq!(p.index(), i, "{} out of order", p.name());
        }
    }

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::F16.bytes(), 2);
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::F64.bytes(), 8);
        assert_eq!(DType::I8.bytes(), 1);
    }
}
