//! Native per-SM, per-clock instruction issue rates.
//!
//! These are the *uncrippled* rates of the underlying silicon (GA100 for the
//! CMP 170HX and A100). The crippling is applied separately by
//! [`crate::device::throttle::ThrottleProfile`] so hypotheses from the
//! paper's §5.4 (driver crack, GSP unlock, …) can be explored by swapping
//! profiles without touching the silicon model.

use crate::isa::class::InstClass;

/// Instructions issued per SM per clock for each class, on healthy silicon.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IssueRates {
    /// fp32 pipe: FFMA/FMUL/FADD rate (GA100: 64 = 2×32-wide units).
    pub fp32: f64,
    /// fp64 units (GA100: 32, i.e. half the fp32 rate).
    pub fp64: f64,
    /// packed-half vector pipe, HFMA2 instructions (GA100's 4×-fp32
    /// non-tensor FP16 path: 128 HFMA2/SM/clk).
    pub half2: f64,
    /// scalar fp16 on the core pipe, *no dual issue* (GA100: 32). This is
    /// why PyTorch/GPU-Burn — which do not vectorize to half2 — top out at
    /// ~6.3 TFLOPS on the CMP 170HX (Graph 3-2).
    pub half_scalar: f64,
    /// int32 IMAD/IMUL/IADD rate (GA100: 64, shares core dispatch).
    pub int32: f64,
    /// dp4a rate (GA100 exposes dp4a at half core rate: 32/SM/clk,
    /// calibrated to Graph EX.1's ≈25 TIOPs peak).
    pub dp4a: f64,
    /// tensor-core HMMA FLOPs per SM per clock (dense f16, A100: 2048;
    /// 0 on devices whose tensor path is not exposed by the driver).
    pub tensor_f16_flops: f64,
    /// MUFU / special-function rate.
    pub sfu: f64,
    /// LSU issue slots per SM per clock (instructions, not bytes).
    pub lsu: f64,
}

impl IssueRates {
    /// GA100 (A100 / CMP 170HX silicon) rates.
    pub fn ga100() -> Self {
        IssueRates {
            fp32: 64.0,
            fp64: 32.0,
            half2: 128.0,
            half_scalar: 32.0,
            int32: 64.0,
            dp4a: 32.0,
            tensor_f16_flops: 2048.0,
            sfu: 16.0,
            lsu: 32.0,
        }
    }

    /// A deliberately tiny legacy profile used for historical cards in the
    /// registry where only headline TFLOPS matter (Tesla C870 / P6 rows of
    /// §3.1). `cores_equiv` is FP32 lanes per SM.
    pub fn legacy(cores_per_sm: f64) -> Self {
        IssueRates {
            fp32: cores_per_sm,
            fp64: cores_per_sm / 32.0,
            half2: 0.0,
            half_scalar: 0.0,
            int32: cores_per_sm,
            dp4a: 0.0,
            tensor_f16_flops: 0.0,
            sfu: cores_per_sm / 4.0,
            lsu: cores_per_sm / 2.0,
        }
    }

    /// Native issue rate (inst/SM/clk) for an instruction class.
    pub fn class_rate(&self, class: InstClass) -> f64 {
        use InstClass::*;
        match class {
            Ffma | Fmul | Fadd => self.fp32,
            Dfma | Dmul | Dadd => self.fp64,
            Hfma2 => self.half2,
            // Packed-half MUL/ADD dual-issue at 2× the HFMA2 rate (the
            // three-operand FMA blocks dual issue). Consequence: the fmad
            // policy is performance-*neutral* for the half2 path — exactly
            // Graph 3-2's "FP16 unaffected regardless of FMA status".
            Hmul2 | Hadd2 => self.half2 * 2.0,
            Hfma | Hmul | Hadd => self.half_scalar,
            Imad | Imul | Iadd => self.int32,
            Dp4a => self.dp4a,
            // HMMA priced as FLOPs/clk; convert to "instructions" of 512
            // FLOPs (16x16x16 MMA fragment per warp-instruction à la A100).
            HmmaF16 => self.tensor_f16_flops / 512.0,
            Mufu => self.sfu,
            Ldg | Stg => self.lsu,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::class::InstClass::*;

    #[test]
    fn ga100_fp32_rate_reproduces_cmp_theoretical_tflops() {
        // 70 SMs × 64 FFMA/clk × 2 FLOP × 1.41 GHz = 12.63 TFLOPS (Table 2-4)
        let r = IssueRates::ga100();
        let tflops = 70.0 * r.fp32 * 2.0 * 1.41e9 / 1e12;
        assert!((tflops - 12.63).abs() < 0.01, "{tflops}");
    }

    #[test]
    fn ga100_half2_rate_reproduces_fp16_theoretical() {
        // 70 × 128 HFMA2 × 4 FLOP × 1.41 GHz = 50.53 TFLOPS (Table 2-4)
        let r = IssueRates::ga100();
        let tflops = 70.0 * r.half2 * 4.0 * 1.41e9 / 1e12;
        assert!((tflops - 50.53).abs() < 0.02, "{tflops}");
    }

    #[test]
    fn ga100_fp64_rate_reproduces_theoretical() {
        // 70 × 32 DFMA × 2 FLOP × 1.41 GHz = 6.317 TFLOPS (Table 2-4)
        let r = IssueRates::ga100();
        let tflops = 70.0 * r.fp64 * 2.0 * 1.41e9 / 1e12;
        assert!((tflops - 6.317).abs() < 0.01, "{tflops}");
    }

    #[test]
    fn fused_and_unfused_share_a_rate_except_half2() {
        let r = IssueRates::ga100();
        assert_eq!(r.class_rate(Ffma), r.class_rate(Fmul));
        assert_eq!(r.class_rate(Dfma), r.class_rate(Dadd));
        // half2 mul/add dual-issue at 2× — fmad-neutral path (Graph 3-2).
        assert_eq!(r.class_rate(Hmul2), 2.0 * r.class_rate(Hfma2));
    }

    #[test]
    fn scalar_half_is_half_core_rate() {
        let r = IssueRates::ga100();
        assert_eq!(r.class_rate(Hfma), r.class_rate(Ffma) / 2.0);
    }
}
