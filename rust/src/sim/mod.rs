//! Kernel-timing engine — the lower-once / simulate-many pipeline.
//!
//! Given a [`crate::isa::Kernel`] (post-fmad-pass) and a
//! [`crate::device::DeviceSpec`], the engine computes execution time, board
//! power and energy via an issue-rate/roofline hybrid:
//!
//! 1. lower the body **once** to a [`LoweredKernel`] — the whole-grid
//!    [`crate::isa::InstMix`] (array-backed, O(1) per class) plus the
//!    device-independent derived quantities: launch geometry for occupancy
//!    quantization, the HBM/L2 traffic split, and the energy-weighted op
//!    count;
//! 2. per execution pipe, sum `count / (SMs × rate × throttle × clock)` —
//!    classes on one pipe serialize, distinct pipes overlap;
//! 3. memory time from [`crate::memhier`] (pattern-derated bandwidth, L2
//!    split);
//! 4. kernel time = max(pipe times, memory time, wave-quantized launch
//!    floor), then DVFS-derate if the power model says the activity exceeds
//!    TDP.
//!
//! The engine also returns an achieved-rate report (TFLOPS/TIOPs/GB/s) in
//! the units the paper's graphs use.
//!
//! # Which entry point?
//!
//! - [`simulate`] — one-shot: a single kernel simulated exactly once.
//!   Lowers internally; nothing is cached.
//! - [`simulate_lowered`] — the hot path: you hold a [`LoweredKernel`]
//!   (from [`LoweredKernel::lower`]) and simulate it repeatedly across
//!   devices, throttle profiles, or [`SimConfig`]s. Zero IR walks after the
//!   first.
//! - [`batch`] — dense grids: `kernels × devices × config(s)` fanned across
//!   `std::thread` workers with deterministic, sequential-identical result
//!   ordering. Use it for anything sweep-shaped: the bench-port intensity
//!   sweeps, the llama-bench quant × policy grid, figure regeneration, and
//!   fleet weighting. Per-cell results are bit-identical to calling
//!   [`simulate_lowered`] in a loop.

pub mod batch;
pub mod engine;
pub mod lowered;
pub mod occupancy;

pub use engine::{simulate, simulate_lowered, KernelTiming, SimConfig};
pub use lowered::LoweredKernel;
