//! Flat instruction mixes — the lowered form the timing engine consumes.

use std::collections::BTreeMap;

use super::class::{InstClass, ALL_CLASSES};
use super::ir::{Kernel, Stmt};

/// Whole-grid dynamic instruction counts per class.
///
/// Uses a `BTreeMap` keyed by class name order via discriminant-stable
/// iteration of [`ALL_CLASSES`]; counts are grid totals (per-thread counts ×
/// thread count).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct InstMix {
    counts: BTreeMap<&'static str, u64>,
}

impl InstMix {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lower a kernel's per-thread body to whole-grid class counts.
    pub fn from_kernel(k: &Kernel) -> Self {
        let mut mix = InstMix::new();
        fn walk(stmts: &[Stmt], mult: u64, mix: &mut InstMix) {
            for s in stmts {
                match s {
                    Stmt::Op(op) => mix.add(op.class, op.count * mult),
                    Stmt::Loop { trips, body } => walk(body, mult * trips, mix),
                }
            }
        }
        walk(&k.body, 1, &mut mix);
        mix.scale(k.threads);
        mix
    }

    pub fn add(&mut self, class: InstClass, count: u64) {
        if count == 0 {
            return;
        }
        *self.counts.entry(class.name()).or_insert(0) += count;
    }

    pub fn get(&self, class: InstClass) -> u64 {
        self.counts.get(class.name()).copied().unwrap_or(0)
    }

    /// Multiply every count (used to go per-thread → whole grid, or to
    /// replicate a layer's mix across a model).
    pub fn scale(&mut self, by: u64) {
        for v in self.counts.values_mut() {
            *v *= by;
        }
    }

    /// Merge another mix into this one.
    pub fn merge(&mut self, other: &InstMix) {
        for (k, v) in &other.counts {
            *self.counts.entry(k).or_insert(0) += v;
        }
    }

    /// Total dynamic instructions.
    pub fn total(&self) -> u64 {
        self.counts.values().sum()
    }

    /// Total floating-point operations represented by the mix.
    pub fn flops(&self) -> u64 {
        ALL_CLASSES
            .iter()
            .map(|&c| self.get(c) * c.flops())
            .sum()
    }

    /// Total integer operations represented by the mix.
    pub fn iops(&self) -> u64 {
        ALL_CLASSES.iter().map(|&c| self.get(c) * c.iops()).sum()
    }

    /// Count of fused-FMA-class instructions (the limiter's trigger set).
    pub fn fused(&self) -> u64 {
        ALL_CLASSES
            .iter()
            .filter(|c| c.is_fused())
            .map(|&c| self.get(c))
            .sum()
    }

    /// Iterate `(class, count)` over nonzero classes.
    pub fn iter(&self) -> impl Iterator<Item = (InstClass, u64)> + '_ {
        ALL_CLASSES.iter().filter_map(move |&c| {
            let n = self.get(c);
            (n > 0).then_some((c, n))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::class::InstClass::*;
    use crate::isa::ir::{Kernel, Stmt};
    use crate::testutil::{forall, Rng};

    fn kernel_with(body: Vec<Stmt>, threads: u64) -> Kernel {
        Kernel::new("t", threads, 128).with_body(body)
    }

    #[test]
    fn lowering_scales_by_threads_and_trips() {
        let k = kernel_with(
            vec![Stmt::looped(8, vec![Stmt::op(Ffma, 3)]), Stmt::op(Stg, 1)],
            100,
        );
        let mix = InstMix::from_kernel(&k);
        assert_eq!(mix.get(Ffma), 8 * 3 * 100);
        assert_eq!(mix.get(Stg), 100);
        assert_eq!(mix.total(), 2400 + 100);
    }

    #[test]
    fn flops_count_fma_as_two() {
        let mut mix = InstMix::new();
        mix.add(Ffma, 10);
        mix.add(Fadd, 5);
        assert_eq!(mix.flops(), 25);
        assert_eq!(mix.fused(), 10);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = InstMix::new();
        a.add(Imad, 4);
        let mut b = InstMix::new();
        b.add(Imad, 6);
        b.add(Dp4a, 2);
        a.merge(&b);
        assert_eq!(a.get(Imad), 10);
        assert_eq!(a.get(Dp4a), 2);
        assert_eq!(a.iops(), 10 * 2 + 2 * 8);
    }

    #[test]
    fn prop_lowering_matches_dynamic_count() {
        // Property: whole-grid total == per-thread dynamic count × threads,
        // for arbitrary nested bodies.
        forall(0xC0FFEE, 200, |rng: &mut Rng| {
            fn gen_body(rng: &mut Rng, depth: u32) -> Vec<Stmt> {
                let n = rng.range(1, 4);
                (0..n)
                    .map(|_| {
                        if depth < 3 && rng.chance(0.3) {
                            Stmt::looped(rng.range(1, 5), gen_body(rng, depth + 1))
                        } else {
                            let class = *rng.pick(&[Ffma, Fmul, Fadd, Imad, Ldg, Stg, Hfma2]);
                            Stmt::op(class, rng.range(1, 16))
                        }
                    })
                    .collect()
            }
            let threads = rng.range(1, 10_000);
            let k = kernel_with(gen_body(rng, 0), threads);
            let mix = InstMix::from_kernel(&k);
            assert_eq!(mix.total(), k.dynamic_insts_per_thread() * threads);
        });
    }

    #[test]
    fn zero_counts_are_not_stored() {
        let mut mix = InstMix::new();
        mix.add(Ffma, 0);
        assert_eq!(mix.total(), 0);
        assert_eq!(mix.iter().count(), 0);
    }
}
