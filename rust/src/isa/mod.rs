//! Simulated instruction-set layer.
//!
//! The paper's entire phenomenon lives at the instruction-class level: the
//! CMP 170HX limiter keys on *fused multiply-add* opcodes (FFMA/DFMA/…)
//! while unfused multiplies/adds, packed-half math, integer math and memory
//! traffic issue at native rates. This module defines:
//!
//! - [`class`] — the instruction classes the device model prices;
//! - [`ir`] — a small structured kernel IR (straight-line ops + counted
//!   loops), rich enough to express the paper's benchmark kernels;
//! - [`pass`] — the `-fmad=false` compiler pass (FMA → MUL+ADD) with the
//!   compiled-library boundary (`KernelSource::Lib` kernels, e.g. cuBLAS,
//!   are *not* rewritten — this is why the paper sees no llama.cpp gain for
//!   f16/f32 models);
//! - [`mix`] — lowering of IR to flat instruction mixes consumed by the
//!   timing engine in [`crate::sim`].

pub mod class;
pub mod ir;
pub mod mix;
pub mod pass;

pub use class::{DType, InstClass, Pipe, ALL_CLASSES, ALL_PIPES, N_CLASSES, N_PIPES};
pub use ir::{Kernel, KernelSource, MemPattern, Op, Stmt, Traffic};
pub use mix::InstMix;
pub use pass::FmadPolicy;
