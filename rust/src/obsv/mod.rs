//! Flight-recorder observability: per-request span tracing, bounded ring
//! journals, per-round fleet time-series, and exporters.
//!
//! The serving pipeline's visibility layer. Every request carries a
//! [`TraceId`] (its server-assigned id) and every stage taps into a
//! shared [`Tracer`]:
//!
//! - the **dispatch stage** journals queue-side events (queued, aged,
//!   requeued, dispatched, shed, deadline-miss) on a pseudo-node ring and
//!   drains every ring once per loop;
//! - each **worker** journals engine events (admitted, prefill, decode
//!   rounds, preempt/park/swap/migrate, rescue/replay, retire/fail) on
//!   its own ring, stamped with its **simulated** clock — never wall
//!   time — so the journal is byte-identical across runs of the same
//!   seeded schedule;
//! - failures ([`crate::faults`] chaos deaths, deadline misses, terminal
//!   errors) trigger a [`FlightDump`]: the ring's last moments, preserved
//!   verbatim;
//! - once per round each worker records a [`SeriesPoint`] (queue depth,
//!   KV page tiers, host-pool bytes, simulated watts) and the dispatcher
//!   a [`DispatchPoint`] (tenant deficits, per-node outstanding).
//!
//! Exporters ([`journal_jsonl`], [`chrome_trace`]) write the snapshot as
//! a JSON-lines journal and a Chrome trace-event file Perfetto loads
//! directly; [`parse_journal`] reads the JSONL back (the `trace` CLI
//! command re-renders from it) and [`attribution_rollup`] answers "where
//! did the latency go" — queue vs prefill vs decode vs stall vs replay —
//! from the retired spans alone. Per-request phase seconds live in a
//! [`PhaseLedger`]; the per-node/per-tenant aggregate is an
//! [`Attribution`] carried by [`crate::coordinator::Metrics`].

mod export;
mod journal;
mod series;
mod span;

pub use export::{
    attribution_rollup, chrome_trace, journal_jsonl, lifecycle_slices, parse_journal, Slice,
};
pub use journal::{FlightDump, Journal, TraceSnapshot, Tracer, RING_CAP};
pub use series::{DispatchPoint, SeriesPoint};
pub use span::{Attribution, PhaseLedger, SpanEvent, SpanKind, TraceId, NODE_SCOPE};
