//! Integration: artifacts → PJRT runtime → numerics vs python goldens.
//!
//! These tests require `make artifacts` to have run (the Makefile `test`
//! target guarantees it); they skip — pass vacuously with a stderr note —
//! when artifacts or a live PJRT client are unavailable.

use cmphx::runtime::{goldens::Json, ModelRuntime};

mod common;
use common::artifact_dir;

// PJRT handles hold `Rc`s (not Sync), so the compiled runtime is cached
// per test thread rather than in a process-wide static.
thread_local! {
    static RUNTIME_TL: std::cell::OnceCell<ModelRuntime> = std::cell::OnceCell::new();
}

/// Run `f` against the cached runtime, or skip when the environment cannot
/// load one. Returns `None` on skip.
fn with_runtime<R>(f: impl FnOnce(&ModelRuntime) -> R) -> Option<R> {
    let dir = artifact_dir()?;
    Some(RUNTIME_TL.with(|cell| {
        let rt = cell.get_or_init(|| ModelRuntime::load(&dir).expect("runtime load"));
        f(rt)
    }))
}

fn golden_prompt(rt: &ModelRuntime) -> Vec<i32> {
    rt.goldens
        .get("prompt")
        .unwrap()
        .as_i64_vec()
        .unwrap()
        .iter()
        .map(|&t| t as i32)
        .collect()
}

#[test]
fn runtime_loads_and_reports_cpu_platform() {
    let _ = with_runtime(|rt| {
        assert!(rt.platform().to_lowercase().contains("cpu"));
        assert_eq!(rt.config.vocab, 512);
        assert_eq!(rt.config.layers, 4);
    });
}

#[test]
fn prefill_matches_python_golden_logits() {
    let _ = with_runtime(|rt| {
        let prompt = golden_prompt(rt);
        let state = rt.prefill(&prompt).unwrap();

        let expected = rt
            .goldens
            .get("prefill_last_logits")
            .unwrap()
            .as_f32_vec()
            .unwrap();
        assert_eq!(state.last_logits.len(), expected.len());
        for (i, (a, b)) in state.last_logits.iter().zip(&expected).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 + 1e-4 * b.abs(),
                "logit {i}: rust {a} vs python {b}"
            );
        }
        let argmax = rt.goldens.get("prefill_argmax").unwrap().as_usize().unwrap();
        assert_eq!(state.argmax() as usize, argmax);
    });
}

#[test]
fn greedy_generation_matches_python_golden_tokens() {
    // The strongest cross-language signal: the whole prefill+decode loop,
    // token for token.
    let _ = with_runtime(|rt| {
        let prompt = golden_prompt(rt);
        let expected: Vec<i32> = rt
            .goldens
            .get("greedy_tokens")
            .unwrap()
            .as_i64_vec()
            .unwrap()
            .iter()
            .map(|&t| t as i32)
            .collect();
        let tokens = rt.generate(&prompt, expected.len()).unwrap();
        assert_eq!(tokens, expected, "rust PJRT generation diverged from jax");
    });
}

#[test]
fn decode_rejects_cache_overflow() {
    let _ = with_runtime(|rt| {
        let prompt: Vec<i32> = (1..=rt.config.prefill_t as i32).collect();
        let mut state = rt.prefill(&prompt).unwrap();
        for _ in 0..(rt.config.max_ctx - rt.config.prefill_t) {
            rt.decode(&mut state, 1).unwrap();
        }
        let err = rt.decode(&mut state, 1).unwrap_err().to_string();
        assert!(err.contains("exhausted"), "{err}");
    });
}

#[test]
fn prefill_rejects_wrong_length() {
    let _ = with_runtime(|rt| {
        assert!(rt.prefill(&[1, 2, 3]).is_err());
        assert!(rt.prefill_padded(&vec![1; rt.config.prefill_t + 1]).is_err());
    });
}

fn mixbench_inputs(g: &Json) -> (xla::Literal, xla::Literal) {
    let mb = g.get("mixbench").unwrap();
    let x = mb.get("x").unwrap().as_f32_vec().unwrap();
    let y = mb.get("y").unwrap().as_f32_vec().unwrap();
    (xla::Literal::vec1(&x), xla::Literal::vec1(&y))
}

#[test]
fn mixbench_kernels_match_goldens_and_diverge_from_each_other() {
    let _ = with_runtime(|rt| {
        let dir = artifact_dir().expect("runtime is live, artifacts exist");
        let (x, y) = mixbench_inputs(&rt.goldens);
        let fused = rt
            .run_kernel(&dir, "mixbench_fused.hlo.txt", &[x.clone(), y.clone()])
            .unwrap()
            .to_vec::<f32>()
            .unwrap();
        let nofma = rt
            .run_kernel(&dir, "mixbench_nofma.hlo.txt", &[x, y])
            .unwrap()
            .to_vec::<f32>()
            .unwrap();

        let mbg = rt.goldens.get("mixbench").unwrap();
        let fused_head = mbg.get("fused_head").unwrap().as_f32_vec().unwrap();
        let nofma_head = mbg.get("nofma_head").unwrap().as_f32_vec().unwrap();
        assert_eq!(&fused[..32], &fused_head[..], "fused kernel vs golden");
        assert_eq!(&nofma[..32], &nofma_head[..], "nofma kernel vs golden");

        // the fmad policy is a real numerical difference (chaotic regime)
        let max_div = fused
            .iter()
            .zip(&nofma)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let golden_div = mbg.get("max_divergence").unwrap().as_f64().unwrap() as f32;
        assert!(max_div > 0.0);
        assert!(
            (max_div - golden_div).abs() < 1e-5,
            "{max_div} vs {golden_div}"
        );
    });
}

#[test]
fn qmatmul_kernel_matches_golden() {
    let _ = with_runtime(|rt| {
        let dir = artifact_dir().expect("runtime is live, artifacts exist");
        let qg = rt.goldens.get("qmatmul").unwrap();
        let (m, k, n) = (
            qg.get("m").unwrap().as_usize().unwrap(),
            qg.get("k").unwrap().as_usize().unwrap(),
            qg.get("n").unwrap().as_usize().unwrap(),
        );
        let x = qg.get("x").unwrap().as_f32_vec().unwrap();
        let qw_bytes: Vec<u8> = qg
            .get("qw")
            .unwrap()
            .as_i64_vec()
            .unwrap()
            .iter()
            .map(|&v| (v as i8) as u8)
            .collect();
        let scales = qg.get("scales").unwrap().as_f32_vec().unwrap();

        let x_lit = xla::Literal::vec1(&x).reshape(&[m as i64, k as i64]).unwrap();
        // i8 has no NativeType impl in the xla crate — build the literal
        // from raw bytes with an S8 element type.
        let qw_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S8,
            &[k, n],
            &qw_bytes,
        )
        .unwrap();
        let s_lit = xla::Literal::vec1(&scales)
            .reshape(&[(k / 32) as i64, n as i64])
            .unwrap();

        let out = rt
            .run_kernel(&dir, "qmatmul.hlo.txt", &[x_lit, qw_lit, s_lit])
            .unwrap()
            .to_vec::<f32>()
            .unwrap();
        assert_eq!(out.len(), m * n);

        let head = qg.get("out_head").unwrap().as_f32_vec().unwrap();
        for (i, (a, b)) in out.iter().zip(&head).enumerate() {
            assert!((a - b).abs() <= 1e-4 + 1e-4 * b.abs(), "elem {i}: {a} vs {b}");
        }
        let checksum: f32 = out.iter().sum();
        let golden_sum = qg.get("out_checksum").unwrap().as_f64().unwrap() as f32;
        assert!(
            (checksum - golden_sum).abs() < 1e-2 + 1e-5 * golden_sum.abs(),
            "{checksum} vs {golden_sum}"
        );
    });
}
