//! Deterministic test utilities.
//!
//! The offline image ships no `proptest`/`quickcheck`, so property-based
//! tests in this crate use [`Rng`], a tiny splitmix64/xoshiro-style PRNG with
//! explicit seeding, plus [`forall`], a minimal property runner that reports
//! the failing case index and seed on panic. Python-side property tests use
//! the real `hypothesis` package.

/// Deterministic 64-bit PRNG (splitmix64 core). Not cryptographic; stable
/// across platforms and releases so failing seeds stay reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a PRNG from an explicit seed.
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`; `n` must be nonzero.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Rejection-free modulo is fine for test-case generation.
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in `[lo, hi]` inclusive, signed.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo.wrapping_add(self.below((hi - lo) as u64 + 1) as i64)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a nonempty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "Rng::pick on empty slice");
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Minimal property runner: executes `prop` for `cases` generated inputs,
/// panicking with the case index and seed on the first failure so the case
/// can be replayed with `Rng::new(seed)`.
pub fn forall<F: FnMut(&mut Rng)>(seed: u64, cases: u32, mut prop: F) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0xA24BAED4963EE407);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {case_seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Assert two floats agree to a relative tolerance.
#[track_caller]
pub fn assert_close(a: f64, b: f64, rtol: f64) {
    let denom = a.abs().max(b.abs()).max(1e-30);
    assert!(
        ((a - b) / denom).abs() <= rtol,
        "assert_close failed: {a} vs {b} (rtol {rtol})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn rng_range_hits_endpoints() {
        let mut r = Rng::new(1);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match r.range(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn rng_f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0;
        forall(11, 64, |_| n += 1);
        assert_eq!(n, 64);
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(1.0, 1.0, 1e-12);
        assert_close(0.0, 0.0, 1e-12);
    }

    #[test]
    #[should_panic]
    fn assert_close_rejects_far() {
        assert_close(1.0, 2.0, 1e-3);
    }
}
