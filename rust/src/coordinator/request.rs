//! Request/response types for the serving path.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// A generation request.
#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    /// Prompt token ids (≤ the model's prefill window).
    pub prompt: Vec<i32>,
    /// Tokens to generate (bounded by KV capacity at serve time).
    pub max_tokens: usize,
    /// Where the response goes. Dropped receiver = cancelled request.
    pub reply: Sender<GenResponse>,
    /// Enqueue timestamp for latency accounting.
    pub enqueued: Instant,
}

/// The served result.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// Generated token ids (empty on error).
    pub tokens: Vec<i32>,
    /// Error text if generation failed.
    pub error: Option<String>,
    /// Wall-clock queueing delay, seconds.
    pub queue_s: f64,
    /// Wall-clock prefill time, seconds.
    pub prefill_s: f64,
    /// Wall-clock decode time, seconds.
    pub decode_s: f64,
    /// Simulated device time for the same work on the serving card,
    /// seconds (the timing-model overlay; see DESIGN.md §E2E).
    pub simulated_device_s: f64,
    /// Times this request was preempted under KV page pressure and later
    /// resumed (each resume recomputed prefill and replayed the tokens
    /// generated so far).
    pub preemptions: u64,
    /// Fleet node index that served (or rejected) the request.
    pub node: usize,
}

impl GenResponse {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }

    /// End-to-end wall latency.
    pub fn latency_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.decode_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn response_latency_sums_phases() {
        let r = GenResponse {
            id: 1,
            tokens: vec![1, 2],
            error: None,
            queue_s: 0.1,
            prefill_s: 0.2,
            decode_s: 0.3,
            simulated_device_s: 0.05,
            preemptions: 0,
            node: 0,
        };
        assert!(r.ok());
        assert!((r.latency_s() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn request_carries_reply_channel() {
        let (tx, rx) = channel();
        let req = GenRequest {
            id: 7,
            prompt: vec![1, 2, 3],
            max_tokens: 4,
            reply: tx,
            enqueued: Instant::now(),
        };
        req.reply
            .send(GenResponse {
                id: req.id,
                tokens: vec![9],
                error: None,
                queue_s: 0.0,
                prefill_s: 0.0,
                decode_s: 0.0,
                simulated_device_s: 0.0,
                preemptions: 0,
                node: 0,
            })
            .unwrap();
        assert_eq!(rx.recv().unwrap().id, 7);
    }
}
