//! Board power model.

/// Energy coefficients for a device. All energies in joules.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PowerModel {
    /// Idle/static floor, W (fans, HBM refresh, leakage).
    pub static_w: f64,
    /// Energy per scalar FLOP-equivalent issue slot, J. FMA and MUL+ADD
    /// burn nearly the same energy per *FLOP*; the limiter does not reduce
    /// energy, only rate — which is why noFMA *hurts* token/W (§4.4).
    pub energy_per_flop: f64,
    /// Extra energy per *instruction* (fetch/decode/operand collect), J.
    /// The noFMA path doubles instruction count, so it pays this twice per
    /// fused-op-equivalent — the mechanism behind the Q6/Q4/Q2 efficiency
    /// drop in Graph 4-3.
    pub energy_per_inst: f64,
    /// Energy per byte moved at the HBM pins, J.
    pub energy_per_byte: f64,
}

impl PowerModel {
    /// GA100-class coefficients (calibrated per module docs).
    pub fn ga100() -> Self {
        PowerModel {
            static_w: 55.0,
            // ~19.5 TFLOPS FP32 sustained at ≈160 W dynamic compute on A100
            // → ~8.2 pJ/FLOP; round for the 7 nm class. Callers weight this
            // per instruction class (InstClass::energy_weight) so packed-
            // half / dp4a / tensor work burns proportionally less.
            energy_per_flop: 8.0e-12,
            energy_per_inst: 5.0e-12,
            // HBM2e ≈ 60–65 pJ/byte at the pins + controller.
            energy_per_byte: 62.0e-12,
        }
    }

    /// Older 16 nm-class silicon (for historical registry entries).
    pub fn pascal() -> Self {
        PowerModel {
            static_w: 30.0,
            energy_per_flop: 18.0e-12,
            energy_per_inst: 11.0e-12,
            energy_per_byte: 80.0e-12,
        }
    }

    /// Average board power for an activity described by totals over a
    /// duration: `flops` FLOPs, `insts` instructions, `bytes` HBM bytes in
    /// `seconds`. Uncapped (see [`PowerModel::board_power`]).
    pub fn raw_power(&self, flops: f64, insts: f64, bytes: f64, seconds: f64) -> PowerBreakdown {
        assert!(seconds > 0.0);
        let compute_w = (flops * self.energy_per_flop + insts * self.energy_per_inst) / seconds;
        let mem_w = bytes * self.energy_per_byte / seconds;
        PowerBreakdown {
            static_w: self.static_w,
            compute_w,
            mem_w,
        }
    }

    /// Board power clipped to `tdp_w`, returning `(power_w, derate)` where
    /// `derate ≥ 1` is the slowdown factor DVFS imposes to stay inside the
    /// power envelope (time stretches by `derate`, power settles at TDP).
    pub fn board_power(
        &self,
        flops: f64,
        insts: f64,
        bytes: f64,
        seconds: f64,
        tdp_w: f64,
    ) -> (f64, f64) {
        let raw = self.raw_power(flops, insts, bytes, seconds).total();
        if raw <= tdp_w {
            (raw, 1.0)
        } else {
            // Dynamic power scales ~linearly with clock at fixed work rate;
            // stretch time until total == TDP.
            let dynamic = raw - self.static_w;
            let budget = tdp_w - self.static_w;
            let derate = dynamic / budget;
            (tdp_w, derate)
        }
    }
}

/// Power decomposition, W.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PowerBreakdown {
    pub static_w: f64,
    pub compute_w: f64,
    pub mem_w: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.static_w + self.compute_w + self.mem_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, forall, Rng};

    #[test]
    fn idle_device_draws_static_floor() {
        let m = PowerModel::ga100();
        let p = m.raw_power(0.0, 0.0, 0.0, 1.0);
        assert_close(p.total(), m.static_w, 1e-12);
    }

    #[test]
    fn a100_fp32_saturation_sits_near_tdp() {
        // 19.5 TFLOPS of FMA for 1 s: 19.5e12 FLOPs, 9.75e12 insts.
        let m = PowerModel::ga100();
        let p = m.raw_power(19.5e12, 9.75e12, 0.0, 1.0);
        assert!(
            p.total() > 230.0 && p.total() < 320.0,
            "saturated FP32 should sit near the 250–300 W class: {}",
            p.total()
        );
    }

    #[test]
    fn bandwidth_bound_decode_sits_below_tdp() {
        // Streaming 1.3 TB/s with modest compute: the §4.4 decode regime.
        let m = PowerModel::ga100();
        let p = m.raw_power(1.0e12, 0.6e12, 1.31e12, 1.0);
        assert!(
            p.total() > 140.0 && p.total() < 250.0,
            "decode should sit in the 150–250 W band: {}",
            p.total()
        );
    }

    #[test]
    fn tdp_clipping_derates() {
        let m = PowerModel::ga100();
        let (p, derate) = m.board_power(40e12, 20e12, 0.0, 1.0, 250.0);
        assert_close(p, 250.0, 1e-9);
        assert!(derate > 1.0);
        // And within budget → no derate.
        let (p2, d2) = m.board_power(1e12, 0.5e12, 0.0, 1.0, 250.0);
        assert!(p2 < 250.0);
        assert_close(d2, 1.0, 1e-12);
    }

    #[test]
    fn nofma_same_flops_more_insts_draws_more_energy() {
        // Decomposition keeps FLOPs but doubles instruction count → higher
        // energy per unit work → lower token/W. This is Graph 4-3's dip.
        let m = PowerModel::ga100();
        let fused = m.raw_power(10e12, 5e12, 0.0, 1.0).total();
        let unfused = m.raw_power(10e12, 10e12, 0.0, 1.0).total();
        assert!(unfused > fused);
    }

    #[test]
    fn prop_power_monotone_in_all_activity() {
        forall(0x50AB, 200, |rng: &mut Rng| {
            let m = PowerModel::ga100();
            let f = rng.f64_range(0.0, 2e13);
            let i = rng.f64_range(0.0, 1e13);
            let b = rng.f64_range(0.0, 2e12);
            let base = m.raw_power(f, i, b, 1.0).total();
            assert!(m.raw_power(f * 1.5, i, b, 1.0).total() >= base);
            assert!(m.raw_power(f, i * 1.5, b, 1.0).total() >= base);
            assert!(m.raw_power(f, i, b * 1.5, 1.0).total() >= base);
        });
    }
}
