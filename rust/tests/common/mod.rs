//! Shared helpers for the artifact-backed integration tests.

use cmphx::runtime::ArtifactDir;

/// The AOT artifact directory — or `None`, with a note on stderr, when
/// this environment cannot run the PJRT runtime at all (artifacts missing
/// or the vendored stub xla crate). Tests treat `None` as a skip.
pub fn artifact_dir() -> Option<ArtifactDir> {
    if !cmphx::runtime::pjrt_available() {
        eprintln!("skipping: PJRT unavailable (stub xla build)");
        return None;
    }
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactDir::open(root) {
        Ok(dir) => Some(dir),
        Err(_) => {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            None
        }
    }
}
