//! Step scheduling across in-flight sequences, plus the continuous-batching
//! admission (page-join) and eviction-planning steps.
//!
//! The decode loop must decide which active sequences advance each
//! iteration. Two policies:
//! - [`StepPolicy::RoundRobin`] — fair interleaving (latency-balanced);
//! - [`StepPolicy::ShortestFirst`] — drain sequences closest to completion
//!   first (frees KV pages sooner; throughput-biased under page pressure).
//!
//! Between rounds, [`plan_admission`] decides how many queued requests may
//! join the in-flight set — the vLLM-style join that replaced the old
//! batch-window-then-drain loop, now gated on free KV **pages** rather
//! than worst-case slots. When a round cannot allocate the growth pages
//! its sequences need, [`plan_eviction`] picks the preemption victim: the
//! longest-remaining sequence is dropped back to the waiting queue (KV
//! freed, prefill recomputed on resume) so short requests keep completing
//! instead of starving behind a long generation.

use super::batcher::BatchPolicy;

/// An in-flight sequence the scheduler sees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqView {
    pub seq: usize,
    pub generated: usize,
    pub target: usize,
}

impl SeqView {
    pub fn remaining(&self) -> usize {
        self.target.saturating_sub(self.generated)
    }

    pub fn done(&self) -> bool {
        self.remaining() == 0
    }
}

/// Scheduling policy for the decode loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPolicy {
    RoundRobin,
    ShortestFirst,
}

/// Order the active (not-done) sequences for the next decode round, writing
/// the plan into a caller-provided buffer. The decode loop calls this every
/// round — reusing `out` makes a planned round allocation-free after the
/// first (no intermediate `Vec<&SeqView>`, no fresh result `Vec`).
pub fn plan_round_into(policy: StepPolicy, seqs: &[SeqView], out: &mut Vec<usize>) {
    out.clear();
    // Positions first (so the sort key is an O(1) slice lookup), then map
    // in place to sequence ids — one buffer, zero transient allocations.
    out.extend(
        seqs.iter()
            .enumerate()
            .filter(|(_, s)| !s.done())
            .map(|(i, _)| i),
    );
    if policy == StepPolicy::ShortestFirst {
        // Stable sort: ties keep submission order, as before.
        out.sort_by_key(|&i| seqs[i].remaining());
    }
    for slot in out.iter_mut() {
        *slot = seqs[*slot].seq;
    }
}

/// Order the active (not-done) sequences for the next decode round.
/// Allocating convenience over [`plan_round_into`].
pub fn plan_round(policy: StepPolicy, seqs: &[SeqView]) -> Vec<usize> {
    let mut out = Vec::with_capacity(seqs.len());
    plan_round_into(policy, seqs, &mut out);
    out
}

/// The admission (page-join) step of continuous batching: how many queued
/// requests may join the decode round right now. Bounded by the policy's
/// concurrency cap and by `admissible` — the number of prefill windows
/// the KV pager's free pool could hold. Admission only fills headroom;
/// creating headroom mid-flight is [`plan_eviction`]'s job.
pub fn plan_admission(policy: &BatchPolicy, live: usize, admissible: usize) -> usize {
    policy.concurrency().saturating_sub(live).min(admissible)
}

/// Pick the preemption victim under KV page pressure: the **longest-
/// remaining** active sequence, ties broken toward the latest index (the
/// most recently admitted) — the inverse of [`StepPolicy::ShortestFirst`]'s
/// step order, so the work closest to completion is never thrown away.
/// Returns an index into `seqs`, or `None` when every sequence is done.
pub fn plan_eviction(seqs: &[SeqView]) -> Option<usize> {
    plan_eviction_shielded(seqs, &[])
}

/// [`plan_eviction`] with an eviction shield: `shielded[i]` marks
/// sequences that resumed through the waiting queue's aging gate and must
/// not bounce straight back to it (the park → age → resume → re-evict
/// livelock). Shielded sequences are victims of last resort: they are
/// picked only when no unshielded active sequence exists, so the shield
/// bounds starvation without sacrificing engine liveness. Indices past
/// `shielded`'s length are unshielded.
pub fn plan_eviction_shielded(seqs: &[SeqView], shielded: &[bool]) -> Option<usize> {
    let pick = |all: bool| {
        seqs.iter()
            .enumerate()
            .filter(|&(i, s)| !s.done() && (all || !shielded.get(i).copied().unwrap_or(false)))
            .max_by_key(|&(i, s)| (s.remaining(), i))
            .map(|(i, _)| i)
    };
    pick(false).or_else(|| pick(true))
}

/// Total decode rounds a batch needs (the longest target governs — decode
/// is serial per sequence).
pub fn rounds_needed(seqs: &[SeqView]) -> usize {
    seqs.iter().map(|s| s.remaining()).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    fn seq(seq: usize, generated: usize, target: usize) -> SeqView {
        SeqView {
            seq,
            generated,
            target,
        }
    }

    #[test]
    fn round_robin_preserves_order_and_skips_done() {
        let seqs = [seq(0, 2, 4), seq(1, 3, 3), seq(2, 0, 5)];
        assert_eq!(plan_round(StepPolicy::RoundRobin, &seqs), vec![0, 2]);
    }

    #[test]
    fn shortest_first_orders_by_remaining() {
        let seqs = [seq(0, 0, 9), seq(1, 0, 2), seq(2, 0, 5)];
        assert_eq!(plan_round(StepPolicy::ShortestFirst, &seqs), vec![1, 2, 0]);
    }

    #[test]
    fn admission_fills_headroom_without_preempting() {
        let p = |max_batch| BatchPolicy { max_batch, ..Default::default() };
        // room under both bounds → admit the smaller of the two
        assert_eq!(plan_admission(&p(4), 1, 8), 3);
        assert_eq!(plan_admission(&p(8), 1, 2), 2);
        // at the cap or out of slots → nothing joins
        assert_eq!(plan_admission(&p(4), 4, 4), 0);
        assert_eq!(plan_admission(&p(4), 0, 0), 0);
        // over-cap live set (cap lowered mid-flight) must not underflow
        assert_eq!(plan_admission(&p(2), 5, 3), 0);
        // zero cap is floored to one sequence
        assert_eq!(plan_admission(&p(0), 0, 3), 1);
    }

    #[test]
    fn eviction_picks_longest_remaining() {
        let seqs = [seq(0, 1, 4), seq(1, 0, 9), seq(2, 2, 5)];
        assert_eq!(plan_eviction(&seqs), Some(1));
    }

    #[test]
    fn eviction_breaks_ties_toward_the_latest_admission() {
        // equal remaining work → the most recently admitted goes back
        let seqs = [seq(0, 0, 5), seq(1, 2, 7), seq(2, 1, 6)];
        assert_eq!(plan_eviction(&seqs), Some(2));
    }

    #[test]
    fn eviction_skips_done_sequences() {
        let seqs = [seq(0, 9, 9), seq(1, 1, 3), seq(2, 5, 5)];
        assert_eq!(plan_eviction(&seqs), Some(1));
        assert_eq!(plan_eviction(&[seq(0, 4, 4)]), None);
        assert_eq!(plan_eviction(&[]), None);
    }

    #[test]
    fn shielded_sequences_are_victims_of_last_resort() {
        let seqs = [seq(0, 0, 9), seq(1, 0, 5), seq(2, 0, 7)];
        // unshielded: the longest-remaining (seq 0) goes
        assert_eq!(plan_eviction_shielded(&seqs, &[false, false, false]), Some(0));
        // shielding the longest redirects the eviction to the next-longest
        assert_eq!(plan_eviction_shielded(&seqs, &[true, false, false]), Some(2));
        // everything shielded: liveness wins — longest-remaining again
        assert_eq!(plan_eviction_shielded(&seqs, &[true, true, true]), Some(0));
        // a short shield slice leaves the tail unshielded
        assert_eq!(plan_eviction_shielded(&seqs, &[true]), Some(2));
        // done sequences are never victims even when all actives shielded
        let seqs = [seq(0, 9, 9), seq(1, 0, 5)];
        assert_eq!(plan_eviction_shielded(&seqs, &[false, true]), Some(1));
    }

    #[test]
    fn prop_eviction_victim_is_never_shorter_than_a_survivor() {
        forall(0xE71C7, 300, |rng: &mut Rng| {
            let n = rng.range(0, 12) as usize;
            let seqs: Vec<SeqView> = (0..n)
                .map(|i| seq(i, rng.range(0, 8) as usize, rng.range(0, 8) as usize))
                .collect();
            match plan_eviction(&seqs) {
                Some(v) => {
                    assert!(!seqs[v].done(), "victim must be active");
                    for s in seqs.iter().filter(|s| !s.done()) {
                        assert!(
                            seqs[v].remaining() >= s.remaining(),
                            "victim {} outlived by seq {}",
                            seqs[v].seq,
                            s.seq
                        );
                    }
                }
                None => assert!(seqs.iter().all(|s| s.done())),
            }
        });
    }

    #[test]
    fn rounds_needed_is_max_remaining() {
        let seqs = [seq(0, 1, 4), seq(1, 0, 2)];
        assert_eq!(rounds_needed(&seqs), 3);
        assert_eq!(rounds_needed(&[]), 0);
    }

    #[test]
    fn plan_round_into_reuses_the_buffer() {
        let mut buf = vec![99, 98, 97, 96]; // stale garbage must be cleared
        let seqs = [seq(0, 0, 9), seq(1, 0, 2), seq(2, 3, 3)];
        plan_round_into(StepPolicy::ShortestFirst, &seqs, &mut buf);
        assert_eq!(buf, vec![1, 0]);
        plan_round_into(StepPolicy::RoundRobin, &seqs, &mut buf);
        assert_eq!(buf, vec![0, 1]);
    }

    #[test]
    fn prop_plan_round_into_matches_plan_round() {
        forall(0xB0F, 300, |rng: &mut Rng| {
            let n = rng.range(0, 12) as usize;
            let seqs: Vec<SeqView> = (0..n)
                .map(|i| seq(i, rng.range(0, 8) as usize, rng.range(0, 8) as usize))
                .collect();
            let policy = if rng.chance(0.5) {
                StepPolicy::RoundRobin
            } else {
                StepPolicy::ShortestFirst
            };
            let mut buf = Vec::new();
            plan_round_into(policy, &seqs, &mut buf);
            assert_eq!(buf, plan_round(policy, &seqs));
        });
    }

    #[test]
    fn prop_every_unfinished_sequence_is_planned_exactly_once() {
        forall(0x5C_ED, 300, |rng: &mut Rng| {
            let n = rng.range(0, 12) as usize;
            let seqs: Vec<SeqView> = (0..n)
                .map(|i| {
                    let target = rng.range(0, 8) as usize;
                    seq(i, rng.range(0, 8) as usize, target)
                })
                .collect();
            let policy = if rng.chance(0.5) {
                StepPolicy::RoundRobin
            } else {
                StepPolicy::ShortestFirst
            };
            let plan = plan_round(policy, &seqs);
            let expected: Vec<usize> =
                seqs.iter().filter(|s| !s.done()).map(|s| s.seq).collect();
            let mut sorted = plan.clone();
            sorted.sort_unstable();
            let mut exp_sorted = expected.clone();
            exp_sorted.sort_unstable();
            assert_eq!(sorted, exp_sorted, "plan must cover active set exactly");
        });
    }
}
