//! Figure/table regeneration — one function per paper artifact.
//!
//! Both the CLI (`cmphx report`) and the `cargo bench` targets call these,
//! so every figure is regenerated from exactly one code path.

pub mod figures;
pub mod specs;

pub use figures::*;
