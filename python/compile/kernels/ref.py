"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Each function is the semantic specification its kernel is tested against
with ``assert_allclose`` (pytest + hypothesis sweeps in python/tests).
"""

import jax
import jax.numpy as jnp

Q8_BLOCK = 32


def _round32(v):
    """Round an f64 value to f32 precision, opaquely to the compiler.

    `lax.reduce_precision` is the one rounding primitive XLA's simplifier
    will not fold into neighbouring ops — anything softer (optimization
    barriers, convert round-trips) gets legally collapsed back to f32
    mul/add which LLVM then re-contracts into FMA.
    """
    return jax.lax.reduce_precision(v, exponent_bits=8, mantissa_bits=23)


def mixbench_fused(x, y, iters: int):
    """Fused-FMA chain: t = fma(t, t, y), single f32 rounding per step.

    fma semantics in f64: the f32×f32 product is exact (48 ≤ 53 mantissa
    bits), the add happens at full f64 precision, and one rounding lands
    the result on the f32 grid — a hardware FFMA for these magnitudes.
    Identical construction to the kernel, so results are bit-exact.
    """
    t = x
    for _ in range(iters):
        t64 = t.astype(jnp.float64)
        s = t64 * t64 + y.astype(jnp.float64)
        t = _round32(s).astype(jnp.float32)
    return t


def mixbench_decomposed(x, y, iters: int):
    """-fmad=false chain: separate MUL and ADD, the product rounded to f32
    *between* them — the decomposition's defining property."""
    t = x
    for _ in range(iters):
        t64 = t.astype(jnp.float64)
        m = _round32(t64 * t64)  # the FMUL's rounding
        t = _round32(m + y.astype(jnp.float64)).astype(jnp.float32)
    return t


def q8_dequant(qweights, scales):
    """Expand q8_0 blocks to dense f32: w[k, n] = q[k, n] * s[k // 32, n]."""
    k, _n = qweights.shape
    assert k % Q8_BLOCK == 0, f"K={k} must be a multiple of {Q8_BLOCK}"
    expanded = jnp.repeat(scales, Q8_BLOCK, axis=0)
    return qweights.astype(jnp.float32) * expanded


def qmatmul(x, qweights, scales):
    """x [M, K] @ dequant(qweights [K, N], scales [K/32, N]) -> [M, N]."""
    return x @ q8_dequant(qweights, scales)


def quantize_q8(w):
    """Quantize dense f32 [K, N] to (int8 [K, N], scales f32 [K/32, N]).

    Per-block absmax scaling, the q8_0 recipe.
    """
    k, n = w.shape
    assert k % Q8_BLOCK == 0
    blocks = w.reshape(k // Q8_BLOCK, Q8_BLOCK, n)
    absmax = jnp.max(jnp.abs(blocks), axis=1)  # [K/32, N]
    scales = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scales[:, None, :]), -127, 127)
    return q.reshape(k, n).astype(jnp.int8), scales.astype(jnp.float32)


def gqa_decode_attention(q, k_cache, v_cache, length):
    """Single-token GQA attention.

    q        [H, D]      query for the new token
    k_cache  [T, KV, D]  keys   (only the first `length` rows are valid)
    v_cache  [T, KV, D]  values
    returns  [H, D]
    """
    h, d = q.shape
    t, kv, _ = k_cache.shape
    group = h // kv
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    kv_idx = jnp.arange(h) // group
    k = k_cache[:, kv_idx, :]  # [T, H, D]
    v = v_cache[:, kv_idx, :]
    scores = jnp.einsum("hd,thd->ht", q, k) * scale  # [H, T]
    mask = jnp.arange(t)[None, :] < length
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jnp.exp(scores - jnp.max(scores, axis=1, keepdims=True))
    w = jnp.where(mask, w, 0.0)
    w = w / jnp.sum(w, axis=1, keepdims=True)
    return jnp.einsum("ht,thd->hd", w, v)
