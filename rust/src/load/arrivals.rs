//! Seeded open-loop arrival generators.
//!
//! Closed-loop benches (a fixed client pool that waits for each response)
//! hide the latency knee: offered load can never exceed service capacity,
//! so the queue never grows and the measured "peak" is the closed loop's
//! self-throttling. Production serving is **open-loop** — clients arrive
//! on their own clock, indifferent to how far behind the fleet is — and
//! the interesting region is exactly the one a closed loop cannot reach:
//! offered load at and past capacity, where goodput, tail latency, and
//! energy-per-useful-token are decided by the overload policy.
//!
//! An [`ArrivalPlan`] is a pure data script on the **simulated clock**,
//! built once from a seed exactly like [`crate::faults::FaultPlan`]: the
//! same `(process, seed, duration, shape)` reproduces the same stream
//! bit-identically on any host, so overload curves are replayable and
//! diffable. Three generators cover the catalog ([`ArrivalProcess`]):
//! memoryless [`ArrivalProcess::Poisson`], bursty two-state
//! [`ArrivalProcess::Mmpp`] (Markov-modulated Poisson), and a slow
//! sinusoidal [`ArrivalProcess::Diurnal`] sampled by thinning. Captured
//! traces replay through [`ArrivalPlan::replay`]. Offered-load sweeps
//! come from [`ArrivalPlan::scaled`], which compresses the stream's time
//! axis without redrawing it — every point on a knee curve serves the
//! *same requests*, only packed tighter.
//!
//! Prompts carry **shared-prefix structure**: each arrival draws one of
//! [`WorkloadShape::families`] prompt families and opens with that
//! family's common prefix before a unique tail, so prefix-cache and
//! affinity-routing behavior under load is part of the workload, not an
//! accident of the bench.

use crate::qos::TenantId;
use crate::testutil::Rng;

/// Token-id space the synthetic prompts draw from.
const VOCAB: u64 = 32_000;

/// 64-bit mix fold (splitmix64 finalizer) used for stream and token
/// fingerprints. Stable across platforms so fingerprints are comparable
/// in CI and across hosts.
pub fn mix64(h: u64, v: u64) -> u64 {
    let mut z = h ^ v.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Fingerprint of the tokens a greedy decode would serve for a prompt:
/// greedy decoding of a fixed model is a pure function of the prompt, so
/// a prompt hash is a faithful stand-in for served-token identity in the
/// pure simulator (the real engine's replay tests pin the actual ids).
pub fn token_fingerprint(prompt: &[i32], max_tokens: usize) -> u64 {
    let mut h = 0xA11C_0DE5_0F7C_0DE5;
    for &tok in prompt {
        h = mix64(h, tok as u64);
    }
    mix64(h, max_tokens as u64)
}

/// One open-loop request: who arrives, when, carrying what work.
#[derive(Clone, Debug, PartialEq)]
pub struct Arrival {
    /// Arrival instant on the simulated clock, seconds from stream start.
    pub at_s: f64,
    /// Billing tenant (index into the serving registry / SLO table).
    pub tenant: TenantId,
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
}

/// The arrival-process catalog. Rates are requests per second on the
/// simulated clock.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant rate — the M/G/1 baseline.
    Poisson { rps: f64 },
    /// Markov-modulated Poisson: alternate between a base and a burst
    /// rate, dwelling an exponential time (mean `mean_dwell_s`) in each
    /// state. The long-run mean rate is the average of the two.
    Mmpp {
        base_rps: f64,
        burst_rps: f64,
        mean_dwell_s: f64,
    },
    /// Sinusoidal daily cycle sampled by thinning: instantaneous rate
    /// `mean_rps · (1 + swing·sin(2πt/period_s))` with `0 ≤ swing < 1`.
    Diurnal {
        mean_rps: f64,
        swing: f64,
        period_s: f64,
    },
}

impl ArrivalProcess {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Mmpp { .. } => "mmpp",
            ArrivalProcess::Diurnal { .. } => "diurnal",
        }
    }

    /// Long-run mean arrival rate the process targets.
    pub fn nominal_rps(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rps } => rps,
            ArrivalProcess::Mmpp {
                base_rps, burst_rps, ..
            } => 0.5 * (base_rps + burst_rps),
            ArrivalProcess::Diurnal { mean_rps, .. } => mean_rps,
        }
    }
}

/// What each arrival carries: tenant fan-out, prompt geometry, and the
/// shared-prefix family structure.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadShape {
    /// Tenants to spread arrivals over, uniformly (ids `0..tenants`).
    pub tenants: usize,
    /// Total prompt length, tokens.
    pub prompt_len: usize,
    /// Leading tokens shared within a prompt family (system prompt).
    pub shared_prefix_len: usize,
    /// Distinct prompt families (each with its own shared prefix).
    pub families: usize,
    /// Decode budget per request.
    pub max_tokens: usize,
}

impl Default for WorkloadShape {
    fn default() -> Self {
        WorkloadShape {
            tenants: 1,
            prompt_len: 32,
            shared_prefix_len: 16,
            families: 4,
            max_tokens: 8,
        }
    }
}

/// A fully materialized open-loop schedule: pure data, sorted by arrival
/// time, replayable bit-identically from its seed.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ArrivalPlan {
    pub arrivals: Vec<Arrival>,
}

impl ArrivalPlan {
    /// Draw a complete arrival stream from a seed. Same
    /// `(process, seed, duration, shape)` → byte-identical plan.
    pub fn seeded(process: ArrivalProcess, seed: u64, duration_s: f64, shape: &WorkloadShape) -> Self {
        assert!(duration_s > 0.0, "empty observation window");
        assert!(shape.tenants > 0 && shape.families > 0, "degenerate workload shape");
        assert!(
            shape.shared_prefix_len <= shape.prompt_len,
            "shared prefix longer than the prompt"
        );
        match process {
            ArrivalProcess::Poisson { rps } => assert!(rps > 0.0, "poisson rate must be positive"),
            ArrivalProcess::Mmpp {
                base_rps,
                burst_rps,
                mean_dwell_s,
            } => assert!(
                base_rps > 0.0 && burst_rps > 0.0 && mean_dwell_s > 0.0,
                "mmpp parameters must be positive"
            ),
            ArrivalProcess::Diurnal {
                mean_rps,
                swing,
                period_s,
            } => assert!(
                mean_rps > 0.0 && period_s > 0.0 && (0.0..1.0).contains(&swing),
                "diurnal parameters out of range"
            ),
        }
        let mut rng = Rng::new(seed);
        let times = match process {
            ArrivalProcess::Poisson { rps } => poisson_times(&mut rng, rps, duration_s),
            ArrivalProcess::Mmpp {
                base_rps,
                burst_rps,
                mean_dwell_s,
            } => mmpp_times(&mut rng, base_rps, burst_rps, mean_dwell_s, duration_s),
            ArrivalProcess::Diurnal {
                mean_rps,
                swing,
                period_s,
            } => diurnal_times(&mut rng, mean_rps, swing, period_s, duration_s),
        };
        let mut arrivals = Vec::with_capacity(times.len());
        for at_s in times {
            let tenant = TenantId(rng.below(shape.tenants as u64) as usize);
            let family = rng.below(shape.families as u64);
            // the family prefix is its own deterministic stream so every
            // member of a family opens with the same tokens
            let mut fam = Rng::new(seed ^ mix64(0xFA_111_1E5, family));
            let mut prompt = Vec::with_capacity(shape.prompt_len);
            for _ in 0..shape.shared_prefix_len {
                prompt.push(fam.below(VOCAB) as i32);
            }
            while prompt.len() < shape.prompt_len {
                prompt.push(rng.below(VOCAB) as i32);
            }
            arrivals.push(Arrival {
                at_s,
                tenant,
                prompt,
                max_tokens: shape.max_tokens,
            });
        }
        ArrivalPlan { arrivals }
    }

    /// Build a plan from externally captured events (a trace). Events are
    /// **stably** sorted by arrival time, so same-instant ties keep the
    /// trace's submission order and each tenant's relative order is
    /// preserved exactly.
    pub fn replay(mut events: Vec<Arrival>) -> Self {
        events.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).expect("non-finite arrival time"));
        ArrivalPlan { arrivals: events }
    }

    /// The same stream with its time axis compressed (`factor > 1`, more
    /// offered load) or stretched (`factor < 1`). Requests, tenants, and
    /// prompts are untouched — every point of a knee sweep serves
    /// identical work.
    pub fn scaled(&self, factor: f64) -> Self {
        assert!(factor > 0.0 && factor.is_finite(), "bad load factor");
        ArrivalPlan {
            arrivals: self
                .arrivals
                .iter()
                .map(|a| Arrival {
                    at_s: a.at_s / factor,
                    ..a.clone()
                })
                .collect(),
        }
    }

    /// Empirical offered rate: arrivals over the stream's span.
    pub fn offered_rps(&self) -> f64 {
        match self.arrivals.last() {
            Some(last) if last.at_s > 0.0 => self.arrivals.len() as f64 / last.at_s,
            _ => 0.0,
        }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Tenants the plan actually references (`1 + max id`), for sizing
    /// SLO and weight tables.
    pub fn tenant_span(&self) -> usize {
        self.arrivals.iter().map(|a| a.tenant.0 + 1).max().unwrap_or(0)
    }

    /// Order-sensitive fingerprint over every field of every arrival —
    /// the byte-identity witness for same-seed reproducibility tests.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0x0511_0A4B_17A1_C0DE;
        for a in &self.arrivals {
            h = mix64(h, a.at_s.to_bits());
            h = mix64(h, a.tenant.0 as u64);
            h = mix64(h, a.max_tokens as u64);
            for &tok in &a.prompt {
                h = mix64(h, tok as u64);
            }
        }
        h
    }
}

/// Exponential inter-arrival draw; `rng.f64()` is in `[0, 1)` so the
/// logarithm's argument stays in `(0, 1]`.
fn exp_draw(rng: &mut Rng, rate: f64) -> f64 {
    -(1.0 - rng.f64()).ln() / rate
}

fn poisson_times(rng: &mut Rng, rps: f64, duration_s: f64) -> Vec<f64> {
    let mut out = Vec::new();
    let mut t = 0.0;
    loop {
        t += exp_draw(rng, rps);
        if t >= duration_s {
            return out;
        }
        out.push(t);
    }
}

fn mmpp_times(rng: &mut Rng, base_rps: f64, burst_rps: f64, mean_dwell_s: f64, duration_s: f64) -> Vec<f64> {
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut bursting = false;
    let mut state_end = exp_draw(rng, 1.0 / mean_dwell_s);
    loop {
        let rate = if bursting { burst_rps } else { base_rps };
        let dt = exp_draw(rng, rate);
        if t + dt >= state_end {
            // jump to the boundary and toggle; memorylessness makes
            // discarding the in-flight gap exact, not an approximation
            t = state_end;
            bursting = !bursting;
            state_end = t + exp_draw(rng, 1.0 / mean_dwell_s);
            if t >= duration_s {
                return out;
            }
            continue;
        }
        t += dt;
        if t >= duration_s {
            return out;
        }
        out.push(t);
    }
}

fn diurnal_times(rng: &mut Rng, mean_rps: f64, swing: f64, period_s: f64, duration_s: f64) -> Vec<f64> {
    let peak = mean_rps * (1.0 + swing);
    let mut out = Vec::new();
    let mut t = 0.0;
    loop {
        t += exp_draw(rng, peak);
        if t >= duration_s {
            return out;
        }
        let rate = mean_rps * (1.0 + swing * (std::f64::consts::TAU * t / period_s).sin());
        if rng.chance(rate / peak) {
            out.push(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, forall};

    fn shape() -> WorkloadShape {
        WorkloadShape {
            tenants: 3,
            prompt_len: 24,
            shared_prefix_len: 12,
            families: 2,
            max_tokens: 6,
        }
    }

    #[test]
    fn same_seed_reproduces_the_stream_bit_identically() {
        for process in [
            ArrivalProcess::Poisson { rps: 40.0 },
            ArrivalProcess::Mmpp {
                base_rps: 20.0,
                burst_rps: 120.0,
                mean_dwell_s: 0.5,
            },
            ArrivalProcess::Diurnal {
                mean_rps: 40.0,
                swing: 0.6,
                period_s: 10.0,
            },
        ] {
            let a = ArrivalPlan::seeded(process, 0xC417, 20.0, &shape());
            let b = ArrivalPlan::seeded(process, 0xC417, 20.0, &shape());
            assert_eq!(a, b, "{} must replay from its seed", process.name());
            assert_eq!(a.fingerprint(), b.fingerprint());
            let c = ArrivalPlan::seeded(process, 0xC418, 20.0, &shape());
            assert_ne!(a.fingerprint(), c.fingerprint(), "different seed, different stream");
            for w in a.arrivals.windows(2) {
                assert!(w[0].at_s <= w[1].at_s, "arrivals sorted by time");
            }
        }
    }

    #[test]
    fn prop_seed_determinism_across_random_shapes() {
        forall(0x0be4_100b, 40, |rng| {
            let seed = rng.next_u64();
            let shape = WorkloadShape {
                tenants: rng.range(1, 4) as usize,
                prompt_len: rng.range(4, 40) as usize,
                shared_prefix_len: 0,
                families: rng.range(1, 3) as usize,
                max_tokens: rng.range(1, 16) as usize,
            };
            let shape = WorkloadShape {
                shared_prefix_len: rng.range(0, shape.prompt_len as u64) as usize,
                ..shape
            };
            let rps = rng.f64_range(5.0, 80.0);
            let a = ArrivalPlan::seeded(ArrivalProcess::Poisson { rps }, seed, 5.0, &shape);
            let b = ArrivalPlan::seeded(ArrivalProcess::Poisson { rps }, seed, 5.0, &shape);
            assert_eq!(a.fingerprint(), b.fingerprint());
            assert_eq!(a, b);
        });
    }

    #[test]
    fn empirical_rates_converge_to_nominal() {
        // long windows so the law of large numbers has room: 10%
        // tolerance on the realized mean rate
        let dur = 400.0;
        for process in [
            ArrivalProcess::Poisson { rps: 25.0 },
            ArrivalProcess::Mmpp {
                base_rps: 10.0,
                burst_rps: 40.0,
                mean_dwell_s: 1.0,
            },
            ArrivalProcess::Diurnal {
                mean_rps: 25.0,
                swing: 0.5,
                period_s: 20.0,
            },
        ] {
            let plan = ArrivalPlan::seeded(process, 7, dur, &WorkloadShape::default());
            let rate = plan.len() as f64 / dur;
            assert_close(rate, process.nominal_rps(), 0.10);
        }
    }

    #[test]
    fn mmpp_is_burstier_than_poisson_at_equal_mean() {
        // squared coefficient of variation of inter-arrival gaps: ≈1 for
        // Poisson, strictly larger for the modulated process
        let cv2 = |plan: &ArrivalPlan| {
            let gaps: Vec<f64> = plan.arrivals.windows(2).map(|w| w[1].at_s - w[0].at_s).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var / (mean * mean)
        };
        let poisson = ArrivalPlan::seeded(
            ArrivalProcess::Poisson { rps: 30.0 },
            11,
            200.0,
            &WorkloadShape::default(),
        );
        let mmpp = ArrivalPlan::seeded(
            ArrivalProcess::Mmpp {
                base_rps: 5.0,
                burst_rps: 55.0,
                mean_dwell_s: 2.0,
            },
            11,
            200.0,
            &WorkloadShape::default(),
        );
        let (p, m) = (cv2(&poisson), cv2(&mmpp));
        assert!((0.7..1.4).contains(&p), "poisson CV² ≈ 1, got {p}");
        assert!(m > 1.8 * p, "mmpp must be visibly burstier: {m} vs {p}");
    }

    #[test]
    fn scaling_compresses_time_without_redrawing_work() {
        let plan = ArrivalPlan::seeded(ArrivalProcess::Poisson { rps: 20.0 }, 3, 30.0, &shape());
        let double = plan.scaled(2.0);
        assert_eq!(double.len(), plan.len());
        assert_close(double.offered_rps(), plan.offered_rps() * 2.0, 1e-12);
        for (a, b) in plan.arrivals.iter().zip(&double.arrivals) {
            assert_eq!(a.prompt, b.prompt, "same request, new clock");
            assert_eq!(a.tenant, b.tenant);
            assert_eq!(b.at_s.to_bits(), (a.at_s / 2.0).to_bits());
        }
    }

    #[test]
    fn prompts_carry_family_shared_prefixes() {
        let s = shape();
        let plan = ArrivalPlan::seeded(ArrivalProcess::Poisson { rps: 50.0 }, 5, 10.0, &s);
        assert!(plan.len() > 50, "enough arrivals to see both families");
        let mut prefixes: Vec<Vec<i32>> =
            plan.arrivals.iter().map(|a| a.prompt[..s.shared_prefix_len].to_vec()).collect();
        prefixes.sort();
        prefixes.dedup();
        assert!(
            prefixes.len() <= s.families && prefixes.len() >= 2,
            "{} distinct prefixes for {} families",
            prefixes.len(),
            s.families
        );
        let mut tails: Vec<Vec<i32>> =
            plan.arrivals.iter().map(|a| a.prompt[s.shared_prefix_len..].to_vec()).collect();
        tails.sort();
        tails.dedup();
        assert!(tails.len() > s.families, "tails are per-request, not shared");
    }

    #[test]
    fn prop_replay_preserves_per_tenant_ordering() {
        forall(0x7E4A4, 60, |rng| {
            // a shuffled multi-tenant trace: replay must order globally by
            // time while each tenant's own sequence stays in its original
            // relative order (payloads tag the original index)
            let tenants = rng.range(1, 4) as usize;
            let mut events = Vec::new();
            for i in 0..rng.range(2, 40) {
                events.push(Arrival {
                    at_s: rng.f64_range(0.0, 10.0),
                    tenant: TenantId(rng.below(tenants as u64) as usize),
                    prompt: vec![i as i32],
                    max_tokens: 1,
                });
            }
            // per-tenant expected order = ascending at_s, ties by index
            let mut expect: Vec<Vec<i32>> = vec![Vec::new(); tenants];
            let mut sorted = events.clone();
            sorted.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
            for e in &sorted {
                expect[e.tenant.0].push(e.prompt[0]);
            }
            let plan = ArrivalPlan::replay(events);
            for w in plan.arrivals.windows(2) {
                assert!(w[0].at_s <= w[1].at_s);
            }
            let mut got: Vec<Vec<i32>> = vec![Vec::new(); tenants];
            for e in &plan.arrivals {
                got[e.tenant.0].push(e.prompt[0]);
            }
            assert_eq!(got, expect, "stable sort keeps per-tenant order");
        });
    }

    #[test]
    fn tenant_span_and_offered_rps_edge_cases() {
        let empty = ArrivalPlan::default();
        assert_eq!(empty.tenant_span(), 0);
        assert_eq!(empty.offered_rps(), 0.0);
        assert!(empty.is_empty());
        let plan = ArrivalPlan::seeded(
            ArrivalProcess::Poisson { rps: 30.0 },
            9,
            10.0,
            &WorkloadShape {
                tenants: 3,
                ..WorkloadShape::default()
            },
        );
        assert!(plan.tenant_span() <= 3 && plan.tenant_span() >= 1);
        assert!(plan.offered_rps() > 0.0);
    }

    #[test]
    #[should_panic(expected = "poisson rate")]
    fn zero_rate_is_rejected() {
        ArrivalPlan::seeded(ArrivalProcess::Poisson { rps: 0.0 }, 1, 1.0, &WorkloadShape::default());
    }
}
