//! Chaos integration: deterministic fault scripts against a two-card
//! fleet, exercising the self-healing path end to end — node death
//! mid-decode, sequence rescue with bit-identical greedy replay on the
//! survivor, the no-rescue ablation arm, and a seeded sweep (the CI smoke
//! matrix drives `CHAOS_SEED` through it).
//!
//! Every test skips (passes vacuously, with a note on stderr) when the
//! AOT artifacts are missing or PJRT is unavailable (the vendored stub xla
//! crate) — environments that cannot run the runtime at all.

use std::time::Duration;

use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{GenResponse, NodeConfig, RoutePolicy, Server, ServerConfig, ServerHandle};
use cmphx::device::registry;
use cmphx::faults::{FaultEvent, FaultKind, FaultPlan};
use cmphx::isa::pass::FmadPolicy;
mod common;
use common::artifact_dir;

/// Two identical 170HX nodes, round-robin routing, stealing off (so the
/// request → node mapping is deterministic and the scripted death always
/// has victims in hand).
fn chaos_config(faults: Option<FaultPlan>, rescue: bool) -> ServerConfig {
    let mut cfg = ServerConfig {
        queue_depth: 32,
        batch: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(20),
            ..BatchPolicy::default()
        },
        step_policy: StepPolicy::RoundRobin,
        fmad: FmadPolicy::Decomposed,
        route: RoutePolicy::RoundRobin,
        nodes: vec![
            NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
            NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        ],
        ..Default::default()
    };
    cfg.qos.steal = false;
    cfg.recovery.rescue = rescue;
    cfg.faults = faults;
    cfg
}

fn start(cfg: ServerConfig) -> Option<ServerHandle> {
    Some(Server::start(artifact_dir()?, cfg).unwrap())
}

/// Kill node 0 at its third engine round: by then its cold-start gather
/// has admitted its share of the workload and every victim is mid-decode
/// with generated tokens at risk.
fn kill_node0() -> FaultPlan {
    FaultPlan::script(vec![FaultEvent { node: 0, round: 3, kind: FaultKind::NodeDeath }])
}

/// Submit `n` fixed prompts for `tokens` each and collect every response
/// in submission order (terminal errors included — chaos runs assert on
/// them, not around them).
fn run_workload(server: &ServerHandle, n: usize, tokens: usize) -> Vec<GenResponse> {
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i as i32 + 2)) % 500 + 1).collect();
            server.submit(prompt, tokens).unwrap()
        })
        .collect();
    rxs.into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(240)).unwrap())
        .collect()
}

#[test]
fn a_killed_card_loses_no_responses_and_replays_bit_identically() {
    // The acceptance scenario: one of two cards dies mid-decode. Every
    // accepted request must still complete, and every rescued sequence
    // must produce the exact tokens a fault-free fleet produces — greedy
    // replay on the survivor reconstructs the dead card's state.
    let Some(baseline) = start(chaos_config(None, true)) else { return };
    let expected: Vec<Vec<i32>> =
        run_workload(&baseline, 6, 12).into_iter().map(|r| r.tokens).collect();
    drop(baseline);

    let Some(server) = start(chaos_config(Some(kill_node0()), true)) else { return };
    let responses = run_workload(&server, 6, 12);
    for (i, r) in responses.iter().enumerate() {
        assert!(r.ok(), "request {i} lost to the death: {:?}", r.error);
        assert_eq!(
            r.tokens, expected[i],
            "request {i}: rescue replay must be bit-identical"
        );
    }
    assert!(
        responses.iter().any(|r| r.rescues >= 1),
        "the death must have rescued in-flight work"
    );
    let fm = server.shutdown_fleet();
    let total = fm.total();
    assert_eq!(total.errors, 0, "zero dropped responses");
    assert_eq!(total.lost_seqs, 0, "rescue must leave nothing behind");
    assert!(total.rescued_seqs >= 1, "node 0 died with sequences in hand");
    assert_eq!(total.requests, 6, "every request retires exactly once");
    assert!(
        total.rescue_replay_s > 0.0,
        "replaying rescued progress must be priced as recompute"
    );
}

#[test]
fn rescue_strictly_beats_the_no_rescue_arm_on_goodput() {
    // The ablation the bench row reports: same scripted death, rescue on
    // vs off. With rescue, goodput holds at 100%; without, node 0's
    // in-flight sequences die with it — strictly fewer ok responses.
    let Some(with) = start(chaos_config(Some(kill_node0()), true)) else { return };
    let ok_with = run_workload(&with, 6, 12).iter().filter(|r| r.ok()).count();
    let m_with = with.shutdown_fleet();

    let Some(without) = start(chaos_config(Some(kill_node0()), false)) else { return };
    let responses = run_workload(&without, 6, 12);
    let ok_without = responses.iter().filter(|r| r.ok()).count();
    let m_without = without.shutdown_fleet();

    assert_eq!(ok_with, 6, "rescue arm must complete the whole workload");
    assert_eq!(m_with.total().lost_seqs, 0);
    assert!(
        ok_with > ok_without,
        "rescue must strictly beat the ablation: {ok_with} vs {ok_without}"
    );
    assert!(
        m_without.total().lost_seqs >= 1,
        "the no-rescue arm must book its losses"
    );
    for r in responses.iter().filter(|r| !r.ok()) {
        assert!(
            r.error.as_deref().unwrap().contains("node died"),
            "losses must say why: {:?}",
            r.error
        );
    }
}

#[test]
fn seeded_chaos_keeps_goodput_with_zero_lost_responses() {
    // The CI smoke matrix: a seed-driven fault script (deaths capped at
    // one of two cards, plus stalls, throttles, link downgrades, VRAM
    // page loss) over a fixed workload. The goodput floor is absolute —
    // every accepted request completes, nothing is lost — and the same
    // seed replays the same script, so a red run is debuggable by seed.
    let seed: u64 = std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42);
    let plan = FaultPlan::seeded(seed, 2, 64, 0.08);
    let Some(server) = start(chaos_config(Some(plan.clone()), true)) else { return };
    let responses = run_workload(&server, 8, 10);
    for (i, r) in responses.iter().enumerate() {
        assert!(r.ok(), "seed {seed}: request {i} failed: {:?}", r.error);
        assert_eq!(r.tokens.len(), 10, "seed {seed}: request {i} short-counted");
    }
    let fm = server.shutdown_fleet();
    let total = fm.total();
    assert_eq!(total.errors, 0, "seed {seed}: zero dropped responses");
    assert_eq!(total.lost_seqs, 0, "seed {seed}: nothing may be lost");
    assert_eq!(total.requests, 8, "seed {seed}");
    assert_eq!(total.tokens_out, 80, "seed {seed}: the goodput floor is every token");
    let deaths = plan.events.iter().filter(|e| e.kind == FaultKind::NodeDeath).count();
    eprintln!(
        "seed {seed}: {} scripted events ({deaths} deaths) — rescued {} lost {} \
         retries {} degraded {}",
        plan.events.len(),
        total.rescued_seqs,
        total.lost_seqs,
        total.retries,
        total.degrade_events,
    );
}
