//! Memory-system models: HBM2e/GDDR bandwidth with access-pattern derating,
//! an L2 working-set model, and the PCIe host link (including the CMP
//! 170HX's x4-gen1 restriction and the paper's Ex.2.2 "populate the
//! coupling capacitors" x16 mod).

pub mod hbm;
pub mod l2;
pub mod pcie;

pub use hbm::MemorySystem;
pub use pcie::{PcieGen, PcieLink};
