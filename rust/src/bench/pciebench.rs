//! PCIe bandwidth benchmark (Graph EX.2): send / receive / bidirectional
//! payload rates over the card's host link, plus the Ex.2.2 x16
//! capacitor-mod hypothetical.

use crate::device::DeviceSpec;
use crate::memhier::pcie::PcieLink;

/// One PCIe measurement row.
#[derive(Clone, Debug)]
pub struct PcieResult {
    pub case: String,
    pub gbps: f64,
    pub theoretical_gbps: f64,
}

/// Transfer direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum XferDir {
    Send,
    Receive,
    Bidirectional,
}

impl XferDir {
    pub fn name(self) -> &'static str {
        match self {
            XferDir::Send => "send",
            XferDir::Receive => "receive",
            XferDir::Bidirectional => "bidirectional",
        }
    }
}

/// Measure one direction on a link using a 256 MiB transfer (the benchmark's
/// default block, large enough to amortize DMA setup).
pub fn measure(link: &PcieLink, dir: XferDir) -> PcieResult {
    const BYTES: u64 = 256 << 20;
    let t = link.transfer_time(BYTES);
    let uni = BYTES as f64 / t / 1e9;
    let (gbps, theo) = match dir {
        // send/receive are symmetric full-duplex lanes
        XferDir::Send | XferDir::Receive => (uni, link.theoretical_bw() / 1e9),
        XferDir::Bidirectional => (2.0 * uni, 2.0 * link.theoretical_bw() / 1e9),
    };
    PcieResult {
        case: dir.name().to_string(),
        gbps,
        theoretical_gbps: theo,
    }
}

/// Graph EX.2: stock x4 link and the x16-mod hypothetical, all directions.
pub fn graph_ex2(dev: &DeviceSpec) -> Vec<PcieResult> {
    let mut rows = Vec::new();
    for dir in [XferDir::Send, XferDir::Receive, XferDir::Bidirectional] {
        let mut r = measure(&dev.pcie, dir);
        r.case = format!("stock-x{} {}", dev.pcie.lanes, r.case);
        rows.push(r);
    }
    let modded = dev.pcie.with_lanes(16);
    for dir in [XferDir::Send, XferDir::Receive, XferDir::Bidirectional] {
        let mut r = measure(&modded, dir);
        r.case = format!("x16-mod {}", r.case);
        rows.push(r);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibration as cal;
    use crate::device::registry;

    #[test]
    fn stock_theoretical_is_one_gbps() {
        let dev = registry::cmp170hx();
        let r = measure(&dev.pcie, XferDir::Send);
        assert!(
            cal::check(&cal::PCIE_STOCK_THEORETICAL_GBPS, r.theoretical_gbps),
            "{}",
            r.theoretical_gbps
        );
        assert!(r.gbps < r.theoretical_gbps);
        assert!(r.gbps > 0.75, "{}", r.gbps);
    }

    #[test]
    fn x16_mod_quadruples() {
        let dev = registry::cmp170hx();
        let rows = graph_ex2(&dev);
        let find = |tag: &str| {
            rows.iter()
                .find(|r| r.case.contains(tag) && r.case.contains("send"))
                .unwrap()
        };
        let stock = find("stock");
        let modded = find("x16");
        let ratio = modded.gbps / stock.gbps;
        assert!((ratio - 4.0).abs() < 0.1, "{ratio}");
    }

    #[test]
    fn bidirectional_doubles_unidirectional() {
        let dev = registry::cmp170hx();
        let uni = measure(&dev.pcie, XferDir::Send).gbps;
        let bi = measure(&dev.pcie, XferDir::Bidirectional).gbps;
        assert!((bi / uni - 2.0).abs() < 1e-6);
    }

    #[test]
    fn a100_link_dwarfs_cmp_link() {
        // Context row the paper's Ex.2 discussion implies: gen4 x16 ≈ 64×
        // the stock CMP link.
        let a100 = registry::a100_pcie();
        let cmp = registry::cmp170hx();
        let a = measure(&a100.pcie, XferDir::Send).gbps;
        let c = measure(&cmp.pcie, XferDir::Send).gbps;
        assert!(a / c > 20.0, "{a} vs {c}");
    }

    #[test]
    fn model_loading_over_x4_gen1_is_slow() {
        // An 8 GB model upload over the stock link takes ~10 s — the cost
        // §6.2's edge deployment amortizes by keeping weights resident.
        let dev = registry::cmp170hx();
        let t = dev.pcie.transfer_time(8 << 30);
        assert!(t > 8.0 && t < 15.0, "{t}");
    }
}
