//! End-to-end serving benchmark: the full L1→L2→L3 stack under load.
//!
//! Compiles the AOT artifacts, then measures served throughput and latency
//! percentiles at several concurrency caps — the batching-policy ablation
//! DESIGN.md calls out — plus the simulated device time for the same token
//! schedule. A final section runs a heterogeneous 170HX + 90HX fleet under
//! continuous batching and answers the §6.2 question: how many recycled
//! cards replace one A100, at what energy cost. Requires `make artifacts`.

use std::time::{Duration, Instant};

use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{NodeConfig, RoutePolicy, Server, ServerConfig};
use cmphx::device::registry;
use cmphx::isa::pass::FmadPolicy;
use cmphx::llm::llamabench::LlamaBench;
use cmphx::llm::quant;
use cmphx::market::tco;
use cmphx::runtime::ArtifactDir;

const REQUESTS: usize = 12;
const TOKENS: usize = 8;

fn artifacts() -> anyhow::Result<ArtifactDir> {
    ArtifactDir::open(std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn config(max_batch: usize, step_policy: StepPolicy) -> ServerConfig {
    ServerConfig {
        queue_depth: 64,
        batch: BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(3),
            ..BatchPolicy::default()
        },
        step_policy,
        fmad: FmadPolicy::Decomposed,
        ..Default::default()
    }
}

fn submit_workload(server: &cmphx::coordinator::ServerHandle, n: usize) -> anyhow::Result<()> {
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i as i32 + 2)) % 500 + 1).collect();
            server.submit(prompt, TOKENS).unwrap()
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv()?;
        assert!(resp.ok(), "{:?}", resp.error);
    }
    Ok(())
}

fn run_once(max_batch: usize, step_policy: StepPolicy) -> anyhow::Result<()> {
    let server = Server::start(artifacts()?, config(max_batch, step_policy))?;
    let t0 = Instant::now();
    submit_workload(&server, REQUESTS)?;
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    println!(
        "batch={max_batch:<2} policy={step_policy:?}: {} tok in {wall:.2}s → {:>6.1} tok/s | p50 {:>6.1}ms p99 {:>6.1}ms | sim {:>6.1}ms {:>5.1} tok/J",
        m.tokens_out,
        m.tokens_out as f64 / wall,
        m.latency_pct(0.5).unwrap_or(0.0) * 1e3,
        m.latency_pct(0.99).unwrap_or(0.0) * 1e3,
        m.simulated_device_s * 1e3,
        m.sim_tokens_per_joule(),
    );
    Ok(())
}

fn run_fleet() -> anyhow::Result<()> {
    let mut cfg = config(4, StepPolicy::RoundRobin);
    cfg.route = RoutePolicy::WeightedThroughput;
    cfg.nodes = vec![
        NodeConfig::new(registry::cmp170hx(), FmadPolicy::Decomposed),
        NodeConfig::new(registry::cmp90hx(), FmadPolicy::Decomposed),
    ];
    let server = Server::start(artifacts()?, cfg)?;
    let t0 = Instant::now();
    submit_workload(&server, 2 * REQUESTS)?;
    let wall = t0.elapsed().as_secs_f64();
    let fm = server.shutdown_fleet();
    println!("served {} requests in {wall:.2}s wall", 2 * REQUESTS);
    print!("{}", fm.render());

    // The §6.2 answer. The replacement ratios compare decode operating
    // points on BOTH sides (the A100 reference is decode-only; mixing in
    // the serving basis — prefill charged at TDP — would bias the numbers
    // against the recycled cards). The *measured* serving rate feeds the
    // fleet-sizing line instead, where both sides share the same basis.
    let bench = LlamaBench::default();
    let a100 = bench.run(&registry::a100_pcie(), &quant::Q8_0, FmadPolicy::Fused);
    for (name, m) in &fm.nodes {
        if m.tokens_out == 0 {
            continue;
        }
        let dev = registry::by_name(name).expect("fleet node in registry");
        // same policy the fleet nodes were configured with above
        let row = bench.run(&dev, &quant::Q8_0, FmadPolicy::Decomposed);
        let rep = tco::a100_replacement(
            &dev,
            row.decode_tps,
            row.decode_power_w,
            a100.decode_tps,
            a100.decode_power_w,
        );
        let plan =
            tco::fleet_for_measured_throughput(&dev, m.sim_tokens_per_sec(), a100.decode_tps);
        println!(
            "{name}: {} cards ≈ one A100 on decode ({:.0}% capex, {:.1}× power, {:.2}× J/token); \
             at the measured serving rate ({:.0} tok/s/card incl. prefill) {} cards",
            rep.cards_per_a100,
            rep.capex_ratio * 100.0,
            rep.power_ratio,
            rep.energy_per_token_ratio,
            m.sim_tokens_per_sec(),
            plan.cards,
        );
    }
    Ok(())
}

/// Serve a long + shorts mix under a deliberately tight page pool, with
/// and without preemption — the paged-KV ablation: how much recompute tax
/// does preempt-and-requeue pay to keep short requests completing?
fn run_pressure(preempt: bool) -> anyhow::Result<()> {
    const LONG: usize = 24;
    const SHORT: usize = 6;
    let dir = artifacts()?;
    let prefill_t = cmphx::runtime::goldens::config_usize(&dir, "prefill_t")?;
    let mut cfg = config(2, StepPolicy::ShortestFirst);
    cfg.batch.kv_block_positions = 1;
    cfg.batch.kv_block_budget =
        Some((prefill_t + LONG - 1).max(2 * (prefill_t + SHORT)));
    cfg.batch.preempt = preempt;
    let server = Server::start(dir, cfg)?;
    let t0 = Instant::now();
    let rx_long = server.submit(vec![3, 1, 4, 1, 5, 9, 2, 6], LONG)?;
    let rx_shorts: Vec<_> = (0..4)
        .map(|i| {
            let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
            server.submit(prompt, SHORT).unwrap()
        })
        .collect();
    let mut served = 0usize;
    for rx in rx_shorts.into_iter().chain(std::iter::once(rx_long)) {
        if rx.recv()?.ok() {
            served += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    println!(
        "preempt={preempt:<5}: {served}/5 served, {} tok in {wall:.2}s | evicted={} resumed={} wasted_sim={:.1}ms | errors={}",
        m.tokens_out,
        m.preemptions,
        m.resumes,
        m.wasted_prefill_s * 1e3,
        m.errors,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if !cmphx::runtime::pjrt_available() {
        println!("e2e serving bench skipped: PJRT unavailable (stub xla build)");
        return Ok(());
    }
    if artifacts().is_err() {
        println!("e2e serving bench skipped: artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    println!("== e2e serving: {REQUESTS} requests × {TOKENS} tokens (tiny-qwen over PJRT) ==");
    for max_batch in [1, 2, 4, 8] {
        run_once(max_batch, StepPolicy::RoundRobin)?;
    }
    println!("-- scheduler ablation at batch=4 --");
    run_once(4, StepPolicy::ShortestFirst)?;
    println!("-- paged KV under page pressure: preempt-and-requeue ablation --");
    run_pressure(true)?;
    run_pressure(false)?;
    println!("-- fleet: 170HX + 90HX, continuous batching, weighted routing --");
    run_fleet()?;
    Ok(())
}
