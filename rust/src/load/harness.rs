//! Open-loop pacing against the *real* coordinator.
//!
//! [`super::sim`] answers the acceptance questions analytically; this
//! driver replays the same [`ArrivalPlan`] against a live
//! [`ServerHandle`] so the knee curves can also be measured end-to-end
//! when the PJRT artifacts are present. The defining property of an
//! open-loop harness is preserved: arrival times come from the plan, not
//! from completions — a slow server does **not** slow the offered load,
//! which is exactly how production traffic finds the latency knee.
//!
//! Plan times are in simulated seconds; `time_scale` maps them onto the
//! wall clock (e.g. `0.01` replays a 60 s plan in 600 ms) so smoke tests
//! stay fast while preserving the arrival *order* and relative spacing.

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use super::arrivals::ArrivalPlan;
use crate::coordinator::{GenResponse, ServerHandle};

/// Everything a paced run produces, indexed like the plan's arrivals.
#[derive(Debug, Default)]
pub struct DriveOutcome {
    /// One slot per arrival: `None` when submit itself was refused
    /// (bounded submit queue full — back-pressure at the front door).
    pub responses: Vec<Option<GenResponse>>,
    /// Arrivals refused at submit.
    pub submit_rejected: u64,
}

impl DriveOutcome {
    /// Responses that completed with tokens and no error.
    pub fn completed(&self) -> usize {
        self.responses.iter().flatten().filter(|r| r.ok()).count()
    }

    /// Responses terminated with an error (shed, deadline, fleet death).
    pub fn failed(&self) -> usize {
        self.responses.iter().flatten().filter(|r| !r.ok()).count()
    }
}

/// Wall-clock offset of a plan arrival under `time_scale`.
pub(crate) fn wall_offset(at_s: f64, time_scale: f64) -> Duration {
    Duration::from_secs_f64((at_s * time_scale).max(0.0))
}

/// Replay `plan` against a running server, open-loop. Blocks until every
/// submitted request has a terminal response (completed or shed).
pub fn drive(handle: &ServerHandle, plan: &ArrivalPlan, time_scale: f64) -> DriveOutcome {
    assert!(time_scale > 0.0 && time_scale.is_finite(), "bad time_scale");
    let start = Instant::now();
    let mut pending: Vec<Option<Receiver<GenResponse>>> = Vec::with_capacity(plan.len());
    let mut out = DriveOutcome::default();
    for a in &plan.arrivals {
        let due = wall_offset(a.at_s, time_scale);
        let elapsed = start.elapsed();
        if due > elapsed {
            // Open loop: wait out the schedule even if the server idles.
            std::thread::sleep(due - elapsed);
        }
        match handle.submit_as(a.tenant, a.prompt.clone(), a.max_tokens) {
            Ok(rx) => pending.push(Some(rx)),
            Err(_) => {
                out.submit_rejected += 1;
                pending.push(None);
            }
        }
    }
    for rx in pending {
        out.responses.push(rx.and_then(|rx| rx.recv().ok()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_offsets_scale_and_never_go_negative() {
        assert_eq!(wall_offset(2.0, 0.5), Duration::from_secs(1));
        assert_eq!(wall_offset(0.25, 0.01), Duration::from_micros(2500));
        assert_eq!(wall_offset(-1.0, 1.0), Duration::ZERO);
    }
}
