//! The serving engine: a worker thread owning the PJRT model runtime.
//!
//! Life of a request: client → bounded queue → [`Batcher`] window → worker
//! prefills each prompt into a KV slot → decode rounds per
//! [`scheduler::plan_round`] until every sequence hits its target → replies
//! on each request's channel. Failures are contained per request; a dropped
//! reply receiver is a cancellation. Every step also accrues the simulated
//! CMP 170HX device-time overlay so the example/bench can report "what this
//! workload would cost on the paper's card".

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::device::registry;
use crate::isa::pass::FmadPolicy;
use crate::llm::llamabench::LlamaBench;
use crate::llm::model::ModelDesc;
use crate::llm::quant;
use crate::runtime::{ArtifactDir, DecodeState, ModelRuntime};

use super::batcher::{BatchPolicy, Batcher};
use super::kv::KvSlots;
use super::metrics::Metrics;
use super::request::{GenRequest, GenResponse};
use super::scheduler::{plan_round_into, SeqView, StepPolicy};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub queue_depth: usize,
    pub batch: BatchPolicy,
    pub step_policy: StepPolicy,
    /// fmad policy of the simulated deployment (drives the overlay).
    pub fmad: FmadPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_depth: 64,
            batch: BatchPolicy::default(),
            step_policy: StepPolicy::RoundRobin,
            fmad: FmadPolicy::Decomposed,
        }
    }
}

/// Client handle: submit requests, read metrics, shut down.
pub struct ServerHandle {
    tx: Option<SyncSender<GenRequest>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    next_id: std::sync::atomic::AtomicU64,
}

/// Simulated per-token device times for the overlay.
#[derive(Clone, Copy, Debug)]
struct Overlay {
    prefill_s_per_token: f64,
    decode_s_per_token: f64,
}

impl Overlay {
    /// Overlay for the CMP 170HX serving the paper's Qwen2.5-1.5B in q8_0
    /// at the configured fmad policy — the workload §6.2 recommends.
    fn cmp170hx(policy: FmadPolicy) -> Overlay {
        let bench = LlamaBench {
            model: ModelDesc::qwen25_15b(),
            ..Default::default()
        };
        let dev = registry::cmp170hx();
        let r = bench.run(&dev, &quant::Q8_0, policy);
        Overlay {
            prefill_s_per_token: 1.0 / r.prefill_tps,
            decode_s_per_token: 1.0 / r.decode_tps,
        }
    }
}

/// The serving engine.
pub struct Server;

impl Server {
    /// Start the worker over an artifact directory. Compilation happens on
    /// the worker thread; `start` returns once the runtime is live (or the
    /// first error is known).
    pub fn start(artifacts: ArtifactDir, config: ServerConfig) -> Result<ServerHandle> {
        let (tx, rx) = sync_channel::<GenRequest>(config.queue_depth);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let metrics_worker = Arc::clone(&metrics);
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);

        let worker = std::thread::Builder::new()
            .name("cmphx-server".into())
            .spawn(move || {
                let runtime = match ModelRuntime::load(&artifacts) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                worker_loop(runtime, rx, config, metrics_worker);
            })?;

        ready_rx.recv()??;
        Ok(ServerHandle {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }
}

impl ServerHandle {
    /// Submit a generation request; returns the response receiver. Errors
    /// when the queue is full (backpressure) or the server is stopped.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_tokens: usize,
    ) -> Result<Receiver<GenResponse>> {
        let (reply, rx) = std::sync::mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req = GenRequest {
            id,
            prompt,
            max_tokens,
            reply,
            enqueued: Instant::now(),
        };
        let tx = self.tx.as_ref().ok_or_else(|| anyhow::anyhow!("server stopped"))?;
        match tx.try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => anyhow::bail!("queue full (backpressure)"),
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("server stopped"),
        }
    }

    /// Snapshot of metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Stop accepting requests, drain, and join the worker.
    pub fn shutdown(mut self) -> Metrics {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    runtime: ModelRuntime,
    rx: Receiver<GenRequest>,
    config: ServerConfig,
    metrics: Arc<Mutex<Metrics>>,
) {
    let overlay = Overlay::cmp170hx(config.fmad);
    let cfg = runtime.config;
    // KV slots sized for the simulated card: Qwen2.5-1.5B q8_0 weights on
    // an 8 GB device; the *real* tiny-qwen state is negligible, the slot
    // count enforces the same admission behaviour the CMP would.
    let model = ModelDesc::qwen25_15b();
    let mut slots = KvSlots::new(
        config.batch.max_batch,
        model.kv_bytes_per_pos() as u64 * cfg.max_ctx as u64,
        8 << 30,
        model.weight_bytes(&quant::Q8_0),
    )
    .expect("slot config must fit the 8GB card");

    let batcher = Batcher::new(rx, config.batch);
    while let Some(batch) = batcher.next_batch() {
        metrics.lock().unwrap().record_batch(batch.len());
        serve_batch(&runtime, &config, &overlay, &mut slots, batch, &metrics);
    }
}

struct Live {
    req: GenRequest,
    state: DecodeState,
    slot: usize,
    tokens: Vec<i32>,
    queue_s: f64,
    prefill_s: f64,
    sim_s: f64,
    decode_started: Instant,
}

fn serve_batch(
    runtime: &ModelRuntime,
    config: &ServerConfig,
    overlay: &Overlay,
    slots: &mut KvSlots,
    batch: Vec<GenRequest>,
    metrics: &Arc<Mutex<Metrics>>,
) {
    let cfg = runtime.config;
    let mut live: Vec<Live> = Vec::new();

    // --- prefill phase ---
    for req in batch {
        let queue_s = req.enqueued.elapsed().as_secs_f64();
        // admission: prompt must fit the window, generation must fit KV
        let budget = cfg.max_ctx - cfg.prefill_t;
        if req.prompt.len() > cfg.prefill_t || req.max_tokens > budget {
            respond_error(
                &req,
                format!(
                    "request exceeds window (prompt {} > {} or tokens {} > {})",
                    req.prompt.len(),
                    cfg.prefill_t,
                    req.max_tokens,
                    budget
                ),
                queue_s,
                metrics,
            );
            continue;
        }
        let Some(slot) = slots.acquire() else {
            respond_error(&req, "no KV slot (overload)".into(), queue_s, metrics);
            continue;
        };
        let t0 = Instant::now();
        match runtime.prefill_padded(&req.prompt) {
            Ok(state) => {
                let prefill_s = t0.elapsed().as_secs_f64();
                let sim_s = overlay.prefill_s_per_token * cfg.prefill_t as f64;
                let first = state.argmax();
                live.push(Live {
                    req,
                    state,
                    slot,
                    tokens: vec![first],
                    queue_s,
                    prefill_s,
                    sim_s,
                    decode_started: Instant::now(),
                });
            }
            Err(e) => {
                slots.release(slot);
                respond_error(&req, format!("prefill failed: {e}"), queue_s, metrics);
            }
        }
    }

    // --- decode rounds ---
    // Round-planning buffers reused across the whole batch: after the first
    // round, planning allocates nothing.
    let mut views: Vec<SeqView> = Vec::with_capacity(live.len());
    let mut plan: Vec<usize> = Vec::with_capacity(live.len());
    loop {
        views.clear();
        views.extend(live.iter().enumerate().map(|(i, l)| SeqView {
            seq: i,
            generated: l.tokens.len(),
            target: l.req.max_tokens.max(1),
        }));
        plan_round_into(config.step_policy, &views, &mut plan);
        if plan.is_empty() {
            break;
        }
        for &idx in &plan {
            let l = &mut live[idx];
            let token = *l.tokens.last().unwrap();
            match runtime.decode(&mut l.state, token) {
                Ok(()) => {
                    l.tokens.push(l.state.argmax());
                    l.sim_s += overlay.decode_s_per_token;
                }
                Err(e) => {
                    // fail just this sequence; mark done by truncating target
                    l.req.max_tokens = l.tokens.len();
                    let msg = format!("decode failed: {e}");
                    let _ = l.req.reply.send(GenResponse {
                        id: l.req.id,
                        tokens: l.tokens.clone(),
                        error: Some(msg),
                        queue_s: l.queue_s,
                        prefill_s: l.prefill_s,
                        decode_s: l.decode_started.elapsed().as_secs_f64(),
                        simulated_device_s: l.sim_s,
                    });
                }
            }
        }
    }

    // --- respond + release ---
    let mut m = metrics.lock().unwrap();
    for l in live {
        slots.release(l.slot);
        let decode_s = l.decode_started.elapsed().as_secs_f64();
        m.wall_prefill_s += l.prefill_s;
        m.wall_decode_s += decode_s;
        m.simulated_device_s += l.sim_s;
        let resp = GenResponse {
            id: l.req.id,
            tokens: l.tokens.clone(),
            error: None,
            queue_s: l.queue_s,
            prefill_s: l.prefill_s,
            decode_s,
            simulated_device_s: l.sim_s,
        };
        m.record_response(resp.latency_s(), resp.tokens.len(), true);
        // dropped receiver = cancelled; ignore send failure
        let _ = l.req.reply.send(resp);
    }
}

fn respond_error(
    req: &GenRequest,
    error: String,
    queue_s: f64,
    metrics: &Arc<Mutex<Metrics>>,
) {
    metrics
        .lock()
        .unwrap()
        .record_response(queue_s, 0, false);
    let _ = req.reply.send(GenResponse {
        id: req.id,
        tokens: vec![],
        error: Some(error),
        queue_s,
        prefill_s: 0.0,
        decode_s: 0.0,
        simulated_device_s: 0.0,
    });
}
