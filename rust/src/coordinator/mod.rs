//! L3 serving coordinator — the §6.2 edge-node deployment, real, at fleet
//! scale.
//!
//! A threaded (std::thread + mpsc; no async runtime in the offline crate
//! set) inference fleet over the AOT artifacts: requests enter a bounded
//! queue, the dispatch stage routes each one across N per-card workers via
//! a [`router::Fleet`] policy, and every worker runs **continuous
//! batching** — new sequences join its decode round whenever a [`kv`] slot
//! frees ([`scheduler::plan_admission`]), with [`batcher::BatchPolicy`]
//! reduced to the admission-policy value type. Each node owns its own
//! runtime, KV slots sized to its card's VRAM, and a per-card simulated
//! device-time/energy overlay, so [`metrics::FleetMetrics`] reports
//! fleet-wide tokens/s, latency percentiles, and tokens/joule for any mix
//! of registry cards.
//!
//! Python never runs here: the executables carry the weights.

pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use batcher::BatchPolicy;
pub use kv::KvSlots;
pub use metrics::{FleetMetrics, Metrics};
pub use request::{GenRequest, GenResponse};
pub use router::{Fleet, RoutePolicy};
pub use server::{NodeConfig, Server, ServerConfig, ServerHandle};
