//! Model runtime: compiled prefill/decode executables + KV-cache state.
//!
//! This is the real inference engine the coordinator serves: prefill a
//! prompt → `DecodeState` (logits + KV literals) → repeated `decode` steps,
//! greedy-sampled in Rust. The weights live inside the compiled executable;
//! the KV cache rides along as literals between steps (CPU PJRT, zero-copy
//! enough at tiny-qwen scale).

use anyhow::{Context, Result};

use super::artifacts::ArtifactDir;
use super::goldens::{self, Json};

/// Model geometry read from goldens.json (written by aot.py from the same
/// Config the HLO was lowered with).
#[derive(Clone, Copy, Debug)]
pub struct RtConfig {
    pub vocab: usize,
    pub layers: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub max_ctx: usize,
    pub prefill_t: usize,
}

/// In-flight generation state for one sequence.
pub struct DecodeState {
    pub k_cache: xla::Literal,
    pub v_cache: xla::Literal,
    pub pos: usize,
    pub last_logits: Vec<f32>,
}

impl DecodeState {
    /// Greedy-sample the next token from the last logits.
    pub fn argmax(&self) -> i32 {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in self.last_logits.iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best as i32
    }
}

/// Compiled model executables bound to a PJRT client.
pub struct ModelRuntime {
    pub config: RtConfig,
    pub goldens: Json,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
}

impl ModelRuntime {
    /// Load artifacts and compile prefill + decode on the CPU PJRT client.
    pub fn load(dir: &ArtifactDir) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let prefill_exe = dir.compile(&client, "prefill.hlo.txt")?;
        let decode_exe = dir.compile(&client, "decode.hlo.txt")?;
        let goldens = goldens::load(dir.path("goldens.json"))?;
        let cfg = goldens.get("config").context("goldens missing config")?;
        let get = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("goldens config missing {k}"))
        };
        let config = RtConfig {
            vocab: get("vocab")?,
            layers: get("layers")?,
            kv_heads: get("kv_heads")?,
            head_dim: get("head_dim")?,
            max_ctx: get("max_ctx")?,
            prefill_t: get("prefill_t")?,
        };
        Ok(ModelRuntime {
            config,
            goldens,
            client,
            prefill_exe,
            decode_exe,
        })
    }

    /// The PJRT platform backing this runtime (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Run prefill on a prompt of exactly `config.prefill_t` tokens
    /// (shorter prompts are left-padded with token 0 by the caller or
    /// [`ModelRuntime::prefill_padded`]).
    pub fn prefill(&self, tokens: &[i32]) -> Result<DecodeState> {
        anyhow::ensure!(
            tokens.len() == self.config.prefill_t,
            "prefill expects exactly {} tokens, got {}",
            self.config.prefill_t,
            tokens.len()
        );
        let input = xla::Literal::vec1(tokens);
        let result = self.prefill_exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?;
        let (logits, k_cache, v_cache) = result.to_tuple3()?;
        let all = logits.to_vec::<f32>()?;
        let v = self.config.vocab;
        let last = all[(self.config.prefill_t - 1) * v..].to_vec();
        Ok(DecodeState {
            k_cache,
            v_cache,
            pos: self.config.prefill_t,
            last_logits: last,
        })
    }

    /// The exact left-padded window [`ModelRuntime::prefill_padded`]
    /// computes KV over: the prompt right-aligned over a zero pad.
    /// (tiny-qwen has no pad token; position-0 zeros act as a benign BOS
    /// run — goldens are generated with full-length prompts.) The
    /// coordinator's prefix cache chain-hashes this same window, so KV
    /// content and cache key can never drift apart.
    pub fn padded_window(&self, tokens: &[i32]) -> Result<Vec<i32>> {
        let t = self.config.prefill_t;
        anyhow::ensure!(tokens.len() <= t, "prompt longer than prefill window");
        let mut padded = vec![0i32; t - tokens.len()];
        padded.extend_from_slice(tokens);
        Ok(padded)
    }

    /// Prefill a prompt of length ≤ prefill_t by right-aligning it over a
    /// zero pad ([`ModelRuntime::padded_window`]).
    pub fn prefill_padded(&self, tokens: &[i32]) -> Result<DecodeState> {
        self.prefill(&self.padded_window(tokens)?)
    }

    /// One decode step: feed `token` at the state's position, update caches
    /// and logits in place.
    pub fn decode(&self, state: &mut DecodeState, token: i32) -> Result<()> {
        anyhow::ensure!(
            state.pos < self.config.max_ctx,
            "KV cache exhausted at pos {}",
            state.pos
        );
        let tok = xla::Literal::scalar(token);
        let pos = xla::Literal::scalar(state.pos as i32);
        // Literals are borrowed by execute — no cache copies on the way in.
        let args: [&xla::Literal; 4] = [&tok, &state.k_cache, &state.v_cache, &pos];
        let result = self.decode_exe.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (logits, k_cache, v_cache) = result.to_tuple3()?;
        state.last_logits = logits.to_vec::<f32>()?;
        state.k_cache = k_cache;
        state.v_cache = v_cache;
        state.pos += 1;
        Ok(())
    }

    /// Greedy generation: prefill `prompt`, then `steps` decode steps.
    /// Returns the generated token ids.
    pub fn generate(&self, prompt: &[i32], steps: usize) -> Result<Vec<i32>> {
        let mut state = self.prefill_padded(prompt)?;
        let mut out = Vec::with_capacity(steps);
        let mut token = state.argmax();
        out.push(token);
        for _ in 1..steps {
            self.decode(&mut state, token)?;
            token = state.argmax();
            out.push(token);
        }
        Ok(out)
    }

    /// Compile + run one of the kernel artifacts with literal inputs —
    /// used by the quickstart example and integration tests.
    pub fn run_kernel(
        &self,
        dir: &ArtifactDir,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let exe = dir.compile(&self.client, name)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }
}
