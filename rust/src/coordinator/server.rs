//! The fleet serving engine: a QoS'd admission stage feeding N per-card
//! continuous-batching workers over paged KV.
//!
//! Life of a request: client → bounded submit queue → **QoS dispatch
//! stage** — the tenant's lane in a deficit-round-robin weighted fair
//! queue ([`crate::qos::wfq`]), rate/energy caps checked against its
//! [`crate::qos::TenantAccounts`] (energy priced with the routed node's
//! overlay), then the [`Fleet`] router picks a card and the request lands
//! on that node's bounded work queue ([`crate::qos::NodeQueues`]) — →
//! the node's worker joins the request into its decode round as soon as
//! the KV pager can hold its prefill window (vLLM-style continuous
//! batching — no stop-the-world batch windows), prefills it, and
//! interleaves decode steps per [`scheduler::plan_round_into`], growing
//! the sequence's KV pages block-by-block, until the sequence hits its
//! target → reply on the request's channel. Admission is **content-aware**:
//! the pager chain-hashes the prompt window and pins already-resident
//! blocks ([`KvPager::admit_prompt`]) — identical system prompts cost one
//! physical copy, copy-on-write privatizes a shared tail on first decode
//! write.
//!
//! The cards are tied together by the **fleet KV fabric**: every worker
//! publishes its resident prefix chains to a [`PrefixDirectory`] each
//! round, and the dispatch stage routes new arrivals toward their
//! deepest resident prefix ([`Fleet::route_affine`]) — a hint, not a
//! lease, since admission re-probes residency and a stale hit degrades
//! to a plain miss. Swapped-out pages live in one *fleet-shared*
//! [`HostPool`], and preempted sequences park in a fleet-shared
//! [`ParkLot`]: an **idle** worker whose queue runs dry steals the
//! newest queued request from the deepest peer queue, or **claims a
//! foreign parked sequence and resumes it on its own card** — a live
//! migration, priced at both ends' PCIe widths (swap-out at the
//! victim's link, restore at the thief's) or replayed prefix-aware when
//! the victim's KV was dropped. Swap DMA is modeled as **overlapped**
//! with the decode round the survivors run while it streams: only the
//! tail of the transfer that outlives the round stalls the simulated
//! clock ([`scheduler::overlap_transfer`]).
//!
//! When a round cannot allocate growth pages, the engine
//! preempts the longest-remaining sequence (ties broken toward the most
//! over-served tenant, [`scheduler::plan_eviction_weighted`]) and prices
//! its comeback per victim ([`scheduler::choose_preempt`]): either the KV
//! is dropped and the request parks in the shared lot to resume by
//! recomputing prefill and replaying its generated tokens (greedy decode
//! is deterministic, so the replay reconstructs the identical state), or
//! — when the §3 PCIe round trip at this card's link width is cheaper
//! than the recompute — the pages are **swapped** to a host-RAM pool and
//! restored on resume with no recompute at all. A
//! parked sequence that waits past [`BatchPolicy::aging_rounds`] engine
//! rounds freezes new admissions until it resumes, and the resumed
//! sequence is shielded from re-eviction — sustained short traffic can no
//! longer park a long sequence indefinitely. Failures are contained per
//! request; a dropped reply receiver is a cancellation.
//!
//! Every node owns its own [`ModelRuntime`], [`KvPager`] sized to its
//! card's VRAM, [`Metrics`], and a simulated device-time/energy overlay
//! calibrated per card (any mix of registry [`DeviceSpec`]s), so a
//! heterogeneous fleet — a 170HX next to a 90HX — reports fleet-wide
//! tokens/s and tokens/joule, per node *and* per tenant.

use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::device::{registry, DeviceSpec};
use crate::faults::{backoff_delay, Degrade, FaultInjector, FaultKind, FaultPlan, RecoveryPolicy};
use crate::isa::pass::FmadPolicy;
use crate::llm::llamabench::{BenchResult, LlamaBench};
use crate::llm::model::ModelDesc;
use crate::llm::quant;
use crate::load::{weight_ranks, AdmissionConfig, AdmissionCtl, Verdict};
use crate::memhier::pcie::PcieLink;
use crate::obsv::{
    DispatchPoint, PhaseLedger, SeriesPoint, SpanKind, TraceId, Tracer, NODE_SCOPE, RING_CAP,
};
use crate::qos::{
    Admission, AdmissionQueue, NodeQueues, Popped, QosConfig, TenantAccounts, TenantId,
    TenantRegistry, WaitPop,
};
use crate::runtime::{ArtifactDir, DecodeState, ModelRuntime};

use super::batcher::BatchPolicy;
use super::kv::{window_chain_hashes, HostPool, KvPager, PrefixDirectory, SeqKv};
use super::metrics::{FleetMetrics, Metrics};
use super::request::{Carried, GenRequest, GenResponse};
use super::router::{Fleet, Node, RoutePolicy};
use super::scheduler::{
    choose_preempt, degraded_concurrency, overlap_transfer, plan_admission,
    plan_admission_prefix_aware, plan_eviction_weighted, plan_round_into, swap_round_trip_s,
    PreemptAction, SeqView, StepPolicy,
};

/// Power charged to a simulated second of swap transfer: the DMA engine
/// plus the near-idle board — an order of magnitude below the TDP a
/// recompute's prefill burns, which is exactly why swapping can win the
/// energy ledger as well as the time one.
const SWAP_LINK_W: f64 = 15.0;

/// One card of the serving fleet: the simulated device identity and the
/// fmad policy its deployment would run.
#[derive(Clone, Debug)]
pub struct NodeConfig {
    pub device: DeviceSpec,
    pub fmad: FmadPolicy,
}

impl NodeConfig {
    pub fn new(device: DeviceSpec, fmad: FmadPolicy) -> Self {
        NodeConfig { device, fmad }
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bound of the shared submit queue (`submit` sheds load past it).
    /// The per-node work queues are bounded separately — and much more
    /// shallowly — by [`QosConfig::node_queue_depth`], so that backlog
    /// accumulates in the tenant-fair queue instead of per-node FIFOs.
    pub queue_depth: usize,
    /// Per-node admission policy (concurrency cap, cold-start gather, KV
    /// page size, preemption, waiting-queue aging).
    pub batch: BatchPolicy,
    pub step_policy: StepPolicy,
    /// fmad policy of the default single-node deployment (and of nodes
    /// added via the CLI); explicit [`NodeConfig`]s carry their own.
    pub fmad: FmadPolicy,
    /// Dispatch-stage routing policy across the fleet.
    pub route: RoutePolicy,
    /// The fleet. Empty = one CMP 170HX (the single-card path, unchanged
    /// in behaviour and per-request results).
    pub nodes: Vec<NodeConfig>,
    /// Multi-tenant QoS: tenants, weighted fair queueing, work stealing.
    pub qos: QosConfig,
    /// Self-healing knobs: sequence rescue on node death, bounded retry
    /// with backoff, per-request deadlines, quarantine probation.
    pub recovery: RecoveryPolicy,
    /// Deterministic fault-injection plan (chaos testing). `None` — the
    /// default — runs with the injector compiled out of the hot path.
    pub faults: Option<FaultPlan>,
    /// Prefix-affine dispatch: route new arrivals toward the card whose
    /// published prefix chains cover the prompt deepest
    /// ([`Fleet::route_affine`]). Off (`--no-affinity`) is the ablation
    /// baseline — every dispatch takes the plain routing policy.
    pub affinity: bool,
    /// Model swap/migration DMA as overlapped with the concurrent decode
    /// round: only the transfer tail past the round's length stalls the
    /// simulated clock. Off (`--no-overlap`) charges transfers serially,
    /// the pre-fabric baseline.
    pub overlap: bool,
    /// Flight-recorder tracing ([`crate::obsv`]): per-request span
    /// journals on every node's simulated clock, per-round fleet
    /// time-series, and automatic ring dumps on chaos deaths and terminal
    /// errors. Off (the default) compiles the tracer down to early
    /// returns — every stamp is simulated-clock, so tracing can never
    /// move the simulated numbers either way.
    pub trace: bool,
    /// Adaptive admission control ([`crate::load::AdmissionCtl`]):
    /// predict each SLO-contracted request's completion at dispatch from
    /// the fleet's backlog priced with the calibrated overlays, and shed
    /// it *before* any prefill is wasted when the prediction violates the
    /// tenant's contract, escalating down a hysteretic brownout ladder
    /// under sustained overload. On by default — it only ever acts on
    /// tenants that declare an SLO (`name:weight:…:slo_ms`), so
    /// uncontracted traffic is untouched. Off (`--no-admission-control`)
    /// is the reactive-only ablation arm: stale requests fail at the
    /// deadline gate after they already queued.
    pub admission: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_depth: 64,
            batch: BatchPolicy::default(),
            step_policy: StepPolicy::RoundRobin,
            fmad: FmadPolicy::Decomposed,
            route: RoutePolicy::WeightedThroughput,
            nodes: Vec::new(),
            qos: QosConfig::default(),
            recovery: RecoveryPolicy::default(),
            faults: None,
            affinity: true,
            overlap: true,
            trace: false,
            admission: true,
        }
    }
}

/// A request re-entering the admission stage from a worker: a **rescue**
/// (its node died; its generated tokens ride along for bit-identical
/// replay) or a bounded **retry** (a transient refusal — no KV pages —
/// worth another dispatch after backoff).
enum Requeue {
    Rescue(GenRequest),
    Retry(GenRequest),
}

impl Requeue {
    fn into_request(self) -> GenRequest {
        match self {
            Requeue::Rescue(r) | Requeue::Retry(r) => r,
        }
    }
}

/// Client handle: submit requests (optionally as a named tenant), read
/// metrics, flip node health, shut down.
pub struct ServerHandle {
    tx: Option<SyncSender<GenRequest>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    node_names: Vec<&'static str>,
    node_metrics: Vec<Arc<Mutex<Metrics>>>,
    tenant_metrics: Arc<Vec<Mutex<Metrics>>>,
    registry: Arc<TenantRegistry>,
    fleet: Arc<Mutex<Fleet>>,
    /// The fleet's flight recorder (disabled unless [`ServerConfig::trace`]).
    tracer: Arc<Tracer>,
    /// Wall-clock deadline stamped on every submission (None = no SLO).
    deadline: Option<Duration>,
    next_id: std::sync::atomic::AtomicU64,
}

/// Simulated per-token device time and power for one node's overlay.
#[derive(Clone, Copy, Debug)]
struct Overlay {
    prefill_s_per_token: f64,
    decode_s_per_token: f64,
    /// Prefill is compute-saturated, so the DVFS governor pins the board at
    /// its envelope — [`crate::power::PowerModel::board_power`] clips
    /// saturated activity to TDP, which is what we charge per prefill
    /// second.
    prefill_w: f64,
    /// Decode power from the §4.4 calibrated residency model.
    decode_w: f64,
}

impl Overlay {
    /// Overlay for one node serving the paper's Qwen2.5-1.5B in q8_0 — the
    /// workload §6.2 recommends — from its calibrated bench row.
    fn from_row(row: &BenchResult, dev: &DeviceSpec) -> Overlay {
        Overlay {
            prefill_s_per_token: 1.0 / row.prefill_tps,
            decode_s_per_token: 1.0 / row.decode_tps,
            prefill_w: dev.tdp_w,
            decode_w: row.decode_power_w,
        }
    }

    /// Estimated simulated joules for one request on this node: a full
    /// prefill window plus `max_tokens` decode steps — what the QoS stage
    /// charges a tenant's energy budget at dispatch (settled to actuals
    /// at retire).
    fn estimate_j(&self, prefill_t: usize, max_tokens: usize) -> f64 {
        self.prefill_s_per_token * prefill_t as f64 * self.prefill_w
            + self.decode_s_per_token * max_tokens as f64 * self.decode_w
    }

    /// Simulated device seconds to rebuild a preempted sequence from
    /// scratch: recompute the prefill window, then replay `replay_steps`
    /// generated tokens. The recompute side of the swap-vs-recompute
    /// choice ([`choose_preempt`]).
    fn recompute_s(&self, prefill_t: usize, replay_steps: usize) -> f64 {
        self.prefill_s_per_token * prefill_t as f64
            + self.decode_s_per_token * replay_steps as f64
    }

    /// Simulated joules for the same rebuild (prefill at the TDP
    /// envelope, replay at calibrated decode power) — the same formula
    /// the dispatch stage prices energy budgets with.
    fn recompute_j(&self, prefill_t: usize, replay_steps: usize) -> f64 {
        self.estimate_j(prefill_t, replay_steps)
    }
}

/// Reject artifact geometries the admission path cannot serve: a runtime
/// with `prefill_t > max_ctx` has no decode budget at all (and the old
/// `max_ctx - prefill_t` subtraction panicked on it at admit time).
pub(crate) fn validate_window(max_ctx: usize, prefill_t: usize) -> Result<()> {
    if prefill_t > max_ctx {
        anyhow::bail!("runtime window invalid: prefill_t {prefill_t} exceeds max_ctx {max_ctx}");
    }
    Ok(())
}

/// Decode-token budget left after the prefill window. Saturating, so even
/// a geometry that slipped past [`validate_window`] yields a clean
/// zero-budget rejection at admit time instead of a usize underflow panic.
pub(crate) fn admission_budget(max_ctx: usize, prefill_t: usize) -> usize {
    max_ctx.saturating_sub(prefill_t)
}

/// Clears a node's liveness flag when its worker thread exits for any
/// reason — including a panic — so the dispatch stage reroutes instead of
/// queueing onto the dead. Requests still queued on the corpse are
/// **rescued** back into the admission stage when the rescue channel is
/// up; otherwise they are dropped, closing their reply channels so
/// waiting clients fail fast instead of hanging until shutdown. On a
/// normal exit the queue is already drained and this is a no-op.
struct AliveGuard {
    queues: Arc<NodeQueues<GenRequest>>,
    fleet: Arc<Mutex<Fleet>>,
    rescue: Option<SyncSender<Requeue>>,
    node: usize,
}

impl Drop for AliveGuard {
    fn drop(&mut self) {
        // Kill-and-drain is atomic: no request can slip into the queue
        // between the death flag and the drain and strand forever.
        for req in self.queues.kill_node(self.node) {
            // The routed-but-never-started slot goes back to the router;
            // a successful rescue re-books on dispatch.
            self.fleet.lock().unwrap().complete(self.node);
            if let Some(tx) = &self.rescue {
                if tx.send(Requeue::Rescue(req)).is_ok() {
                    continue;
                }
            }
            // No rescue path: the drop closes the reply channel.
        }
    }
}

/// The serving engine.
pub struct Server;

impl Server {
    /// Start the fleet over an artifact directory: one runtime-owning
    /// worker per node plus the QoS dispatch stage. Compilation happens on
    /// the worker threads; `start` returns once every node is live (or the
    /// first error is known).
    pub fn start(artifacts: ArtifactDir, config: ServerConfig) -> Result<ServerHandle> {
        let model = ModelDesc::qwen25_15b();
        let registry = Arc::new(TenantRegistry::new(config.qos.tenants.clone())?);
        let nodes: Vec<NodeConfig> = if config.nodes.is_empty() {
            vec![NodeConfig::new(registry::cmp170hx(), config.fmad)]
        } else {
            config.nodes.clone()
        };

        // One calibrated bench row per node: overlay rates, routing weight,
        // energy pricing, and decode power all come from a single batched
        // sweep.
        let bench = LlamaBench { model, ..Default::default() };
        let cells: Vec<(DeviceSpec, FmadPolicy)> =
            nodes.iter().map(|n| (n.device.clone(), n.fmad)).collect();
        let rows = bench.run_nodes(&cells, &quant::Q8_0);

        let mut fleet_inner = Fleet::new(
            nodes
                .iter()
                .zip(&rows)
                .map(|(n, r)| Node::new(n.device.name, r.decode_tps))
                .collect(),
            config.route,
        );
        // Flapping cards re-enter on probation: `mark_healthy` readmits
        // them one probe at a time until they pass this many serves.
        fleet_inner.set_probation_rounds(config.recovery.probation_rounds);
        fleet_inner.set_affinity_bonus(config.qos.affinity_bonus);
        let fleet = Arc::new(Mutex::new(fleet_inner));

        let queue_depth = config.queue_depth.max(1);
        let weights_bytes = model.weight_bytes(&quant::Q8_0);
        // Tenant WFQ weights, shared with the workers so eviction can
        // normalize each tenant's service when picking a victim.
        let tenant_weights: Arc<Vec<f64>> = Arc::new(registry.weights());
        let accounts = Arc::new(Mutex::new(TenantAccounts::new(&registry, Instant::now())));
        let tenant_metrics: Arc<Vec<Mutex<Metrics>>> =
            Arc::new((0..registry.len()).map(|_| Mutex::new(Metrics::new())).collect());
        let queues: Arc<NodeQueues<GenRequest>> = Arc::new(NodeQueues::new(nodes.len()));
        // The fleet KV fabric's shared pieces: one prefix directory (every
        // worker publishes its resident chains; dispatch routes toward
        // them), one host-RAM pool (host memory is a single physical
        // resource, and a page swapped out by one card can be restored by
        // another), and one park lot (preempted sequences are claimable
        // by idle peers — live migration).
        let directory = Arc::new(PrefixDirectory::new(nodes.len()));
        let host_pool = Arc::new(Mutex::new(HostPool::new(config.batch.host_pool_bytes)));
        let park = Arc::new(ParkLot::new());
        // Each worker reports its runtime's prefill window once validated;
        // the dispatch stage prices energy estimates with it (one artifact
        // set serves every node, so any node's answer is the fleet's).
        let (ready_tx, ready_rx) = sync_channel::<Result<usize>>(nodes.len());
        // The rescue channel: workers send dead-node sequences and bounded
        // retries back to the dispatch stage. The dispatcher holds the
        // receiver; a disconnect therefore means every worker has exited.
        let (rescue_tx, rescue_rx) = sync_channel::<Requeue>(256);
        let injector: Option<Arc<FaultInjector>> = config
            .faults
            .as_ref()
            .map(|plan| Arc::new(FaultInjector::new(plan, nodes.len())));
        // The flight recorder: one ring per worker plus the dispatch
        // stage's pseudo-node, shared by every layer that emits spans.
        let tracer = Arc::new(Tracer::new(nodes.len(), RING_CAP, config.trace));
        let mut overlays: Vec<Overlay> = Vec::with_capacity(nodes.len());
        let mut workers = Vec::with_capacity(nodes.len());
        let mut node_metrics = Vec::with_capacity(nodes.len());
        let node_names: Vec<&'static str> = nodes.iter().map(|n| n.device.name).collect();

        for (i, (node, row)) in nodes.iter().zip(&rows).enumerate() {
            let metrics = Arc::new(Mutex::new(Metrics::new()));
            node_metrics.push(Arc::clone(&metrics));

            let overlay = Overlay::from_row(row, &node.device);
            overlays.push(overlay);
            let vram_bytes = node.device.mem.capacity_bytes;
            // This card's actual host link (x1/x4 stock, x16 modded) —
            // what the swap-vs-recompute chooser prices transfers at.
            let link = node.device.pcie;
            let tenant_weights = Arc::clone(&tenant_weights);
            let artifacts = artifacts.clone();
            let ready = ready_tx.clone();
            let fleet = Arc::clone(&fleet);
            let queues = Arc::clone(&queues);
            let tenant_metrics = Arc::clone(&tenant_metrics);
            let accounts = Arc::clone(&accounts);
            let policy = config.batch;
            let step_policy = config.step_policy;
            let steal = config.qos.steal;
            let admit_scan = config.qos.admit_scan;
            let rescue = config.recovery.rescue.then(|| rescue_tx.clone());
            let recovery = config.recovery.clone();
            let injector = injector.clone();
            let directory = Arc::clone(&directory);
            let host_pool = Arc::clone(&host_pool);
            let park = Arc::clone(&park);
            let overlap = config.overlap;
            let tracer = Arc::clone(&tracer);

            let worker = std::thread::Builder::new()
                .name(format!("cmphx-node{i}"))
                .spawn(move || {
                    let _alive = AliveGuard {
                        queues: Arc::clone(&queues),
                        fleet: Arc::clone(&fleet),
                        rescue: rescue.clone(),
                        node: i,
                    };
                    let runtime = match ModelRuntime::load(&artifacts) {
                        Ok(rt) => rt,
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    // The window geometry is validated at startup so admit
                    // never sees an inverted (prefill_t > max_ctx) config.
                    if let Err(e) =
                        validate_window(runtime.config.max_ctx, runtime.config.prefill_t)
                    {
                        let _ = ready.send(Err(e));
                        return;
                    }
                    // Paged KV sized against this node's own VRAM: weights
                    // are pinned, everything else is carved into blocks of
                    // `kv_block_positions` token positions of the serving
                    // model (the binding 8 GB ceiling for the 170HX).
                    let mut pager = match KvPager::new(
                        policy.block_positions(),
                        model.kv_bytes_per_pos(),
                        vram_bytes,
                        weights_bytes,
                    ) {
                        Ok(p) => p,
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    if let Some(cap) = policy.kv_block_budget {
                        if let Err(e) = pager.limit_blocks(cap) {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    }
                    // The reclaimable-cache tier only exists when prefix
                    // sharing can find its blocks again; `--no-kv-cache`
                    // (or a prefix-blind run) reverts to refcount-zero
                    // frees — the ablation baseline.
                    pager.set_retention(policy.kv_retention && policy.prefix_cache);
                    // Cached-tier victim selection (`--reclaim-policy`):
                    // strict LRU, or depth-aware — spend deep private
                    // tail chunks before shallow shared prefixes.
                    pager.set_reclaim_policy(policy.reclaim);
                    // The pool must hold at least one prefill window plus
                    // one decode position, or admission could never make
                    // progress and the engine would spin.
                    if pager.max_positions() < runtime.config.prefill_t + 1 {
                        let _ = ready.send(Err(anyhow::anyhow!(
                            "KV budget of {} blocks × {} positions cannot hold one \
                             prefill window ({} tokens) plus a decode step",
                            pager.capacity_blocks(),
                            pager.block_positions(),
                            runtime.config.prefill_t,
                        )));
                        return;
                    }
                    let _ = ready.send(Ok(runtime.config.prefill_t));
                    let base_blocks = pager.capacity_blocks();
                    let base_max_batch = policy.max_batch;
                    worker_loop(NodeWorker {
                        node: i,
                        runtime,
                        queues,
                        policy,
                        step_policy,
                        overlay,
                        link,
                        pager,
                        host_pool,
                        directory,
                        park,
                        overlap,
                        metrics,
                        tenant_metrics,
                        tenant_weights,
                        accounts,
                        fleet,
                        steal,
                        admit_scan,
                        rescue,
                        recovery,
                        injector,
                        tracer,
                        degrade: Degrade::default(),
                        base_blocks,
                        base_max_batch,
                    });
                })?;
            workers.push(worker);
        }
        drop(ready_tx);
        let mut prefill_t = 0usize;
        for _ in 0..nodes.len() {
            match ready_rx.recv()? {
                Ok(p) => prefill_t = p,
                Err(e) => {
                    // Wake and release any node that did come up — with the
                    // queue set never closing, surviving workers would poll
                    // an abandoned engine forever.
                    queues.close();
                    return Err(e);
                }
            }
        }

        // The workers hold the only surviving rescue senders: when the
        // last worker exits, the dispatcher's drain loop sees the channel
        // disconnect and knows nothing can be rescued any more.
        drop(rescue_tx);

        // QoS dispatch stage: tenant-fair admission, budget enforcement,
        // then the Fleet's routing policy fans out to the node queues.
        let (tx, rx) = sync_channel::<GenRequest>(queue_depth);
        let dispatcher = Dispatcher {
            rx,
            rescue_rx,
            queue: AdmissionQueue::new(
                config.qos.enabled,
                &registry.weights(),
                config.qos.aging_pops,
            ),
            delayed: Vec::new(),
            recovery: config.recovery.clone(),
            fleet: Arc::clone(&fleet),
            queues: Arc::clone(&queues),
            accounts,
            node_metrics: node_metrics.iter().map(Arc::clone).collect(),
            tenant_metrics: Arc::clone(&tenant_metrics),
            overlays,
            prefill_t,
            node_depth: config.qos.node_queue_depth.max(1),
            directory: config.affinity.then(|| Arc::clone(&directory)),
            block_positions: config.batch.block_positions(),
            tracer: Arc::clone(&tracer),
            admission: config
                .admission
                .then(|| AdmissionCtl::new(AdmissionConfig::default())),
            weight_rank: weight_ranks(&registry.weights()),
        };
        let dispatcher = std::thread::Builder::new()
            .name("cmphx-dispatch".into())
            .spawn(move || dispatcher.run())?;

        Ok(ServerHandle {
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            workers,
            node_names,
            node_metrics,
            tenant_metrics,
            registry,
            fleet,
            tracer,
            deadline: config.recovery.deadline,
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }
}

/// The QoS dispatch stage: drains the submit channel into the per-tenant
/// fair queue, pops in DRR order (rate-capped lanes defer), prices and
/// charges energy against the routed node's overlay, and pushes onto the
/// node's bounded work queue — failing over past dead workers like the
/// old channel-based dispatch did.
struct Dispatcher {
    rx: Receiver<GenRequest>,
    /// Workers hand back rescued (node death) and retryable (transient
    /// admission failure) requests here; the channel disconnects when the
    /// last worker exits.
    rescue_rx: Receiver<Requeue>,
    queue: AdmissionQueue<GenRequest>,
    /// Retries serving out their exponential backoff: (due, request).
    delayed: Vec<(Instant, GenRequest)>,
    recovery: RecoveryPolicy,
    fleet: Arc<Mutex<Fleet>>,
    queues: Arc<NodeQueues<GenRequest>>,
    accounts: Arc<Mutex<TenantAccounts>>,
    node_metrics: Vec<Arc<Mutex<Metrics>>>,
    tenant_metrics: Arc<Vec<Mutex<Metrics>>>,
    overlays: Vec<Overlay>,
    prefill_t: usize,
    /// Per-node work-queue bound ([`QosConfig::node_queue_depth`]) —
    /// shallow, so the backlog stays in the fair queue.
    node_depth: usize,
    /// Fleet prefix directory for affine routing. `None` is the
    /// `--no-affinity` ablation: every dispatch uses the plain policy.
    directory: Option<Arc<PrefixDirectory>>,
    /// KV block granularity — the chain-hash chunk size must match the
    /// pagers' so directory lookups compare like with like.
    block_positions: usize,
    /// Flight recorder: queue-side spans journal on the dispatch
    /// pseudo-node's ring, and the dispatcher drains every ring per loop.
    tracer: Arc<Tracer>,
    /// Adaptive admission control ([`crate::load::AdmissionCtl`]):
    /// `None` is the `--no-admission-control` reactive-only ablation.
    admission: Option<AdmissionCtl>,
    /// Per-tenant fair-share weight rank in `[0, 1]` — the brownout
    /// ladder's shed order (lightest tenants shed first).
    weight_rank: Vec<f64>,
}

impl Dispatcher {
    fn run(mut self) {
        let mut open = true;
        let mut tick: u64 = 0;
        loop {
            let now = Instant::now();
            // Flight-recorder drain: move every node's buffered spans
            // into the retained log so the rings stay near-empty (the
            // rings still dump on their own if a node dies mid-round).
            self.tracer.drain();
            self.drain_rescues(now);
            self.promote_delayed(now);
            // Ingest: wait briefly when nothing is queued for dispatch —
            // a bounded wait, not a blocking recv, because a worker may
            // hand back a rescue or a retry may come due at any time.
            if open && self.queue.is_empty() {
                match self.rx.recv_timeout(Duration::from_millis(5)) {
                    Ok(r) => self.enqueue(r),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => open = false,
                }
            }
            if open {
                loop {
                    match self.rx.try_recv() {
                        Ok(r) => self.enqueue(r),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
            if self.queue.is_empty() {
                if !open {
                    if self.delayed.is_empty() {
                        break;
                    }
                    // drained submit channel; pace the wait for the next
                    // retry to come due instead of spinning
                    std::thread::sleep(Duration::from_millis(1));
                }
                continue;
            }
            // Pop-on-demand: defer the fair-queue decision until some node
            // can actually take a request. Popping into full node queues
            // would freeze tenant order inside per-node FIFOs and let a
            // flood pre-stake every slot — exactly what WFQ exists to
            // prevent.
            if !self.queues.any_space(self.node_depth) {
                if open {
                    match self.rx.recv_timeout(Duration::from_millis(1)) {
                        Ok(r) => self.enqueue(r),
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => open = false,
                    }
                } else {
                    std::thread::sleep(Duration::from_millis(1));
                }
                continue;
            }
            let now = Instant::now();
            let popped = {
                let acc = self.accounts.lock().unwrap();
                self.queue.pop_eligible(|t, cost| acc.rate_ok(t, cost, now))
            };
            match popped {
                Popped::Item(t, req) => {
                    self.dispatch(t, req, now);
                    self.sample_tick(&mut tick);
                }
                Popped::Blocked(head_cost) => {
                    // Every queued lane is rate-deferred: sleep until the
                    // nearest bucket could cover the cheapest refused head
                    // (a new arrival wakes us too). Pricing the real head
                    // cost matters — a nominal cost would report "ready"
                    // long before the bucket can pay, degenerating into a
                    // busy poll.
                    let hint = self
                        .accounts
                        .lock()
                        .unwrap()
                        .min_ready_in(head_cost, now)
                        .clamp(Duration::from_millis(1), Duration::from_millis(50));
                    if open {
                        match self.rx.recv_timeout(hint) {
                            Ok(r) => self.enqueue(r),
                            Err(RecvTimeoutError::Timeout) => {}
                            Err(RecvTimeoutError::Disconnected) => open = false,
                        }
                    } else {
                        std::thread::sleep(hint);
                    }
                }
                Popped::Empty => {}
            }
        }
        // Every accepted request has been routed; the workers drain their
        // queues, then see Closed.
        self.queues.close();
        // Workers still busy after the close can die and hand their
        // in-flight sequences back. Keep requeueing and re-dispatching
        // until the last worker drops its rescue sender — only then is it
        // certain nothing can be placed, and the leftovers are failed.
        loop {
            match self.rescue_rx.recv_timeout(Duration::from_millis(10)) {
                Ok(rq) => self.requeue(rq, Instant::now()),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
            let now = Instant::now();
            self.promote_delayed(now);
            while !self.queue.is_empty() && self.queues.any_space(self.node_depth) {
                match self.queue.pop_eligible(|_, _| true) {
                    Popped::Item(t, req) => {
                        self.dispatch(t, req, now);
                        self.sample_tick(&mut tick);
                    }
                    _ => break,
                }
            }
            self.tracer.drain();
        }
        self.fail_parked("no healthy nodes (worker unavailable)");
        self.tracer.drain();
    }

    /// Record one dispatch-stage trace sample: admission-queue depth, the
    /// WFQ lanes' deficit counters, and the router's outstanding work.
    fn sample_tick(&self, tick: &mut u64) {
        if !self.tracer.enabled() {
            return;
        }
        self.tracer.set_round(self.tracer.dispatch_node(), *tick);
        self.tracer.sample_dispatch(DispatchPoint {
            tick: *tick,
            queued: self.queue.len(),
            lane_deficits: self.queue.lane_deficits(),
            outstanding: self.fleet.lock().unwrap().outstanding_snapshot(),
        });
        *tick += 1;
    }

    fn enqueue(&mut self, r: GenRequest) {
        self.tracer.emit(self.tracer.dispatch_node(), TraceId(r.id), SpanKind::Queued);
        // Service is measured in generated tokens — the unit the overlay
        // prices and the DRR deficit counts.
        self.queue.push(r.tenant, r.max_tokens as f64, r);
    }

    /// Pull everything the workers handed back since the last pass.
    fn drain_rescues(&mut self, now: Instant) {
        while let Ok(rq) = self.rescue_rx.try_recv() {
            self.requeue(rq, now);
        }
    }

    /// Remaining service for a request that may carry replayed progress —
    /// the cost a re-entering rescue is priced at.
    fn remaining_cost(req: &GenRequest) -> f64 {
        req.max_tokens.saturating_sub(req.carry.replay.len()).max(1) as f64
    }

    /// Re-admit a request a worker handed back. Rescues re-enter at the
    /// *head* of their tenant's lane — the sequence already waited its
    /// turn and holds replayable progress that ages badly. Retries park in
    /// `delayed` until their exponential backoff elapses.
    fn requeue(&mut self, rq: Requeue, now: Instant) {
        let dn = self.tracer.dispatch_node();
        match rq {
            Requeue::Rescue(req) => {
                self.tracer.emit(dn, TraceId(req.id), SpanKind::Requeued);
                self.queue.push_front(req.tenant, Self::remaining_cost(&req), req);
            }
            Requeue::Retry(req) => {
                self.tracer.emit(dn, TraceId(req.id), SpanKind::Requeued);
                let due = now + backoff_delay(self.recovery.backoff, req.carry.attempt);
                self.delayed.push((due, req));
            }
        }
    }

    /// Move every retry whose backoff has elapsed back into the fair
    /// queue (at the lane head — it was already admitted once).
    fn promote_delayed(&mut self, now: Instant) {
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].0 <= now {
                let (_, req) = self.delayed.swap_remove(i);
                self.queue.push_front(req.tenant, Self::remaining_cost(&req), req);
            } else {
                i += 1;
            }
        }
    }

    /// Fail everything still parked in the fair queue or the backoff pen —
    /// the last healthy node is gone, so these can never be served. Parked
    /// requests must fail *promptly* here, not linger until shutdown.
    fn fail_parked(&mut self, why: &str) {
        let mut orphans: Vec<GenRequest> = Vec::new();
        while let Popped::Item(_, req) = self.queue.pop_eligible(|_, _| true) {
            orphans.push(req);
        }
        orphans.extend(std::mem::take(&mut self.delayed).into_iter().map(|(_, r)| r));
        for req in orphans {
            self.accounts
                .lock()
                .unwrap()
                .settle_energy(req.tenant, req.charged_j, req.carry.sim_j);
            self.shed(req, 0, why, false);
        }
    }

    /// Route one request to a live worker, failing over past dead ones. A
    /// bounced push marks the node unhealthy — it stays excluded until
    /// [`ServerHandle::mark_healthy`] restores it — and the request is
    /// rerouted to the next healthy node. Only when no healthy node
    /// remains is the request failed.
    fn dispatch(&mut self, t: TenantId, mut req: GenRequest, now: Instant) {
        // A request past its wall-clock deadline fails here, not on a
        // card: routing it would burn node time on an answer the client
        // has already given up on.
        if req.deadline.is_some_and(|d| now >= d) {
            let dn = self.tracer.dispatch_node();
            self.tracer.emit(dn, TraceId(req.id), SpanKind::DeadlineMiss);
            self.tracer.flight_dump(dn, "deadline miss at dispatch");
            self.tenant_metrics[t.0].lock().unwrap().deadline_misses += 1;
            self.accounts
                .lock()
                .unwrap()
                .settle_energy(t, req.charged_j, req.carry.sim_j);
            self.shed(req, 0, "deadline exceeded before dispatch", false);
            return;
        }
        // Adaptive admission: predict this request's completion — the
        // least-loaded healthy card's backlog plus the request's own
        // service demand, both priced with the calibrated overlays — and
        // shed *now*, before any prefill is wasted, when the prediction
        // violates the tenant's SLO contract. Contract-less tenants
        // always pass; an empty healthy set falls through to the
        // no-healthy-node path below.
        if let Some(ctl) = self.admission.as_mut() {
            let predicted = predicted_completion_s(
                &self.fleet,
                &self.queues,
                &self.overlays,
                self.queue.len(),
                self.prefill_t,
                req.max_tokens,
            );
            if predicted.is_finite() {
                let rank = self.weight_rank.get(t.0).copied().unwrap_or(1.0);
                if let Verdict::Shed { level } = ctl.decide(predicted, req.slo_s, rank) {
                    self.tenant_metrics[t.0].lock().unwrap().admission_sheds += 1;
                    self.accounts
                        .lock()
                        .unwrap()
                        .settle_energy(t, req.charged_j, req.carry.sim_j);
                    self.shed(
                        req,
                        0,
                        &format!(
                            "admission control: predicted SLO violation \
                             (brownout level {level})"
                        ),
                        false,
                    );
                    return;
                }
            }
        }
        let (mut idx, affine) = {
            let mut f = self.fleet.lock().unwrap();
            if f.healthy_count() == 0 {
                drop(f);
                self.accounts
                    .lock()
                    .unwrap()
                    .settle_energy(t, req.charged_j, req.carry.sim_j);
                self.shed(req, 0, "no healthy nodes (worker unavailable)", true);
                // Nothing parked behind this request can be served either.
                self.fail_parked("no healthy nodes (worker unavailable)");
                return;
            }
            // Prefix-affine routing: hash the prompt's padded window the
            // way the pagers chunk it and prefer the card already holding
            // the longest matching chain. The directory is a hint — a
            // stale entry just routes to a card that re-prefills.
            let depths = self.directory.as_ref().and_then(|d| {
                let window = padded_window(&req.prompt, self.prefill_t)?;
                Some(d.match_depths(&window_chain_hashes(&window, self.block_positions)))
            });
            match depths {
                Some(depths) => {
                    let idx = f.route_affine(&depths);
                    (idx, depths[idx] > 0)
                }
                None => (f.route(), false),
            }
        };
        if affine {
            self.node_metrics[idx].lock().unwrap().affine_routes += 1;
        }
        // Rescues and retries were already charged on first dispatch —
        // charging again would double-bill the tenant for the fault.
        if req.charged_j == 0.0 {
            let est_j = self.overlays[idx].estimate_j(self.prefill_t, req.max_tokens);
            {
                let mut acc = self.accounts.lock().unwrap();
                if acc.try_charge_energy(t, est_j) == Admission::EnergyExhausted {
                    drop(acc);
                    self.fleet.lock().unwrap().complete(idx);
                    self.shed(req, idx, "tenant energy budget exhausted", false);
                    return;
                }
                acc.charge_rate(t, req.max_tokens as f64, now);
            }
            req.charged_j = est_j;
        }
        loop {
            let trace = TraceId(req.id);
            match self.queues.push_bounded(idx, req, self.node_depth) {
                Ok(()) => {
                    self.tracer.emit(
                        self.tracer.dispatch_node(),
                        trace,
                        SpanKind::Dispatched { node: idx },
                    );
                    return;
                }
                Err(bounced) => {
                    req = bounced;
                    let any_healthy = {
                        let mut f = self.fleet.lock().unwrap();
                        // the bounced push never reached a worker: uncount
                        // it, then exclude the dead node
                        f.complete(idx);
                        f.mark_unhealthy(idx);
                        f.healthy_count() > 0
                    };
                    if !any_healthy {
                        // Every worker is gone: fail the request (and hand
                        // its energy charge back) instead of wedging.
                        self.accounts
                            .lock()
                            .unwrap()
                            .settle_energy(t, req.charged_j, req.carry.sim_j);
                        self.shed(req, idx, "no healthy nodes (worker unavailable)", true);
                        self.fail_parked("no healthy nodes (worker unavailable)");
                        return;
                    }
                    idx = self.fleet.lock().unwrap().route();
                }
            }
        }
    }

    /// Answer a request the QoS stage refused. Counted on the tenant's
    /// rollup always; on the node's metrics only when a node was actually
    /// involved (`on_node` — the dead-fleet path the old dispatch had).
    fn shed(&self, req: GenRequest, node: usize, why: &str, on_node: bool) {
        if self.tracer.enabled() {
            self.tracer.emit(
                self.tracer.dispatch_node(),
                TraceId(req.id),
                SpanKind::Shed { error: why.to_string() },
            );
        }
        // fold in queue time banked across earlier dispatch attempts
        let queue_s = req.carry.queue_s + req.enqueued.elapsed().as_secs_f64();
        if on_node {
            let mut m = self.node_metrics[node].lock().unwrap();
            if req.slo_s.is_some() {
                m.record_slo(false);
            }
            m.record_response(queue_s, 0, false);
        }
        {
            // A shed contracted request can never meet its SLO — it
            // counts against the tenant's attainment like a late serve.
            let mut tm = self.tenant_metrics[req.tenant.0].lock().unwrap();
            if req.slo_s.is_some() {
                tm.record_slo(false);
            }
            tm.record_response(queue_s, 0, false);
        }
        let _ = req.reply.send(empty_response(
            req.id,
            req.tenant,
            node,
            queue_s,
            Some(why.into()),
        ));
    }
}

/// The dispatcher's replica of [`ModelRuntime::padded_window`]: the same
/// leading-zero pad the engine prefills with, so directory lookups hash
/// exactly the chains a pager would build for this prompt. `None` when the
/// prompt overflows the window (admission will reject it anyway).
fn padded_window(prompt: &[i32], prefill_t: usize) -> Option<Vec<i32>> {
    if prompt.len() > prefill_t {
        return None;
    }
    let mut w = vec![0i32; prefill_t - prompt.len()];
    w.extend_from_slice(prompt);
    Some(w)
}

/// The admission controller's completion prediction for one request: the
/// least-loaded healthy card's backlog (outstanding work, its bounded
/// queue, and this request's share of the admission queue) priced at that
/// card's calibrated overlay, plus the request's own full-window service
/// demand. Infinite when no healthy card remains — the caller's
/// no-healthy-node path owns that outcome.
fn predicted_completion_s(
    fleet: &Mutex<Fleet>,
    queues: &NodeQueues<GenRequest>,
    overlays: &[Overlay],
    admission_backlog: usize,
    prefill_t: usize,
    max_tokens: usize,
) -> f64 {
    let f = fleet.lock().unwrap();
    let share = admission_backlog as f64 / f.healthy_count().max(1) as f64;
    f.nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| n.healthy)
        .map(|(i, n)| {
            let o = &overlays[i];
            let service = o.prefill_s_per_token * prefill_t as f64
                + o.decode_s_per_token * max_tokens as f64;
            (n.outstanding as f64 + queues.len(i) as f64 + share + 1.0) * service
        })
        .fold(f64::INFINITY, f64::min)
}

impl ServerHandle {
    /// Submit a generation request as the default tenant; returns the
    /// response receiver. Errors when `max_tokens` is zero (nothing to
    /// generate — the old path silently produced one token and counted it
    /// in throughput), when the queue is full (backpressure), or when the
    /// server is stopped.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_tokens: usize,
    ) -> Result<Receiver<GenResponse>> {
        self.submit_as(TenantRegistry::DEFAULT, prompt, max_tokens)
    }

    /// [`ServerHandle::submit`], billed to an explicit tenant (fair-share
    /// lane, rate and energy caps).
    pub fn submit_as(
        &self,
        tenant: TenantId,
        prompt: Vec<i32>,
        max_tokens: usize,
    ) -> Result<Receiver<GenResponse>> {
        if !self.registry.contains(tenant) {
            anyhow::bail!("unknown tenant id {}", tenant.0);
        }
        if max_tokens == 0 {
            anyhow::bail!("max_tokens must be at least 1 (zero-token requests are rejected)");
        }
        let (reply, rx) = std::sync::mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // The tenant's SLO contract (when declared) overrides the
        // server-wide recovery deadline and rides along for the admission
        // controller's prediction and the attainment rollup.
        let spec = self.registry.spec(tenant);
        let req = GenRequest {
            id,
            tenant,
            prompt,
            max_tokens,
            charged_j: 0.0,
            reply,
            enqueued: Instant::now(),
            deadline: spec.slo().or(self.deadline).map(|d| Instant::now() + d),
            slo_s: spec.slo_s(),
            carry: Carried::default(),
        };
        let tx = self.tx.as_ref().ok_or_else(|| anyhow::anyhow!("server stopped"))?;
        match tx.try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => anyhow::bail!("queue full (backpressure)"),
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("server stopped"),
        }
    }

    /// Resolve a tenant name against the server's registry.
    pub fn tenant_id(&self, name: &str) -> Option<TenantId> {
        self.registry.id(name)
    }

    /// The server's tenant table.
    pub fn registry(&self) -> &TenantRegistry {
        &self.registry
    }

    /// The fleet's flight recorder — clone the `Arc` before shutdown to
    /// snapshot/export the journal after the fleet has drained. Disabled
    /// (every call an early return) unless [`ServerConfig::trace`].
    pub fn tracer(&self) -> Arc<Tracer> {
        Arc::clone(&self.tracer)
    }

    /// Operator hook: restore a node to the routable set (the worker
    /// recovered, or the card was replaced). The dispatch stage resumes
    /// routing to it immediately.
    pub fn mark_healthy(&self, node: usize) -> Result<()> {
        let mut f = self.fleet.lock().unwrap();
        if node >= f.nodes.len() {
            anyhow::bail!("node {node} out of range");
        }
        f.mark_healthy(node);
        Ok(())
    }

    /// Operator hook: drain a node out of the routable set.
    pub fn mark_unhealthy(&self, node: usize) -> Result<()> {
        let mut f = self.fleet.lock().unwrap();
        if node >= f.nodes.len() {
            anyhow::bail!("node {node} out of range");
        }
        f.mark_unhealthy(node);
        Ok(())
    }

    /// Fleet-wide metrics snapshot (all nodes merged).
    pub fn metrics(&self) -> Metrics {
        self.fleet_metrics().total()
    }

    /// Per-node and per-tenant metrics snapshot.
    pub fn fleet_metrics(&self) -> FleetMetrics {
        // Router incident data (downtime, recoveries — the MTTR inputs)
        // is snapshotted first, then stamped into each node's metrics
        // clone: the router and metrics locks are never held together.
        let node_fault: Vec<(f64, u64)> = {
            let f = self.fleet.lock().unwrap();
            f.nodes.iter().map(|n| (n.downtime_s, n.recoveries)).collect()
        };
        FleetMetrics {
            nodes: self
                .node_names
                .iter()
                .zip(&self.node_metrics)
                .enumerate()
                .map(|(i, (name, m))| {
                    let mut snap = m.lock().unwrap().clone();
                    if let Some(&(down, rec)) = node_fault.get(i) {
                        snap.fault_downtime_s = down;
                        snap.fault_recoveries = rec;
                    }
                    (*name, snap)
                })
                .collect(),
            tenants: self
                .registry
                .iter()
                .zip(self.tenant_metrics.iter())
                .map(|((_, spec), m)| (spec.name.clone(), m.lock().unwrap().clone()))
                .collect(),
        }
    }

    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Stop accepting requests, drain, and join the fleet.
    pub fn shutdown(mut self) -> Metrics {
        self.stop();
        self.metrics()
    }

    /// Like [`ServerHandle::shutdown`], keeping per-node and per-tenant
    /// attribution.
    pub fn shutdown_fleet(mut self) -> FleetMetrics {
        self.stop();
        self.fleet_metrics()
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Everything one node's continuous-batching loop owns.
struct NodeWorker {
    node: usize,
    runtime: ModelRuntime,
    queues: Arc<NodeQueues<GenRequest>>,
    policy: BatchPolicy,
    step_policy: StepPolicy,
    overlay: Overlay,
    /// This card's host link — prices swap transfers in the §3 model.
    link: PcieLink,
    pager: KvPager,
    /// Fleet-shared host-RAM budget for swapped-out KV pages. Host RAM is
    /// one physical resource behind every card's PCIe link, so pages one
    /// card swapped out can be restored by any other — the substrate for
    /// live migration.
    host_pool: Arc<Mutex<HostPool>>,
    /// Fleet prefix directory this worker publishes its resident chains
    /// into each round. Hints, not leases: the dispatcher routes on them,
    /// admission re-probes the pager.
    directory: Arc<PrefixDirectory>,
    /// Fleet-shared park lot of preempted sequences. Owners resume their
    /// own FIFO; an idle peer may claim a foreign entry — live migration.
    park: Arc<ParkLot>,
    /// Overlap swap DMA with the concurrent decode round (off = serial
    /// charge baseline for the `--no-overlap` ablation).
    overlap: bool,
    metrics: Arc<Mutex<Metrics>>,
    tenant_metrics: Arc<Vec<Mutex<Metrics>>>,
    /// WFQ weights by tenant id, for service-normalized eviction.
    tenant_weights: Arc<Vec<f64>>,
    accounts: Arc<Mutex<TenantAccounts>>,
    fleet: Arc<Mutex<Fleet>>,
    steal: bool,
    /// Bounded admission scan depth ([`QosConfig::admit_scan`]): how many
    /// queued requests the capacity-edge gate inspects for a radix-tree
    /// match before popping. Floor 1 = head-only (the PR 7 peek).
    admit_scan: usize,
    /// Hand-back channel to the dispatch stage for rescued (node death)
    /// and retried (transient admission failure) requests. `None` when
    /// [`RecoveryPolicy::rescue`] is off — then a death drops its work.
    rescue: Option<SyncSender<Requeue>>,
    recovery: RecoveryPolicy,
    /// Seeded fault script for this fleet (chaos runs only).
    injector: Option<Arc<FaultInjector>>,
    /// Flight recorder: this worker journals engine spans on its own ring,
    /// stamped with its simulated clock.
    tracer: Arc<Tracer>,
    /// Live degraded-mode state accumulated from injected faults.
    degrade: Degrade,
    /// KV capacity at startup — the denominator for pro-rata admission
    /// shrink after VRAM page loss.
    base_blocks: usize,
    /// [`BatchPolicy::max_batch`] at startup, before degradation shrank it.
    base_max_batch: usize,
}

/// One in-flight sequence.
struct Live {
    req: GenRequest,
    state: DecodeState,
    kv: SeqKv,
    tokens: Vec<i32>,
    queue_s: f64,
    prefill_s: f64,
    /// Wall decode seconds accumulated before the last (re)join — preempted
    /// stretches are summed here, the current stretch in `decode_started`.
    decode_s: f64,
    /// Simulated device seconds split by phase (prefill / decode / stall /
    /// replay) — summed, the request's simulated latency.
    ledger: PhaseLedger,
    sim_j: f64,
    preemptions: u64,
    /// Preemptions that swapped to host RAM instead of recomputing.
    swaps: u64,
    /// Resumed through the aging gate: shielded from re-eviction (victim
    /// of last resort) so the park → resume → re-evict cycle terminates.
    shielded: bool,
    failed: Option<String>,
    decode_started: Instant,
}

impl Live {
    fn target(&self) -> usize {
        if self.failed.is_some() {
            self.tokens.len()
        } else {
            self.req.max_tokens
        }
    }

    fn done(&self) -> bool {
        self.tokens.len() >= self.target()
    }
}

/// A preempted sequence parked off-device: its KV pages are gone;
/// everything needed to recompute the state on resume rides along.
struct Preempted {
    req: GenRequest,
    tokens: Vec<i32>,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
    /// Simulated per-phase device seconds accrued before the park.
    ledger: PhaseLedger,
    sim_j: f64,
    preemptions: u64,
    /// Preemptions that swapped to host RAM instead of recomputing.
    swaps: u64,
    /// The decode state parked in host RAM when this eviction swapped
    /// instead of dropping — resume restores it over PCIe and skips the
    /// recompute entirely. `None` is the drop-and-replay path.
    swapped: Option<DecodeState>,
    /// Host-pool bytes reserved for the swapped pages (0 when dropped).
    swap_bytes: u64,
    /// The recompute estimate the eviction chooser priced the swap
    /// against (prefix-credited). Swap-in settles `saved_recompute_s`
    /// from the same number, so the ledger matches the decision.
    recompute_est_s: f64,
    /// When the sequence was evicted — parked time is queueing time, and
    /// the client-observed latency must include it.
    parked_at: Instant,
    /// Engine rounds this sequence has sat parked. At
    /// [`BatchPolicy::aging_rounds`] the worker freezes new admissions
    /// until the resume fits.
    parked_rounds: u64,
    /// Whether the aging gate already engaged for this parked stretch
    /// (counted once into [`Metrics::aged_promotions`]).
    aged: bool,
}

impl Preempted {
    /// Accumulated queue seconds including the current parked stretch.
    fn queue_s_now(&self) -> f64 {
        self.queue_s + self.parked_at.elapsed().as_secs_f64()
    }
}

/// What happened when a parked sequence tried to re-enter decode.
enum Resumed {
    Joined,
    /// Not enough free pages right now — parked again, retry next round.
    NoPages(Preempted),
    /// Terminal failure (recompute failed, or the pool can never hold it);
    /// the request was answered.
    Failed,
}

/// Fleet-shared lot of parked (preempted) sequences, tagged by the node
/// that owns them. Owners resume their own entries in FIFO order; an idle
/// peer may `claim_foreign` an entry instead — that is live migration: the
/// victim's pages already sit in the shared host pool (or replay from
/// tokens), so the thief restores them over its *own* PCIe link. A single
/// mutex over the whole lot guarantees each sequence is resumed exactly
/// once even when several workers race for it.
struct ParkLot {
    parked: Mutex<Vec<(usize, Preempted)>>,
}

/// Outcome of a foreign-claim attempt ([`ParkLot::claim_foreign`]).
enum Claim {
    /// `(original owner, entry)` — the router slot re-books to the thief.
    Taken(usize, Preempted),
    /// Foreign entries exist but the hysteresis gate held every one back
    /// (too young, or its owner would resume it next round).
    Deferred,
    /// Nothing foreign is parked.
    Empty,
}

impl ParkLot {
    fn new() -> Self {
        ParkLot { parked: Mutex::new(Vec::new()) }
    }

    /// Pop the oldest entry owned by `node`.
    fn pop_owned(&self, node: usize) -> Option<Preempted> {
        let mut lot = self.parked.lock().unwrap();
        let i = lot.iter().position(|(owner, _)| *owner == node)?;
        Some(lot.remove(i).1)
    }

    /// Re-park at the front: a failed resume retries before newer entries.
    fn push_front(&self, node: usize, p: Preempted) {
        self.parked.lock().unwrap().insert(0, (node, p));
    }

    fn push_back(&self, node: usize, p: Preempted) {
        self.parked.lock().unwrap().push((node, p));
    }

    /// Claim the oldest *claimable* entry owned by someone else — the
    /// migration grab, behind a hysteresis gate. A young foreign entry
    /// (under `min_age` parked rounds) is one its owner — who resumes its
    /// own lot ahead of new arrivals every round — would likely take back
    /// next round; grabbing it pays two PCIe transfers to move work that
    /// was about to run anyway. So a claim needs the entry aged past
    /// `min_age`, **or** its owner visibly backlogged (`owner_busy`:
    /// queued arrivals will out-compete the resume, or the owner is
    /// dead). Age alone eventually qualifies every entry, so a parked
    /// sequence on a page-starved idle owner is still rescued. Returns
    /// [`Claim::Deferred`] when foreign entries exist but the gate held
    /// them all back, so the caller can count the thrash avoided.
    fn claim_foreign(
        &self,
        thief: usize,
        min_age: u64,
        owner_busy: impl Fn(usize) -> bool,
    ) -> Claim {
        let mut lot = self.parked.lock().unwrap();
        let mut deferred = false;
        for i in 0..lot.len() {
            let (owner, p) = &lot[i];
            if *owner == thief {
                continue;
            }
            if p.parked_rounds >= min_age || owner_busy(*owner) {
                let (owner, p) = lot.remove(i);
                return Claim::Taken(owner, p);
            }
            deferred = true;
        }
        if deferred {
            Claim::Deferred
        } else {
            Claim::Empty
        }
    }

    /// One engine round passed on `node`: age its parked entries.
    fn age_owned(&self, node: usize) {
        let mut lot = self.parked.lock().unwrap();
        for (owner, p) in lot.iter_mut() {
            if *owner == node {
                p.parked_rounds += 1;
            }
        }
    }

    /// Whether the aging gate is engaged for `node` (any owned entry past
    /// `aging_rounds`), plus the `(tenant, request id)` of entries that
    /// *newly* crossed the threshold this round (each counted once).
    fn aging_gate(&self, node: usize, aging_rounds: u64) -> (bool, Vec<(TenantId, u64)>) {
        let mut lot = self.parked.lock().unwrap();
        let mut engaged = false;
        let mut newly = Vec::new();
        for (owner, p) in lot.iter_mut() {
            if *owner == node && p.parked_rounds >= aging_rounds {
                engaged = true;
                if !p.aged {
                    p.aged = true;
                    newly.push((p.req.tenant, p.req.id));
                }
            }
        }
        (engaged, newly)
    }

    /// Remove and return every entry owned by `node` (node-death path).
    fn drain_owned(&self, node: usize) -> Vec<Preempted> {
        let mut lot = self.parked.lock().unwrap();
        let mut out = Vec::new();
        let mut i = 0;
        while i < lot.len() {
            if lot[i].0 == node {
                out.push(lot.remove(i).1);
            } else {
                i += 1;
            }
        }
        out
    }

    fn has_owned(&self, node: usize) -> bool {
        self.parked
            .lock()
            .unwrap()
            .iter()
            .any(|(owner, _)| *owner == node)
    }

    /// Entries owned by `node` — the trace series' park-lot gauge.
    fn owned_count(&self, node: usize) -> usize {
        self.parked.lock().unwrap().iter().filter(|(owner, _)| *owner == node).count()
    }
}

fn worker_loop(mut w: NodeWorker) {
    let mut live: Vec<Live> = Vec::new();
    let park = Arc::clone(&w.park);
    // Round-planning buffers reused across the engine's lifetime: planning
    // a round allocates nothing after the first.
    let mut views: Vec<SeqView> = Vec::new();
    let mut shield: Vec<bool> = Vec::new();
    let mut overserve: Vec<f64> = Vec::new();
    let mut plan: Vec<usize> = Vec::new();
    let mut stalled: Vec<usize> = Vec::new();
    let mut open = true;
    // Directory sync state: the chain set this worker last published and
    // the epoch it was installed under. Rounds send deltas against it; the
    // first round — or a delta the directory refuses because its epoch
    // moved (a death/recovery clear) — falls back to a full publish.
    let mut published: std::collections::HashSet<u64> = std::collections::HashSet::new();
    let mut published_epoch: u64 = 0;
    let mut synced = false;
    // Engine-round counter — the coordinate every span this worker emits
    // is stamped with (alongside its simulated clock).
    let mut round: u64 = 0;

    while open || !live.is_empty() || park.has_owned(w.node) {
        round += 1;
        w.tracer.set_round(w.node, round);
        // --- injected faults (chaos runs): a scripted death hands every
        //     queued, live, and parked sequence back to the dispatch
        //     stage for rescue; lesser faults degrade this round. ---
        if apply_faults(&mut w) {
            died(&mut w, std::mem::take(&mut live));
            return;
        }
        if w.degrade.stall_rounds > 0 {
            // Transient stall (wedged driver): no work this round, but
            // parked sequences still age toward their admission freeze.
            w.degrade.stall_rounds -= 1;
            // Cache-reclaim retractions still flush: a stalled card must
            // not keep advertising chains its page pressure already
            // dropped, or affine routing keeps piling work onto the
            // wedged node for prefixes it no longer holds.
            let dropped: Vec<u64> = w
                .pager
                .take_retracted()
                .into_iter()
                .filter(|h| published.remove(h))
                .collect();
            if synced
                && !dropped.is_empty()
                && !w.directory.publish_delta(w.node, published_epoch, &[], &dropped)
            {
                // epoch moved under us (death/recovery clear): resync
                // with a full publish on the next working round
                synced = false;
            }
            std::thread::sleep(Duration::from_millis(1));
            park.age_owned(w.node);
            continue;
        }
        // Publish this card's resident prefix chains for affine routing —
        // all tree tiers, cached included: warm-but-idle KV attracting a
        // returning user's route is the radix cache's whole payoff. Sent
        // as a delta against last round's set; an unchanged set costs one
        // epoch check. A hint, not a lease: pages may be evicted before a
        // routed request arrives, and admission's two-pass probe degrades
        // any stale hit to a plain miss.
        // Drain the pager's reclaim-retraction buffer: every chain the
        // cache tier dropped since last round is absent from
        // `index_hashes()` now, so the diff against `published` below
        // retracts it in this round's delta — the buffer's dedicated
        // flush path is the stalled-round branch above (where no diff
        // runs), and draining here keeps it bounded.
        w.pager.take_retracted();
        let resident: std::collections::HashSet<u64> =
            w.pager.index_hashes().into_iter().collect();
        let added: Vec<u64> = resident.difference(&published).copied().collect();
        let retracted: Vec<u64> = published.difference(&resident).copied().collect();
        let delta_ok = synced
            && if added.is_empty() && retracted.is_empty() {
                w.directory.epoch(w.node) == published_epoch
            } else {
                w.directory.publish_delta(w.node, published_epoch, &added, &retracted)
            };
        if !delta_ok {
            published_epoch = w.directory.publish(w.node, resident.iter().copied().collect());
            synced = true;
        }
        published = resident;
        let prefill_t = w.runtime.config.prefill_t;
        // --- admission (page-join): fill headroom, never stall decode.
        //     Preempted sequences resume before new arrivals join. ---
        let mut want = plan_admission(&w.policy, live.len(), w.pager.admissible(prefill_t));
        while want > 0 {
            let Some(parked) = park.pop_owned(w.node) else { break };
            match resume(&mut w, parked, &mut live) {
                Resumed::Joined => want -= 1,
                Resumed::NoPages(parked) => {
                    if live.is_empty() {
                        // Nothing holds pages yet the resume cannot fit:
                        // the pool can never hold this sequence. Fail it
                        // terminally rather than spinning forever (and
                        // hand back its host-pool reservation if the
                        // eviction had swapped).
                        if parked.swapped.is_some() {
                            w.host_pool.lock().unwrap().release(parked.swap_bytes);
                        }
                        let queue_s = parked.queue_s_now();
                        reject(
                            &mut w,
                            &parked.req,
                            "KV pool cannot hold the resumed sequence".into(),
                            queue_s,
                            parked.sim_j,
                        );
                    } else {
                        park.push_front(w.node, parked);
                        break;
                    }
                }
                Resumed::Failed => {}
            }
        }
        // A resume re-admits its full replay length — usually more pages
        // than the one prefill window `want` was budgeted on — so refresh
        // the headroom before admitting new arrivals. Without this, the
        // arrival loop pops a queued request into a terminal page-overload
        // reject that plan_admission exists to prevent.
        want = want.min(plan_admission(&w.policy, live.len(), w.pager.admissible(prefill_t)));
        // --- park-lot aging gate: a parked sequence past its round
        //     budget freezes new admissions, reserving every page a
        //     retirement frees for the resume — new shorts can no longer
        //     slip in ahead of the replay indefinitely. ---
        let (aged_parked, newly_aged) = park.aging_gate(w.node, w.policy.aging_rounds);
        if !newly_aged.is_empty() {
            w.metrics.lock().unwrap().aged_promotions += newly_aged.len() as u64;
            for &(t, id) in &newly_aged {
                w.tenant_metrics[t.0].lock().unwrap().aged_promotions += 1;
                w.tracer.emit(w.node, TraceId(id), SpanKind::Aged);
            }
        }
        // --- prefix-aware admission at the capacity edge: plan_admission
        //     budgets a full fresh prefill window, but a request whose
        //     prefix already lives in this card's radix tree (live-shared
        //     or cached) only needs the tail — and cached blocks count
        //     toward the budget, since reclaiming one costs a tree unlink,
        //     not a prefill. Scan the first `admit_scan` queued requests
        //     (bounded, so fair-queue order bends at most K−1 positions),
        //     pop the deepest eligible tree match, and admit it directly.
        //     The admit re-probes under the pager's two-pass check, so an
        //     eviction between scan and admit degrades to a retry, never
        //     an error. ---
        if open && want == 0 && !aged_parked && w.policy.prefix_cache {
            let admissible = w.pager.admissible(prefill_t);
            let free = w.pager.free_blocks();
            let cached = w.pager.cached_blocks();
            let window_blocks = w.pager.blocks_for(prefill_t);
            let popped = w.queues.pop_best_within(w.node, w.admit_scan, |r| {
                let window = w.runtime.padded_window(&r.prompt).ok()?;
                let resident = w.pager.resident_prefix_blocks(&window);
                let opens = plan_admission_prefix_aware(
                    &w.policy,
                    live.len(),
                    admissible,
                    free,
                    cached,
                    window_blocks,
                    resident,
                ) > 0;
                opens.then_some(resident)
            });
            if let Some(req) = popped {
                admit(&mut w, req, &mut live);
            }
        }
        if open && want > 0 && !aged_parked {
            if live.is_empty() && !park.has_owned(w.node) {
                // Idle engine: block for the first arrival — stealing a
                // queued request from the deepest peer queue, or claiming
                // a foreign parked sequence (live migration) when every
                // queue stays dry — then gather up to `max_wait` of
                // company for the cold-start round.
                let first = loop {
                    if let Some(req) = w.queues.try_pop(w.node) {
                        break Some(req);
                    }
                    if w.steal {
                        if let Some(req) = steal(&w) {
                            break Some(req);
                        }
                        if migrate_parked(&mut w, &park, &mut live) {
                            break None;
                        }
                    }
                    match w.queues.wait_pop(w.node, Duration::from_millis(10)) {
                        WaitPop::Item(req) => break Some(req),
                        WaitPop::TimedOut => {}
                        WaitPop::Closed => {
                            if w.steal {
                                if let Some(req) = steal(&w) {
                                    break Some(req);
                                }
                                if migrate_parked(&mut w, &park, &mut live) {
                                    break None;
                                }
                            }
                            open = false;
                            break None;
                        }
                    }
                };
                match first {
                    Some(req) => {
                        if admit(&mut w, req, &mut live) {
                            want -= 1;
                        }
                        let deadline = Instant::now() + w.policy.max_wait;
                        while want > 0 {
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            match w.queues.wait_pop(w.node, deadline - now) {
                                WaitPop::Item(req) => {
                                    if admit(&mut w, req, &mut live) {
                                        want -= 1;
                                    }
                                }
                                WaitPop::TimedOut => break,
                                WaitPop::Closed => {
                                    open = false;
                                    break;
                                }
                            }
                        }
                    }
                    None => {
                        // A migrated sequence joined `live` (or the fleet
                        // is closed and empty). The joined sequence used
                        // one admission slot.
                        if !live.is_empty() {
                            want = want.saturating_sub(1);
                        }
                    }
                }
            } else {
                // Busy engine: non-blocking joins — the continuous part.
                while want > 0 {
                    match w.queues.try_pop(w.node) {
                        Some(req) => {
                            if admit(&mut w, req, &mut live) {
                                want -= 1;
                            }
                        }
                        None => break,
                    }
                }
            }
        }
        if live.is_empty() {
            park.age_owned(w.node);
            continue;
        }

        // Sequences already done (a max_tokens == 1 request is complete
        // straight out of prefill) retire *before* pressure resolution —
        // their pages must not inflate the shortfall and preempt or fail
        // a peer that would fit once they free.
        retire_done(&mut w, &mut live);
        if live.is_empty() {
            park.age_owned(w.node);
            continue;
        }

        // --- plan one decode round, resolving KV page pressure: every
        //     planned sequence must own the page its next token writes
        //     before any device work happens ---
        loop {
            views.clear();
            views.extend(live.iter().enumerate().map(|(i, l)| SeqView {
                seq: i,
                generated: l.tokens.len(),
                target: l.target(),
            }));
            plan_round_into(w.step_policy, &views, &mut plan);
            if plan.is_empty() {
                break;
            }
            stalled.clear();
            for &idx in &plan {
                let l = &live[idx];
                let grown = w
                    .pager
                    .grow(l.kv, l.state.pos + 1)
                    .expect("live sequences hold valid KV handles");
                if !grown {
                    stalled.push(idx);
                }
            }
            if stalled.is_empty() {
                break;
            }
            // Page pressure. The victim is the longest-remaining sequence
            // — evicting the work furthest from completion frees the most
            // future page demand and never throws away a nearly-done
            // sequence. Aged resumes are shielded (victims of last
            // resort), so the park → resume → re-evict cycle terminates.
            // The shield and the tenant service surplus (tokens served on
            // the owner's rollup ÷ its WFQ weight — the tie-breaker that
            // extends fairness into the pager) are computed only here, on
            // the pressure path, keeping the per-sequence metric locks
            // off pressure-free rounds entirely.
            shield.clear();
            shield.extend(live.iter().map(|l| l.shielded));
            overserve.clear();
            overserve.extend(live.iter().map(|l| {
                let t = l.req.tenant.0;
                let served = w.tenant_metrics[t].lock().unwrap().tokens_out as f64;
                served / w.tenant_weights.get(t).copied().unwrap_or(1.0).max(1e-9)
            }));
            let victim = plan_eviction_weighted(&views, &shield, &overserve)
                .expect("non-empty plan has an active seq");
            if w.policy.preempt && live.len() > 1 {
                let evicted = live.swap_remove(victim);
                let survivors = live.len();
                preempt(&mut w, evicted, survivors);
                continue; // replan against the freed pages
            }
            if stalled.len() == plan.len() {
                // Nothing can advance and no retirement will ever free a
                // page (preemption disabled, or this is the last
                // sequence): fail the victim to restore liveness.
                let mut evicted = live.swap_remove(victim);
                evicted.failed = Some(format!(
                    "KV pages exhausted ({} of {} blocks free) and preemption {}",
                    w.pager.free_blocks(),
                    w.pager.capacity_blocks(),
                    if w.policy.preempt {
                        "cannot help (no other sequence to evict)"
                    } else {
                        "is disabled"
                    },
                ));
                retire(&mut w, evicted);
                continue;
            }
            // Partial pressure without preemption: the stalled sequences
            // sit this round out (they retry when a peer retires and frees
            // pages); everyone else steps.
            plan.retain(|idx| !stalled.contains(idx));
            break;
        }

        // --- one decode round across the planned set ---
        if !plan.is_empty() {
            {
                let mut m = w.metrics.lock().unwrap();
                m.record_batch(plan.len());
                m.sync_prefix(w.pager.prefix_stats());
                m.sync_cache(w.pager.cached_bytes());
            }
            // A thermal throttle stretches every simulated decode step
            // this round; the token stream itself is unchanged.
            let slow = w.degrade.decode_factor();
            let mut round_s = 0.0;
            for &idx in &plan {
                let l = &mut live[idx];
                let token = *l.tokens.last().unwrap();
                match w.runtime.decode(&mut l.state, token) {
                    Ok(()) => {
                        l.tokens.push(l.state.argmax());
                        l.ledger.decode_s += w.overlay.decode_s_per_token * slow;
                        l.sim_j += w.overlay.decode_s_per_token * slow * w.overlay.decode_w;
                        round_s += w.overlay.decode_s_per_token * slow;
                    }
                    Err(e) => l.failed = Some(format!("decode failed: {e}")),
                }
            }
            w.degrade.tick_round();
            // The round advances this node's simulated clock by the device
            // seconds it charged; the span is stamped at the round's end.
            if w.tracer.enabled() {
                w.tracer.advance(w.node, round_s);
                w.tracer.emit(
                    w.node,
                    NODE_SCOPE,
                    SpanKind::DecodeRound { seqs: plan.len(), sim_s: round_s },
                );
                sample_series(&w, &live, round, round_s);
            }
        }

        // --- retire finished sequences; their pages free for the next
        //     round's admissions and resumes ---
        retire_done(&mut w, &mut live);
        park.age_owned(w.node);
    }
    // Final prefix-cache snapshot: admissions after the last stepped
    // round (e.g. a drain that never decoded) still land in the metrics.
    {
        let mut m = w.metrics.lock().unwrap();
        m.sync_prefix(w.pager.prefix_stats());
        m.sync_cache(w.pager.cached_bytes());
    }
    // Retract this card's published chains: a drained worker must not
    // attract affine routes.
    w.directory.clear(w.node);
}

/// Snapshot one node's gauges into the trace time-series after a decode
/// round: queue depth, decode-set size, park-lot occupancy, KV page
/// tiers, fleet host-pool bytes, and the simulated draw of the round just
/// charged. Stamped with the node's simulated clock, never wall time.
fn sample_series(w: &NodeWorker, live: &[Live], round: u64, round_s: f64) {
    let (_, sim_s) = w.tracer.now(w.node);
    w.tracer.sample(SeriesPoint {
        node: w.node,
        round,
        sim_s,
        queue_depth: w.queues.len(w.node),
        live_seqs: live.len(),
        parked_seqs: w.park.owned_count(w.node),
        pinned_blocks: w.pager.used_blocks(),
        cached_blocks: w.pager.cached_blocks(),
        free_blocks: w.pager.free_blocks(),
        host_pool_bytes: w.host_pool.lock().unwrap().used_bytes(),
        watts: if round_s > 0.0 { w.overlay.decode_w } else { 0.0 },
    });
}

/// Poll the fault script and apply this round's events to the worker.
/// Returns true when the node dies (the caller unwinds through [`died`]).
fn apply_faults(w: &mut NodeWorker) -> bool {
    let Some(injector) = w.injector.clone() else { return false };
    let mut dead = false;
    for kind in injector.begin_round(w.node) {
        w.tracer.emit(w.node, NODE_SCOPE, SpanKind::Fault { kind: kind.name() });
        match kind {
            FaultKind::NodeDeath => dead = true,
            FaultKind::TransientStall { rounds } => {
                w.degrade.stall_rounds += rounds;
                w.metrics.lock().unwrap().degrade_events += 1;
            }
            FaultKind::LinkDowngrade { lanes } => {
                w.link = w.link.with_lanes(lanes);
                // Ladder step 1: the narrow link no longer earns a swap's
                // round trip; future evictions drop-and-recompute.
                w.degrade.swap_disabled = true;
                w.metrics.lock().unwrap().degrade_events += 1;
            }
            FaultKind::VramPageLoss { blocks } => {
                let lost = w.pager.lose_blocks(blocks);
                w.degrade.lost_blocks += lost;
                // Ladder step 3: admission shrinks pro-rata with the
                // surviving page pool.
                w.policy.max_batch = degraded_concurrency(
                    w.base_max_batch,
                    w.pager.capacity_blocks(),
                    w.base_blocks,
                );
                w.metrics.lock().unwrap().degrade_events += 1;
            }
            FaultKind::SwapInFailure => {
                // armed inside the injector; consumed at the next swap-in
            }
            FaultKind::ThermalThrottle { factor, rounds } => {
                w.degrade.throttle_factor = factor;
                w.degrade.throttle_rounds += rounds;
                w.metrics.lock().unwrap().degrade_events += 1;
            }
        }
    }
    dead
}

/// The node died mid-flight. Hand every queued, live, and parked sequence
/// back to the dispatch stage with its replayable progress (greedy decode
/// is deterministic, so a healthy card reconstructs the exact state);
/// whatever cannot be handed back is answered terminally so no client
/// ever hangs on a dead card.
fn died(w: &mut NodeWorker, live: Vec<Live>) {
    w.fleet.lock().unwrap().mark_unhealthy(w.node);
    // Retract published prefix chains immediately: a dead card must stop
    // attracting affine routes before the dispatcher's next decision.
    w.directory.clear(w.node);
    // Atomically kill + drain our queue. Queued requests never started:
    // they re-enter with whatever they already carried (no new rescue
    // count — no progress was at risk).
    for req in w.queues.kill_node(w.node) {
        w.fleet.lock().unwrap().complete(w.node);
        let trace = TraceId(req.id);
        if requeue_or_lose(w, req) {
            w.tracer.emit(w.node, trace, SpanKind::Rescued { from: w.node });
        }
    }
    let now = Instant::now();
    for l in live {
        w.pager.release(l.kv).expect("page accounting");
        let decode_s = l.decode_s + l.decode_started.elapsed().as_secs_f64();
        let mut req = l.req;
        req.carry = Carried {
            replay: l.tokens,
            queue_s: l.queue_s,
            prefill_s: l.prefill_s,
            decode_s,
            ledger: l.ledger,
            sim_j: l.sim_j,
            preemptions: l.preemptions,
            swaps: l.swaps,
            rescues: req.carry.rescues + 1,
            attempt: req.carry.attempt,
        };
        req.enqueued = now;
        let (tenant, kept_s) = (req.tenant, req.carry.ledger.device_s());
        let trace = TraceId(req.id);
        w.fleet.lock().unwrap().complete(w.node);
        if requeue_or_lose(w, req) {
            w.tracer.emit(w.node, trace, SpanKind::Rescued { from: w.node });
            count_rescue(w, tenant, kept_s);
        }
    }
    // Parked sequences still owned by this node are rescued the same way.
    // A sequence a peer already claimed (mid-migration) is not in the lot
    // anymore — it lives in the thief's set and survives untouched.
    for mut p in w.park.drain_owned(w.node) {
        if p.swapped.take().is_some() {
            w.host_pool.lock().unwrap().release(p.swap_bytes);
        }
        let queue_s = p.queue_s_now();
        let mut req = p.req;
        req.carry = Carried {
            replay: p.tokens,
            queue_s,
            prefill_s: p.prefill_s,
            decode_s: p.decode_s,
            ledger: p.ledger,
            sim_j: p.sim_j,
            preemptions: p.preemptions,
            swaps: p.swaps,
            rescues: req.carry.rescues + 1,
            attempt: req.carry.attempt,
        };
        req.enqueued = now;
        let (tenant, kept_s) = (req.tenant, req.carry.ledger.device_s());
        let trace = TraceId(req.id);
        w.fleet.lock().unwrap().complete(w.node);
        if requeue_or_lose(w, req) {
            w.tracer.emit(w.node, trace, SpanKind::Rescued { from: w.node });
            count_rescue(w, tenant, kept_s);
        }
    }
    // The dead node's last moments, preserved verbatim: the ring's
    // undrained tail (faults, rescues, the rounds before the death) moves
    // into a flight dump the exporter writes as one `flight_dump` line.
    w.tracer.flight_dump(w.node, "node death");
}

/// Book one successful rescue hand-back on the node and tenant rollups.
/// `kept_s` is the simulated device time the rescue preserved — work a
/// rescue-less engine would have re-burned or thrown away.
fn count_rescue(w: &NodeWorker, tenant: TenantId, kept_s: f64) {
    {
        let mut m = w.metrics.lock().unwrap();
        m.rescued_seqs += 1;
        m.rescue_kept_s += kept_s;
    }
    w.tenant_metrics[tenant.0].lock().unwrap().rescued_seqs += 1;
}

/// Hand one request (with its carried progress) back to the dispatch
/// stage for re-admission elsewhere. When rescue is off or the dispatcher
/// is gone, the request is answered with a terminal error instead — lost,
/// but never hung. The caller has already `complete()`d the router slot.
fn requeue_or_lose(w: &mut NodeWorker, req: GenRequest) -> bool {
    let req = match &w.rescue {
        Some(tx) => match tx.send(Requeue::Rescue(req)) {
            Ok(()) => return true,
            Err(e) => e.0.into_request(),
        },
        None => req,
    };
    let queue_s = req.carry.queue_s;
    {
        let mut m = w.metrics.lock().unwrap();
        m.lost_seqs += 1;
        m.record_response(queue_s, 0, false);
    }
    {
        let mut tm = w.tenant_metrics[req.tenant.0].lock().unwrap();
        tm.lost_seqs += 1;
        tm.simulated_energy_j += req.carry.sim_j;
        tm.record_response(queue_s, 0, false);
    }
    w.accounts.lock().unwrap().settle_energy(req.tenant, req.charged_j, req.carry.sim_j);
    let _ = req.reply.send(empty_response(
        req.id,
        req.tenant,
        w.node,
        queue_s,
        Some("node died; rescue unavailable".into()),
    ));
    false
}

/// Claim a foreign parked sequence and resume it here — live migration.
/// The victim's swap-out was already priced at *its* card's PCIe link;
/// the restore below goes over *this* card's link (`w.link`), so both
/// ends of the move carry their own §3 transfer cost. A dropped (swapless)
/// victim replays from tokens instead — prefix-aware, so a warm prefix on
/// this card shortens the recompute. Returns true when a sequence joined
/// this worker's live set.
fn migrate_parked(w: &mut NodeWorker, park: &ParkLot, live: &mut Vec<Live>) -> bool {
    // Hysteresis: only grab entries old enough that their owner clearly
    // isn't coming back for them, unless the owner is visibly backlogged
    // (or dead) — an idle owner resumes its own lot next round for free.
    let queues = Arc::clone(&w.queues);
    let claim = park.claim_foreign(w.node, w.policy.migrate_min_age, |owner| {
        !queues.alive(owner) || queues.len(owner) > 0
    });
    let (victim, p) = match claim {
        Claim::Taken(victim, p) => (victim, p),
        Claim::Deferred => {
            w.metrics.lock().unwrap().migration_deferrals += 1;
            return false;
        }
        Claim::Empty => return false,
    };
    let tenant = p.req.tenant;
    let trace = TraceId(p.req.id);
    // Re-book the router slot onto this card up front: resume's terminal
    // failure path completes the slot on `w.node`, and retire later
    // completes it there too.
    w.fleet.lock().unwrap().reassign(victim, w.node);
    match resume(w, p, live) {
        Resumed::Joined => {
            w.tracer.emit(w.node, trace, SpanKind::Migrated { from: victim });
            w.metrics.lock().unwrap().migrations += 1;
            w.tenant_metrics[tenant.0].lock().unwrap().migrations += 1;
            true
        }
        Resumed::NoPages(p) => {
            // Could not fit here after all: undo the booking and hand the
            // sequence back to its owner's FIFO head.
            w.fleet.lock().unwrap().reassign(w.node, victim);
            park.push_front(victim, p);
            false
        }
        Resumed::Failed => false,
    }
}

/// Pull the newest request off the deepest peer queue and re-book it onto
/// this node in the router's ledger.
fn steal(w: &NodeWorker) -> Option<GenRequest> {
    let (victim, req) = w.queues.steal_from(w.node)?;
    w.fleet.lock().unwrap().reassign(victim, w.node);
    w.metrics.lock().unwrap().steals += 1;
    w.tenant_metrics[req.tenant.0].lock().unwrap().steals += 1;
    Some(req)
}

/// Retire every done sequence in the live set; their pages free
/// immediately for admissions, resumes, and peers' growth.
fn retire_done(w: &mut NodeWorker, live: &mut Vec<Live>) {
    let mut i = 0;
    while i < live.len() {
        if !live[i].done() {
            i += 1;
            continue;
        }
        let l = live.swap_remove(i);
        retire(w, l);
    }
}

/// Admit one routed request: window checks, KV pages for the prefill
/// window, prefill. Returns true when the request joined the in-flight
/// set.
fn admit(w: &mut NodeWorker, mut req: GenRequest, live: &mut Vec<Live>) -> bool {
    let cfg = w.runtime.config;
    // queue time banked across earlier dispatch attempts plus this one
    let queue_s = req.carry.queue_s + req.enqueued.elapsed().as_secs_f64();
    if req.max_tokens == 0 {
        // submit() rejects these at the API; a zero-token request built by
        // any other path is answered as an empty success without touching
        // decode (and without polluting throughput metrics with a token).
        w.metrics.lock().unwrap().record_response(queue_s, 0, true);
        w.tenant_metrics[req.tenant.0].lock().unwrap().record_response(queue_s, 0, true);
        w.accounts.lock().unwrap().settle_energy(req.tenant, req.charged_j, 0.0);
        w.fleet.lock().unwrap().complete(w.node);
        let _ = req.reply.send(empty_response(req.id, req.tenant, w.node, queue_s, None));
        return false;
    }
    // Deadline checkpoint: past-due work is refused before it can take
    // pages (the client already gave up; pages would be pure waste).
    if req.deadline.is_some_and(|d| Instant::now() >= d) {
        w.metrics.lock().unwrap().deadline_misses += 1;
        w.tenant_metrics[req.tenant.0].lock().unwrap().deadline_misses += 1;
        reject(w, &req, "deadline exceeded".into(), queue_s, req.carry.sim_j);
        return false;
    }
    // Degradation ladder, step 2: a degraded card (throttled, or short of
    // VRAM) sheds tenants that over-drew their sustained rate first — the
    // capacity the fault removed is capacity they had already borrowed.
    if (w.degrade.throttled() || w.degrade.lost_blocks > 0)
        && w.accounts.lock().unwrap().rate_in_debt(req.tenant, Instant::now())
    {
        reject(
            w,
            &req,
            "shed by degraded node (tenant over sustained rate)".into(),
            queue_s,
            req.carry.sim_j,
        );
        return false;
    }
    let budget = admission_budget(cfg.max_ctx, cfg.prefill_t);
    if req.prompt.len() > cfg.prefill_t || req.max_tokens > budget {
        let msg = format!(
            "request exceeds window (prompt {} > {} or tokens {} > {})",
            req.prompt.len(),
            cfg.prefill_t,
            req.max_tokens,
            budget
        );
        reject(w, &req, msg, queue_s, req.carry.sim_j);
        return false;
    }
    // The sequence must fit this card's page pool even running alone, or
    // admission would loop forever growing toward pages that don't exist.
    let final_positions = cfg.prefill_t + req.max_tokens - 1;
    if w.pager.blocks_for(final_positions) > w.pager.capacity_blocks() {
        let msg = format!(
            "request needs {} KV blocks at full length but the card has {}",
            w.pager.blocks_for(final_positions),
            w.pager.capacity_blocks()
        );
        reject(w, &req, msg, queue_s, req.carry.sim_j);
        return false;
    }
    let Some((kv, hits, resurrected)) = admit_pages(w, &req.prompt) else {
        return retry_or_reject(w, req, "no KV pages (overload)", queue_s);
    };
    let cached = cached_positions(w, hits);
    // A rescued sequence re-admits its full replayed length up front — a
    // mid-replay eviction would throw away exactly the progress the
    // rescue preserved.
    let replay = std::mem::take(&mut req.carry.replay);
    if !replay.is_empty() {
        let replay_positions = cfg.prefill_t + replay.len().saturating_sub(1);
        if !w.pager.grow(kv, replay_positions).expect("just-admitted KV handle") {
            w.pager.release(kv).expect("releasing the just-admitted pages");
            req.carry.replay = replay;
            return retry_or_reject(w, req, "no KV pages (overload)", queue_s);
        }
    }
    let t0 = Instant::now();
    match w.runtime.prefill_padded(&req.prompt) {
        Ok(mut state) => {
            // Replay a rescue's generated tokens: greedy decode is
            // deterministic, so this reconstructs the dead card's state —
            // and the eventual token stream — bit for bit.
            for &tok in replay.iter().take(replay.len().saturating_sub(1)) {
                if let Err(e) = w.runtime.decode(&mut state, tok) {
                    w.pager.release(kv).expect("page accounting");
                    reject(
                        w,
                        &req,
                        format!("rescue replay failed: {e}"),
                        queue_s,
                        req.carry.sim_j,
                    );
                    return false;
                }
            }
            credit_prefix_hits(w, cached, resurrected);
            let prefill_s = t0.elapsed().as_secs_f64();
            let trace = TraceId(req.id);
            w.tracer.emit(w.node, trace, SpanKind::Admitted { cached_tokens: cached });
            // A rescue re-enters with the dead node's ledger; fresh
            // requests start from zero. Either way the admission charge
            // advances this node's simulated clock, and the span is
            // stamped at the phase's end.
            let mut ledger = req.carry.ledger;
            let sim_j = if replay.is_empty() {
                let s = w.overlay.prefill_s_per_token * (cfg.prefill_t - cached) as f64;
                ledger.prefill_s += s;
                w.tracer.advance(w.node, s);
                w.tracer.emit(w.node, trace, SpanKind::Prefill { sim_s: s });
                s * w.overlay.prefill_w
            } else {
                // The replay is priced like a recompute-resume: prefill
                // minus prefix credit, plus the replayed decode steps.
                let steps = replay.len().saturating_sub(1);
                let s = w.overlay.recompute_s(cfg.prefill_t - cached, steps);
                let j = w.overlay.recompute_j(cfg.prefill_t - cached, steps);
                w.metrics.lock().unwrap().rescue_replay_s += s;
                ledger.replay_s += s;
                w.tracer.advance(w.node, s);
                w.tracer.emit(w.node, trace, SpanKind::Replayed { tokens: steps, sim_s: s });
                j
            };
            let tokens =
                if replay.is_empty() { vec![state.argmax()] } else { replay };
            live.push(Live {
                queue_s,
                prefill_s: req.carry.prefill_s + prefill_s,
                decode_s: req.carry.decode_s,
                ledger,
                sim_j: req.carry.sim_j + sim_j,
                preemptions: req.carry.preemptions,
                swaps: req.carry.swaps,
                req,
                state,
                kv,
                tokens,
                shielded: false,
                failed: None,
                decode_started: Instant::now(),
            });
            true
        }
        Err(e) => {
            w.pager.release(kv).expect("releasing the just-admitted pages");
            reject(w, &req, format!("prefill failed: {e}"), queue_s, req.carry.sim_j);
            false
        }
    }
}

/// Bounded retry: while attempts remain (and the dispatch stage is still
/// reachable) a transiently-refused request goes back for another pass
/// after exponential backoff, instead of failing outright. Falls back to
/// a terminal reject once retries are spent. Returns false always —
/// nothing joined the live set either way.
fn retry_or_reject(w: &mut NodeWorker, mut req: GenRequest, why: &str, queue_s: f64) -> bool {
    if req.carry.attempt < w.recovery.max_retries {
        if let Some(tx) = w.rescue.clone() {
            req.carry.attempt += 1;
            // bank the wait so far; the clock restarts on re-entry
            req.carry.queue_s += req.enqueued.elapsed().as_secs_f64();
            req.enqueued = Instant::now();
            let tenant = req.tenant;
            match tx.send(Requeue::Retry(req)) {
                Ok(()) => {
                    w.metrics.lock().unwrap().retries += 1;
                    w.tenant_metrics[tenant.0].lock().unwrap().retries += 1;
                    w.fleet.lock().unwrap().complete(w.node);
                    return false;
                }
                Err(e) => req = e.0.into_request(),
            }
        }
    }
    let attempt = req.carry.attempt + 1;
    let sim_j = req.carry.sim_j;
    reject(w, &req, format!("{why} (attempt {attempt})"), queue_s, sim_j);
    false
}

/// Reserve prefill-window pages for one prompt. With the prefix cache on,
/// the pager matches the runtime's own padded window
/// ([`ModelRuntime::padded_window`] — the exact content
/// `prefill_padded` computes KV over, one shared construction) — the
/// chain hashes key exactly the content the blocks would hold — pinning
/// resident blocks instead of allocating. Returns the handle, the hit
/// count, and how many of those hits were **resurrected** from the
/// reclaimable cache rather than live-shared (both always 0 on the
/// prefix-blind path).
fn admit_pages(w: &mut NodeWorker, prompt: &[i32]) -> Option<(SeqKv, usize, usize)> {
    if !w.policy.prefix_cache {
        return w.pager.admit(w.runtime.config.prefill_t).map(|kv| (kv, 0, 0));
    }
    // The admission window check ran before this point, so the prompt
    // always fits; a window error therefore reads as an admission miss.
    let window = w.runtime.padded_window(prompt).ok()?;
    let before = w.pager.prefix_stats().resurrected_blocks;
    let (kv, hits) = w.pager.admit_prompt(&window)?;
    let resurrected = (w.pager.prefix_stats().resurrected_blocks - before) as usize;
    Some((kv, hits, resurrected))
}

/// Positions of the prefill window covered by `hits` cache-hit blocks —
/// on the simulated card their KV is already resident, so their share of
/// the prefill never runs.
fn cached_positions(w: &NodeWorker, hits: usize) -> usize {
    (hits * w.pager.block_positions()).min(w.runtime.config.prefill_t)
}

/// Credit `cached` resident positions to the saved-prefill ledger, split
/// by tier: positions covered by `resurrected` cached-tier blocks are
/// savings only the radix tree's retention earned (no live sharer held
/// them), the rest were live-shared. Called only after the prefill
/// actually succeeded — crediting earlier would book savings for work
/// that never ran at all when prefill errors out.
fn credit_prefix_hits(w: &mut NodeWorker, cached: usize, resurrected: usize) {
    if cached > 0 {
        let res_pos = (resurrected * w.pager.block_positions()).min(cached);
        let mut m = w.metrics.lock().unwrap();
        m.saved_prefill_s += w.overlay.prefill_s_per_token * cached as f64;
        m.saved_prefill_resurrected_s += w.overlay.prefill_s_per_token * res_pos as f64;
    }
}

/// Evict one in-flight sequence under page pressure. The comeback is
/// priced per victim ([`choose_preempt`]): when the §3 PCIe round trip of
/// its pages at this card's link width is cheaper than the overlay's
/// recompute estimate — and the host pool can hold them — the decode
/// state is **swapped** to host RAM (transfer-out charged now,
/// transfer-in at resume); otherwise the KV is dropped and resume
/// recomputes prefill and replays the generated tokens (greedy decode is
/// deterministic, so the replay reconstructs the identical state —
/// vLLM's recompute-on-resume).
fn preempt(w: &mut NodeWorker, l: Live, concurrent: usize) {
    let prefill_t = w.runtime.config.prefill_t;
    let replay_steps = l.tokens.len().saturating_sub(1);
    // The whole pricing pass is gated on the swap knob: with swap off
    // (the default) an eviction is just a release + park, no victim
    // table walks or cost estimates on the pressure path.
    let mut swap = false;
    let mut kv_bytes = 0u64;
    let mut recompute_est_s = 0.0;
    // Degradation ladder, step 1: a downgraded link no longer earns the
    // round trip the chooser would price at full width — swap is off.
    if w.policy.swap && !w.degrade.swap_disabled {
        // Price the recompute side with the same prefix credit a
        // recompute-resume would get: prompt blocks that survive this
        // release — live-shared with another holder, or demoted to the
        // reclaimable cache instead of freed — come back as cache hits,
        // so their share of the prefill replay never runs.
        let survivors = if w.policy.prefix_cache {
            let prompt_blocks = w.pager.blocks_for(prefill_t);
            w.pager
                .seq_survivor_blocks(l.kv, prompt_blocks)
                .expect("live sequences hold valid KV handles")
        } else {
            0
        };
        let cached = (survivors * w.pager.block_positions()).min(prefill_t);
        recompute_est_s = w.overlay.recompute_s(prefill_t - cached, replay_steps);
        // Transfer side priced symmetrically: only blocks that would
        // actually vanish from the card cross the link — shared prompt
        // blocks stay resident for their other holders, and retained
        // content-addressed blocks stay as cache; both re-pin on
        // restore, the same blocks the recompute estimate was just
        // credited for.
        kv_bytes =
            w.pager.seq_swap_bytes(l.kv).expect("live sequences hold valid KV handles");
        swap = choose_preempt(kv_bytes, &w.link, recompute_est_s) == PreemptAction::Swap
            && w.host_pool.lock().unwrap().try_reserve(kv_bytes);
    }
    w.pager.release(l.kv).expect("page accounting");
    let trace = TraceId(l.req.id);
    w.tracer.emit(w.node, trace, SpanKind::Preempted { swapped: swap });
    let (mut ledger, mut sim_j) = (l.ledger, l.sim_j);
    let (swapped, swap_bytes) = if swap {
        // Swap-out: the pages leave the device over the host link now.
        // With overlap on, the DMA rides under the survivors' decode
        // round — only the tail that outlasts the round stalls the
        // victim's clock. Energy is unaffected: the link moves the same
        // bytes either way.
        let t_out = w.link.transfer_time(kv_bytes);
        let round_s = if w.overlap {
            w.overlay.decode_s_per_token * w.degrade.decode_factor() * concurrent as f64
        } else {
            0.0
        };
        let (hidden, stall) = overlap_transfer(t_out, round_s);
        ledger.stall_s += stall;
        sim_j += t_out * SWAP_LINK_W;
        w.tracer.advance(w.node, stall);
        w.tracer.emit(
            w.node,
            trace,
            SpanKind::SwapOut { bytes: kv_bytes, stall_s: stall },
        );
        {
            let mut m = w.metrics.lock().unwrap();
            m.preemptions += 1;
            m.swap_outs += 1;
            m.swap_bytes += kv_bytes;
            m.swap_transfer_s += t_out;
            m.swap_overlapped_s += hidden;
            m.swap_stalled_s += stall;
        }
        (Some(l.state), kv_bytes)
    } else {
        w.metrics.lock().unwrap().preemptions += 1;
        (None, 0)
    };
    w.tracer.emit(w.node, trace, SpanKind::Parked);
    w.park.push_back(w.node, Preempted {
        decode_s: l.decode_s + l.decode_started.elapsed().as_secs_f64(),
        req: l.req,
        tokens: l.tokens,
        queue_s: l.queue_s,
        prefill_s: l.prefill_s,
        ledger,
        sim_j,
        preemptions: l.preemptions + 1,
        swaps: l.swaps + swap as u64,
        swapped,
        swap_bytes,
        recompute_est_s,
        parked_at: Instant::now(),
        parked_rounds: 0,
        aged: false,
    });
}

/// Re-enter a preempted sequence: re-admit its pages (the full replay
/// length up front, so the resume cannot itself be preempted mid-replay),
/// then either **restore the swapped state from host RAM** (transfer-in
/// over the card's link, no recompute) or recompute prefill and replay
/// the generated tokens, and rejoin the live set.
fn resume(w: &mut NodeWorker, mut p: Preempted, live: &mut Vec<Live>) -> Resumed {
    let cfg = w.runtime.config;
    let resume_positions = cfg.prefill_t + p.tokens.len().saturating_sub(1);
    // Both comeback paths re-admit prefix-aware: the recompute path's
    // cache hits are prefill work that really never reruns, and a swap
    // restore re-pins surviving shared prompt blocks instead of
    // duplicating content that never left the card (only its private
    // pages crossed the link).
    let Some((kv, hits, resurrected)) = admit_pages(w, &p.req.prompt) else {
        return Resumed::NoPages(p);
    };
    if !w.pager.grow(kv, resume_positions).expect("just-admitted KV handle") {
        w.pager.release(kv).expect("releasing the just-admitted pages");
        return Resumed::NoPages(p);
    }
    // The parked stretch ends here: from now on the request is either
    // restoring/recomputing or terminally answered.
    let queue_s = p.queue_s_now();
    let replay_steps = p.tokens.len().saturating_sub(1);
    let trace = TraceId(p.req.id);
    // Injected swap-in failure: the host copy is unreadable. Release the
    // reservation and fall through to the recompute path — greedy decode
    // rebuilds the identical state, so the failure costs time, not
    // correctness.
    if p.swapped.is_some()
        && w.injector.as_ref().is_some_and(|i| i.take_swap_in_failure(w.node))
    {
        p.swapped = None;
        w.host_pool.lock().unwrap().release(p.swap_bytes);
        p.swap_bytes = 0;
        w.metrics.lock().unwrap().swap_in_failures += 1;
        w.tenant_metrics[p.req.tenant.0].lock().unwrap().swap_in_failures += 1;
    }
    if let Some(state) = p.swapped.take() {
        // Swap-in: the parked private pages come back over the host
        // link; the recompute the chooser priced against never runs.
        // (Shared prompt blocks whose other holders released meanwhile
        // are re-created by the prefix-aware admission above — the
        // parked state is complete, so the restore is still exact; the
        // transfer bill just stays at the bytes actually parked.) The
        // margin between the chooser's own estimate and the round trip
        // is what the swap bought — settled from the same number the
        // decision used, so ledger and decision cannot disagree.
        w.host_pool.lock().unwrap().release(p.swap_bytes);
        let t_in = w.link.transfer_time(p.swap_bytes);
        let saved =
            (p.recompute_est_s - swap_round_trip_s(p.swap_bytes, &w.link)).max(0.0);
        // With overlap on, the restore DMA rides under the current live
        // set's decode round; only the tail past the round stalls this
        // sequence's rejoin.
        let round_s = if w.overlap {
            w.overlay.decode_s_per_token * w.degrade.decode_factor() * live.len() as f64
        } else {
            0.0
        };
        let (hidden, stall) = overlap_transfer(t_in, round_s);
        w.tracer.advance(w.node, stall);
        w.tracer.emit(
            w.node,
            trace,
            SpanKind::SwapIn { bytes: p.swap_bytes, stall_s: stall },
        );
        {
            let mut m = w.metrics.lock().unwrap();
            m.resumes += 1;
            m.swap_ins += 1;
            m.swap_bytes += p.swap_bytes;
            m.swap_transfer_s += t_in;
            m.swap_overlapped_s += hidden;
            m.swap_stalled_s += stall;
            m.saved_recompute_s += saved;
        }
        let mut ledger = p.ledger;
        ledger.stall_s += stall;
        live.push(Live {
            req: p.req,
            state,
            kv,
            tokens: p.tokens,
            queue_s,
            prefill_s: p.prefill_s,
            decode_s: p.decode_s,
            ledger,
            sim_j: p.sim_j + t_in * SWAP_LINK_W,
            preemptions: p.preemptions,
            swaps: p.swaps,
            shielded: p.aged,
            failed: None,
            decode_started: Instant::now(),
        });
        return Resumed::Joined;
    }
    let cached = cached_positions(w, hits);
    let t0 = Instant::now();
    let mut state = match w.runtime.prefill_padded(&p.req.prompt) {
        Ok(s) => s,
        Err(e) => {
            w.pager.release(kv).expect("page accounting");
            reject(w, &p.req, format!("resume prefill failed: {e}"), queue_s, p.sim_j);
            return Resumed::Failed;
        }
    };
    for &tok in p.tokens.iter().take(replay_steps) {
        if let Err(e) = w.runtime.decode(&mut state, tok) {
            w.pager.release(kv).expect("page accounting");
            reject(w, &p.req, format!("resume replay failed: {e}"), queue_s, p.sim_j);
            return Resumed::Failed;
        }
    }
    credit_prefix_hits(w, cached, resurrected);
    let recompute_wall_s = t0.elapsed().as_secs_f64();
    // Simulated cost of the recompute — all of it wasted work, bought by
    // the headroom the earlier eviction created. Prefix-cache hits shrink
    // the bill: resident prompt blocks skip their share of the prefill.
    let wasted_s = w.overlay.recompute_s(cfg.prefill_t - cached, replay_steps);
    let wasted_j = w.overlay.recompute_j(cfg.prefill_t - cached, replay_steps);
    w.tracer.advance(w.node, wasted_s);
    w.tracer.emit(
        w.node,
        trace,
        SpanKind::Replayed { tokens: replay_steps, sim_s: wasted_s },
    );
    {
        let mut m = w.metrics.lock().unwrap();
        m.resumes += 1;
        m.wasted_prefill_s += wasted_s;
    }
    let mut ledger = p.ledger;
    ledger.replay_s += wasted_s;
    live.push(Live {
        req: p.req,
        state,
        kv,
        tokens: p.tokens,
        queue_s,
        prefill_s: p.prefill_s + recompute_wall_s,
        decode_s: p.decode_s,
        ledger,
        sim_j: p.sim_j + wasted_j,
        preemptions: p.preemptions,
        swaps: p.swaps,
        // An aged resume re-entered through the admission freeze; shield
        // it so the next page squeeze picks a different victim.
        shielded: p.aged,
        failed: None,
        decode_started: Instant::now(),
    });
    Resumed::Joined
}

/// Retire one finished (or failed) sequence: release its pages, account
/// metrics (node and tenant), settle the tenant's energy charge to
/// actuals, tell the router, reply.
fn retire(w: &mut NodeWorker, l: Live) {
    w.pager.release(l.kv).expect("page accounting");
    let decode_s = l.decode_s + l.decode_started.elapsed().as_secs_f64();
    let ok = l.failed.is_none();
    let trace = TraceId(l.req.id);
    if w.tracer.enabled() {
        match &l.failed {
            None => w.tracer.emit(
                w.node,
                trace,
                SpanKind::Retired {
                    tokens: l.tokens.len(),
                    queue_s: l.queue_s,
                    ledger: l.ledger,
                },
            ),
            Some(e) => {
                w.tracer.emit(w.node, trace, SpanKind::Failed { error: e.clone() });
                w.tracer.flight_dump(w.node, "terminal error");
            }
        }
    }
    let resp = GenResponse {
        id: l.req.id,
        tenant: l.req.tenant,
        tokens: l.tokens,
        error: l.failed.map(|e| format!("{e} [trace {}]", l.req.id)),
        queue_s: l.queue_s,
        prefill_s: l.prefill_s,
        decode_s,
        simulated_device_s: l.ledger.device_s(),
        preemptions: l.preemptions,
        swaps: l.swaps,
        rescues: l.req.carry.rescues,
        node: w.node,
        ledger: l.ledger,
        trace,
    };
    // SLO attainment: a contracted request scores met only when it
    // succeeded within its latency target — a late success is served
    // waste, exactly what the admission controller exists to avoid.
    let slo_met = l.req.slo_s.map(|s| ok && resp.latency_s() <= s);
    {
        let mut m = w.metrics.lock().unwrap();
        m.wall_prefill_s += l.prefill_s;
        m.wall_decode_s += decode_s;
        m.simulated_device_s += l.ledger.device_s();
        m.simulated_energy_j += l.sim_j;
        m.attrib.record(l.queue_s, &l.ledger);
        if let Some(met) = slo_met {
            m.record_slo(met);
        }
        m.record_response(resp.latency_s(), resp.tokens.len(), ok);
    }
    {
        let mut tm = w.tenant_metrics[l.req.tenant.0].lock().unwrap();
        tm.simulated_device_s += l.ledger.device_s();
        tm.simulated_energy_j += l.sim_j;
        tm.attrib.record(l.queue_s, &l.ledger);
        if let Some(met) = slo_met {
            tm.record_slo(met);
        }
        tm.record_response(resp.latency_s(), resp.tokens.len(), ok);
    }
    w.accounts.lock().unwrap().settle_energy(l.req.tenant, l.req.charged_j, l.sim_j);
    {
        // A clean retirement is also a probation probe result: enough
        // successes readmit a recovered card to full routing trust.
        let mut f = w.fleet.lock().unwrap();
        f.complete(w.node);
        f.note_result(w.node, ok);
    }
    // dropped receiver = cancelled; ignore send failure
    let _ = l.req.reply.send(resp);
}

/// Reply with a terminal error for a request that holds no pages.
/// `actual_j` is whatever simulated energy the request did burn before
/// failing (zero for never-admitted requests) — the tenant's account is
/// settled to it.
fn reject(w: &mut NodeWorker, req: &GenRequest, error: String, queue_s: f64, actual_j: f64) {
    if w.tracer.enabled() {
        w.tracer.emit(w.node, TraceId(req.id), SpanKind::Failed { error: error.clone() });
        w.tracer.flight_dump(w.node, "terminal error");
    }
    {
        let mut m = w.metrics.lock().unwrap();
        if req.slo_s.is_some() {
            m.record_slo(false);
        }
        m.record_response(queue_s, 0, false);
    }
    {
        let mut tm = w.tenant_metrics[req.tenant.0].lock().unwrap();
        tm.simulated_energy_j += actual_j;
        if req.slo_s.is_some() {
            tm.record_slo(false);
        }
        tm.record_response(queue_s, 0, false);
    }
    w.accounts.lock().unwrap().settle_energy(req.tenant, req.charged_j, actual_j);
    w.fleet.lock().unwrap().complete(w.node);
    let _ = req.reply.send(empty_response(req.id, req.tenant, w.node, queue_s, Some(error)));
}

/// A terminal no-tokens reply (a rejection, or a zero-token empty
/// success) — the one place the "nothing was generated" response shape
/// lives.
fn empty_response(
    id: u64,
    tenant: TenantId,
    node: usize,
    queue_s: f64,
    error: Option<String>,
) -> GenResponse {
    GenResponse {
        id,
        tenant,
        tokens: vec![],
        error: error.map(|e| format!("{e} [trace {id}]")),
        queue_s,
        prefill_s: 0.0,
        decode_s: 0.0,
        simulated_device_s: 0.0,
        preemptions: 0,
        swaps: 0,
        rescues: 0,
        node,
        ledger: PhaseLedger::default(),
        trace: TraceId(id),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::TenantSpec;

    fn stub_handle(tx: SyncSender<GenRequest>) -> ServerHandle {
        ServerHandle {
            tx: Some(tx),
            dispatcher: None,
            workers: Vec::new(),
            node_names: vec!["stub"],
            node_metrics: vec![Arc::new(Mutex::new(Metrics::new()))],
            tenant_metrics: Arc::new(vec![Mutex::new(Metrics::new())]),
            registry: Arc::new(TenantRegistry::new(vec![]).unwrap()),
            fleet: Arc::new(Mutex::new(Fleet::uniform(1, 1.0, RoutePolicy::RoundRobin))),
            deadline: None,
            next_id: std::sync::atomic::AtomicU64::new(1),
            tracer: Arc::new(Tracer::off(1)),
        }
    }

    fn dummy_request(id: u64) -> (GenRequest, Receiver<GenResponse>) {
        let (reply, rx) = std::sync::mpsc::channel();
        let req = GenRequest {
            id,
            tenant: TenantRegistry::DEFAULT,
            prompt: vec![1, 2, 3],
            max_tokens: 2,
            charged_j: 0.0,
            reply,
            enqueued: Instant::now(),
            deadline: None,
            slo_s: None,
            carry: Carried::default(),
        };
        (req, rx)
    }

    fn test_overlay() -> Overlay {
        Overlay {
            prefill_s_per_token: 1e-3,
            decode_s_per_token: 2e-3,
            prefill_w: 100.0,
            decode_w: 50.0,
        }
    }

    /// A dispatcher over stub queues (no workers), for exercising the
    /// routing/shedding logic directly.
    fn stub_dispatcher(nodes: usize, tenants: Vec<TenantSpec>) -> Dispatcher {
        let registry = TenantRegistry::new(tenants).unwrap();
        let (_tx, rx) = sync_channel::<GenRequest>(4);
        // leak the rescue sender so the receiver stays connected for the
        // test's lifetime (a disconnect means "all workers gone")
        let (rescue_tx, rescue_rx) = sync_channel::<Requeue>(64);
        std::mem::forget(rescue_tx);
        Dispatcher {
            rx,
            rescue_rx,
            delayed: Vec::new(),
            recovery: RecoveryPolicy::default(),
            queue: AdmissionQueue::new(true, &registry.weights(), 512),
            fleet: Arc::new(Mutex::new(Fleet::uniform(nodes, 1.0, RoutePolicy::RoundRobin))),
            queues: Arc::new(NodeQueues::new(nodes)),
            accounts: Arc::new(Mutex::new(TenantAccounts::new(&registry, Instant::now()))),
            node_metrics: (0..nodes).map(|_| Arc::new(Mutex::new(Metrics::new()))).collect(),
            tenant_metrics: Arc::new(
                (0..registry.len()).map(|_| Mutex::new(Metrics::new())).collect(),
            ),
            overlays: vec![test_overlay(); nodes],
            prefill_t: 16,
            node_depth: 8,
            directory: None,
            block_positions: 16,
            tracer: Arc::new(Tracer::off(nodes)),
            admission: Some(AdmissionCtl::new(AdmissionConfig::default())),
            weight_rank: weight_ranks(&registry.weights()),
        }
    }

    #[test]
    fn zero_token_requests_are_rejected_at_submit() {
        // Regression: `max_tokens == 0` used to be floored to one token in
        // the decode loop, silently generating output and counting it in
        // throughput metrics.
        let (tx, rx) = sync_channel::<GenRequest>(4);
        let handle = stub_handle(tx);
        let err = handle.submit(vec![1, 2], 0).unwrap_err().to_string();
        assert!(err.contains("max_tokens"), "{err}");
        assert!(rx.try_recv().is_err(), "nothing may reach the queue");
        // a normal request still flows
        let _reply = handle.submit(vec![1, 2], 3).unwrap();
        assert_eq!(rx.try_recv().unwrap().max_tokens, 3);
    }

    #[test]
    fn submit_as_rejects_unknown_tenants() {
        let (tx, rx) = sync_channel::<GenRequest>(4);
        let handle = stub_handle(tx);
        let err = handle
            .submit_as(TenantId(7), vec![1], 2)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown tenant"), "{err}");
        assert!(rx.try_recv().is_err());
        // the default tenant id always resolves
        assert_eq!(handle.tenant_id("default"), Some(TenantRegistry::DEFAULT));
        let _reply = handle.submit_as(TenantRegistry::DEFAULT, vec![1], 2).unwrap();
        assert_eq!(rx.try_recv().unwrap().tenant, TenantRegistry::DEFAULT);
    }

    #[test]
    fn window_validation_rejects_inverted_geometry() {
        assert!(validate_window(64, 16).is_ok());
        assert!(validate_window(64, 64).is_ok());
        let err = validate_window(16, 64).unwrap_err().to_string();
        assert!(err.contains("prefill_t"), "{err}");
    }

    #[test]
    fn admission_budget_saturates_instead_of_panicking() {
        assert_eq!(admission_budget(64, 16), 48);
        // Regression: the old `max_ctx - prefill_t` underflowed (panicked)
        // on a runtime configured with prefill_t > max_ctx.
        assert_eq!(admission_budget(16, 64), 0);
        assert_eq!(admission_budget(64, 64), 0);
    }

    #[test]
    fn dispatch_reroutes_off_dead_workers_and_excludes_them() {
        // Node 0's worker is gone (liveness flag cleared by its drop
        // guard); node 1 is alive.
        let mut d = stub_dispatcher(2, vec![]);
        d.queues.mark_dead(0);
        // Round-robin picks node 0 first; the bounced push must mark it
        // unhealthy and reroute the same request to node 1 (regression:
        // the request was failed and the dead node kept taking traffic).
        let (req, reply) = dummy_request(1);
        d.dispatch(req.tenant, req, Instant::now());
        assert_eq!(d.queues.try_pop(1).unwrap().id, 1, "request must be rerouted");
        assert!(reply.try_recv().is_err(), "request must not be failed");
        {
            let f = d.fleet.lock().unwrap();
            assert_eq!(f.healthy_count(), 1);
            assert_eq!(f.nodes[0].outstanding, 0, "bounced push must be uncounted");
            assert_eq!(f.nodes[1].outstanding, 1);
        }
        // The dead node stays excluded: every later request lands on the
        // healthy card while it idles — no more routing to the dead one.
        let mut replies = Vec::new();
        for id in 2..6 {
            let (req, reply) = dummy_request(id);
            d.dispatch(req.tenant, req, Instant::now());
            replies.push(reply);
        }
        let mut got = Vec::new();
        while let Some(r) = d.queues.try_pop(1) {
            got.push(r.id);
        }
        assert_eq!(got, vec![2, 3, 4, 5]);
        // the bounced first attempt stays in node 0's cumulative history
        assert_eq!(d.fleet.lock().unwrap().nodes[0].assigned, 1);
        assert!(replies.iter().all(|r| r.try_recv().is_err()));
    }

    #[test]
    fn dispatch_fails_the_request_only_when_no_healthy_node_remains() {
        let mut d = stub_dispatcher(1, vec![]);
        d.queues.mark_dead(0);
        let (req, reply) = dummy_request(9);
        d.dispatch(req.tenant, req, Instant::now());
        let resp = reply.try_recv().unwrap();
        assert!(!resp.ok());
        assert!(resp.error.as_deref().unwrap().contains("unavailable"));
        assert_eq!(d.fleet.lock().unwrap().healthy_count(), 0);
        assert_eq!(d.node_metrics[0].lock().unwrap().errors, 1);
        assert_eq!(d.tenant_metrics[0].lock().unwrap().errors, 1);
        // a recovered fleet serves again once the operator flips it back
        d.queues = Arc::new(NodeQueues::new(1));
        d.fleet.lock().unwrap().mark_healthy(0);
        let (req, reply) = dummy_request(10);
        d.dispatch(req.tenant, req, Instant::now());
        assert_eq!(d.queues.try_pop(0).unwrap().id, 10);
        assert!(reply.try_recv().is_err(), "served, not failed");
    }

    #[test]
    fn dispatch_sheds_requests_past_the_tenant_energy_budget() {
        // A 1 J budget covers nothing at the stub overlay's rates: the
        // request must be shed with a terminal error, charged nothing,
        // counted on the tenant (not the node), and the node uncounted.
        let mut capped = TenantSpec::new("capped", 1.0);
        capped.energy_budget_j = Some(1.0);
        let mut d = stub_dispatcher(1, vec![capped]);
        let t = TenantId(1);
        let (mut req, reply) = dummy_request(1);
        req.tenant = t;
        req.max_tokens = 100;
        d.dispatch(t, req, Instant::now());
        let resp = reply.try_recv().unwrap();
        assert!(!resp.ok());
        assert!(resp.error.as_deref().unwrap().contains("energy budget"), "{resp:?}");
        assert_eq!(resp.tenant, t);
        assert_eq!(d.queues.len(0), 0, "nothing may reach the worker");
        assert_eq!(d.fleet.lock().unwrap().nodes[0].outstanding, 0);
        assert_eq!(d.tenant_metrics[1].lock().unwrap().errors, 1);
        assert_eq!(d.node_metrics[0].lock().unwrap().errors, 0);
        assert_eq!(d.accounts.lock().unwrap().energy_spent(t), 0.0);
        // an uncapped tenant still flows
        let (req, reply) = dummy_request(2);
        d.dispatch(req.tenant, req, Instant::now());
        assert_eq!(d.queues.try_pop(0).unwrap().id, 2);
        assert!(reply.try_recv().is_err());
    }

    #[test]
    fn dispatch_charges_the_estimate_to_the_tenant_account() {
        let mut capped = TenantSpec::new("capped", 1.0);
        capped.energy_budget_j = Some(1e6);
        let mut d = stub_dispatcher(1, vec![capped]);
        let t = TenantId(1);
        let (mut req, _reply) = dummy_request(1);
        req.tenant = t;
        req.max_tokens = 10;
        d.dispatch(t, req, Instant::now());
        let est = test_overlay().estimate_j(16, 10);
        let spent = d.accounts.lock().unwrap().energy_spent(t);
        assert!((spent - est).abs() < 1e-12, "{spent} vs {est}");
        let queued = d.queues.try_pop(0).unwrap();
        assert!((queued.charged_j - est).abs() < 1e-12);
    }

    #[test]
    fn no_healthy_nodes_fails_every_parked_request_promptly() {
        // Regression: requests parked in the WFQ (or the backoff pen)
        // when the last healthy node died used to linger until shutdown;
        // they must all fail immediately with a distinct error.
        let mut d = stub_dispatcher(1, vec![]);
        let mut parked = Vec::new();
        for id in 1..=2 {
            let (req, reply) = dummy_request(id);
            d.queue.push(req.tenant, req.max_tokens as f64, req);
            parked.push(reply);
        }
        let (req, reply) = dummy_request(3);
        let due = Instant::now() + Duration::from_secs(3600);
        d.delayed.push((due, req));
        parked.push(reply);
        d.queues.mark_dead(0);
        let (req, direct) = dummy_request(4);
        d.dispatch(req.tenant, req, Instant::now());
        let resp = direct.try_recv().unwrap();
        assert!(resp.error.as_deref().unwrap().contains("no healthy nodes"), "{resp:?}");
        for reply in parked {
            let resp = reply.try_recv().expect("parked request must be answered now");
            assert!(
                resp.error.as_deref().unwrap().contains("no healthy nodes"),
                "{resp:?}"
            );
        }
        assert!(d.queue.is_empty());
        assert!(d.delayed.is_empty());
        // 4 terminal errors on the default tenant's rollup
        assert_eq!(d.tenant_metrics[0].lock().unwrap().errors, 4);
    }

    #[test]
    fn dispatch_fails_requests_past_their_deadline() {
        let mut d = stub_dispatcher(1, vec![]);
        let (mut req, reply) = dummy_request(1);
        req.deadline = Some(Instant::now() - Duration::from_millis(1));
        d.dispatch(req.tenant, req, Instant::now());
        let resp = reply.try_recv().unwrap();
        assert!(resp.error.as_deref().unwrap().contains("deadline"), "{resp:?}");
        assert_eq!(d.tenant_metrics[0].lock().unwrap().deadline_misses, 1);
        assert_eq!(d.queues.len(0), 0, "past-due work must not reach a worker");
        assert_eq!(d.fleet.lock().unwrap().nodes[0].outstanding, 0);
        // an undated request flows normally
        let (req, reply) = dummy_request(2);
        d.dispatch(req.tenant, req, Instant::now());
        assert_eq!(d.queues.try_pop(0).unwrap().id, 2);
        assert!(reply.try_recv().is_err());
    }

    #[test]
    fn admission_control_sheds_doomed_contracted_requests_at_submit() {
        let mut d = stub_dispatcher(1, vec![]);
        // own service alone (16 prefill + 1000 decode tokens on the test
        // overlay ≈ 2 s) dooms a 100 ms contract before any queueing
        let (mut req, reply) = dummy_request(1);
        req.max_tokens = 1000;
        req.slo_s = Some(0.1);
        d.dispatch(req.tenant, req, Instant::now());
        let resp = reply.try_recv().unwrap();
        let err = resp.error.as_deref().unwrap();
        assert!(err.contains("admission control"), "{err}");
        {
            let tm = d.tenant_metrics[0].lock().unwrap();
            assert_eq!(tm.admission_sheds, 1);
            assert_eq!((tm.slo_eligible, tm.slo_met), (1, 0), "a shed counts as a miss");
        }
        assert_eq!(d.queues.len(0), 0, "doomed work must never reach a worker");
        assert_eq!(d.fleet.lock().unwrap().nodes[0].outstanding, 0);

        // the same contract with a feasible prediction flows normally
        let (mut req, reply) = dummy_request(2);
        req.slo_s = Some(0.5);
        d.dispatch(req.tenant, req, Instant::now());
        assert_eq!(d.queues.try_pop(0).unwrap().id, 2);
        assert!(reply.try_recv().is_err());
    }

    #[test]
    fn contract_less_requests_always_pass_admission_control() {
        let mut d = stub_dispatcher(1, vec![]);
        let (mut req, _reply) = dummy_request(1);
        req.max_tokens = 1000; // hopeless against any contract — but there is none
        d.dispatch(req.tenant, req, Instant::now());
        assert_eq!(d.queues.len(0), 1);
        let tm = d.tenant_metrics[0].lock().unwrap();
        assert_eq!((tm.admission_sheds, tm.slo_eligible), (0, 0));
    }

    #[test]
    fn the_no_admission_control_ablation_admits_doomed_requests() {
        let mut d = stub_dispatcher(1, vec![]);
        d.admission = None;
        let (mut req, reply) = dummy_request(1);
        req.max_tokens = 1000;
        req.slo_s = Some(0.1);
        d.dispatch(req.tenant, req, Instant::now());
        assert_eq!(d.queues.len(0), 1, "the reactive arm queues work it cannot save");
        assert!(reply.try_recv().is_err(), "no early shed without the controller");
        assert_eq!(d.tenant_metrics[0].lock().unwrap().admission_sheds, 0);
    }

    #[test]
    fn rescues_reenter_ahead_and_retries_wait_out_backoff() {
        let mut d = stub_dispatcher(1, vec![]);
        let now = Instant::now();
        // two ordinary arrivals, then a rescue hand-back
        for id in 1..=2 {
            let (req, _reply) = dummy_request(id);
            std::mem::forget(_reply);
            d.enqueue(req);
        }
        let (req, _r3) = dummy_request(3);
        std::mem::forget(_r3);
        d.requeue(Requeue::Rescue(req), now);
        // the rescue jumps the lane: it pops before the earlier arrivals
        let Popped::Item(_, first) = d.queue.pop_eligible(|_, _| true) else {
            panic!("queue must not be empty")
        };
        assert_eq!(first.id, 3, "a rescue re-enters at the head of its lane");
        // a retry parks in the backoff pen, invisible until it comes due
        let (mut req, _r4) = dummy_request(4);
        std::mem::forget(_r4);
        req.carry.attempt = 1;
        d.requeue(Requeue::Retry(req), now);
        assert_eq!(d.delayed.len(), 1);
        d.promote_delayed(now);
        assert_eq!(d.delayed.len(), 1, "backoff has not elapsed");
        let backoff = backoff_delay(d.recovery.backoff, 1);
        d.promote_delayed(now + backoff + Duration::from_millis(1));
        assert!(d.delayed.is_empty(), "due retry must be promoted");
        let Popped::Item(_, promoted) = d.queue.pop_eligible(|_, _| true) else {
            panic!("promoted retry must be poppable")
        };
        assert_eq!(promoted.id, 4);
    }

    #[test]
    fn a_dead_workers_guard_rescues_its_queued_requests() {
        let queues: Arc<NodeQueues<GenRequest>> = Arc::new(NodeQueues::new(1));
        let fleet = Arc::new(Mutex::new(Fleet::uniform(1, 1.0, RoutePolicy::RoundRobin)));
        fleet.lock().unwrap().route();
        let (rescue_tx, rescue_rx) = sync_channel::<Requeue>(8);
        let (req, reply) = dummy_request(7);
        queues.push_bounded(0, req, 8).unwrap();
        drop(AliveGuard {
            queues: Arc::clone(&queues),
            fleet: Arc::clone(&fleet),
            rescue: Some(rescue_tx),
            node: 0,
        });
        assert!(!queues.alive(0), "guard must mark the node dead");
        let rescued = rescue_rx.try_recv().expect("queued request must be handed back");
        assert!(
            reply.try_recv().is_err(),
            "no terminal reply may be sent to a rescued request"
        );
        assert_eq!(rescued.into_request().id, 7);
        assert_eq!(
            fleet.lock().unwrap().nodes[0].outstanding,
            0,
            "the guard must hand the routed slot back"
        );
    }

    /// A parked stub with no progress — enough to exercise ParkLot's
    /// ownership and ordering rules.
    fn parked_stub(id: u64) -> Preempted {
        let (req, reply) = dummy_request(id);
        std::mem::forget(reply);
        Preempted {
            req,
            tokens: vec![1],
            queue_s: 0.0,
            prefill_s: 0.0,
            decode_s: 0.0,
            ledger: PhaseLedger::default(),
            sim_j: 0.0,
            preemptions: 1,
            swaps: 0,
            swapped: None,
            swap_bytes: 0,
            recompute_est_s: 0.0,
            parked_at: Instant::now(),
            parked_rounds: 0,
            aged: false,
        }
    }

    #[test]
    fn park_lot_orders_owners_fifo_and_migrates_the_oldest_foreign_entry() {
        let lot = ParkLot::new();
        lot.push_back(0, parked_stub(1));
        lot.push_back(1, parked_stub(2));
        lot.push_back(0, parked_stub(3));
        assert!(lot.has_owned(0) && lot.has_owned(1));
        // Owners resume in FIFO order.
        assert_eq!(lot.pop_owned(0).unwrap().req.id, 1);
        // A thief claims the oldest entry it does not own — with its
        // original owner tag, so the router slot can be re-booked.
        // (min_age 0 disarms the hysteresis gate: the PR 7 behaviour.)
        let Claim::Taken(owner, p) = lot.claim_foreign(1, 0, |_| true) else {
            panic!("an aged foreign entry must be claimable");
        };
        assert_eq!((owner, p.req.id), (0, 3));
        // Only node 1's own entry remains: nothing foreign to node 1.
        assert!(matches!(lot.claim_foreign(1, 0, |_| true), Claim::Empty));
        assert!(!lot.has_owned(0));
        // A failed resume re-parks at the head of the owner's FIFO.
        lot.push_front(1, parked_stub(4));
        assert_eq!(lot.pop_owned(1).unwrap().req.id, 4);
        // Aging: entries cross the threshold once, engaging the gate and
        // reporting each newly aged tenant exactly once.
        lot.age_owned(1);
        let (engaged, newly) = lot.aging_gate(1, 1);
        assert!(engaged);
        assert_eq!(newly.len(), 1);
        let (engaged, newly) = lot.aging_gate(1, 1);
        assert!(engaged, "the gate stays engaged while the entry waits");
        assert!(newly.is_empty(), "an entry ages only once");
        // Node death drains exactly the dead node's entries.
        assert_eq!(lot.drain_owned(1).len(), 1);
        assert!(!lot.has_owned(1));
    }

    #[test]
    fn migration_hysteresis_defers_young_claims_then_takes_them() {
        let lot = ParkLot::new();
        lot.push_back(0, parked_stub(1));
        // Too young, and its idle owner will likely resume it next round:
        // the grab is deferred (the thrash the PR 7 fabric paid for).
        assert!(matches!(lot.claim_foreign(1, 2, |_| false), Claim::Deferred));
        lot.age_owned(0);
        assert!(matches!(lot.claim_foreign(1, 2, |_| false), Claim::Deferred));
        // Age alone eventually qualifies the entry, so a page-starved but
        // idle owner can still be relieved (no livelock).
        lot.age_owned(0);
        let Claim::Taken(owner, p) = lot.claim_foreign(1, 2, |_| false) else {
            panic!("an entry parked past min_age must be claimable");
        };
        assert_eq!((owner, p.req.id), (0, 1));
        // A backlogged owner is not coming back for its entry: the other
        // half of the gate takes even a brand-new park immediately.
        lot.push_back(0, parked_stub(2));
        let Claim::Taken(owner, p) = lot.claim_foreign(1, 2, |_| true) else {
            panic!("a busy owner's entry must be claimable regardless of age");
        };
        assert_eq!((owner, p.req.id), (0, 2));
        // Own entries are never foreign, whatever the gate says.
        lot.push_back(1, parked_stub(3));
        assert!(matches!(lot.claim_foreign(1, 0, |_| true), Claim::Empty));
    }

    #[test]
    fn dispatch_routes_affine_toward_the_published_prefix_holder() {
        let mut d = stub_dispatcher(2, vec![]);
        let directory = Arc::new(PrefixDirectory::new(2));
        d.directory = Some(Arc::clone(&directory));
        // Node 1 publishes the chains of the padded [1, 2, 3] window —
        // exactly what dummy_request submits.
        let window = padded_window(&[1, 2, 3], d.prefill_t).unwrap();
        directory.publish(1, window_chain_hashes(&window, d.block_positions));
        let now = Instant::now();
        let (req, _reply) = dummy_request(1);
        std::mem::forget(_reply);
        d.dispatch(TenantRegistry::DEFAULT, req, now);
        assert!(d.queues.try_pop(1).is_some(), "the warm card must win the route");
        assert_eq!(d.node_metrics[1].lock().unwrap().affine_routes, 1);
        assert_eq!(d.node_metrics[0].lock().unwrap().affine_routes, 0);
        // A prompt matching nothing published falls back to the plain
        // policy (round-robin from node 0) and books no affine route.
        let (mut req, _r2) = dummy_request(2);
        std::mem::forget(_r2);
        req.prompt = vec![9, 9, 9];
        d.dispatch(TenantRegistry::DEFAULT, req, now);
        assert!(d.queues.try_pop(0).is_some());
        assert_eq!(d.node_metrics[0].lock().unwrap().affine_routes, 0);
    }

    /// Drive the fleet KV fabric analytically: two cards, a cyclic
    /// three-family workload sharing a 512-token prefix, residency capped
    /// at two sequences per card (releasing the oldest, as retirement
    /// would). Returns (fleet prefix hits, goodput in tokens per
    /// simulated second).
    fn run_fabric_fleet(affinity: bool) -> (usize, f64) {
        const BLOCK: usize = 16;
        const PREFILL_T: usize = 1024;
        const SHARED: usize = 512;
        const DECODE: usize = 64;
        let overlay = test_overlay();
        let mut fleet = Fleet::uniform(2, 1.0, RoutePolicy::RoundRobin);
        let directory = PrefixDirectory::new(2);
        let mut pagers = [
            KvPager::new(BLOCK, 1024, 160 * BLOCK as u64 * 1024, 0).unwrap(),
            KvPager::new(BLOCK, 1024, 160 * BLOCK as u64 * 1024, 0).unwrap(),
        ];
        // This harness pins the PR 7 live-shared baseline: refcount zero
        // frees, so only concurrently resident sequences share. The cached
        // tier's fleet lift is pinned separately by the returning-user
        // acceptance test below.
        pagers[0].set_retention(false);
        pagers[1].set_retention(false);
        let mut resident: [Vec<SeqKv>; 2] = [Vec::new(), Vec::new()];
        let mut hits_total = 0usize;
        let mut sim_s = 0.0f64;
        for i in 0..12usize {
            let family = i % 3;
            let mut window: Vec<i32> = (1..=SHARED as i32).collect();
            window.extend(
                (0..(PREFILL_T - SHARED)).map(|p| (1000 * (family + 1) + p) as i32),
            );
            let node = if affinity {
                fleet.route_affine(&directory.match_depths(&window_chain_hashes(
                    &window,
                    BLOCK,
                )))
            } else {
                fleet.route()
            };
            let (kv, hits) =
                pagers[node].admit_prompt(&window).expect("card has page headroom");
            hits_total += hits;
            let cached = (hits * BLOCK).min(PREFILL_T);
            sim_s += overlay.prefill_s_per_token * (PREFILL_T - cached) as f64
                + overlay.decode_s_per_token * DECODE as f64;
            resident[node].push(kv);
            if resident[node].len() > 2 {
                let oldest = resident[node].remove(0);
                pagers[node].release(oldest).unwrap();
                fleet.complete(node);
            }
            directory.publish(node, pagers[node].index_hashes());
        }
        (hits_total, 12.0 * DECODE as f64 / sim_s)
    }

    #[test]
    fn fabric_affinity_beats_plain_routing_on_a_shared_prefix_fleet() {
        // The headline acceptance pin: on a two-card fleet serving three
        // request families behind a shared 512-token prefix, affine
        // routing converges each family onto one card (full 64-block hits
        // from the third arrival on), while round-robin keeps splitting
        // families across cards and only ever reuses the shared half.
        let (hits_on, goodput_on) = run_fabric_fleet(true);
        let (hits_off, goodput_off) = run_fabric_fleet(false);
        assert_eq!(hits_on, 576);
        assert_eq!(hits_off, 320);
        assert!(
            hits_on as f64 >= 1.5 * hits_off as f64,
            "affinity must win fleet prefix hits by >= 1.5x: {hits_on} vs {hits_off}"
        );
        assert!(
            goodput_on > goodput_off,
            "affinity must strictly win goodput: {goodput_on} vs {goodput_off}"
        );
    }

    /// Drive the returning-user workload analytically: two cards, eight
    /// users behind a shared 256-token system prompt, each coming back
    /// for a second turn after their first has retired (residency capped
    /// at two sequences per card, releasing the oldest as retirement
    /// would). With retention on, a returning user's released private
    /// history is resurrected from the radix cache; under the
    /// `--no-kv-cache` ablation refcount zero freed it, so only the
    /// live-shared system prompt hits. Returns (fleet prefix hits,
    /// resurrected blocks, goodput in tokens per simulated second).
    fn run_returning_users(retention: bool) -> (usize, usize, f64) {
        const BLOCK: usize = 16;
        const PREFILL_T: usize = 1024;
        const SHARED: usize = 256;
        const DECODE: usize = 64;
        const USERS: usize = 8;
        let overlay = test_overlay();
        let directory = PrefixDirectory::new(2);
        let mut pagers = [
            KvPager::new(BLOCK, 1024, 600 * BLOCK as u64 * 1024, 0).unwrap(),
            KvPager::new(BLOCK, 1024, 600 * BLOCK as u64 * 1024, 0).unwrap(),
        ];
        pagers[0].set_retention(retention);
        pagers[1].set_retention(retention);
        let mut resident: [Vec<SeqKv>; 2] = [Vec::new(), Vec::new()];
        let mut hits_total = 0usize;
        let mut sim_s = 0.0f64;
        for _turn in 0..2 {
            for user in 0..USERS {
                let mut window: Vec<i32> = (1..=SHARED as i32).collect();
                window.extend(
                    (0..(PREFILL_T - SHARED)).map(|p| (1000 * (user + 1) + p) as i32),
                );
                let depths =
                    directory.match_depths(&window_chain_hashes(&window, BLOCK));
                let node = if depths[0] >= depths[1] { 0 } else { 1 };
                let (kv, hits) =
                    pagers[node].admit_prompt(&window).expect("card has page headroom");
                hits_total += hits;
                let cached = (hits * BLOCK).min(PREFILL_T);
                sim_s += overlay.prefill_s_per_token * (PREFILL_T - cached) as f64
                    + overlay.decode_s_per_token * DECODE as f64;
                resident[node].push(kv);
                if resident[node].len() > 2 {
                    let oldest = resident[node].remove(0);
                    pagers[node].release(oldest).unwrap();
                }
                directory.publish(node, pagers[node].index_hashes());
            }
        }
        let resurrected = pagers
            .iter()
            .map(|p| p.prefix_stats().resurrected_blocks as usize)
            .sum();
        (hits_total, resurrected, (2 * USERS * DECODE) as f64 / sim_s)
    }

    #[test]
    fn returning_users_resurrect_their_kv_across_the_fleet_acceptance() {
        // The radix-cache acceptance pin (the `serve_radix_cache` bench
        // row's analytical twin). Turn one is identical in both arms: the
        // first user misses cold (0 hits) and the next seven each share
        // the 16-block system prompt (7 x 16 = 112). On the second turn
        // every user's full 64-block window is resident with retention on
        // (8 x 64 = 512, of which 8 x 48 private blocks are resurrected
        // from the cached tier), while the ablation re-prefills everything
        // but the live-shared prompt (8 x 16 = 128).
        let (hits_on, resurrected_on, goodput_on) = run_returning_users(true);
        let (hits_off, resurrected_off, goodput_off) = run_returning_users(false);
        assert_eq!(hits_on, 112 + 512);
        assert_eq!(resurrected_on, 8 * 48);
        assert_eq!(hits_off, 112 + 128);
        assert_eq!(resurrected_off, 0);
        assert!(
            hits_on as f64 >= 1.5 * hits_off as f64,
            "retention must win fleet prefix hits by >= 1.5x: {hits_on} vs {hits_off}"
        );
        assert!(
            goodput_on > goodput_off,
            "resurrected prefill must show up as goodput: {goodput_on} vs {goodput_off}"
        );
    }
}
