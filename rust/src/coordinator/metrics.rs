//! Serving metrics: counters, latency distribution, and the simulated
//! device-time overlay.

/// Online latency/throughput accumulator with fixed percentile tracking
/// (stores samples; edge-node request volumes make this fine).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub errors: u64,
    pub tokens_out: u64,
    latencies_s: Vec<f64>,
    pub wall_prefill_s: f64,
    pub wall_decode_s: f64,
    /// Simulated CMP 170HX device seconds for the same workload.
    pub simulated_device_s: f64,
    pub batches: u64,
    batch_sizes: Vec<usize>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_response(&mut self, latency_s: f64, tokens: usize, ok: bool) {
        self.requests += 1;
        if !ok {
            self.errors += 1;
        }
        self.tokens_out += tokens as u64;
        self.latencies_s.push(latency_s);
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batch_sizes.push(size);
    }

    /// Latency percentile (0.0–1.0). None when empty.
    pub fn latency_pct(&self, p: f64) -> Option<f64> {
        if self.latencies_s.is_empty() {
            return None;
        }
        let mut xs = self.latencies_s.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((xs.len() as f64 - 1.0) * p).round() as usize;
        Some(xs[idx])
    }

    pub fn mean_latency(&self) -> Option<f64> {
        if self.latencies_s.is_empty() {
            None
        } else {
            Some(self.latencies_s.iter().sum::<f64>() / self.latencies_s.len() as f64)
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    /// Decode throughput over the measured wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        let t = self.wall_prefill_s + self.wall_decode_s;
        if t == 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / t
        }
    }

    /// Speed ratio: how much faster/slower the simulated CMP device is than
    /// this host for the same served work.
    pub fn sim_speedup_vs_host(&self) -> Option<f64> {
        if self.simulated_device_s == 0.0 {
            None
        } else {
            Some((self.wall_prefill_s + self.wall_decode_s) / self.simulated_device_s)
        }
    }

    /// Render a summary block.
    pub fn render(&self) -> String {
        format!(
            "requests={} errors={} tokens={} mean_batch={:.2}\n\
             latency mean={:.1}ms p50={:.1}ms p99={:.1}ms\n\
             host: prefill {:.3}s decode {:.3}s → {:.1} tok/s\n\
             simulated CMP 170HX device time: {:.4}s ({}× host)",
            self.requests,
            self.errors,
            self.tokens_out,
            self.mean_batch_size(),
            self.mean_latency().unwrap_or(0.0) * 1e3,
            self.latency_pct(0.5).unwrap_or(0.0) * 1e3,
            self.latency_pct(0.99).unwrap_or(0.0) * 1e3,
            self.wall_prefill_s,
            self.wall_decode_s,
            self.tokens_per_sec(),
            self.simulated_device_s,
            self.sim_speedup_vs_host()
                .map(|s| format!("{s:.1}"))
                .unwrap_or_else(|| "-".into()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_order_correctly() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record_response(i as f64, 1, true);
        }
        assert!(m.latency_pct(0.5).unwrap() <= m.latency_pct(0.99).unwrap());
        assert_eq!(m.latency_pct(0.0).unwrap(), 1.0);
        assert_eq!(m.latency_pct(1.0).unwrap(), 100.0);
    }

    #[test]
    fn empty_metrics_are_none_or_zero() {
        let m = Metrics::new();
        assert!(m.latency_pct(0.5).is_none());
        assert!(m.mean_latency().is_none());
        assert_eq!(m.tokens_per_sec(), 0.0);
    }

    #[test]
    fn errors_counted_separately() {
        let mut m = Metrics::new();
        m.record_response(0.1, 0, false);
        m.record_response(0.1, 5, true);
        assert_eq!(m.requests, 2);
        assert_eq!(m.errors, 1);
        assert_eq!(m.tokens_out, 5);
    }

    #[test]
    fn render_contains_key_fields() {
        let mut m = Metrics::new();
        m.record_response(0.25, 8, true);
        m.record_batch(2);
        m.wall_decode_s = 1.0;
        m.simulated_device_s = 0.1;
        let s = m.render();
        assert!(s.contains("requests=1"));
        assert!(s.contains("simulated CMP 170HX"));
    }
}
