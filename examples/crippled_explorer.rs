//! §5.4 pathway explorer: what each theoretical unlock route would buy.
//!
//! The paper sketches three recovery pathways — (a) cracked driver,
//! (b) open-source driver / GSP partial unlock, (c) hand-written CUDA
//! avoiding FMA. Each is a throttle profile; this example sweeps them
//! across the precision suite and the llama-bench grid.
//!
//! Run: `cargo run --release --example crippled_explorer`

use cmphx::bench::{openclbench, Precision};
use cmphx::device::{registry, ThrottleProfile};
use cmphx::isa::pass::FmadPolicy;
use cmphx::llm::llamabench::LlamaBench;
use cmphx::llm::quant;

fn main() {
    let pathways: Vec<(&str, ThrottleProfile, FmadPolicy)> = vec![
        (
            "stock (limiter, default build)",
            ThrottleProfile::cmp170hx_limiter(),
            FmadPolicy::Fused,
        ),
        (
            "§2.2 -fmad=false rebuild",
            ThrottleProfile::cmp170hx_limiter(),
            FmadPolicy::Decomposed,
        ),
        (
            "§5.4(b) GSP partial unlock",
            ThrottleProfile::gsp_partial_unlock(),
            FmadPolicy::Fused,
        ),
        (
            "§5.4(a) full driver crack",
            ThrottleProfile::native(),
            FmadPolicy::Fused,
        ),
    ];

    println!(
        "{:<34} {:>9} {:>9} {:>9} {:>9}",
        "pathway", "FP32", "FP16", "FP64", "INT8"
    );
    for (name, profile, policy) in &pathways {
        let dev = registry::cmp170hx().with_throttle(profile.clone());
        let fp32 = openclbench::peak(&dev, Precision::Fp32, *policy).tflops();
        let fp16 = openclbench::peak(&dev, Precision::Fp16Half2, *policy).tflops();
        let fp64 = openclbench::peak(&dev, Precision::Fp64, *policy).tflops();
        let int8 = openclbench::peak(&dev, Precision::Int8, *policy).tiops();
        println!("{name:<34} {fp32:>9.3} {fp16:>9.2} {fp64:>9.3} {int8:>9.2}");
    }

    println!("\nllama-bench impact (Qwen2.5-1.5B q4_k_m):");
    println!(
        "{:<34} {:>12} {:>12} {:>10}",
        "pathway", "prefill t/s", "decode t/s", "tok/s/W"
    );
    let bench = LlamaBench::default();
    for (name, profile, policy) in &pathways {
        let dev = registry::cmp170hx().with_throttle(profile.clone());
        let r = bench.run(&dev, &quant::Q4_K_M, *policy);
        println!(
            "{name:<34} {:>12.0} {:>12.0} {:>10.2}",
            r.prefill_tps, r.decode_tps, r.tokens_per_watt
        );
    }

    println!(
        "\nConclusion (§5.4): the -fmad rebuild captures most of the value the\n\
         risky pathways promise for quantized inference — decode is bandwidth-\n\
         bound and bandwidth was never throttled."
    );
}
