"""Layer-1 Pallas kernels (build-time only; never on the request path).

Kernels:
- ``mixbench``  — the paper's mixed-operational-intensity hot loop, with
  ``fused``/``decomposed`` rounding variants mirroring the ``-fmad`` policy;
- ``qmatmul``   — q8_0 block-dequantized matmul (the llama.cpp MMQ analog);
- ``attention`` — GQA single-token decode attention over a KV cache.

Every kernel has a pure-jnp oracle in ``ref.py`` and runs with
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls).
"""
