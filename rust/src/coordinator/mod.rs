//! L3 serving coordinator — the §6.2 edge-node deployment, real.
//!
//! A threaded (std::thread + mpsc; no async runtime in the offline crate
//! set) inference server over the AOT artifacts: requests enter a bounded
//! queue, a [`batcher`] groups them under a size/latency window, a worker
//! owning the [`crate::runtime::ModelRuntime`] prefills each sequence into
//! a [`kv`] slot and interleaves decode steps round-robin ([`scheduler`])
//! until every sequence finishes. [`metrics`] records real wall-clock
//! latencies *and* the simulated CMP 170HX device-time overlay, and
//! [`router`] spreads load across a fleet of (simulated) cards.
//!
//! Python never runs here: the executables carry the weights.

pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use batcher::{Batcher, BatchPolicy};
pub use kv::KvSlots;
pub use metrics::Metrics;
pub use request::{GenRequest, GenResponse};
pub use router::{Fleet, RoutePolicy};
pub use server::{Server, ServerConfig, ServerHandle};
