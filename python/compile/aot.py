"""AOT lowering: jax → HLO **text** → artifacts/ for the Rust runtime.

HLO text (NOT ``lowered.compile()``/proto ``.serialize()``) is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (``make artifacts``):
- ``prefill.hlo.txt``         tokens i32[T] -> (logits[T,V], k_cache, v_cache)
- ``decode.hlo.txt``          (token i32[], k_cache, v_cache, pos i32[]) ->
                              (logits[V], k_cache, v_cache)
- ``mixbench_fused.hlo.txt``  (x f32[N], y f32[N]) -> chain with FMA rounding
- ``mixbench_nofma.hlo.txt``  same chain, -fmad=false rounding
- ``qmatmul.hlo.txt``         (x f32[M,K], qw i8[K,N], s f32[K/32,N]) -> f32[M,N]
- ``goldens.json``            inputs + expected outputs for rust/tests
- ``manifest.json``           artifact inventory

Model weights are baked into the HLO as constants (the deployment shape the
paper's §6.2 edge node wants: the binary + one artifact directory, no
Python anywhere near the request path).
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model as M  # noqa: E402
from .kernels import mixbench as mb  # noqa: E402
from .kernels import qmatmul as qm  # noqa: E402
from .kernels import ref  # noqa: E402

PREFILL_T = 16
MIXBENCH_N = 1024
MIXBENCH_ITERS = 64
QM_M, QM_K, QM_N = 16, 64, 96


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (the 0.5.1-safe path).

    ``as_hlo_text(True)`` = print_large_constants: the baked model weights
    must survive the text round-trip (the default elides them as ``{...}``).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def build_artifacts(out_dir: str, seed: int = 0) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    cfg = M.Config()
    params = M.init_params(cfg, seed)
    manifest = {"model": "tiny-qwen", "seed": seed, "entries": {}}

    def emit(name, fn, *example_args):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                f"{a.dtype}{list(a.shape)}" for a in example_args
            ],
            "bytes": len(text),
        }
        return path

    # --- L2 model entries (weights baked as constants) ---
    tokens_spec = jax.ShapeDtypeStruct((PREFILL_T,), jnp.int32)
    emit("prefill", lambda toks: M.prefill(cfg, params, toks), tokens_spec)

    cache_spec = jax.ShapeDtypeStruct(
        (cfg.layers, cfg.max_ctx, cfg.kv_heads, cfg.head_dim), jnp.float32
    )
    tok_spec = jax.ShapeDtypeStruct((), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    emit(
        "decode",
        lambda tok, kc, vc, pos: M.decode_step(cfg, params, tok, kc, vc, pos),
        tok_spec,
        cache_spec,
        cache_spec,
        pos_spec,
    )

    # --- L1 kernel entries ---
    vec_spec = jax.ShapeDtypeStruct((MIXBENCH_N,), jnp.float32)
    emit(
        "mixbench_fused",
        lambda x, y: (mb.mixbench(x, y, MIXBENCH_ITERS, True),),
        vec_spec,
        vec_spec,
    )
    emit(
        "mixbench_nofma",
        lambda x, y: (mb.mixbench(x, y, MIXBENCH_ITERS, False),),
        vec_spec,
        vec_spec,
    )
    emit(
        "qmatmul",
        lambda x, w, s: (qm.qmatmul(x, w, s),),
        jax.ShapeDtypeStruct((QM_M, QM_K), jnp.float32),
        jax.ShapeDtypeStruct((QM_K, QM_N), jnp.int8),
        jax.ShapeDtypeStruct((QM_K // ref.Q8_BLOCK, QM_N), jnp.float32),
    )

    # --- goldens for the rust integration tests ---
    rng = np.random.default_rng(seed)
    prompt = np.arange(1, PREFILL_T + 1, dtype=np.int32) % cfg.vocab
    logits, kc, vc = M.prefill(cfg, params, jnp.asarray(prompt))
    gen = M.greedy_generate(cfg, params, jnp.asarray(prompt), 8)

    # Chaotic regime of t ← t² + y (y < -1.4): rounding-mode differences
    # amplify instead of converging to a shared fixed point, so the golden
    # actually witnesses the fused-vs-decomposed numerics.
    mx = rng.uniform(-1.0, 1.0, MIXBENCH_N).astype(np.float32)
    my = rng.uniform(-1.8, -1.5, MIXBENCH_N).astype(np.float32)
    mix_fused = np.asarray(mb.mixbench(jnp.asarray(mx), jnp.asarray(my), MIXBENCH_ITERS, True))
    mix_nofma = np.asarray(mb.mixbench(jnp.asarray(mx), jnp.asarray(my), MIXBENCH_ITERS, False))

    qx = rng.normal(size=(QM_M, QM_K)).astype(np.float32)
    qw_dense = rng.normal(size=(QM_K, QM_N)).astype(np.float32)
    qw, qs = ref.quantize_q8(jnp.asarray(qw_dense))
    qout = np.asarray(ref.qmatmul(jnp.asarray(qx), qw, qs))

    goldens = {
        "config": {
            "vocab": cfg.vocab,
            "hidden": cfg.hidden,
            "layers": cfg.layers,
            "q_heads": cfg.q_heads,
            "kv_heads": cfg.kv_heads,
            "head_dim": cfg.head_dim,
            "ffn": cfg.ffn,
            "max_ctx": cfg.max_ctx,
            "prefill_t": PREFILL_T,
        },
        "prompt": prompt.tolist(),
        "prefill_last_logits": np.asarray(logits[-1]).tolist(),
        "prefill_argmax": int(np.argmax(np.asarray(logits[-1]))),
        "greedy_tokens": gen,
        "mixbench": {
            "n": MIXBENCH_N,
            "iters": MIXBENCH_ITERS,
            "x": mx.tolist(),
            "y": my.tolist(),
            "fused_head": mix_fused[:32].tolist(),
            "nofma_head": mix_nofma[:32].tolist(),
            "max_divergence": float(np.max(np.abs(mix_fused - mix_nofma))),
        },
        "qmatmul": {
            "m": QM_M,
            "k": QM_K,
            "n": QM_N,
            "x": qx.flatten().tolist(),
            "qw": np.asarray(qw).flatten().tolist(),
            "scales": np.asarray(qs).flatten().tolist(),
            "out_head": qout.flatten()[:64].tolist(),
            "out_checksum": float(qout.sum()),
        },
    }
    with open(os.path.join(out_dir, "goldens.json"), "w") as f:
        json.dump(goldens, f)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    manifest = build_artifacts(args.out, args.seed)
    for name, e in manifest["entries"].items():
        print(f"wrote {e['file']}: {e['bytes']} chars, args {e['args']}")


if __name__ == "__main__":
    main()
