"""Pallas q8_0 block-dequantized matmul — the llama.cpp MMQ analog.

CUDA MMQ assigns a thread-block per output tile and dequantizes q8_0 blocks
from shared memory with DP4A dots. TPU rethink: the output is tiled
(BM × BN) across the grid with the full K dimension resident in VMEM per
program; dequant (int8 × per-block scale) fuses into the kernel prologue and
the dot targets the MXU with an f32 accumulator. Per-block scales live in a
``[K/32, N]`` array so the expansion is a cheap ``jnp.repeat`` in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import Q8_BLOCK

BM = 16
BN = 32


def _kernel(x_ref, q_ref, s_ref, o_ref):
    x = x_ref[...]  # [BM, K]
    q = q_ref[...]  # [K, BN] int8
    s = s_ref[...]  # [K/32, BN] f32
    w = q.astype(jnp.float32) * jnp.repeat(s, Q8_BLOCK, axis=0)
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32)


@jax.jit
def qmatmul(x, qweights, scales):
    """x [M, K] f32 @ q8_0(qweights [K, N] i8, scales [K/32, N] f32).

    M must be a multiple of BM (16) and N of BN (32); K of 32.
    """
    m, k = x.shape
    k2, n = qweights.shape
    assert k == k2 and k % Q8_BLOCK == 0
    assert m % BM == 0 and n % BN == 0, f"M={m} % {BM}, N={n} % {BN}"
    grid = (m // BM, n // BN)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((k // Q8_BLOCK, BN), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        interpret=True,
    )(x, qweights, scales)


@functools.partial(jax.jit, static_argnames=())
def qmatmul_padded(x, qweights, scales):
    """qmatmul for arbitrary M: pads M up to the next multiple of BM."""
    m = x.shape[0]
    pad = (-m) % BM
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = qmatmul(x, qweights, scales)
    return out[:m]
