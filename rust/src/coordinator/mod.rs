//! L3 serving coordinator — the §6.2 edge-node deployment, real, at fleet
//! scale.
//!
//! A threaded (std::thread + mpsc; no async runtime in the offline crate
//! set) inference fleet over the AOT artifacts: requests enter a bounded
//! queue, the dispatch stage routes each one across N per-card workers via
//! a [`router::Fleet`] policy (dead workers are marked unhealthy and
//! excluded, with the in-hand request rerouted), and every worker runs
//! **continuous batching over paged KV** — sequences join its decode
//! round whenever the [`kv::KvPager`] can hold their prefill window
//! ([`scheduler::plan_admission`]), grow VRAM block-by-block as they
//! decode, and under page pressure the longest-remaining sequence is
//! **preempted and requeued** ([`scheduler::plan_eviction`]): KV dropped,
//! prefill recomputed on resume, vLLM-style, so long generations cannot
//! starve short ones. [`batcher::BatchPolicy`] carries the admission and
//! paging knobs. Each node owns its own runtime, pager sized to its
//! card's VRAM, and a per-card simulated device-time/energy overlay, so
//! [`metrics::FleetMetrics`] reports fleet-wide tokens/s, latency
//! percentiles, tokens/joule, and the preemption/recompute tax for any
//! mix of registry cards.
//!
//! Python never runs here: the executables carry the weights.

pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use batcher::BatchPolicy;
pub use kv::{KvPager, SeqKv};
pub use metrics::{FleetMetrics, Metrics};
pub use request::{GenRequest, GenResponse};
pub use router::{Fleet, RoutePolicy};
pub use server::{NodeConfig, Server, ServerConfig, ServerHandle};
