"""L2 model tests: architecture pieces, prefill/decode consistency, and
generation determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

settings.register_profile("ci", deadline=None, max_examples=10)
settings.load_profile("ci")

CFG = M.Config()


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


class TestPieces:
    def test_rmsnorm_unit_variance(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 256)) * 7.0, jnp.float32)
        out = M.rmsnorm(x, jnp.ones(256), 1e-6)
        rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_rope_preserves_norm(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(6, 8, 32)), jnp.float32)
        out = M.rope(x, jnp.arange(6), CFG.rope_theta)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(out), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1),
            rtol=1e-5,
        )

    def test_rope_position_zero_is_identity(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(1, 8, 32)), jnp.float32)
        out = M.rope(x, jnp.zeros(1, jnp.int32), CFG.rope_theta)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)

    def test_rope_is_relative(self):
        # <rope(q, m), rope(k, n)> depends only on m - n.
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(1, 1, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, 32)), jnp.float32)

        def dot(m, n):
            qm = M.rope(q, jnp.asarray([m]), CFG.rope_theta)
            kn = M.rope(k, jnp.asarray([n]), CFG.rope_theta)
            return float(jnp.sum(qm * kn))

        assert abs(dot(5, 3) - dot(9, 7)) < 1e-4
        assert abs(dot(5, 3) - dot(6, 3)) > 1e-6  # different offsets differ

    def test_param_shapes(self, params):
        assert params["embed"].shape == (CFG.vocab, CFG.hidden)
        assert len(params["layers"]) == CFG.layers
        layer = params["layers"][0]
        assert layer["w_gate_q"].dtype == jnp.int8
        assert layer["w_gate_s"].shape == (CFG.hidden // 32, CFG.ffn)


class TestPrefillDecode:
    def test_prefill_shapes(self, params):
        tokens = jnp.arange(10, dtype=jnp.int32)
        logits, kc, vc = M.prefill(CFG, params, tokens)
        assert logits.shape == (10, CFG.vocab)
        assert kc.shape == (CFG.layers, CFG.max_ctx, CFG.kv_heads, CFG.head_dim)

    def test_prefill_equals_sequential_decode(self, params):
        # The paper's two inference phases must agree: processing a prompt
        # in parallel (prefill) and feeding it token-by-token through the
        # decode path produce the same logits.
        prompt = jnp.asarray([3, 1, 4, 1, 5, 9, 2, 6], jnp.int32)
        logits, _, _ = M.prefill(CFG, params, prompt)
        kc, vc = M.empty_cache(CFG)
        for t in range(len(prompt)):
            lg, kc, vc = M.decode_step(CFG, params, prompt[t], kc, vc, jnp.int32(t))
            np.testing.assert_allclose(lg, logits[t], rtol=3e-4, atol=3e-4)

    def test_decode_is_causal(self, params):
        # Changing cache rows at or beyond `pos` must not change the output.
        prompt = jnp.asarray([7, 8, 9, 10], jnp.int32)
        _, kc, vc = M.prefill(CFG, params, prompt)
        lg1, _, _ = M.decode_step(CFG, params, jnp.int32(11), kc, vc, jnp.int32(4))
        kc2 = kc.at[:, 10:].set(123.0)
        vc2 = vc.at[:, 10:].set(-123.0)
        lg2, _, _ = M.decode_step(CFG, params, jnp.int32(11), kc2, vc2, jnp.int32(4))
        np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg2))

    def test_decode_appends_cache_row(self, params):
        kc, vc = M.empty_cache(CFG)
        _, kc2, vc2 = M.decode_step(CFG, params, jnp.int32(5), kc, vc, jnp.int32(0))
        assert np.any(np.asarray(kc2)[:, 0] != 0.0)
        np.testing.assert_array_equal(np.asarray(kc2)[:, 1:], np.asarray(kc)[:, 1:])

    @given(seed=st.integers(0, 2**31), t=st.integers(1, 12))
    def test_hypothesis_prefill_finite(self, seed, t):
        params = M.init_params(CFG, seed=seed % 3)  # cache a few param sets
        rng = np.random.default_rng(seed)
        tokens = jnp.asarray(rng.integers(0, CFG.vocab, t), jnp.int32)
        logits, _, _ = M.prefill(CFG, params, tokens)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_generation_is_deterministic(self, params):
        prompt = jnp.asarray([1, 2, 3, 4], jnp.int32)
        a = M.greedy_generate(CFG, params, prompt, 6)
        b = M.greedy_generate(CFG, params, prompt, 6)
        assert a == b
        assert all(0 <= t < CFG.vocab for t in a)

    def test_different_prompts_diverge(self, params):
        a = M.greedy_generate(CFG, params, jnp.asarray([1, 2, 3, 4], jnp.int32), 6)
        b = M.greedy_generate(CFG, params, jnp.asarray([9, 8, 7, 6], jnp.int32), 6)
        assert a != b
