//! Step scheduling across in-flight sequences.
//!
//! The decode loop must decide which active sequences advance each
//! iteration. Two policies:
//! - [`StepPolicy::RoundRobin`] — fair interleaving (latency-balanced);
//! - [`StepPolicy::ShortestFirst`] — drain sequences closest to completion
//!   first (frees KV slots sooner; throughput-biased under slot pressure).

/// An in-flight sequence the scheduler sees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqView {
    pub seq: usize,
    pub generated: usize,
    pub target: usize,
}

impl SeqView {
    pub fn remaining(&self) -> usize {
        self.target.saturating_sub(self.generated)
    }

    pub fn done(&self) -> bool {
        self.remaining() == 0
    }
}

/// Scheduling policy for the decode loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPolicy {
    RoundRobin,
    ShortestFirst,
}

/// Order the active (not-done) sequences for the next decode round.
pub fn plan_round(policy: StepPolicy, seqs: &[SeqView]) -> Vec<usize> {
    let mut active: Vec<&SeqView> = seqs.iter().filter(|s| !s.done()).collect();
    match policy {
        StepPolicy::RoundRobin => {}
        StepPolicy::ShortestFirst => {
            active.sort_by_key(|s| s.remaining());
        }
    }
    active.iter().map(|s| s.seq).collect()
}

/// Total decode rounds a batch needs (the longest target governs — decode
/// is serial per sequence).
pub fn rounds_needed(seqs: &[SeqView]) -> usize {
    seqs.iter().map(|s| s.remaining()).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    fn seq(seq: usize, generated: usize, target: usize) -> SeqView {
        SeqView {
            seq,
            generated,
            target,
        }
    }

    #[test]
    fn round_robin_preserves_order_and_skips_done() {
        let seqs = [seq(0, 2, 4), seq(1, 3, 3), seq(2, 0, 5)];
        assert_eq!(plan_round(StepPolicy::RoundRobin, &seqs), vec![0, 2]);
    }

    #[test]
    fn shortest_first_orders_by_remaining() {
        let seqs = [seq(0, 0, 9), seq(1, 0, 2), seq(2, 0, 5)];
        assert_eq!(plan_round(StepPolicy::ShortestFirst, &seqs), vec![1, 2, 0]);
    }

    #[test]
    fn rounds_needed_is_max_remaining() {
        let seqs = [seq(0, 1, 4), seq(1, 0, 2)];
        assert_eq!(rounds_needed(&seqs), 3);
        assert_eq!(rounds_needed(&[]), 0);
    }

    #[test]
    fn prop_every_unfinished_sequence_is_planned_exactly_once() {
        forall(0x5C_ED, 300, |rng: &mut Rng| {
            let n = rng.range(0, 12) as usize;
            let seqs: Vec<SeqView> = (0..n)
                .map(|i| {
                    let target = rng.range(0, 8) as usize;
                    seq(i, rng.range(0, 8) as usize, target)
                })
                .collect();
            let policy = if rng.chance(0.5) {
                StepPolicy::RoundRobin
            } else {
                StepPolicy::ShortestFirst
            };
            let plan = plan_round(policy, &seqs);
            let expected: Vec<usize> =
                seqs.iter().filter(|s| !s.done()).map(|s| s.seq).collect();
            let mut sorted = plan.clone();
            sorted.sort_unstable();
            let mut exp_sorted = expected.clone();
            exp_sorted.sort_unstable();
            assert_eq!(sorted, exp_sorted, "plan must cover active set exactly");
        });
    }
}
