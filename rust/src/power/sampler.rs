//! `nvidia-smi`-style power sampler.
//!
//! The paper records power with nvidia-smi polling during llama-bench runs
//! (§4.4). This sampler accumulates (power, duration) observations from the
//! timing engine and reports the same statistics a polling loop would:
//! time-weighted mean, peak, and total energy.

/// Accumulates power observations weighted by duration.
#[derive(Clone, Debug, Default)]
pub struct PowerSampler {
    samples: Vec<(f64, f64)>, // (watts, seconds)
}

impl PowerSampler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `watts` sustained for `seconds`.
    pub fn record(&mut self, watts: f64, seconds: f64) {
        assert!(watts >= 0.0 && seconds >= 0.0);
        if seconds > 0.0 {
            self.samples.push((watts, seconds));
        }
    }

    /// Total wall time observed.
    pub fn elapsed(&self) -> f64 {
        self.samples.iter().map(|(_, s)| s).sum()
    }

    /// Total energy, joules.
    pub fn energy_j(&self) -> f64 {
        self.samples.iter().map(|(w, s)| w * s).sum()
    }

    /// Time-weighted mean power, W (what nvidia-smi averaging reports).
    pub fn mean_w(&self) -> f64 {
        let t = self.elapsed();
        if t == 0.0 {
            0.0
        } else {
            self.energy_j() / t
        }
    }

    /// Peak observed power, W.
    pub fn peak_w(&self) -> f64 {
        self.samples.iter().map(|(w, _)| *w).fold(0.0, f64::max)
    }

    /// Work-per-energy figure of merit: `units` of work (e.g. tokens) over
    /// the observed window → units per joule. `tokens/W` at steady state is
    /// `units / elapsed / mean_w = units / energy`... × 1s; we report
    /// units/s/W which equals units/J.
    pub fn per_watt(&self, units: f64) -> f64 {
        let e = self.energy_j();
        if e == 0.0 {
            0.0
        } else {
            units / e
        }
    }

    pub fn reset(&mut self) {
        self.samples.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn mean_is_time_weighted() {
        let mut s = PowerSampler::new();
        s.record(100.0, 3.0);
        s.record(200.0, 1.0);
        assert_close(s.mean_w(), (300.0 + 200.0) / 4.0, 1e-12);
        assert_close(s.peak_w(), 200.0, 1e-12);
        assert_close(s.energy_j(), 500.0, 1e-12);
    }

    #[test]
    fn empty_sampler_reports_zero() {
        let s = PowerSampler::new();
        assert_eq!(s.mean_w(), 0.0);
        assert_eq!(s.energy_j(), 0.0);
        assert_eq!(s.per_watt(100.0), 0.0);
    }

    #[test]
    fn tokens_per_watt_equals_tokens_per_joule() {
        let mut s = PowerSampler::new();
        s.record(250.0, 2.0); // 500 J
        // 1000 tokens in 2 s at 250 W → (1000/2)/250 = 2 tokens/s/W = 1000/500.
        assert_close(s.per_watt(1000.0), 2.0, 1e-12);
    }

    #[test]
    fn zero_duration_samples_ignored() {
        let mut s = PowerSampler::new();
        s.record(500.0, 0.0);
        assert_eq!(s.elapsed(), 0.0);
        assert_eq!(s.peak_w(), 0.0);
    }

    #[test]
    fn reset_clears() {
        let mut s = PowerSampler::new();
        s.record(100.0, 1.0);
        s.reset();
        assert_eq!(s.energy_j(), 0.0);
    }
}
