//! Bounded flight-recorder ring journals and the fleet [`Tracer`].
//!
//! Each node owns a [`Journal`]: a mutex-guarded ring of the last
//! [`RING_CAP`] span events plus the node's simulated clock and round
//! counter. Emission is one short uncontended lock (push + stamp); the
//! dispatch stage drains every ring into the tracer's retained log on its
//! loop, so under normal operation the rings stay near-empty and nothing
//! is lost. When a ring does wrap between drains, the *oldest* entries
//! drop and a per-ring `dropped` counter records the gap — flight-recorder
//! semantics: the moments just before a crash are always present.
//!
//! [`Tracer::flight_dump`] snapshots a node's ring at the moment of a
//! chaos death, deadline miss, or terminal error: the ring's current
//! contents move into a [`FlightDump`] (reason + clock coordinates
//! attached) that the JSONL exporter writes as a single `flight_dump`
//! line. The tracer is cheap to disable: `enabled == false` makes every
//! emit/advance/sample an early return, which is the tracing-off arm of
//! the `serve_trace_overhead` bench ablation — and because every stamp is
//! simulated-clock, tracing on can never move the simulated numbers at
//! all (the analytic overhead bound).

use std::collections::VecDeque;
use std::sync::Mutex;

use super::series::{DispatchPoint, SeriesPoint};
use super::span::{SpanEvent, SpanKind, TraceId};

/// Ring capacity per node: deep enough to hold several busy rounds of a
/// full batch, small enough that a forgotten drain cannot grow unbounded.
pub const RING_CAP: usize = 4096;

struct Ring {
    events: VecDeque<SpanEvent>,
    /// Next sequence number — strictly increasing per node, never reused,
    /// so `(node, seq)` totally orders a node's history across wraps.
    seq: u64,
    /// Entries lost to ring wraps since the last drain.
    dropped: u64,
    /// The node's simulated clock, seconds.
    sim_now: f64,
    /// The node's engine round.
    round: u64,
}

/// One node's bounded span ring plus its simulated clock.
pub struct Journal {
    node: usize,
    cap: usize,
    inner: Mutex<Ring>,
}

impl Journal {
    fn new(node: usize, cap: usize) -> Self {
        assert!(cap > 0, "a flight recorder needs at least one slot");
        Journal {
            node,
            cap,
            inner: Mutex::new(Ring {
                events: VecDeque::new(),
                seq: 0,
                dropped: 0,
                sim_now: 0.0,
                round: 0,
            }),
        }
    }

    pub fn node(&self) -> usize {
        self.node
    }

    /// Append one event, stamped with the ring's next seq and the node's
    /// current (round, simulated-clock) coordinates.
    pub fn emit(&self, trace: TraceId, kind: SpanKind) {
        let mut r = self.inner.lock().unwrap();
        if r.events.len() == self.cap {
            r.events.pop_front();
            r.dropped += 1;
        }
        let ev = SpanEvent {
            seq: r.seq,
            node: self.node,
            round: r.round,
            sim_s: r.sim_now,
            trace,
            kind,
        };
        r.seq += 1;
        r.events.push_back(ev);
    }

    /// Advance the node's simulated clock by `d` seconds.
    pub fn advance(&self, d: f64) {
        debug_assert!(d >= 0.0, "the simulated clock is monotone");
        self.inner.lock().unwrap().sim_now += d;
    }

    /// Set the node's engine round (the worker's loop counter).
    pub fn set_round(&self, round: u64) {
        self.inner.lock().unwrap().round = round;
    }

    /// Current (round, simulated seconds).
    pub fn now(&self) -> (u64, f64) {
        let r = self.inner.lock().unwrap();
        (r.round, r.sim_now)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take everything buffered, plus the drop count accrued since the
    /// previous drain.
    fn drain(&self) -> (Vec<SpanEvent>, u64) {
        let mut r = self.inner.lock().unwrap();
        let dropped = std::mem::take(&mut r.dropped);
        (r.events.drain(..).collect(), dropped)
    }
}

/// A ring snapshot taken at a failure: what the node was doing in the
/// moments before it died / missed a deadline / failed a request.
#[derive(Clone, Debug, PartialEq)]
pub struct FlightDump {
    pub node: usize,
    pub reason: String,
    /// Node clock coordinates at the dump.
    pub round: u64,
    pub sim_s: f64,
    /// Ring-wrap losses since the last drain (a nonzero value means the
    /// dump's window is truncated at the old end).
    pub dropped: u64,
    pub events: Vec<SpanEvent>,
}

/// Everything the exporters consume, in canonical order: events sorted by
/// `(node, seq)` (drain interleaving cannot perturb the output), dumps and
/// samples in capture order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceSnapshot {
    pub events: Vec<SpanEvent>,
    pub dumps: Vec<FlightDump>,
    pub series: Vec<SeriesPoint>,
    pub dispatch: Vec<DispatchPoint>,
    /// Ring-wrap losses over the whole run, per node.
    pub dropped: Vec<u64>,
}

/// The fleet-wide trace collector: one [`Journal`] per node plus one for
/// the dispatch stage, the drained retained log, flight dumps, and the
/// per-round time-series. Shared as an `Arc` by the dispatcher, every
/// worker, and the server handle.
pub struct Tracer {
    enabled: bool,
    /// `journals[0..nodes]` are the workers'; the last entry is the
    /// dispatch stage's (no simulated clock of its own — queue-side
    /// events are stamped at sim 0 on its ring).
    journals: Vec<Journal>,
    drained: Mutex<Vec<SpanEvent>>,
    dumps: Mutex<Vec<FlightDump>>,
    series: Mutex<Vec<SeriesPoint>>,
    dispatch: Mutex<Vec<DispatchPoint>>,
    dropped: Mutex<Vec<u64>>,
}

impl Tracer {
    pub fn new(nodes: usize, cap: usize, enabled: bool) -> Self {
        Tracer {
            enabled,
            journals: (0..=nodes).map(|n| Journal::new(n, cap)).collect(),
            drained: Mutex::new(Vec::new()),
            dumps: Mutex::new(Vec::new()),
            series: Mutex::new(Vec::new()),
            dispatch: Mutex::new(Vec::new()),
            dropped: Mutex::new(vec![0; nodes + 1]),
        }
    }

    /// A disabled tracer for `nodes` cards: every call is an early return.
    pub fn off(nodes: usize) -> Self {
        Tracer::new(nodes, 1, false)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The pseudo-node index the dispatch stage journals under (one past
    /// the last worker).
    pub fn dispatch_node(&self) -> usize {
        self.journals.len() - 1
    }

    pub fn emit(&self, node: usize, trace: TraceId, kind: SpanKind) {
        if self.enabled {
            self.journals[node].emit(trace, kind);
        }
    }

    /// Advance `node`'s simulated clock by `d` seconds.
    pub fn advance(&self, node: usize, d: f64) {
        if self.enabled {
            self.journals[node].advance(d);
        }
    }

    /// Stamp `node`'s engine round.
    pub fn set_round(&self, node: usize, round: u64) {
        if self.enabled {
            self.journals[node].set_round(round);
        }
    }

    /// `node`'s current (round, simulated-seconds) clock coordinates.
    pub fn now(&self, node: usize) -> (u64, f64) {
        self.journals[node].now()
    }

    /// Move every ring's buffered events into the retained log — the
    /// dispatch stage calls this once per loop.
    pub fn drain(&self) {
        if !self.enabled {
            return;
        }
        let mut log = self.drained.lock().unwrap();
        let mut dropped = self.dropped.lock().unwrap();
        for (i, j) in self.journals.iter().enumerate() {
            let (evs, d) = j.drain();
            log.extend(evs);
            dropped[i] += d;
        }
    }

    /// Snapshot `node`'s ring into a [`FlightDump`] — called on chaos
    /// death, deadline miss, or terminal error. The dumped events leave
    /// the ring (they live in the dump from now on).
    pub fn flight_dump(&self, node: usize, reason: &str) {
        if !self.enabled {
            return;
        }
        let (round, sim_s) = self.journals[node].now();
        let (events, dropped) = self.journals[node].drain();
        self.dumps.lock().unwrap().push(FlightDump {
            node,
            reason: reason.to_string(),
            round,
            sim_s,
            dropped,
            events,
        });
    }

    /// Record one per-round fleet sample.
    pub fn sample(&self, p: SeriesPoint) {
        if self.enabled {
            self.series.lock().unwrap().push(p);
        }
    }

    /// Record one dispatch-stage sample (tenant deficits, outstanding).
    pub fn sample_dispatch(&self, p: DispatchPoint) {
        if self.enabled {
            self.dispatch.lock().unwrap().push(p);
        }
    }

    pub fn dump_count(&self) -> usize {
        self.dumps.lock().unwrap().len()
    }

    /// Drain everything and return the canonical snapshot the exporters
    /// consume. Events are sorted by `(node, seq)` so the output is
    /// independent of how drains interleaved across the run.
    pub fn snapshot(&self) -> TraceSnapshot {
        if !self.enabled {
            return TraceSnapshot::default();
        }
        self.drain();
        let mut events = self.drained.lock().unwrap().clone();
        events.sort_by_key(|e| (e.node, e.seq));
        TraceSnapshot {
            events,
            dumps: self.dumps.lock().unwrap().clone(),
            series: self.series.lock().unwrap().clone(),
            dispatch: self.dispatch.lock().unwrap().clone(),
            dropped: self.dropped.lock().unwrap().clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obsv::span::NODE_SCOPE;

    #[test]
    fn the_ring_is_bounded_and_counts_drops() {
        let j = Journal::new(0, 3);
        for i in 0..5u64 {
            j.emit(TraceId(i), SpanKind::Queued);
        }
        assert_eq!(j.len(), 3, "ring holds only the newest cap entries");
        let (evs, dropped) = j.drain();
        assert_eq!(dropped, 2, "two oldest entries wrapped out");
        // the survivors are the newest, with their original seqs intact
        assert_eq!(evs.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![2, 3, 4]);
        assert_eq!(evs[0].trace, TraceId(2));
        let (evs2, dropped2) = j.drain();
        assert!(evs2.is_empty());
        assert_eq!(dropped2, 0, "drain resets the drop counter");
    }

    #[test]
    fn events_stamp_the_simulated_clock_not_wall_time() {
        let j = Journal::new(1, 16);
        j.set_round(3);
        j.advance(0.25);
        j.emit(TraceId(9), SpanKind::Admitted { cached_tokens: 4 });
        j.advance(0.5);
        j.emit(NODE_SCOPE, SpanKind::DecodeRound { seqs: 2, sim_s: 0.5 });
        let (evs, _) = j.drain();
        assert_eq!(evs[0].round, 3);
        assert!((evs[0].sim_s - 0.25).abs() < 1e-12);
        assert!((evs[1].sim_s - 0.75).abs() < 1e-12);
        assert_eq!(evs[0].node, 1);
        assert_eq!(j.now(), (3, 0.75));
    }

    #[test]
    fn tracer_drains_rings_into_the_retained_log_in_canonical_order() {
        let t = Tracer::new(2, 8, true);
        t.emit(1, TraceId(5), SpanKind::Queued);
        t.emit(0, TraceId(4), SpanKind::Queued);
        t.drain();
        t.emit(0, TraceId(4), SpanKind::Admitted { cached_tokens: 0 });
        let snap = t.snapshot();
        // sorted by (node, seq): node 0's two events, then node 1's one
        assert_eq!(snap.events.len(), 3);
        assert_eq!(snap.events[0].node, 0);
        assert_eq!(snap.events[1].kind.name(), "admitted");
        assert_eq!(snap.events[2].node, 1);
        assert_eq!(t.dispatch_node(), 2, "one pseudo-node past the workers");
    }

    #[test]
    fn flight_dump_snapshots_the_ring_at_the_failure() {
        let t = Tracer::new(1, 8, true);
        t.set_round(0, 2);
        t.emit(0, TraceId(1), SpanKind::Admitted { cached_tokens: 0 });
        t.drain(); // earlier history already retained
        t.emit(0, TraceId(1), SpanKind::Preempted { swapped: false });
        t.flight_dump(0, "node death");
        let snap = t.snapshot();
        assert_eq!(snap.dumps.len(), 1);
        let d = &snap.dumps[0];
        assert_eq!(d.reason, "node death");
        assert_eq!(d.round, 2);
        assert_eq!(d.events.len(), 1, "the dump holds the undrained tail");
        assert_eq!(d.events[0].kind.name(), "preempted");
        // dumped events left the ring: the retained log has only the
        // earlier drained event
        assert_eq!(snap.events.len(), 1);
        assert_eq!(snap.events[0].kind.name(), "admitted");
    }

    #[test]
    fn a_disabled_tracer_records_nothing() {
        let t = Tracer::off(2);
        t.emit(0, TraceId(1), SpanKind::Queued);
        t.advance(0, 1.0);
        t.sample(SeriesPoint { node: 0, ..SeriesPoint::default() });
        t.flight_dump(0, "ignored");
        let snap = t.snapshot();
        assert!(snap.events.is_empty());
        assert!(snap.dumps.is_empty());
        assert!(snap.series.is_empty());
        assert!(!t.enabled());
    }
}
