//! END-TO-END driver (DESIGN.md §E2E): serve a real model through the full
//! stack — L1 Pallas kernels → L2 JAX tiny-qwen → AOT HLO → L3 Rust
//! coordinator on the PJRT CPU client — under a bursty batched workload,
//! reporting real latency/throughput plus the simulated CMP 170HX device
//! time for the same schedule.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example edge_inference`

use std::time::{Duration, Instant};

use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{Server, ServerConfig};
use cmphx::isa::pass::FmadPolicy;
use cmphx::runtime::ArtifactDir;

fn main() -> anyhow::Result<()> {
    let artifacts = ArtifactDir::discover()?;
    let config = ServerConfig {
        queue_depth: 64,
        batch: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(4),
            ..BatchPolicy::default()
        },
        step_policy: StepPolicy::RoundRobin,
        fmad: FmadPolicy::Decomposed,
        ..Default::default()
    };
    println!("edge node starting: compiling AOT artifacts on PJRT CPU…");
    let t0 = Instant::now();
    let server = Server::start(artifacts, config)?;
    println!("ready in {:.2}s (weights live inside the executable)\n", t0.elapsed().as_secs_f64());

    // Bursty workload: 3 waves of requests with different prompts/lengths,
    // the §6.2 "community edge node" pattern.
    let mut receivers = Vec::new();
    let wave_sizes = [6usize, 4, 6];
    let t_serve = Instant::now();
    for (w, &n) in wave_sizes.iter().enumerate() {
        for i in 0..n {
            let seed = (w * 17 + i * 7 + 1) as i32;
            let prompt: Vec<i32> = (1..=8).map(|t| (t * seed) % 500 + 1).collect();
            let tokens = 6 + (i % 3) * 4; // mixed generation lengths
            receivers.push((w, server.submit(prompt, tokens)?));
        }
        std::thread::sleep(Duration::from_millis(30));
    }

    let mut ok = 0usize;
    for (wave, rx) in receivers {
        let resp = rx.recv()?;
        if resp.ok() {
            ok += 1;
            println!(
                "wave {wave} req {:>2}: {:>2} tokens  queue {:>6.1}ms  prefill {:>6.1}ms  decode {:>6.1}ms  | sim CMP {:>5.1}ms  first: {:?}",
                resp.id,
                resp.tokens.len(),
                resp.queue_s * 1e3,
                resp.prefill_s * 1e3,
                resp.decode_s * 1e3,
                resp.simulated_device_s * 1e3,
                &resp.tokens[..resp.tokens.len().min(4)],
            );
        } else {
            println!("wave {wave} req {}: ERROR {}", resp.id, resp.error.unwrap());
        }
    }
    let wall = t_serve.elapsed().as_secs_f64();
    let metrics = server.shutdown();

    println!("\n===== edge node report =====");
    println!("{}", metrics.render());
    println!(
        "served {ok}/{} requests in {wall:.2}s wall ({:.1} req/s)",
        wave_sizes.iter().sum::<usize>(),
        ok as f64 / wall
    );
    println!(
        "\nInterpretation: the same token schedule on a real CMP 170HX\n\
         (Qwen2.5-1.5B q8_0, -fmad=false) would take {:.1} ms of device time —\n\
         the overlay prices every prefill token and decode step with the §4\n\
         calibrated model.",
        metrics.simulated_device_s * 1e3
    );
    Ok(())
}
