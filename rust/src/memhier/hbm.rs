//! Device-memory (HBM2e / GDDR) bandwidth model.
//!
//! The paper's central economic argument rests on the CMP 170HX *retaining*
//! its full 1493 GB/s HBM2e system (Graph 3-5) — Ethash is bandwidth-bound,
//! so NVIDIA could not throttle memory without destroying the card's mining
//! value. We model achieved bandwidth as peak × a pattern-dependent
//! efficiency, with L2 hits served at L2 bandwidth.

use crate::isa::ir::MemPattern;

/// Device memory system: capacity, peak bandwidth, pattern efficiencies and
/// the L2 slice in front of it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemorySystem {
    pub kind: &'static str,
    pub capacity_bytes: u64,
    /// Peak theoretical bandwidth, bytes/s (Table 2-3: 1493 GB/s).
    pub peak_bw: f64,
    /// Achieved fraction for fully coalesced streams (calibrated to Graph
    /// 3-5's coalesced read/write ≈ 85–90% of peak).
    pub coalesced_eff: f64,
    /// Achieved fraction for misaligned access (Graph 3-5 shows a heavy
    /// penalty: roughly half of coalesced).
    pub misaligned_eff: f64,
    /// Achieved fraction for strided gathers (quantized-weight walks).
    pub strided_eff: f64,
    /// L2 capacity (Table 2-2: 8 MB) and bandwidth multiple over HBM.
    pub l2_bytes: u64,
    pub l2_bw_mult: f64,
}

impl MemorySystem {
    /// HBM2e system of the CMP 170HX (Table 2-3).
    pub fn cmp170hx_hbm2e() -> Self {
        MemorySystem {
            kind: "HBM2e",
            capacity_bytes: 8 * (1u64 << 30),
            peak_bw: 1493.0e9,
            coalesced_eff: 0.88,
            misaligned_eff: 0.45,
            strided_eff: 0.62,
            l2_bytes: 8 * (1 << 20),
            l2_bw_mult: 3.0,
        }
    }

    /// A100 40GB PCIe (paper's §4 reference: 1555 GB/s).
    pub fn a100_hbm2e() -> Self {
        MemorySystem {
            kind: "HBM2e",
            capacity_bytes: 40 * (1u64 << 30),
            peak_bw: 1555.0e9,
            coalesced_eff: 0.88,
            misaligned_eff: 0.45,
            strided_eff: 0.62,
            l2_bytes: 40 * (1 << 20),
            l2_bw_mult: 3.0,
        }
    }

    /// Generic GDDR6 system for the smaller CMP family entries.
    pub fn gddr6(capacity_gb: u64, peak_gbps: f64) -> Self {
        MemorySystem {
            kind: "GDDR6",
            capacity_bytes: capacity_gb * (1 << 30),
            peak_bw: peak_gbps * 1e9,
            coalesced_eff: 0.85,
            misaligned_eff: 0.40,
            strided_eff: 0.55,
            l2_bytes: 4 * (1 << 20),
            l2_bw_mult: 2.5,
        }
    }

    /// Achieved bandwidth (bytes/s) for an access pattern.
    pub fn achieved_bw(&self, pattern: MemPattern) -> f64 {
        let eff = match pattern {
            MemPattern::Coalesced => self.coalesced_eff,
            MemPattern::Misaligned => self.misaligned_eff,
            MemPattern::Strided => self.strided_eff,
        };
        self.peak_bw * eff
    }

    /// Time to move `hbm_bytes` from HBM plus `l2_bytes` from L2, for a
    /// given pattern. L2 traffic rides the faster slice; the two phases are
    /// pipelined so we take the max of (HBM time, L2 time) rather than the
    /// sum.
    pub fn transfer_time(&self, hbm_bytes: f64, l2_bytes: f64, pattern: MemPattern) -> f64 {
        let hbm_t = hbm_bytes / self.achieved_bw(pattern);
        let l2_t = l2_bytes / (self.achieved_bw(pattern) * self.l2_bw_mult);
        hbm_t.max(l2_t)
    }

    /// Does a resident working set of `bytes` fit in device memory?
    pub fn fits(&self, bytes: u64) -> bool {
        bytes <= self.capacity_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn cmp_memory_matches_table_2_3() {
        let m = MemorySystem::cmp170hx_hbm2e();
        assert_eq!(m.capacity_bytes, 8 << 30);
        assert_close(m.peak_bw, 1.493e12, 1e-9);
        assert_eq!(m.l2_bytes, 8 << 20);
    }

    #[test]
    fn coalesced_beats_misaligned_beats_nothing() {
        let m = MemorySystem::cmp170hx_hbm2e();
        use MemPattern::*;
        assert!(m.achieved_bw(Coalesced) > m.achieved_bw(Strided));
        assert!(m.achieved_bw(Strided) > m.achieved_bw(Misaligned));
    }

    #[test]
    fn cmp_retains_a100_class_bandwidth() {
        // The paper's pivotal observation: 1493/1555 ≈ 96% of A100.
        let cmp = MemorySystem::cmp170hx_hbm2e();
        let a100 = MemorySystem::a100_hbm2e();
        let ratio = cmp.peak_bw / a100.peak_bw;
        assert!(ratio > 0.95 && ratio < 0.97, "{ratio}");
    }

    #[test]
    fn transfer_time_is_linear_in_bytes() {
        let m = MemorySystem::cmp170hx_hbm2e();
        let t1 = m.transfer_time(1e9, 0.0, MemPattern::Coalesced);
        let t2 = m.transfer_time(2e9, 0.0, MemPattern::Coalesced);
        assert_close(t2 / t1, 2.0, 1e-12);
    }

    #[test]
    fn l2_traffic_is_cheaper_than_hbm() {
        let m = MemorySystem::cmp170hx_hbm2e();
        let hbm = m.transfer_time(1e9, 0.0, MemPattern::Coalesced);
        let l2 = m.transfer_time(0.0, 1e9, MemPattern::Coalesced);
        assert!(l2 < hbm);
    }

    #[test]
    fn capacity_check() {
        let m = MemorySystem::cmp170hx_hbm2e();
        assert!(m.fits(7 << 30));
        assert!(!m.fits(9 << 30)); // Qwen2.5-1.5B f32 wouldn't fit either
    }
}
