//! Multi-tenant QoS for the serving fleet: weighted fair queueing, aging,
//! per-tenant budgets, and cross-node work stealing.
//!
//! The paper's recycled-card pitch (§5/§6.2) is *cheap shared capacity*:
//! many clients on a few weak boards. That setting dies by flooding — one
//! client saturating a FIFO admission queue ruins every other client's
//! latency — so this layer sits between [`ServerHandle::submit`] and the
//! per-card workers and owns the sharing policy:
//!
//! - [`tenant`] — the tenant registry: named identities with a fair-share
//!   weight and optional token-rate / simulated-energy caps
//!   ([`TenantSpec`]), resolved from the [`TenantId`] every
//!   [`crate::coordinator::GenRequest`] carries.
//! - [`wfq`] — deficit-round-robin weighted fair queueing over per-tenant
//!   lanes, with an aging promoter bounding worst-case wait; the plain
//!   FIFO it replaced survives as the ablation arm of
//!   [`wfq::AdmissionQueue`].
//! - [`budget`] — leaky-bucket token rates (over-rate lanes defer) and
//!   lifetime energy accounts priced via the per-card calibrated overlay
//!   (estimated joules charged at dispatch, settled to actuals at retire).
//! - [`queues`] — bounded per-node work queues replacing the dispatch
//!   channels, so an idle worker can steal the newest request from the
//!   deepest peer queue when routing guessed wrong.
//!
//! The worker-side half of the policy (the preemption waiting queue's
//! aging gate and eviction shield) lives with the engine in
//! [`crate::coordinator::server`]; the knob is
//! [`crate::coordinator::BatchPolicy::aging_rounds`].
//!
//! [`ServerHandle::submit`]: crate::coordinator::ServerHandle::submit

pub mod budget;
pub mod queues;
pub mod tenant;
pub mod wfq;

pub use budget::{Admission, TenantAccounts, TokenBucket};
pub use queues::{NodeQueues, WaitPop};
pub use tenant::{TenantId, TenantRegistry, TenantSpec};
pub use wfq::{AdmissionQueue, Popped, WfqQueue};

/// QoS policy for one server: which tenants exist and which mechanisms
/// are armed. Default is QoS on with only the default tenant — a single
/// lane, behaviourally identical to the FIFO path it replaced.
#[derive(Clone, Debug)]
pub struct QosConfig {
    /// Weighted fair queueing across tenant lanes. Off = the old FIFO
    /// admission queue (the ablation baseline).
    pub enabled: bool,
    /// Cross-node work stealing by idle workers.
    pub steal: bool,
    /// WFQ aging promoter: a queued request that has waited this many
    /// pops is served next regardless of lane deficits. `0` degenerates
    /// to global FIFO by arrival.
    pub aging_pops: u64,
    /// Bound of each node's work queue. Kept **shallow** on purpose: the
    /// backlog must accumulate in the fair queue (where tenant order is
    /// still fluid) rather than in per-node FIFOs (where it is frozen) —
    /// the dispatch stage pops a request only when some node has a free
    /// slot, so a deep flood cannot pre-stake node queues and nullify
    /// WFQ. Floor 1.
    pub node_queue_depth: usize,
    /// Bounded admission scan depth (`--admit-scan`): at the capacity
    /// edge the worker inspects up to this many queued requests and
    /// pops the one whose prompt matches deepest in its radix tree,
    /// instead of only peeking the head. Floor 1 (head-only, the PR 7
    /// behaviour); the scan stays bounded so WFQ/aging order is
    /// perturbed at most K−1 positions.
    pub admit_scan: usize,
    /// Peak prefix-affinity routing multiplier (`--affinity-bonus`),
    /// threaded to [`crate::coordinator::router::Fleet::set_affinity_bonus`].
    /// 2.0 is the PR 7 fixed bonus; values ≤ 1.0 degrade affine routing
    /// to the plain policy.
    pub affinity_bonus: f64,
    /// Tenants beyond the implicit uncapped `default`.
    pub tenants: Vec<TenantSpec>,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            enabled: true,
            steal: true,
            aging_pops: 512,
            node_queue_depth: 2,
            admit_scan: 4,
            affinity_bonus: 2.0,
            tenants: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_fair_and_stealing_with_no_extra_tenants() {
        let q = QosConfig::default();
        assert!(q.enabled);
        assert!(q.steal);
        assert!(q.aging_pops > 0);
        assert_eq!(q.admit_scan, 4);
        assert_eq!(q.affinity_bonus, 2.0);
        assert!(q.tenants.is_empty());
    }
}
