//! Flat instruction mixes — the lowered form the timing engine consumes.

use super::class::{InstClass, ALL_CLASSES, N_CLASSES};
use super::ir::{Kernel, Stmt};

/// Whole-grid dynamic instruction counts per class.
///
/// Backed by a fixed `[u64; N_CLASSES]` indexed by [`InstClass::index`] —
/// `get`/`add` are O(1) array accesses with zero heap allocation, and the
/// `total`/`flops`/`iops`/`fused` aggregates are maintained incrementally on
/// every mutation so the hot queries in [`crate::sim`] are plain field
/// reads. Counts are grid totals (per-thread counts × thread count).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InstMix {
    counts: [u64; N_CLASSES],
    total: u64,
    flops: u64,
    iops: u64,
    fused: u64,
}

impl Default for InstMix {
    fn default() -> Self {
        InstMix {
            counts: [0; N_CLASSES],
            total: 0,
            flops: 0,
            iops: 0,
            fused: 0,
        }
    }
}

impl InstMix {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lower a kernel's per-thread body to whole-grid class counts.
    pub fn from_kernel(k: &Kernel) -> Self {
        let mut mix = InstMix::new();
        fn walk(stmts: &[Stmt], mult: u64, mix: &mut InstMix) {
            for s in stmts {
                match s {
                    Stmt::Op(op) => mix.add(op.class, op.count * mult),
                    Stmt::Loop { trips, body } => walk(body, mult * trips, mix),
                }
            }
        }
        walk(&k.body, 1, &mut mix);
        mix.scale(k.threads);
        mix
    }

    pub fn add(&mut self, class: InstClass, count: u64) {
        if count == 0 {
            return;
        }
        self.counts[class.index()] += count;
        self.total += count;
        self.flops += count * class.flops();
        self.iops += count * class.iops();
        if class.is_fused() {
            self.fused += count;
        }
    }

    pub fn get(&self, class: InstClass) -> u64 {
        self.counts[class.index()]
    }

    /// Multiply every count (used to go per-thread → whole grid, or to
    /// replicate a layer's mix across a model).
    pub fn scale(&mut self, by: u64) {
        for v in self.counts.iter_mut() {
            *v *= by;
        }
        self.total *= by;
        self.flops *= by;
        self.iops *= by;
        self.fused *= by;
    }

    /// Merge another mix into this one.
    pub fn merge(&mut self, other: &InstMix) {
        for (v, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *v += o;
        }
        self.total += other.total;
        self.flops += other.flops;
        self.iops += other.iops;
        self.fused += other.fused;
    }

    /// Total dynamic instructions.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Total floating-point operations represented by the mix.
    pub fn flops(&self) -> u64 {
        self.flops
    }

    /// Total integer operations represented by the mix.
    pub fn iops(&self) -> u64 {
        self.iops
    }

    /// Count of fused-FMA-class instructions (the limiter's trigger set).
    pub fn fused(&self) -> u64 {
        self.fused
    }

    /// Iterate `(class, count)` over nonzero classes, in [`ALL_CLASSES`]
    /// (discriminant) order.
    pub fn iter(&self) -> impl Iterator<Item = (InstClass, u64)> + '_ {
        ALL_CLASSES.iter().filter_map(move |&c| {
            let n = self.counts[c.index()];
            (n > 0).then_some((c, n))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::class::InstClass::*;
    use crate::isa::ir::{Kernel, Stmt};
    use crate::testutil::{forall, Rng};

    fn kernel_with(body: Vec<Stmt>, threads: u64) -> Kernel {
        Kernel::new("t", threads, 128).with_body(body)
    }

    #[test]
    fn lowering_scales_by_threads_and_trips() {
        let k = kernel_with(
            vec![Stmt::looped(8, vec![Stmt::op(Ffma, 3)]), Stmt::op(Stg, 1)],
            100,
        );
        let mix = InstMix::from_kernel(&k);
        assert_eq!(mix.get(Ffma), 8 * 3 * 100);
        assert_eq!(mix.get(Stg), 100);
        assert_eq!(mix.total(), 2400 + 100);
    }

    #[test]
    fn flops_count_fma_as_two() {
        let mut mix = InstMix::new();
        mix.add(Ffma, 10);
        mix.add(Fadd, 5);
        assert_eq!(mix.flops(), 25);
        assert_eq!(mix.fused(), 10);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = InstMix::new();
        a.add(Imad, 4);
        let mut b = InstMix::new();
        b.add(Imad, 6);
        b.add(Dp4a, 2);
        a.merge(&b);
        assert_eq!(a.get(Imad), 10);
        assert_eq!(a.get(Dp4a), 2);
        assert_eq!(a.iops(), 10 * 2 + 2 * 8);
    }

    #[test]
    fn prop_lowering_matches_dynamic_count() {
        // Property: whole-grid total == per-thread dynamic count × threads,
        // for arbitrary nested bodies.
        forall(0xC0FFEE, 200, |rng: &mut Rng| {
            fn gen_body(rng: &mut Rng, depth: u32) -> Vec<Stmt> {
                let n = rng.range(1, 4);
                (0..n)
                    .map(|_| {
                        if depth < 3 && rng.chance(0.3) {
                            Stmt::looped(rng.range(1, 5), gen_body(rng, depth + 1))
                        } else {
                            let class = *rng.pick(&[Ffma, Fmul, Fadd, Imad, Ldg, Stg, Hfma2]);
                            Stmt::op(class, rng.range(1, 16))
                        }
                    })
                    .collect()
            }
            let threads = rng.range(1, 10_000);
            let k = kernel_with(gen_body(rng, 0), threads);
            let mix = InstMix::from_kernel(&k);
            assert_eq!(mix.total(), k.dynamic_insts_per_thread() * threads);
        });
    }

    #[test]
    fn zero_counts_are_not_stored() {
        let mut mix = InstMix::new();
        mix.add(Ffma, 0);
        assert_eq!(mix.total(), 0);
        assert_eq!(mix.iter().count(), 0);
    }

    /// Reference model with the previous implementation's semantics: a
    /// string-keyed map of counts where every query recomputes from scratch.
    #[derive(Default)]
    struct MapMix {
        counts: std::collections::BTreeMap<&'static str, u64>,
    }

    impl MapMix {
        fn add(&mut self, class: InstClass, count: u64) {
            if count == 0 {
                return;
            }
            *self.counts.entry(class.name()).or_insert(0) += count;
        }
        fn get(&self, class: InstClass) -> u64 {
            self.counts.get(class.name()).copied().unwrap_or(0)
        }
        fn scale(&mut self, by: u64) {
            for v in self.counts.values_mut() {
                *v *= by;
            }
        }
        fn merge(&mut self, other: &MapMix) {
            for (k, v) in &other.counts {
                *self.counts.entry(k).or_insert(0) += v;
            }
        }
        fn total(&self) -> u64 {
            self.counts.values().sum()
        }
        fn flops(&self) -> u64 {
            ALL_CLASSES.iter().map(|&c| self.get(c) * c.flops()).sum()
        }
        fn iops(&self) -> u64 {
            ALL_CLASSES.iter().map(|&c| self.get(c) * c.iops()).sum()
        }
        fn fused(&self) -> u64 {
            ALL_CLASSES
                .iter()
                .filter(|c| c.is_fused())
                .map(|&c| self.get(c))
                .sum()
        }
    }

    fn assert_same(mix: &InstMix, model: &MapMix) {
        for &c in ALL_CLASSES {
            assert_eq!(mix.get(c), model.get(c), "count mismatch for {}", c.name());
        }
        assert_eq!(mix.total(), model.total());
        assert_eq!(mix.flops(), model.flops());
        assert_eq!(mix.iops(), model.iops());
        assert_eq!(mix.fused(), model.fused());
        // iter() yields exactly the nonzero classes.
        let nonzero: Vec<(InstClass, u64)> = mix.iter().collect();
        for (c, n) in &nonzero {
            assert_eq!(model.get(*c), *n);
            assert!(*n > 0);
        }
        assert_eq!(nonzero.len(), model.counts.values().filter(|&&v| v > 0).count());
    }

    #[test]
    fn prop_array_mix_matches_map_semantics() {
        // The array-backed mix must be observationally identical to the old
        // BTreeMap-backed implementation over arbitrary interleavings of
        // add / merge / scale, including the incremental aggregates.
        forall(0xA44A7, 300, |rng: &mut Rng| {
            let mut mix = InstMix::new();
            let mut model = MapMix::default();
            for _ in 0..rng.range(1, 24) {
                match rng.below(4) {
                    0 | 1 => {
                        let class = *rng.pick(ALL_CLASSES);
                        let count = rng.range(0, 1 << 16);
                        mix.add(class, count);
                        model.add(class, count);
                    }
                    2 => {
                        let mut other = InstMix::new();
                        let mut other_model = MapMix::default();
                        for _ in 0..rng.range(0, 5) {
                            let class = *rng.pick(ALL_CLASSES);
                            let count = rng.range(1, 1 << 16);
                            other.add(class, count);
                            other_model.add(class, count);
                        }
                        mix.merge(&other);
                        model.merge(&other_model);
                    }
                    _ => {
                        // Scale factors kept small so counts × class FLOP
                        // weights stay far from u64 overflow over 24 steps.
                        let by = rng.range(0, 2);
                        mix.scale(by);
                        model.scale(by);
                    }
                }
                assert_same(&mix, &model);
            }
        });
    }

    #[test]
    fn prop_from_kernel_matches_map_semantics() {
        // Lowering arbitrary random kernels gives identical mixes under both
        // representations (the old path built the map via the same walk).
        forall(0x1117, 200, |rng: &mut Rng| {
            fn gen_body(rng: &mut Rng, depth: u32) -> Vec<Stmt> {
                let n = rng.range(1, 5);
                (0..n)
                    .map(|_| {
                        if depth < 3 && rng.chance(0.3) {
                            Stmt::looped(rng.range(1, 6), gen_body(rng, depth + 1))
                        } else {
                            Stmt::op(*rng.pick(ALL_CLASSES), rng.range(0, 32))
                        }
                    })
                    .collect()
            }
            let k = kernel_with(gen_body(rng, 0), rng.range(1, 1 << 16));
            let mix = InstMix::from_kernel(&k);
            let mut model = MapMix::default();
            fn walk(stmts: &[Stmt], mult: u64, model: &mut MapMix) {
                for s in stmts {
                    match s {
                        Stmt::Op(op) => model.add(op.class, op.count * mult),
                        Stmt::Loop { trips, body } => walk(body, mult * trips, model),
                    }
                }
            }
            walk(&k.body, 1, &mut model);
            model.scale(k.threads);
            assert_same(&mix, &model);
        });
    }
}
