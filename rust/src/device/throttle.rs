//! The crippling mechanism: per-instruction-class issue-rate multipliers.
//!
//! The CMP 170HX's limiter (§3, §5.1 of the paper; confirmed empirically by
//! niconiconi's teardown) watches the decoded instruction stream and
//! throttles *fused multiply-add* classes to a small fraction of their
//! native rate. Everything else — unfused FP math, packed-half, integer,
//! memory — issues at full speed. This module also carries the hypothetical
//! unlock profiles of §5.4 so the `crippled_explorer` example can sweep
//! recovery pathways.

use std::collections::BTreeMap;

use crate::isa::class::{InstClass, ALL_CLASSES};

/// Per-class issue-rate multipliers (1.0 = native). Missing classes default
/// to native.
#[derive(Clone, Debug, PartialEq)]
pub struct ThrottleProfile {
    pub name: &'static str,
    mults: BTreeMap<&'static str, f64>,
}

impl ThrottleProfile {
    fn empty(name: &'static str) -> Self {
        ThrottleProfile {
            name,
            mults: BTreeMap::new(),
        }
    }

    /// Healthy silicon — no limiter (A100, and the §5.4(a) "driver crack"
    /// hypothetical endpoint).
    pub fn native() -> Self {
        Self::empty("native")
    }

    /// The CMP 170HX production limiter, calibrated to Graphs 3-1…3-4:
    ///
    /// | class | mult | evidence |
    /// |---|---|---|
    /// | FFMA        | 1/32 | 12.63 TFLOPS → measured ~0.39 (Graph 3-1) |
    /// | DFMA/DMUL/DADD | 1/32 | 6.317 → ~0.19 (Graph 3-3); *unfused f64 also throttled*, so noFMA makes FP64 worse — exactly what the paper reports |
    /// | HFMA (scalar) | 1 | PyTorch path reaches its (scalar) pipe peak ≈6.3 (Graph 3-2) |
    /// | HFMA2 | 1 | OpenCL half2 reaches ≈49 of 50.53 (Graph 3-2) |
    /// | IMAD/IADD/IMUL/DP4A | 1 | "integer performance remains uncrippled" (§5.2, Graph 3-4/EX.1) |
    /// | LDG/STG | 1 | full 1493 GB/s retained (Graph 3-5) |
    ///
    /// Note: §3.3's prose says FP64 is "1/64 … 1/128 with noFMA" but its own
    /// Graph 3-3 shows 0.18–0.20 TFLOPS ≈ theoretical/32; we calibrate to
    /// the graph (see DESIGN.md §3).
    pub fn cmp170hx_limiter() -> Self {
        let mut p = Self::empty("cmp170hx-limiter");
        p.set(InstClass::Ffma, 1.0 / 32.0);
        p.set(InstClass::Dfma, 1.0 / 32.0);
        p.set(InstClass::Dmul, 1.0 / 32.0);
        p.set(InstClass::Dadd, 1.0 / 32.0);
        // Tensor cores physically present but fused off / not exposed.
        p.set(InstClass::HmmaF16, 0.0);
        p
    }

    /// §5.4(b): open-source kernel driver + user-space Vulkan. The paper
    /// conjectures restrictions may live in the GSP firmware; this profile
    /// models the optimistic case where FP32 contraction recovers but FP64
    /// stays fused-off and tensor cores remain dark.
    pub fn gsp_partial_unlock() -> Self {
        let mut p = Self::empty("gsp-partial-unlock");
        p.set(InstClass::Dfma, 1.0 / 32.0);
        p.set(InstClass::Dmul, 1.0 / 32.0);
        p.set(InstClass::Dadd, 1.0 / 32.0);
        p.set(InstClass::HmmaF16, 0.0);
        p
    }

    /// §5.4(c): stay on the stock driver but author every kernel by hand to
    /// avoid fused ops — identical to the production limiter (the *pass*
    /// provides the avoidance; kept as a named alias for the explorer).
    pub fn custom_cuda_path() -> Self {
        let mut p = Self::cmp170hx_limiter();
        p.name = "custom-cuda-path";
        p
    }

    /// Set the multiplier for one class.
    pub fn set(&mut self, class: InstClass, mult: f64) {
        assert!((0.0..=1.0).contains(&mult), "mult out of range: {mult}");
        self.mults.insert(class.name(), mult);
    }

    /// Multiplier for a class (1.0 when unthrottled).
    pub fn mult(&self, class: InstClass) -> f64 {
        self.mults.get(class.name()).copied().unwrap_or(1.0)
    }

    /// True if any class is throttled below native.
    pub fn is_crippled(&self) -> bool {
        ALL_CLASSES.iter().any(|&c| self.mult(c) < 1.0)
    }

    /// Classes throttled below native, with their multipliers.
    pub fn throttled_classes(&self) -> Vec<(InstClass, f64)> {
        ALL_CLASSES
            .iter()
            .filter_map(|&c| {
                let m = self.mult(c);
                (m < 1.0).then_some((c, m))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::class::InstClass::*;

    #[test]
    fn native_profile_throttles_nothing() {
        let p = ThrottleProfile::native();
        assert!(!p.is_crippled());
        for &c in ALL_CLASSES {
            assert_eq!(p.mult(c), 1.0);
        }
    }

    #[test]
    fn limiter_targets_fused_fp32_but_not_unfused() {
        let p = ThrottleProfile::cmp170hx_limiter();
        assert_eq!(p.mult(Ffma), 1.0 / 32.0);
        assert_eq!(p.mult(Fmul), 1.0);
        assert_eq!(p.mult(Fadd), 1.0);
    }

    #[test]
    fn limiter_throttles_all_fp64_classes() {
        // This is what makes noFMA *hurt* FP64: the decomposed DMUL/DADD
        // are throttled too, and there are twice as many of them.
        let p = ThrottleProfile::cmp170hx_limiter();
        for c in [Dfma, Dmul, Dadd] {
            assert_eq!(p.mult(c), 1.0 / 32.0);
        }
    }

    #[test]
    fn limiter_leaves_half_int_and_memory_alone() {
        let p = ThrottleProfile::cmp170hx_limiter();
        for c in [Hfma2, Hfma, Imad, Iadd, Dp4a, Ldg, Stg] {
            assert_eq!(p.mult(c), 1.0, "{c:?}");
        }
    }

    #[test]
    fn limiter_disables_tensor_cores() {
        assert_eq!(ThrottleProfile::cmp170hx_limiter().mult(HmmaF16), 0.0);
    }

    #[test]
    fn is_crippled_detects_limiter() {
        assert!(ThrottleProfile::cmp170hx_limiter().is_crippled());
        assert!(ThrottleProfile::gsp_partial_unlock().is_crippled());
    }

    #[test]
    fn throttled_classes_lists_exactly_the_limited_set() {
        let p = ThrottleProfile::cmp170hx_limiter();
        let names: Vec<_> = p.throttled_classes().iter().map(|(c, _)| c.name()).collect();
        assert_eq!(names, vec!["FFMA", "DFMA", "DMUL", "DADD", "HMMA.F16"]);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range_mult() {
        let mut p = ThrottleProfile::native();
        p.set(Ffma, 1.5);
    }
}
