//! Per-tenant admission budgets: token-rate leaky buckets and lifetime
//! energy accounts.
//!
//! Rates are enforced at the dispatch stage *before* a request is routed:
//! a tenant over its sustained tokens/s cap is **deferred** (its WFQ lane
//! waits for the bucket to refill), while a tenant past its energy budget
//! is **shed** (the request is answered with a terminal error — energy is
//! a lifetime contract, not a rate). Energy is priced with the routed
//! node's calibrated time+energy overlay: a request is charged its
//! *estimated* joules (one prefill window plus `max_tokens` decode steps
//! at that card's rates) when dispatched, and the worker settles the
//! account to the actually-simulated joules at retire time, so long-run
//! spend tracks the overlay, not the estimate.

use std::time::{Duration, Instant};

use super::tenant::{TenantId, TenantRegistry};

/// Leaky-bucket rate limiter over generated-token cost. The level may go
/// negative (a single request larger than one second of rate is admitted
/// when the bucket is full and paid back as debt), which enforces the
/// sustained rate without permanently blocking big requests.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    level: f64,
    last: Instant,
}

impl TokenBucket {
    /// A bucket sustaining `rate` tokens/s with one second of burst.
    pub fn new(rate: f64, now: Instant) -> Self {
        let burst = rate.max(1.0);
        TokenBucket { rate, burst, level: burst, last: now }
    }

    fn level_at(&self, now: Instant) -> f64 {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        (self.level + dt * self.rate).min(self.burst)
    }

    /// Would a `cost`-token request pass right now? Does not charge.
    pub fn check(&self, cost: f64, now: Instant) -> bool {
        self.level_at(now) >= cost.min(self.burst)
    }

    /// Charge `cost` tokens (callers [`TokenBucket::check`] first; the
    /// charge itself is unconditional so check-then-charge stays atomic
    /// under the caller's lock).
    pub fn charge(&mut self, cost: f64, now: Instant) {
        self.level = self.level_at(now) - cost;
        self.last = now;
    }

    /// Is the bucket in debt (level below zero) right now? Debt means the
    /// tenant has consumed ahead of its sustained rate — the degradation
    /// ladder sheds these tenants first when a fault shrinks the fleet.
    pub fn in_debt(&self, now: Instant) -> bool {
        self.level_at(now) < 0.0
    }

    /// How long until a `cost`-token request would pass.
    pub fn ready_in(&self, cost: f64, now: Instant) -> Duration {
        let need = cost.min(self.burst) - self.level_at(now);
        if need <= 0.0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(need / self.rate)
        }
    }
}

/// Why a request may not dispatch right now.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Admission {
    Granted,
    /// Over the token-rate cap; retry after roughly this long.
    RateLimited(Duration),
    /// Lifetime energy budget exhausted — terminal.
    EnergyExhausted,
}

#[derive(Clone, Debug)]
struct AccountLane {
    bucket: Option<TokenBucket>,
    budget_j: Option<f64>,
    spent_j: f64,
}

/// All tenants' budget state, indexed by [`TenantId`]. Shared between the
/// dispatch stage (rate checks + estimated charges) and the node workers
/// (actual-energy settlement) behind one mutex.
#[derive(Clone, Debug)]
pub struct TenantAccounts {
    lanes: Vec<AccountLane>,
}

impl TenantAccounts {
    pub fn new(registry: &TenantRegistry, now: Instant) -> Self {
        TenantAccounts {
            lanes: registry
                .iter()
                .map(|(_, s)| AccountLane {
                    bucket: s.tok_s.map(|r| TokenBucket::new(r, now)),
                    budget_j: s.energy_budget_j,
                    spent_j: 0.0,
                })
                .collect(),
        }
    }

    /// Is `t` under its token-rate cap for a `cost`-token request? Pure
    /// check — the dispatch stage probes WFQ lane heads with this and only
    /// [`TenantAccounts::charge_rate`]s the request it actually pops.
    pub fn rate_ok(&self, t: TenantId, cost: f64, now: Instant) -> bool {
        self.lanes[t.0].bucket.as_ref().map_or(true, |b| b.check(cost, now))
    }

    pub fn charge_rate(&mut self, t: TenantId, cost: f64, now: Instant) {
        if let Some(b) = self.lanes[t.0].bucket.as_mut() {
            b.charge(cost, now);
        }
    }

    /// Shortest wait until any rate-limited tenant could pass a
    /// `cost`-token request — the dispatch stage's sleep hint when every
    /// queued lane is deferred.
    pub fn min_ready_in(&self, cost: f64, now: Instant) -> Duration {
        self.lanes
            .iter()
            .filter_map(|l| l.bucket.as_ref().map(|b| b.ready_in(cost, now)))
            .min()
            .unwrap_or(Duration::ZERO)
    }

    /// Charge an estimated dispatch cost against `t`'s energy budget.
    /// Over-budget requests are refused (nothing is charged).
    pub fn try_charge_energy(&mut self, t: TenantId, est_j: f64) -> Admission {
        let lane = &mut self.lanes[t.0];
        if let Some(budget) = lane.budget_j {
            if lane.spent_j + est_j > budget {
                return Admission::EnergyExhausted;
            }
        }
        lane.spent_j += est_j;
        Admission::Granted
    }

    /// Replace a request's estimated charge with its actually-simulated
    /// joules once the worker retires it.
    pub fn settle_energy(&mut self, t: TenantId, charged_est_j: f64, actual_j: f64) {
        let lane = &mut self.lanes[t.0];
        lane.spent_j += actual_j - charged_est_j;
    }

    pub fn energy_spent(&self, t: TenantId) -> f64 {
        self.lanes[t.0].spent_j
    }

    /// Has `t` consumed ahead of its sustained token rate (bucket in
    /// debt)? Uncapped tenants are never in debt. Degraded nodes use this
    /// to shed the tenants that over-drew capacity the fault just took
    /// away, instead of punishing everyone equally.
    pub fn rate_in_debt(&self, t: TenantId, now: Instant) -> bool {
        self.lanes[t.0].bucket.as_ref().is_some_and(|b| b.in_debt(now))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::tenant::TenantSpec;

    fn registry(specs: Vec<TenantSpec>) -> TenantRegistry {
        TenantRegistry::new(specs).unwrap()
    }

    #[test]
    fn bucket_allows_burst_then_enforces_rate() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, t0);
        // a full bucket passes one second of tokens immediately
        assert!(b.check(10.0, t0));
        b.charge(10.0, t0);
        assert!(!b.check(1.0, t0), "drained bucket must defer");
        // 500 ms refills 5 tokens at 10 tok/s
        let t1 = t0 + Duration::from_millis(500);
        assert!(b.check(5.0, t1));
        assert!(!b.check(6.0, t1));
        let wait = b.ready_in(6.0, t1);
        assert!(wait > Duration::from_millis(90) && wait < Duration::from_millis(110), "{wait:?}");
    }

    #[test]
    fn oversized_requests_pass_on_a_full_bucket_and_leave_debt() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(4.0, t0);
        // cost 12 > burst 4: admitted when full, paid back as debt
        assert!(b.check(12.0, t0));
        b.charge(12.0, t0);
        // two seconds later the debt (-8) has only refilled to 0
        let t2 = t0 + Duration::from_secs(2);
        assert!(!b.check(1.0, t2));
        let t3 = t0 + Duration::from_secs(3);
        assert!(b.check(4.0, t3));
    }

    #[test]
    fn uncapped_tenants_always_pass_rate_checks() {
        let now = Instant::now();
        let acc = TenantAccounts::new(&registry(vec![]), now);
        assert!(acc.rate_ok(TenantRegistry::DEFAULT, 1e9, now));
        assert_eq!(acc.min_ready_in(8.0, now), Duration::ZERO);
    }

    #[test]
    fn energy_budget_sheds_only_past_the_cap() {
        let now = Instant::now();
        let mut spec = TenantSpec::new("capped", 1.0);
        spec.energy_budget_j = Some(100.0);
        let reg = registry(vec![spec]);
        let t = reg.id("capped").unwrap();
        let mut acc = TenantAccounts::new(&reg, now);
        assert_eq!(acc.try_charge_energy(t, 60.0), Admission::Granted);
        assert_eq!(acc.try_charge_energy(t, 60.0), Admission::EnergyExhausted);
        assert_eq!(acc.energy_spent(t), 60.0, "refused charges must not accrue");
        assert_eq!(acc.try_charge_energy(t, 40.0), Admission::Granted);
        // the default tenant is uncapped
        assert_eq!(
            acc.try_charge_energy(TenantRegistry::DEFAULT, 1e12),
            Admission::Granted
        );
    }

    #[test]
    fn settlement_replaces_the_estimate_with_actuals() {
        let now = Instant::now();
        let mut spec = TenantSpec::new("capped", 1.0);
        spec.energy_budget_j = Some(100.0);
        let reg = registry(vec![spec]);
        let t = reg.id("capped").unwrap();
        let mut acc = TenantAccounts::new(&reg, now);
        assert_eq!(acc.try_charge_energy(t, 90.0), Admission::Granted);
        // the request actually cost 30 J — 60 J of headroom comes back
        acc.settle_energy(t, 90.0, 30.0);
        assert!((acc.energy_spent(t) - 30.0).abs() < 1e-12);
        assert_eq!(acc.try_charge_energy(t, 60.0), Admission::Granted);
    }

    #[test]
    fn debt_tracks_overdraw_and_clears_with_refill() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(4.0, t0);
        assert!(!b.in_debt(t0), "a full bucket is not in debt");
        b.charge(12.0, t0); // level −8
        assert!(b.in_debt(t0));
        // refilled to 0 after two seconds — drained is not in debt
        assert!(!b.in_debt(t0 + Duration::from_secs(2)));
        // the accounts view: metered tenants report, uncapped never do
        let mut metered = TenantSpec::new("metered", 1.0);
        metered.tok_s = Some(4.0);
        let reg = registry(vec![metered, TenantSpec::new("free", 1.0)]);
        let (m, f) = (reg.id("metered").unwrap(), reg.id("free").unwrap());
        let mut acc = TenantAccounts::new(&reg, t0);
        assert!(!acc.rate_in_debt(m, t0));
        acc.charge_rate(m, 12.0, t0);
        assert!(acc.rate_in_debt(m, t0));
        acc.charge_rate(f, 1e9, t0);
        assert!(!acc.rate_in_debt(f, t0), "uncapped lanes have no debt");
        assert!(!acc.rate_in_debt(TenantRegistry::DEFAULT, t0));
    }

    #[test]
    fn rate_check_and_charge_are_per_tenant() {
        let now = Instant::now();
        let mut metered = TenantSpec::new("metered", 1.0);
        metered.tok_s = Some(8.0);
        let reg = registry(vec![metered, TenantSpec::new("free", 1.0)]);
        let m = reg.id("metered").unwrap();
        let f = reg.id("free").unwrap();
        let mut acc = TenantAccounts::new(&reg, now);
        assert!(acc.rate_ok(m, 8.0, now));
        acc.charge_rate(m, 8.0, now);
        assert!(!acc.rate_ok(m, 8.0, now), "metered lane must defer");
        assert!(acc.rate_ok(f, 800.0, now), "uncapped lane must not");
        let hint = acc.min_ready_in(8.0, now);
        assert!(hint > Duration::ZERO && hint <= Duration::from_secs(1), "{hint:?}");
    }
}
