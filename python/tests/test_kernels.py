"""L1 kernels vs pure-jnp oracles — the CORE correctness signal.

Exact equality where the semantics promise it (mixbench variants), tight
allclose for the matmul/attention reductions. Hypothesis sweeps shapes and
value regimes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as at
from compile.kernels import mixbench as mb
from compile.kernels import qmatmul as qm
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


def vec(seed, n, lo, hi):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, n), jnp.float32)


# --------------------------------------------------------------------------
# mixbench
# --------------------------------------------------------------------------


class TestMixbench:
    @pytest.mark.parametrize("iters", [0, 1, 2, 16, 64])
    def test_fused_matches_oracle_exactly(self, iters):
        x = vec(1, 512, 0.5, 0.9)
        y = vec(2, 512, -0.5, -0.1)
        np.testing.assert_array_equal(
            mb.mixbench(x, y, iters, True), ref.mixbench_fused(x, y, iters)
        )

    @pytest.mark.parametrize("iters", [0, 1, 2, 16, 64])
    def test_decomposed_matches_oracle_exactly(self, iters):
        x = vec(3, 512, 0.5, 0.9)
        y = vec(4, 512, -0.5, -0.1)
        np.testing.assert_array_equal(
            mb.mixbench(x, y, iters, False), ref.mixbench_decomposed(x, y, iters)
        )

    def test_variants_differ_in_rounding(self):
        # The fmad policy is a *numerical* change, not just a perf one. In
        # the chaotic regime of t ← t² + y the single- vs double-rounding
        # difference amplifies to visible divergence; both stay on the
        # bounded attractor.
        x = vec(5, 2048, -1.0, 1.0)
        y = vec(6, 2048, -1.8, -1.5)
        fused = np.asarray(mb.mixbench(x, y, 64, True))
        nofma = np.asarray(mb.mixbench(x, y, 64, False))
        assert np.any(fused != nofma)
        assert np.all(np.abs(fused) <= 2.0) and np.all(np.abs(nofma) <= 2.0)

    def test_zero_iters_is_identity(self):
        x = vec(7, 256, 0.5, 0.9)
        y = vec(8, 256, -0.5, -0.1)
        np.testing.assert_array_equal(mb.mixbench(x, y, 0, True), x)

    @given(
        n_blocks=st.integers(1, 8),
        iters=st.integers(0, 32),
        fused=st.booleans(),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_shapes_and_values(self, n_blocks, iters, fused, seed):
        n = n_blocks * mb.BLOCK
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.uniform(-1.0, 1.0, n), jnp.float32)
        y = jnp.asarray(rng.uniform(-0.25, 0.25, n), jnp.float32)
        expect = (ref.mixbench_fused if fused else ref.mixbench_decomposed)(x, y, iters)
        np.testing.assert_array_equal(mb.mixbench(x, y, iters, fused), expect)

    def test_rejects_non_multiple_of_block(self):
        with pytest.raises(AssertionError):
            mb.mixbench(jnp.zeros(100, jnp.float32), jnp.zeros(100, jnp.float32), 1, True)


# --------------------------------------------------------------------------
# qmatmul
# --------------------------------------------------------------------------


class TestQmatmul:
    def test_matches_oracle(self):
        rng = np.random.default_rng(10)
        w = jnp.asarray(rng.normal(size=(128, 96)), jnp.float32)
        qw, s = ref.quantize_q8(w)
        x = jnp.asarray(rng.normal(size=(32, 128)), jnp.float32)
        np.testing.assert_allclose(
            qm.qmatmul(x, qw, s), ref.qmatmul(x, qw, s), rtol=1e-5, atol=1e-5
        )

    def test_quantization_error_is_bounded(self):
        # q8_0 absmax: |w - dequant(quant(w))| <= absmax/254 per block.
        rng = np.random.default_rng(11)
        w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
        qw, s = ref.quantize_q8(w)
        back = ref.q8_dequant(qw, s)
        blocks = np.asarray(w).reshape(2, 32, 32)
        absmax = np.abs(blocks).max(axis=1)
        bound = np.repeat(absmax, 32, axis=0) / 254.0 + 1e-7
        assert np.all(np.abs(np.asarray(back) - np.asarray(w)) <= bound)

    @given(
        mi=st.integers(1, 4),
        kb=st.integers(1, 6),
        nb=st.integers(1, 4),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_tile_shapes(self, mi, kb, nb, seed):
        m, k, n = mi * qm.BM, kb * ref.Q8_BLOCK, nb * qm.BN
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        qw, s = ref.quantize_q8(w)
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        np.testing.assert_allclose(
            qm.qmatmul(x, qw, s), ref.qmatmul(x, qw, s), rtol=2e-5, atol=2e-5
        )

    @given(m=st.integers(1, 40), seed=st.integers(0, 2**31))
    def test_padded_wrapper_handles_any_m(self, m, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
        qw, s = ref.quantize_q8(w)
        x = jnp.asarray(rng.normal(size=(m, 64)), jnp.float32)
        np.testing.assert_allclose(
            qm.qmatmul_padded(x, qw, s), ref.qmatmul(x, qw, s), rtol=2e-5, atol=2e-5
        )

    def test_zero_scales_give_zero_output(self):
        x = jnp.ones((16, 32), jnp.float32)
        qw = jnp.ones((32, 32), jnp.int8)
        s = jnp.zeros((1, 32), jnp.float32)
        assert np.all(np.asarray(qm.qmatmul(x, qw, s)) == 0.0)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


class TestAttention:
    def _case(self, seed, t, kv, h, d, length):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(t, kv, d)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(t, kv, d)), jnp.float32)
        return q, kc, vc, length

    def test_matches_oracle(self):
        q, kc, vc, length = self._case(20, 64, 2, 8, 32, 17)
        out = at.gqa_decode_attention(q, kc, vc, length, kv_heads=2)
        np.testing.assert_allclose(
            out, ref.gqa_decode_attention(q, kc, vc, length), rtol=1e-5, atol=1e-6
        )

    def test_length_one_returns_first_value_row(self):
        # With a single valid position, softmax weight is 1 on row 0.
        q, kc, vc, _ = self._case(21, 16, 2, 8, 32, 1)
        out = np.asarray(at.gqa_decode_attention(q, kc, vc, 1, kv_heads=2))
        expected = np.asarray(vc)[0, np.arange(8) // 4, :]
        np.testing.assert_allclose(out, expected, rtol=1e-6, atol=1e-6)

    def test_masked_tail_is_ignored(self):
        # Garbage beyond `length` must not affect the result.
        q, kc, vc, length = self._case(22, 32, 2, 8, 32, 9)
        out1 = at.gqa_decode_attention(q, kc, vc, length, kv_heads=2)
        kc2 = kc.at[length:].set(1e9)
        vc2 = vc.at[length:].set(-1e9)
        out2 = at.gqa_decode_attention(q, kc2, vc2, length, kv_heads=2)
        np.testing.assert_array_equal(out1, out2)

    @given(
        t_pow=st.integers(3, 6),
        kv=st.sampled_from([1, 2, 4]),
        group=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([16, 32]),
        seed=st.integers(0, 2**31),
        data=st.data(),
    )
    def test_hypothesis_geometry(self, t_pow, kv, group, d, seed, data):
        t = 2**t_pow
        h = kv * group
        length = data.draw(st.integers(1, t))
        q, kc, vc, _ = self._case(seed, t, kv, h, d, length)
        out = at.gqa_decode_attention(q, kc, vc, length, kv_heads=kv)
        np.testing.assert_allclose(
            out, ref.gqa_decode_attention(q, kc, vc, length), rtol=2e-5, atol=2e-5
        )

    def test_attention_output_is_convex_combination(self):
        # Softmax weights are a convex combination: the output of each head
        # lies inside the bounding box of its value rows.
        q, kc, vc, length = self._case(23, 32, 2, 8, 32, 32)
        out = np.asarray(at.gqa_decode_attention(q, kc, vc, length, kv_heads=2))
        v = np.asarray(vc)
        for head in range(8):
            rows = v[:, head // 4, :]
            assert np.all(out[head] <= rows.max(axis=0) + 1e-5)
            assert np.all(out[head] >= rows.min(axis=0) - 1e-5)
