//! Fleet planner: the §6.2 economics, runnable.
//!
//! Given a target decode throughput for an edge service, compare fleets of
//! recycled CMP 170HX cards (stock vs noFMA-rebuilt, stock-x4 vs x16-mod)
//! against new A100s: cards needed, capex, power, $/(token/s), and routing
//! across a heterogeneous fleet.
//!
//! Run: `cargo run --release --example fleet_planner`

use cmphx::coordinator::router::{Fleet, RoutePolicy};
use cmphx::device::registry;
use cmphx::isa::pass::FmadPolicy;
use cmphx::llm::llamabench::LlamaBench;
use cmphx::llm::quant;
use cmphx::market::sales;
use cmphx::market::tco::{a100_replacement, fleet_for_throughput, reuse_value};

const TARGET_TPS: f64 = 2_000.0; // tokens/s of q4_k_m decode

fn main() {
    println!("=== how many stranded cards exist? (Table 1-2) ===");
    for s in sales::Scenario::all() {
        let est = sales::estimate_sales(cmphx::calibration::CMP_REVENUE_USD, &s);
        println!(
            "scenario {}: {:>9.0} cards total ({:>7.0} are 170HX)",
            est.scenario, est.total_units, est.rows[4].2
        );
    }

    println!("\n=== fleet sizing for {TARGET_TPS:.0} tok/s of q4_k_m decode ===");
    let candidates = [
        ("CMP 170HX (stock build)", registry::cmp170hx(), FmadPolicy::Fused),
        ("CMP 170HX (-fmad=false)", registry::cmp170hx(), FmadPolicy::Decomposed),
        ("CMP 170HX x16-mod (-fmad)", registry::cmp170hx_x16(), FmadPolicy::Decomposed),
        ("A100 40GB PCIe (new)", registry::a100_pcie(), FmadPolicy::Fused),
    ];
    println!(
        "{:<28} {:>6} {:>12} {:>9} {:>14}",
        "device", "cards", "capex $", "power W", "$/(tok/s)"
    );
    for (label, dev, policy) in &candidates {
        let plan = fleet_for_throughput(dev, &quant::Q4_K_M, *policy, TARGET_TPS);
        println!(
            "{label:<28} {:>6} {:>12.0} {:>9.0} {:>14.2}",
            plan.cards,
            plan.capex_usd,
            plan.power_w,
            plan.capex_usd / plan.decode_tps_total,
        );
    }

    println!("\n=== per-card reuse value (duty 100%, $0.12/kWh) ===");
    for (label, dev, policy) in &candidates {
        let v = reuse_value(dev, &quant::Q4_K_M, *policy, 1.0);
        println!(
            "{label:<28} {:>7.0} tok/s  ${:>7.2}/(tok/s)  energy ${:>6.0}/yr",
            v.decode_tps, v.usd_per_decode_tps, v.energy_usd_per_year
        );
    }

    println!("\n=== routing a mixed fleet (170HX + x16-mod), weighted ===");
    let mut fleet = Fleet::from_devices(
        &[registry::cmp170hx(), registry::cmp170hx_x16(), registry::cmp170hx()],
        &quant::Q4_K_M,
        FmadPolicy::Decomposed,
        RoutePolicy::WeightedThroughput,
    );
    // steady-state: route 10k requests, completing at node speed
    for step in 0..10_000u64 {
        let i = fleet.route();
        if step % 2 == 0 {
            // completions keep queues shallow
            let busiest = (0..fleet.nodes.len())
                .max_by_key(|&j| fleet.nodes[j].outstanding)
                .unwrap();
            if fleet.nodes[busiest].outstanding > 0 {
                fleet.complete(busiest);
            }
            let _ = i;
        }
    }
    for node in &fleet.nodes {
        println!(
            "{:<22} weight {:>6.0} tok/s  assigned {:>6} requests",
            node.name, node.weight, node.assigned
        );
    }

    println!("\n=== how many 170HX cards replace one A100, at what energy cost? ===");
    let bench = LlamaBench::default();
    let a100 = bench.run(&registry::a100_pcie(), &quant::Q4_K_M, FmadPolicy::Fused);
    for (label, dev, policy) in [
        ("CMP 170HX (-fmad=false)", registry::cmp170hx(), FmadPolicy::Decomposed),
        ("CMP 170HX x16-mod (-fmad)", registry::cmp170hx_x16(), FmadPolicy::Decomposed),
    ] {
        let row = bench.run(&dev, &quant::Q4_K_M, policy);
        let rep = a100_replacement(
            &dev,
            row.decode_tps,
            row.decode_power_w,
            a100.decode_tps,
            a100.decode_power_w,
        );
        println!(
            "{label:<28} {} cards ≈ one A100  ({:.0}% capex, {:.1}× wall power, {:.2}× J/token)",
            rep.cards_per_a100,
            rep.capex_ratio * 100.0,
            rep.power_ratio,
            rep.energy_per_token_ratio,
        );
    }

    println!(
        "\nConclusion (§6.2): at 2021 ASPs a restored 170HX fleet undercuts new\n\
         A100s on $/(tok/s) for bandwidth-bound decode; at 2024 salvage prices\n\
         (~$400/card) the gap is an order of magnitude. The binding constraints\n\
         are the 8 GB VRAM ceiling and the x4-gen1 host link."
    );
}
