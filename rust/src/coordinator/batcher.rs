//! Dynamic batching: group queued requests under a (max size, max wait)
//! window — the same policy family the vLLM-style routers use, scaled to
//! an edge node.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batch window policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Hard cap on batch size (bounded by KV slots).
    pub max_batch: usize,
    /// Max time the first request in a window waits for company.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// Pulls items from a channel and groups them into batches.
pub struct Batcher<T> {
    rx: Receiver<T>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        Batcher { rx, policy }
    }

    /// Block for the next batch. Returns `None` when the channel is closed
    /// and drained. A batch is emitted when it reaches `max_batch` or when
    /// `max_wait` has elapsed since its first item arrived.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // Block for the first item.
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::thread;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        }
    }

    #[test]
    fn full_batch_emitted_without_waiting_out_the_window() {
        let (tx, rx) = channel();
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, policy(4, 10_000));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(1), "must not wait the window");
    }

    #[test]
    fn window_expiry_emits_partial_batch() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        let b = Batcher::new(rx, policy(8, 20));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![42]);
    }

    #[test]
    fn closed_empty_channel_ends_iteration() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = Batcher::new(rx, policy(4, 10));
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn disconnect_mid_window_emits_what_arrived() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let b = Batcher::new(rx, policy(4, 500));
        let handle = thread::spawn(move || {
            tx.send(2).unwrap();
            drop(tx);
        });
        let batch = b.next_batch().unwrap();
        handle.join().unwrap();
        assert!(batch == vec![1, 2] || batch == vec![1], "{batch:?}");
    }

    #[test]
    fn batches_preserve_arrival_order() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, policy(3, 1));
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2]);
        assert_eq!(b.next_batch().unwrap(), vec![3, 4, 5]);
    }
}
