//! Integration: the serving coordinator end-to-end over real artifacts,
//! including failure injection (oversized requests, overload, cancels).

use std::time::Duration;

use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{Server, ServerConfig};
use cmphx::isa::pass::FmadPolicy;
use cmphx::runtime::ArtifactDir;

fn artifact_dir() -> ArtifactDir {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    ArtifactDir::open(root).expect("run `make artifacts` first")
}

fn config(max_batch: usize) -> ServerConfig {
    ServerConfig {
        queue_depth: 32,
        batch: BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(20),
        },
        step_policy: StepPolicy::RoundRobin,
        fmad: FmadPolicy::Decomposed,
    }
}

#[test]
fn serves_a_batch_of_requests_with_real_tokens() {
    let server = Server::start(artifact_dir(), config(4)).unwrap();
    let mut rxs = Vec::new();
    for i in 0..4 {
        let prompt: Vec<i32> = (1..=8).map(|t| (t * (i + 2)) % 500 + 1).collect();
        rxs.push(server.submit(prompt, 6).unwrap());
    }
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.ok(), "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 6);
        assert!(resp.tokens.iter().all(|&t| (0..512).contains(&t)));
        assert!(resp.simulated_device_s > 0.0, "overlay must accrue");
    }
    let m = server.shutdown();
    assert_eq!(m.requests, 4);
    assert_eq!(m.errors, 0);
    assert_eq!(m.tokens_out, 24);
    assert!(m.simulated_device_s > 0.0);
    assert!(m.mean_batch_size() >= 1.0);
}

#[test]
fn identical_prompts_get_identical_tokens() {
    // Determinism across the whole path: batching must not leak state
    // between sequences.
    let server = Server::start(artifact_dir(), config(3)).unwrap();
    let prompt: Vec<i32> = vec![5, 9, 13, 2, 8, 1, 30, 44];
    let rx1 = server.submit(prompt.clone(), 5).unwrap();
    let rx2 = server.submit(prompt.clone(), 5).unwrap();
    let rx3 = server.submit(prompt, 5).unwrap();
    let a = rx1.recv_timeout(Duration::from_secs(120)).unwrap().tokens;
    let b = rx2.recv_timeout(Duration::from_secs(120)).unwrap().tokens;
    let c = rx3.recv_timeout(Duration::from_secs(120)).unwrap().tokens;
    assert_eq!(a, b);
    assert_eq!(b, c);
    drop(server);
}

#[test]
fn oversized_requests_are_rejected_not_crashed() {
    let server = Server::start(artifact_dir(), config(2)).unwrap();
    // prompt longer than the prefill window
    let rx = server.submit(vec![1; 64], 4).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(!resp.ok());
    assert!(resp.error.as_deref().unwrap().contains("window"));
    // generation longer than the KV budget
    let rx = server.submit(vec![1, 2, 3], 10_000).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(!resp.ok());
    // and the server still works afterwards
    let rx = server.submit(vec![1, 2, 3], 3).unwrap();
    assert!(rx.recv_timeout(Duration::from_secs(120)).unwrap().ok());
    let m = server.shutdown();
    assert_eq!(m.errors, 2);
}

#[test]
fn cancelled_requests_do_not_wedge_the_worker() {
    let server = Server::start(artifact_dir(), config(2)).unwrap();
    // drop the receiver immediately = cancel
    drop(server.submit(vec![1, 2, 3], 4).unwrap());
    // a live request right behind it must still be served
    let rx = server.submit(vec![4, 5, 6], 4).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    assert!(resp.ok());
    drop(server);
}

#[test]
fn shutdown_drains_outstanding_requests() {
    let server = Server::start(artifact_dir(), config(4)).unwrap();
    let rx = server.submit(vec![7, 7, 7], 4).unwrap();
    let metrics = server.shutdown(); // joins the worker
    let resp = rx.recv_timeout(Duration::from_secs(1)).unwrap();
    assert!(resp.ok(), "in-flight request must complete during shutdown");
    assert_eq!(metrics.requests, 1);
}

#[test]
fn scheduler_policies_serve_mixed_lengths() {
    for policy in [StepPolicy::RoundRobin, StepPolicy::ShortestFirst] {
        let mut cfg = config(3);
        cfg.step_policy = policy;
        let server = Server::start(artifact_dir(), cfg).unwrap();
        let rx_short = server.submit(vec![1, 2], 2).unwrap();
        let rx_long = server.submit(vec![3, 4], 8).unwrap();
        let short = rx_short.recv_timeout(Duration::from_secs(120)).unwrap();
        let long = rx_long.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(short.tokens.len(), 2, "{policy:?}");
        assert_eq!(long.tokens.len(), 8, "{policy:?}");
        drop(server);
    }
}
