//! GPU-Burn port — the paper's control group (§1.3.3, Table 2-9).
//!
//! GPU-Burn runs a sustained dense GEMM (cuBLAS) sized to fill VRAM, always
//! compiled/linked as shipped — the paper explicitly does *not* rebuild it
//! with `-fmad=false`, and since the hot loop lives in cuBLAS's prebuilt
//! SASS the flag would not bite anyway ([`KernelSource::Lib`]). Its FP32
//! number therefore pins the *default* (crippled) bar in Graph 3-1, and its
//! FP16 number lands on the scalar-half pipe like PyTorch's (Graph 3-2).

use crate::device::DeviceSpec;
use crate::isa::class::InstClass;
use crate::isa::ir::{Kernel, KernelSource, MemPattern, Stmt, Traffic};
use crate::sim::{simulate_lowered, LoweredKernel, SimConfig};

use super::{Precision, ToolResult};

/// GEMM dimension GPU-Burn picks for ~90% VRAM usage on an 8 GB card.
const N: u64 = 8192;

/// cuBLAS sustains ~99% of pipe issue on large square GEMMs (fully
/// unrolled, software-pipelined inner loops).
const LIB_ISSUE_EFF: f64 = 0.99;

/// Build the one GEMM iteration kernel: C = A·B + C, N×N×N.
pub fn gemm_kernel(precision: Precision) -> Kernel {
    let (class, elem) = match precision {
        Precision::Fp64 => (InstClass::Dfma, 8),
        // GPU-Burn's -tc off FP16 path is scalar half FMA (no half2
        // vectorization in its naive kernel) — the paper's 6.3 TFLOPS.
        Precision::Fp16Scalar | Precision::Fp16Half2 => (InstClass::Hfma, 2),
        _ => (InstClass::Ffma, 4),
    };
    let threads = N * N;
    let tile_reuse = 64.0; // blocked GEMM reuses operand tiles from L2
    let unique = 3 * N * N * elem;
    Kernel::new(format!("gpuburn.{}", precision.name()), threads, 256)
        .with_body(vec![
            Stmt::looped(N, vec![Stmt::op(class, 1)]),
            // index math amortized 16× by unrolling
            Stmt::op(InstClass::Imad, N / 16),
            Stmt::op(InstClass::Stg, 1),
        ])
        .with_traffic(Traffic {
            read_bytes: (2.0 * N as f64 * N as f64 * elem as f64 * (N as f64 / 128.0)) as u64,
            write_bytes: N * N * elem,
            pattern: MemPattern::Coalesced,
            l2_hit_rate: crate::memhier::l2::hit_rate(unique, tile_reuse, 8 << 20),
        })
        .with_source(KernelSource::Lib)
}

/// Run the burn GEMM once on the device (steady-state rate; the real tool
/// loops it for `-tc 3600` seconds).
pub fn run(dev: &DeviceSpec, precision: Precision) -> ToolResult {
    let lk = LoweredKernel::lower(&gemm_kernel(precision));
    let cfg = SimConfig {
        issue_efficiency: LIB_ISSUE_EFF,
        ..Default::default()
    };
    ToolResult {
        tool: "gpu-burn",
        case: precision.name().to_string(),
        timing: simulate_lowered(&lk, dev, &cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibration as cal;
    use crate::device::registry;
    use crate::isa::pass::{apply_fmad, FmadPolicy};

    #[test]
    fn fp32_pins_the_crippled_default_bar() {
        let dev = registry::cmp170hx();
        let t = run(&dev, Precision::Fp32).tflops();
        assert!(cal::check(&cal::FP32_DEFAULT_TFLOPS, t), "{t}");
    }

    #[test]
    fn fp16_lands_on_scalar_pipe() {
        let dev = registry::cmp170hx();
        let t = run(&dev, Precision::Fp16Scalar).tflops();
        assert!(cal::check(&cal::FP16_SCALAR_TFLOPS, t), "{t}");
    }

    #[test]
    fn rebuilding_with_nofma_would_not_help_a_lib_kernel() {
        // The control-group property: even if someone passed -fmad=false,
        // the Lib-sourced GEMM is untouched by the pass.
        let k = gemm_kernel(Precision::Fp32);
        let rewritten = apply_fmad(&k, FmadPolicy::Decomposed);
        assert_eq!(k.body, rewritten.body);
    }

    #[test]
    fn burn_sits_at_tdp_on_healthy_silicon() {
        // GPU-Burn's purpose is to pin the card at TDP; on the A100 the
        // GEMM saturates compute and DVFS caps power.
        let dev = registry::a100_pcie();
        let r = run(&dev, Precision::Fp32);
        assert!((r.timing.power_w - dev.tdp_w).abs() < 1.0, "{}", r.timing.power_w);
    }

    #[test]
    fn crippled_burn_runs_cool() {
        // On the CMP the FP32 pipe is 1/32-rate: the burn can't fill the
        // power envelope — matching the community observation that mining
        // cards idle far below TDP in compute workloads.
        let dev = registry::cmp170hx();
        let r = run(&dev, Precision::Fp32);
        assert!(r.timing.power_w < 0.8 * dev.tdp_w, "{}", r.timing.power_w);
    }
}
