//! L3 serving coordinator — the §6.2 edge-node deployment, real, at fleet
//! scale, multi-tenant.
//!
//! A threaded (std::thread + mpsc; no async runtime in the offline crate
//! set) inference fleet over the AOT artifacts. The pipeline is
//! **submit → QoS → dispatch → worker**: requests enter a bounded submit
//! queue carrying a [`crate::qos::TenantId`]; the QoS dispatch stage
//! drains them into per-tenant lanes of a deficit-round-robin weighted
//! fair queue ([`crate::qos::wfq`]) with an aging promoter, enforces each
//! tenant's token-rate cap (over-rate lanes defer) and lifetime energy
//! budget (priced with the routed card's calibrated overlay, settled to
//! actuals at retire — [`crate::qos::budget`]), and routes the popped
//! request across N per-card workers via a [`router::Fleet`] policy onto
//! bounded per-node work queues ([`crate::qos::NodeQueues`]) — the full
//! pipeline is **submit → QoS → affinity-routed dispatch → worker/fabric
//! data plane**. Routing is **prefix-affine** by default: each worker
//! publishes its pager's resident chain hashes into a fleet
//! [`kv::PrefixDirectory`] every round, and dispatch hashes the incoming
//! prompt's padded window the same way ([`kv::window_chain_hashes`]) and
//! biases [`router::Fleet::route_affine`] toward the card holding the
//! longest matching chain (bounded, so warm cards cannot monopolize; a
//! directory entry is a hint, not a lease — stale hits degrade to plain
//! re-prefill misses at admission). Dead workers are marked unhealthy and
//! excluded, with the in-hand request rerouted;
//! [`server::ServerHandle::mark_healthy`] restores a recovered node. An
//! **idle worker steals** work at two levels: the newest queued request
//! off the deepest peer queue, or — when every queue is dry — a foreign
//! parked sequence from the shared lot (**live migration**: host-resident
//! swapped pages restore over the thief's own PCIe link, both ends priced
//! by the §3 model; dropped victims replay prefix-aware), capping tail
//! latency when routing guessed wrong.
//!
//! Every worker runs **continuous batching over paged KV** — sequences
//! join its decode round whenever the [`kv::KvPager`] can hold their
//! prefill window ([`scheduler::plan_admission`]), grow VRAM
//! block-by-block as they decode, and under page pressure the
//! longest-remaining sequence is **preempted and requeued** (remaining-
//! length ties broken toward the most over-served tenant,
//! [`scheduler::plan_eviction_weighted`]), vLLM-style, so long
//! generations cannot starve short ones — and a parked sequence past
//! [`batcher::BatchPolicy::aging_rounds`] freezes new admissions until it
//! resumes (the resumed sequence is shielded from re-eviction), so short
//! traffic cannot starve a parked long one either. The pager is
//! **content-aware**: admission chain-hashes the prompt window and pins
//! already-resident blocks with copy-on-write on first write
//! ([`kv::KvPager::admit_prompt`]) — identical system prompts cost one
//! physical copy, another large admission multiplier on 8 GB cards. The
//! preemption comeback is **cost-aware**: [`scheduler::choose_preempt`]
//! prices the §3 PCIe round trip of the victim's pages at the card's
//! link width against the overlay's recompute estimate, swapping to a
//! fleet-shared host-RAM pool ([`kv::HostPool`]) when the link wins and
//! recomputing when the GPU does — and the swap DMA **overlaps** the
//! concurrent decode round ([`scheduler::overlap_transfer`]), charging
//! only the tail that outlasts it (metrics split the transfer into
//! overlapped vs stalled seconds). [`batcher::BatchPolicy`] carries the
//! admission,
//! paging, prefix-cache, swap, and aging knobs. Each node owns its own
//! runtime, pager sized to its card's VRAM, and a per-card simulated
//! device-time/energy overlay, so [`metrics::FleetMetrics`] reports
//! tokens/s, latency percentiles, tokens/joule, the preemption/recompute
//! tax, and the prefix-hit/CoW/swap ledgers for any mix of registry
//! cards — per node *and* per tenant.
//!
//! The whole pipeline is **observable** through [`crate::obsv`]: every
//! request carries a [`crate::obsv::TraceId`] (its request id) and each
//! stage taps typed span events into per-node bounded flight-recorder
//! rings ([`crate::obsv::Tracer`]), stamped with the node's *simulated*
//! clock so traces replay bit-identically across runs. The tap points,
//! in pipeline order: the dispatch stage journals `queued` / `requeued` /
//! `aged` / `dispatched` / `shed` / `deadline_miss` on its pseudo-node
//! ring and samples admission-queue depth, per-lane WFQ deficits, and
//! per-node outstanding counts each dispatch tick; each worker journals
//! `admitted` (with prefix-cache hits), `prefill`, per-round
//! `decode_round`, `preempted`/`swap_out`/`parked`, `swap_in`/`replayed`
//! on comeback, `migrated`, chaos `fault`s, `rescued` off a corpse, and
//! terminal `retired` (carrying the request's
//! [`crate::obsv::PhaseLedger`] — prefill/decode/stall/replay seconds) or
//! `failed`, plus a per-round [`crate::obsv::SeriesPoint`] (queue depth,
//! live/parked sequences, pinned/cached/free pages, host-pool bytes,
//! simulated watts). The dispatcher drains every ring into the retained
//! log each loop; a chaos death, deadline miss, or terminal error snapshots
//! the victim's ring into a flight dump first, so the moments before a
//! crash always survive. `serve --trace FILE` exports the JSONL journal +
//! a Perfetto-loadable Chrome trace (see `docs/perfetto.md`), and the
//! latency-attribution rollup (queue vs prefill vs decode vs stall vs
//! replay, per node and per tenant) folds into
//! [`metrics::FleetMetrics::render`].
//!
//! The fleet is **self-healing** under the fault model salvage mining
//! cards earn ([`crate::faults`]): a seeded [`crate::faults::FaultPlan`]
//! can kill a card mid-decode, stall it, downgrade its PCIe link, lose
//! VRAM pages, or corrupt a swap-in — and the engine rescues every
//! in-flight and queued sequence off the corpse back through the QoS
//! stage (generated tokens ride along; greedy replay on a healthy card is
//! bit-identical), retries transient refusals with exponential backoff,
//! enforces per-request wall-clock deadlines, quarantines recovered cards
//! behind probation probes, and degrades non-fatal faults down a ladder
//! (swap off on a narrow link, over-rate tenants shed, admission shrunk
//! pro-rata with surviving VRAM) instead of failing the node outright.
//!
//! Python never runs here: the executables carry the weights.

pub mod batcher;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use batcher::BatchPolicy;
pub use kv::{
    window_chain_hashes, HostPool, KvPager, PrefixDirectory, PrefixStats, ReclaimPolicy, SeqKv,
};
pub use metrics::{jain_index, FleetMetrics, Metrics};
pub use request::{Carried, GenRequest, GenResponse};
pub use router::{Fleet, RoutePolicy};
pub use server::{NodeConfig, Server, ServerConfig, ServerHandle};
