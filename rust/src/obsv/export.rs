//! Journal exporters: JSON-lines, Chrome trace-event (Perfetto-loadable),
//! and the latency-attribution rollup.
//!
//! No serde in the offline crate set, so both writers emit JSON by hand
//! with canonical formatting (`{:.9}` for seconds, fields in fixed order)
//! and [`parse_journal`] reads it back with a small depth/string-aware
//! scanner. Canonical formatting is what makes the determinism acceptance
//! checkable as *byte equality*: export → parse → export is the identity
//! on the text, and two runs of the same seeded schedule produce the same
//! bytes (`same_seed_exports_are_byte_identical` below drives a seeded
//! [`crate::faults::FaultPlan`] through a scripted tracer twice).
//!
//! The Chrome writer maps the journal onto the trace-event format
//! `chrome://tracing` / [Perfetto](https://ui.perfetto.dev) load
//! natively: each retired request becomes a row of complete (`ph:"X"`)
//! per-phase slices reconstructed from its [`PhaseLedger`]
//! ([`lifecycle_slices`]), node-scoped work (decode rounds, prefills)
//! becomes slices on thread 0 of the node's process, everything else
//! becomes instants (`ph:"i"`), and the time-series becomes counter
//! tracks (`ph:"C"`). Timestamps are simulated microseconds.

use anyhow::{bail, Context};

use super::journal::{FlightDump, TraceSnapshot};
use super::series::{DispatchPoint, SeriesPoint};
use super::span::{PhaseLedger, SpanEvent, SpanKind, TraceId, NODE_SCOPE};

// ---------------------------------------------------------------- writing

/// Escape a string for a JSON literal.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn trace_json(t: TraceId) -> String {
    if t.is_node_scope() {
        "null".into()
    } else {
        t.0.to_string()
    }
}

/// The kind-specific fields of one span, as `,"k":v` fragments.
fn kind_args(kind: &SpanKind) -> String {
    match kind {
        SpanKind::Queued
        | SpanKind::Requeued
        | SpanKind::Aged
        | SpanKind::Parked
        | SpanKind::DeadlineMiss => String::new(),
        SpanKind::Dispatched { node } => format!(",\"to\":{node}"),
        SpanKind::Admitted { cached_tokens } => format!(",\"cached_tokens\":{cached_tokens}"),
        SpanKind::Prefill { sim_s } => format!(",\"phase_s\":{sim_s:.9}"),
        SpanKind::DecodeRound { seqs, sim_s } => {
            format!(",\"seqs\":{seqs},\"phase_s\":{sim_s:.9}")
        }
        SpanKind::Preempted { swapped } => format!(",\"swapped\":{swapped}"),
        SpanKind::Migrated { from } | SpanKind::Rescued { from } => format!(",\"from\":{from}"),
        SpanKind::SwapOut { bytes, stall_s } | SpanKind::SwapIn { bytes, stall_s } => {
            format!(",\"bytes\":{bytes},\"stall_s\":{stall_s:.9}")
        }
        SpanKind::Replayed { tokens, sim_s } => {
            format!(",\"tokens\":{tokens},\"phase_s\":{sim_s:.9}")
        }
        SpanKind::Retired { tokens, queue_s, ledger } => format!(
            ",\"tokens\":{tokens},\"queue_s\":{queue_s:.9},\"prefill_s\":{:.9},\
             \"decode_s\":{:.9},\"stall_s\":{:.9},\"replay_s\":{:.9}",
            ledger.prefill_s, ledger.decode_s, ledger.stall_s, ledger.replay_s
        ),
        SpanKind::Failed { error } | SpanKind::Shed { error } => {
            format!(",\"error\":\"{}\"", esc(error))
        }
        SpanKind::Fault { kind } => format!(",\"fault\":\"{kind}\""),
    }
}

fn span_obj(e: &SpanEvent) -> String {
    format!(
        "{{\"type\":\"span\",\"node\":{},\"seq\":{},\"round\":{},\"sim_s\":{:.9},\
         \"trace\":{},\"kind\":\"{}\"{}}}",
        e.node,
        e.seq,
        e.round,
        e.sim_s,
        trace_json(e.trace),
        e.kind.name(),
        kind_args(&e.kind)
    )
}

fn dump_line(d: &FlightDump) -> String {
    let events: Vec<String> = d.events.iter().map(span_obj).collect();
    format!(
        "{{\"type\":\"flight_dump\",\"node\":{},\"reason\":\"{}\",\"round\":{},\
         \"sim_s\":{:.9},\"dropped\":{},\"events\":[{}]}}",
        d.node,
        esc(&d.reason),
        d.round,
        d.sim_s,
        d.dropped,
        events.join(",")
    )
}

fn series_line(p: &SeriesPoint) -> String {
    format!(
        "{{\"type\":\"series\",\"node\":{},\"round\":{},\"sim_s\":{:.9},\
         \"queue_depth\":{},\"live_seqs\":{},\"parked_seqs\":{},\"pinned_blocks\":{},\
         \"cached_blocks\":{},\"free_blocks\":{},\"host_pool_bytes\":{},\"watts\":{:.9}}}",
        p.node,
        p.round,
        p.sim_s,
        p.queue_depth,
        p.live_seqs,
        p.parked_seqs,
        p.pinned_blocks,
        p.cached_blocks,
        p.free_blocks,
        p.host_pool_bytes,
        p.watts
    )
}

fn dispatch_line(p: &DispatchPoint) -> String {
    let lanes: Vec<String> = p.lane_deficits.iter().map(|d| format!("{d:.9}")).collect();
    let outstanding: Vec<String> = p.outstanding.iter().map(|o| o.to_string()).collect();
    format!(
        "{{\"type\":\"dispatch\",\"tick\":{},\"queued\":{},\"lane_deficits\":[{}],\
         \"outstanding\":[{}]}}",
        p.tick,
        p.queued,
        lanes.join(","),
        outstanding.join(",")
    )
}

/// Serialize a snapshot as the JSONL journal: one header line, then every
/// retained span, flight dump, series point, and dispatch sample — in
/// canonical order, so identical snapshots are identical bytes.
pub fn journal_jsonl(snap: &TraceSnapshot) -> String {
    let dropped: Vec<String> = snap.dropped.iter().map(|d| d.to_string()).collect();
    let mut out = format!(
        "{{\"type\":\"trace_header\",\"version\":1,\"nodes\":{},\"dropped\":[{}]}}\n",
        snap.dropped.len().saturating_sub(1),
        dropped.join(",")
    );
    for e in &snap.events {
        out.push_str(&span_obj(e));
        out.push('\n');
    }
    for d in &snap.dumps {
        out.push_str(&dump_line(d));
        out.push('\n');
    }
    for p in &snap.series {
        out.push_str(&series_line(p));
        out.push('\n');
    }
    for p in &snap.dispatch {
        out.push_str(&dispatch_line(p));
        out.push('\n');
    }
    out
}

// ------------------------------------------------------------- lifecycle

/// One reconstructed per-phase slice of a retired request's lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub struct Slice {
    pub name: &'static str,
    pub start_s: f64,
    pub dur_s: f64,
}

/// Reconstruct a retired request's lifecycle slices from its retire
/// event's ledger: contiguous `queued → prefill → replay → decode →
/// stall` spans ending at the retire stamp `end_sim_s`, zero-duration
/// phases omitted. The durations sum to `queue_s + ledger.device_s()` —
/// the request's end-to-end simulated latency — which the acceptance
/// test pins.
pub fn lifecycle_slices(queue_s: f64, ledger: &PhaseLedger, end_sim_s: f64) -> Vec<Slice> {
    let mut t = end_sim_s - queue_s - ledger.device_s();
    let mut out = Vec::new();
    for (name, dur) in [
        ("queued", queue_s),
        ("prefill", ledger.prefill_s),
        ("replay", ledger.replay_s),
        ("decode", ledger.decode_s),
        ("stall", ledger.stall_s),
    ] {
        if dur > 0.0 {
            out.push(Slice { name, start_s: t, dur_s: dur });
        }
        t += dur;
    }
    out
}

// ---------------------------------------------------------- chrome trace

fn us(s: f64) -> String {
    format!("{:.3}", s * 1e6)
}

/// A request's Chrome thread id: trace + 1 so requests never collide with
/// the node-scope thread 0.
fn tid(t: TraceId) -> u64 {
    if t.is_node_scope() {
        0
    } else {
        t.0 + 1
    }
}

/// Serialize a snapshot in Chrome trace-event format. `pid` is the node
/// (the dispatch stage is one past the last worker), `tid` is the request
/// trace (0 = node-scoped), timestamps are simulated microseconds.
pub fn chrome_trace(snap: &TraceSnapshot) -> String {
    let mut evs: Vec<String> = Vec::new();
    let all: Vec<&SpanEvent> =
        snap.events.iter().chain(snap.dumps.iter().flat_map(|d| d.events.iter())).collect();
    for e in all {
        let (pid, tid) = (e.node, tid(e.trace));
        match &e.kind {
            SpanKind::Retired { tokens, queue_s, ledger } => {
                for s in lifecycle_slices(*queue_s, ledger, e.sim_s) {
                    evs.push(format!(
                        "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                         \"pid\":{pid},\"tid\":{tid},\"args\":{{\"trace\":{}}}}}",
                        s.name,
                        us(s.start_s),
                        us(s.dur_s),
                        trace_json(e.trace)
                    ));
                }
                evs.push(format!(
                    "{{\"name\":\"retired\",\"ph\":\"i\",\"ts\":{},\"s\":\"t\",\
                     \"pid\":{pid},\"tid\":{tid},\"args\":{{\"tokens\":{tokens}}}}}",
                    us(e.sim_s)
                ));
            }
            SpanKind::DecodeRound { seqs, sim_s } => evs.push(format!(
                "{{\"name\":\"decode_round\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":{pid},\"tid\":0,\"args\":{{\"seqs\":{seqs}}}}}",
                us(e.sim_s - sim_s),
                us(*sim_s)
            )),
            SpanKind::Prefill { sim_s } => evs.push(format!(
                "{{\"name\":\"prefill\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":{pid},\"tid\":{tid},\"args\":{{\"trace\":{}}}}}",
                us(e.sim_s - sim_s),
                us(*sim_s),
                trace_json(e.trace)
            )),
            SpanKind::Replayed { tokens, sim_s } => evs.push(format!(
                "{{\"name\":\"replay\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":{pid},\"tid\":{tid},\"args\":{{\"tokens\":{tokens}}}}}",
                us(e.sim_s - sim_s),
                us(*sim_s)
            )),
            kind => {
                let args = kind_args(kind);
                // reuse the JSONL arg fragments as instant args
                let args = if args.is_empty() {
                    String::new()
                } else {
                    args[1..].to_string()
                };
                evs.push(format!(
                    "{{\"name\":\"{}\",\"ph\":\"i\",\"ts\":{},\"s\":\"t\",\
                     \"pid\":{pid},\"tid\":{tid},\"args\":{{{args}}}}}",
                    kind.name(),
                    us(e.sim_s)
                ));
            }
        }
    }
    for p in &snap.series {
        let ts = us(p.sim_s);
        let pid = p.node;
        evs.push(format!(
            "{{\"name\":\"kv_pages\",\"ph\":\"C\",\"ts\":{ts},\"pid\":{pid},\
             \"args\":{{\"pinned\":{},\"cached\":{},\"free\":{}}}}}",
            p.pinned_blocks, p.cached_blocks, p.free_blocks
        ));
        evs.push(format!(
            "{{\"name\":\"power_w\",\"ph\":\"C\",\"ts\":{ts},\"pid\":{pid},\
             \"args\":{{\"w\":{:.3}}}}}",
            p.watts
        ));
        evs.push(format!(
            "{{\"name\":\"load\",\"ph\":\"C\",\"ts\":{ts},\"pid\":{pid},\
             \"args\":{{\"queue\":{},\"live\":{},\"parked\":{}}}}}",
            p.queue_depth, p.live_seqs, p.parked_seqs
        ));
        evs.push(format!(
            "{{\"name\":\"host_pool_bytes\",\"ph\":\"C\",\"ts\":{ts},\"pid\":{pid},\
             \"args\":{{\"bytes\":{}}}}}",
            p.host_pool_bytes
        ));
    }
    let dispatch_pid = snap.dropped.len().saturating_sub(1);
    for p in &snap.dispatch {
        let ts = format!("{}.000", p.tick);
        evs.push(format!(
            "{{\"name\":\"admission_queue\",\"ph\":\"C\",\"ts\":{ts},\"pid\":{dispatch_pid},\
             \"args\":{{\"queued\":{}}}}}",
            p.queued
        ));
        if !p.lane_deficits.is_empty() {
            let lanes: Vec<String> = p
                .lane_deficits
                .iter()
                .enumerate()
                .map(|(i, d)| format!("\"lane{i}\":{d:.3}"))
                .collect();
            evs.push(format!(
                "{{\"name\":\"lane_deficit\",\"ph\":\"C\",\"ts\":{ts},\
                 \"pid\":{dispatch_pid},\"args\":{{{}}}}}",
                lanes.join(",")
            ));
        }
        if !p.outstanding.is_empty() {
            let nodes: Vec<String> = p
                .outstanding
                .iter()
                .enumerate()
                .map(|(i, o)| format!("\"node{i}\":{o}"))
                .collect();
            evs.push(format!(
                "{{\"name\":\"outstanding\",\"ph\":\"C\",\"ts\":{ts},\
                 \"pid\":{dispatch_pid},\"args\":{{{}}}}}",
                nodes.join(",")
            ));
        }
    }
    format!("{{\"traceEvents\":[\n{}\n]}}\n", evs.join(",\n"))
}

// ---------------------------------------------------------------- rollup

/// Human-readable latency-attribution rollup over a snapshot's retired
/// spans, per node plus a total — what `cmphx trace` prints.
pub fn attribution_rollup(snap: &TraceSnapshot) -> String {
    use super::span::Attribution;
    let nodes = snap.dropped.len().saturating_sub(1).max(1);
    let mut per: Vec<(Attribution, u64)> = vec![(Attribution::default(), 0); nodes];
    let all = snap.events.iter().chain(snap.dumps.iter().flat_map(|d| d.events.iter()));
    for e in all {
        if let SpanKind::Retired { queue_s, ledger, .. } = &e.kind {
            if let Some((a, n)) = per.get_mut(e.node) {
                a.record(*queue_s, ledger);
                *n += 1;
            }
        }
    }
    let mut total = (Attribution::default(), 0u64);
    let mut out = String::new();
    for (i, (a, n)) in per.iter().enumerate() {
        total.0.merge(a);
        total.1 += n;
        out.push_str(&format!(
            "node {i}: {n} retired | queue={:.4}s prefill={:.4}s decode={:.4}s \
             stall={:.4}s replay={:.4}s\n",
            a.queue_s, a.prefill_s, a.decode_s, a.stall_s, a.replay_s
        ));
    }
    let (a, n) = total;
    out.push_str(&format!(
        "total : {n} retired | queue={:.4}s prefill={:.4}s decode={:.4}s \
         stall={:.4}s replay={:.4}s\n",
        a.queue_s, a.prefill_s, a.decode_s, a.stall_s, a.replay_s
    ));
    out
}

// ---------------------------------------------------------------- parsing

/// Find the raw value of `"key":` at depth 1 of one JSON object,
/// string- and nesting-aware (keys inside nested values or string
/// literals are never matched).
fn raw_field<'a>(obj: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let b = obj.as_bytes();
    let (mut i, mut depth) = (0usize, 0i32);
    let (mut in_str, mut escaped) = (false, false);
    while i < b.len() {
        let c = b[i];
        if in_str {
            if escaped {
                escaped = false;
            } else if c == b'\\' {
                escaped = true;
            } else if c == b'"' {
                in_str = false;
            }
            i += 1;
            continue;
        }
        match c {
            b'"' => {
                if depth == 1 && obj[i..].starts_with(&pat) {
                    let start = i + pat.len();
                    return Some(&obj[start..value_end(obj, start)]);
                }
                in_str = true;
            }
            b'{' | b'[' => depth += 1,
            b'}' | b']' => depth -= 1,
            _ => {}
        }
        i += 1;
    }
    None
}

/// End of the JSON value starting at `start`: the next `,`/`}`/`]` at the
/// value's own depth.
fn value_end(obj: &str, start: usize) -> usize {
    let b = obj.as_bytes();
    let (mut i, mut depth) = (start, 0i32);
    let (mut in_str, mut escaped) = (false, false);
    while i < b.len() {
        let c = b[i];
        if in_str {
            if escaped {
                escaped = false;
            } else if c == b'\\' {
                escaped = true;
            } else if c == b'"' {
                in_str = false;
            }
            i += 1;
            continue;
        }
        match c {
            b'"' => in_str = true,
            b'{' | b'[' => depth += 1,
            b'}' | b']' if depth > 0 => depth -= 1,
            b'}' | b']' => return i,
            b',' if depth == 0 => return i,
            _ => {}
        }
        i += 1;
    }
    i
}

/// Split a JSON array body (no outer brackets) into its top-level
/// element slices.
fn split_elems(body: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0usize;
    while start < body.len() {
        let end = value_end(body, start);
        let piece = body[start..end].trim();
        if !piece.is_empty() {
            out.push(piece);
        }
        start = end + 1;
    }
    out
}

fn unesc(s: &str) -> anyhow::Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some('u') => {
                let hex: String = (&mut chars).take(4).collect();
                let code = u32::from_str_radix(&hex, 16).context("bad \\u escape")?;
                out.push(char::from_u32(code).context("bad \\u codepoint")?);
            }
            other => bail!("unknown escape \\{other:?}"),
        }
    }
    Ok(out)
}

fn u64_field(obj: &str, key: &str) -> anyhow::Result<u64> {
    raw_field(obj, key)
        .with_context(|| format!("missing field {key}"))?
        .trim()
        .parse()
        .with_context(|| format!("bad u64 field {key}"))
}

fn usize_field(obj: &str, key: &str) -> anyhow::Result<usize> {
    Ok(u64_field(obj, key)? as usize)
}

fn f64_field(obj: &str, key: &str) -> anyhow::Result<f64> {
    raw_field(obj, key)
        .with_context(|| format!("missing field {key}"))?
        .trim()
        .parse()
        .with_context(|| format!("bad f64 field {key}"))
}

fn bool_field(obj: &str, key: &str) -> anyhow::Result<bool> {
    match raw_field(obj, key).with_context(|| format!("missing field {key}"))?.trim() {
        "true" => Ok(true),
        "false" => Ok(false),
        other => bail!("bad bool field {key}: {other}"),
    }
}

fn str_field(obj: &str, key: &str) -> anyhow::Result<String> {
    let raw = raw_field(obj, key).with_context(|| format!("missing field {key}"))?.trim();
    let inner = raw
        .strip_prefix('"')
        .and_then(|r| r.strip_suffix('"'))
        .with_context(|| format!("field {key} is not a string: {raw}"))?;
    unesc(inner)
}

fn trace_field(obj: &str) -> anyhow::Result<TraceId> {
    match raw_field(obj, "trace").context("missing field trace")?.trim() {
        "null" => Ok(NODE_SCOPE),
        n => Ok(TraceId(n.parse().context("bad trace id")?)),
    }
}

fn parse_span(obj: &str) -> anyhow::Result<SpanEvent> {
    let kind_name = str_field(obj, "kind")?;
    let kind = match kind_name.as_str() {
        "queued" => SpanKind::Queued,
        "requeued" => SpanKind::Requeued,
        "aged" => SpanKind::Aged,
        "parked" => SpanKind::Parked,
        "deadline_miss" => SpanKind::DeadlineMiss,
        "dispatched" => SpanKind::Dispatched { node: usize_field(obj, "to")? },
        "admitted" => SpanKind::Admitted { cached_tokens: usize_field(obj, "cached_tokens")? },
        "prefill" => SpanKind::Prefill { sim_s: f64_field(obj, "phase_s")? },
        "decode_round" => SpanKind::DecodeRound {
            seqs: usize_field(obj, "seqs")?,
            sim_s: f64_field(obj, "phase_s")?,
        },
        "preempted" => SpanKind::Preempted { swapped: bool_field(obj, "swapped")? },
        "migrated" => SpanKind::Migrated { from: usize_field(obj, "from")? },
        "rescued" => SpanKind::Rescued { from: usize_field(obj, "from")? },
        "swap_out" => SpanKind::SwapOut {
            bytes: u64_field(obj, "bytes")?,
            stall_s: f64_field(obj, "stall_s")?,
        },
        "swap_in" => SpanKind::SwapIn {
            bytes: u64_field(obj, "bytes")?,
            stall_s: f64_field(obj, "stall_s")?,
        },
        "replayed" => SpanKind::Replayed {
            tokens: usize_field(obj, "tokens")?,
            sim_s: f64_field(obj, "phase_s")?,
        },
        "retired" => SpanKind::Retired {
            tokens: usize_field(obj, "tokens")?,
            queue_s: f64_field(obj, "queue_s")?,
            ledger: PhaseLedger {
                prefill_s: f64_field(obj, "prefill_s")?,
                decode_s: f64_field(obj, "decode_s")?,
                stall_s: f64_field(obj, "stall_s")?,
                replay_s: f64_field(obj, "replay_s")?,
            },
        },
        "failed" => SpanKind::Failed { error: str_field(obj, "error")? },
        "shed" => SpanKind::Shed { error: str_field(obj, "error")? },
        "fault" => {
            // fault names come from FaultKind::name(); map back to the
            // static str so the roundtrip stays byte-identical
            let name = str_field(obj, "fault")?;
            let known = [
                "node_death",
                "transient_stall",
                "link_downgrade",
                "vram_page_loss",
                "swap_in_failure",
                "thermal_throttle",
            ];
            let kind = known
                .iter()
                .find(|k| **k == name)
                .with_context(|| format!("unknown fault kind {name}"))?;
            SpanKind::Fault { kind }
        }
        other => bail!("unknown span kind {other}"),
    };
    Ok(SpanEvent {
        seq: u64_field(obj, "seq")?,
        node: usize_field(obj, "node")?,
        round: u64_field(obj, "round")?,
        sim_s: f64_field(obj, "sim_s")?,
        trace: trace_field(obj)?,
        kind,
    })
}

fn parse_array_u64(obj: &str, key: &str) -> anyhow::Result<Vec<u64>> {
    let raw = raw_field(obj, key).with_context(|| format!("missing field {key}"))?.trim();
    let body = raw
        .strip_prefix('[')
        .and_then(|r| r.strip_suffix(']'))
        .with_context(|| format!("field {key} is not an array"))?;
    split_elems(body)
        .into_iter()
        .map(|e| e.parse().with_context(|| format!("bad u64 in {key}")))
        .collect()
}

fn parse_array_f64(obj: &str, key: &str) -> anyhow::Result<Vec<f64>> {
    let raw = raw_field(obj, key).with_context(|| format!("missing field {key}"))?.trim();
    let body = raw
        .strip_prefix('[')
        .and_then(|r| r.strip_suffix(']'))
        .with_context(|| format!("field {key} is not an array"))?;
    split_elems(body)
        .into_iter()
        .map(|e| e.parse().with_context(|| format!("bad f64 in {key}")))
        .collect()
}

/// Parse a JSONL journal back into a [`TraceSnapshot`] — the `trace` CLI
/// command's reader, and the well-formedness gate the trace smoke
/// asserts (every line must parse, every span kind must be known).
pub fn parse_journal(text: &str) -> anyhow::Result<TraceSnapshot> {
    let mut snap = TraceSnapshot::default();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let ctx = || format!("journal line {}", lineno + 1);
        let ty = str_field(line, "type").with_context(ctx)?;
        match ty.as_str() {
            "trace_header" => {
                snap.dropped = parse_array_u64(line, "dropped").with_context(ctx)?;
            }
            "span" => snap.events.push(parse_span(line).with_context(ctx)?),
            "flight_dump" => {
                let body = raw_field(line, "events").context("missing dump events")?;
                let body = body
                    .trim()
                    .strip_prefix('[')
                    .and_then(|r| r.strip_suffix(']'))
                    .context("dump events is not an array")?;
                let events = split_elems(body)
                    .into_iter()
                    .map(parse_span)
                    .collect::<anyhow::Result<Vec<_>>>()
                    .with_context(ctx)?;
                snap.dumps.push(FlightDump {
                    node: usize_field(line, "node").with_context(ctx)?,
                    reason: str_field(line, "reason").with_context(ctx)?,
                    round: u64_field(line, "round").with_context(ctx)?,
                    sim_s: f64_field(line, "sim_s").with_context(ctx)?,
                    dropped: u64_field(line, "dropped").with_context(ctx)?,
                    events,
                });
            }
            "series" => snap.series.push(SeriesPoint {
                node: usize_field(line, "node").with_context(ctx)?,
                round: u64_field(line, "round").with_context(ctx)?,
                sim_s: f64_field(line, "sim_s").with_context(ctx)?,
                queue_depth: usize_field(line, "queue_depth").with_context(ctx)?,
                live_seqs: usize_field(line, "live_seqs").with_context(ctx)?,
                parked_seqs: usize_field(line, "parked_seqs").with_context(ctx)?,
                pinned_blocks: usize_field(line, "pinned_blocks").with_context(ctx)?,
                cached_blocks: usize_field(line, "cached_blocks").with_context(ctx)?,
                free_blocks: usize_field(line, "free_blocks").with_context(ctx)?,
                host_pool_bytes: u64_field(line, "host_pool_bytes").with_context(ctx)?,
                watts: f64_field(line, "watts").with_context(ctx)?,
            }),
            "dispatch" => snap.dispatch.push(DispatchPoint {
                tick: u64_field(line, "tick").with_context(ctx)?,
                queued: usize_field(line, "queued").with_context(ctx)?,
                lane_deficits: parse_array_f64(line, "lane_deficits").with_context(ctx)?,
                outstanding: parse_array_u64(line, "outstanding").with_context(ctx)?,
            }),
            other => bail!("{}: unknown line type {other}", ctx()),
        }
    }
    Ok(snap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{FaultInjector, FaultKind, FaultPlan};
    use crate::obsv::journal::Tracer;

    /// A deterministic single-threaded fleet story driven by a seeded
    /// fault script: 2 nodes, 16 rounds, six requests queued and one full
    /// rescued lifecycle — the shape the live engine emits, with every
    /// stamp on the simulated clock.
    fn scripted_tracer(seed: u64) -> Tracer {
        let plan = FaultPlan::seeded(seed, 2, 16, 0.3);
        let inj = FaultInjector::new(&plan, 2);
        let t = Tracer::new(2, 64, true);
        let dj = t.dispatch_node();
        for i in 0..6u64 {
            t.emit(dj, TraceId(i), SpanKind::Queued);
            t.emit(dj, TraceId(i), SpanKind::Dispatched { node: (i % 2) as usize });
        }
        let mut sim = [0.0f64; 2];
        for round in 1..=16u64 {
            for node in 0..2usize {
                t.set_round(node, round);
                for f in inj.begin_round(node) {
                    t.emit(node, NODE_SCOPE, SpanKind::Fault { kind: f.name() });
                    if f == FaultKind::NodeDeath {
                        t.emit(node, TraceId(node as u64), SpanKind::Rescued { from: node });
                        t.flight_dump(node, "node death");
                    }
                }
                t.advance(node, 0.002);
                sim[node] += 0.002;
                t.emit(node, NODE_SCOPE, SpanKind::DecodeRound { seqs: 3, sim_s: 0.002 });
                t.sample(SeriesPoint {
                    node,
                    round,
                    sim_s: sim[node],
                    queue_depth: (round % 3) as usize,
                    live_seqs: 3,
                    pinned_blocks: 10 + round as usize,
                    cached_blocks: 2,
                    free_blocks: 20 - round as usize,
                    watts: 221.5,
                    ..SeriesPoint::default()
                });
            }
            if round % 4 == 0 {
                t.drain();
            }
            t.sample_dispatch(DispatchPoint {
                tick: round,
                queued: (round % 2) as usize,
                lane_deficits: vec![0.5, -0.25],
                outstanding: vec![2, 1],
            });
        }
        t.emit(
            0,
            TraceId(0),
            SpanKind::Retired {
                tokens: 8,
                queue_s: 0.001,
                ledger: PhaseLedger {
                    prefill_s: 0.004,
                    decode_s: 0.016,
                    stall_s: 0.0005,
                    replay_s: 0.002,
                },
            },
        );
        t
    }

    #[test]
    fn same_seed_exports_are_byte_identical() {
        // The determinism acceptance: the same seeded fault script drives
        // two independent tracers through the same schedule → the JSONL
        // journal and the Chrome trace are byte-identical. A different
        // seed perturbs the fault events and must show in the bytes.
        let a = scripted_tracer(7).snapshot();
        let b = scripted_tracer(7).snapshot();
        assert_eq!(journal_jsonl(&a), journal_jsonl(&b));
        assert_eq!(chrome_trace(&a), chrome_trace(&b));
        let c = scripted_tracer(8).snapshot();
        assert_ne!(journal_jsonl(&a), journal_jsonl(&c));
    }

    #[test]
    fn jsonl_roundtrips_byte_identically() {
        // export → parse → export is the identity on the text: the parser
        // reconstructs every line type and the writer's formatting is
        // canonical.
        let snap = scripted_tracer(42).snapshot();
        let text = journal_jsonl(&snap);
        let parsed = parse_journal(&text).unwrap();
        assert_eq!(journal_jsonl(&parsed), text);
        // and the chrome view regenerated from the parsed journal matches
        assert_eq!(chrome_trace(&parsed), chrome_trace(&snap));
    }

    #[test]
    fn lifecycle_slices_sum_to_end_to_end_sim_latency() {
        // The acceptance invariant: a rescued request's reconstructed
        // per-phase slices are contiguous, end at the retire stamp, and
        // their durations sum to queue + device seconds — its end-to-end
        // simulated latency.
        let ledger = PhaseLedger {
            prefill_s: 0.004,
            decode_s: 0.016,
            stall_s: 0.0005,
            replay_s: 0.002,
        };
        let queue_s = 0.001;
        let end = 0.125;
        let slices = lifecycle_slices(queue_s, &ledger, end);
        assert_eq!(slices.len(), 5, "every nonzero phase appears");
        let total: f64 = slices.iter().map(|s| s.dur_s).sum();
        assert!((total - (queue_s + ledger.device_s())).abs() < 1e-12);
        for w in slices.windows(2) {
            assert!(
                (w[0].start_s + w[0].dur_s - w[1].start_s).abs() < 1e-12,
                "slices are contiguous"
            );
        }
        let last = slices.last().unwrap();
        assert!((last.start_s + last.dur_s - end).abs() < 1e-12, "lifecycle ends at retire");
        assert_eq!(
            slices.iter().map(|s| s.name).collect::<Vec<_>>(),
            vec!["queued", "prefill", "replay", "decode", "stall"]
        );
        // zero-duration phases vanish
        let fresh = lifecycle_slices(0.0, &PhaseLedger::default(), 1.0);
        assert!(fresh.is_empty());
    }

    #[test]
    fn a_rescued_lifecycle_reconstructs_from_the_journal() {
        // queued → dispatched → admitted → preempted → rescued → replayed
        // → retired, as the engine emits it; the chrome export must carry
        // a slice row whose spans cover the whole simulated latency.
        let t = Tracer::new(2, 64, true);
        let dj = t.dispatch_node();
        let id = TraceId(3);
        t.emit(dj, id, SpanKind::Queued);
        t.emit(dj, id, SpanKind::Dispatched { node: 0 });
        t.emit(0, id, SpanKind::Admitted { cached_tokens: 2 });
        t.advance(0, 0.004);
        t.emit(0, id, SpanKind::Prefill { sim_s: 0.004 });
        t.emit(0, id, SpanKind::Preempted { swapped: false });
        t.emit(0, id, SpanKind::Rescued { from: 0 });
        t.emit(dj, id, SpanKind::Requeued);
        t.emit(dj, id, SpanKind::Dispatched { node: 1 });
        t.emit(1, id, SpanKind::Admitted { cached_tokens: 0 });
        t.advance(1, 0.006);
        t.emit(1, id, SpanKind::Replayed { tokens: 4, sim_s: 0.002 });
        t.advance(1, 0.016);
        let ledger =
            PhaseLedger { prefill_s: 0.008, decode_s: 0.012, stall_s: 0.0, replay_s: 0.002 };
        t.emit(1, id, SpanKind::Retired { tokens: 8, queue_s: 0.003, ledger });
        let snap = t.snapshot();
        let text = journal_jsonl(&snap);
        assert!(text.contains("\"kind\":\"rescued\""), "{text}");
        let chrome = chrome_trace(&snap);
        // the retired row's X slices sum to the end-to-end latency
        let retired = snap
            .events
            .iter()
            .find_map(|e| match &e.kind {
                SpanKind::Retired { queue_s, ledger, .. } => {
                    Some(lifecycle_slices(*queue_s, ledger, e.sim_s))
                }
                _ => None,
            })
            .unwrap();
        let total: f64 = retired.iter().map(|s| s.dur_s).sum();
        assert!((total - (0.003 + ledger.device_s())).abs() < 1e-12);
        assert!(chrome.contains("\"name\":\"replay\""), "{chrome}");
        assert!(chrome.contains("\"ph\":\"X\""), "{chrome}");
    }

    #[test]
    fn chrome_trace_has_the_loadable_shape() {
        let snap = scripted_tracer(1).snapshot();
        let c = chrome_trace(&snap);
        assert!(c.starts_with("{\"traceEvents\":[\n"));
        assert!(c.ends_with("\n]}\n"));
        assert!(c.contains("\"ph\":\"X\""), "slices present");
        assert!(c.contains("\"ph\":\"C\""), "counter tracks present");
        assert!(c.contains("\"ph\":\"i\""), "instants present");
        assert!(c.contains("\"name\":\"kv_pages\""));
        assert!(c.contains("\"name\":\"power_w\""));
        assert!(c.contains("\"name\":\"lane_deficit\""));
        // braces balance outside string literals — the loadability smoke
        let (mut depth, mut in_str, mut esc) = (0i64, false, false);
        for ch in c.chars() {
            if in_str {
                if esc {
                    esc = false;
                } else if ch == '\\' {
                    esc = true;
                } else if ch == '"' {
                    in_str = false;
                }
                continue;
            }
            match ch {
                '"' => in_str = true,
                '{' | '[' => depth += 1,
                '}' | ']' => depth -= 1,
                _ => {}
            }
            assert!(depth >= 0);
        }
        assert_eq!(depth, 0, "balanced JSON");
    }

    #[test]
    fn error_strings_escape_and_roundtrip() {
        let t = Tracer::new(1, 8, true);
        t.emit(
            0,
            TraceId(5),
            SpanKind::Failed { error: "bad \"quote\"\nand \\ backslash [trace 5]".into() },
        );
        let snap = t.snapshot();
        let text = journal_jsonl(&snap);
        let parsed = parse_journal(&text).unwrap();
        match &parsed.events[0].kind {
            SpanKind::Failed { error } => {
                assert_eq!(error, "bad \"quote\"\nand \\ backslash [trace 5]")
            }
            other => panic!("expected failed, got {other:?}"),
        }
        assert_eq!(journal_jsonl(&parsed), text);
    }

    #[test]
    fn flight_dumps_serialize_with_their_events_inline() {
        let t = Tracer::new(1, 8, true);
        t.emit(0, TraceId(1), SpanKind::Admitted { cached_tokens: 0 });
        t.flight_dump(0, "terminal error: KV pages exhausted [trace 1]");
        let snap = t.snapshot();
        let text = journal_jsonl(&snap);
        assert!(text.contains("\"type\":\"flight_dump\""));
        let parsed = parse_journal(&text).unwrap();
        assert_eq!(parsed.dumps.len(), 1);
        assert_eq!(parsed.dumps[0].events.len(), 1);
        assert_eq!(parsed.dumps[0].reason, "terminal error: KV pages exhausted [trace 1]");
        assert_eq!(journal_jsonl(&parsed), text);
    }

    #[test]
    fn attribution_rollup_sums_retired_spans_per_node() {
        let t = Tracer::new(2, 64, true);
        let l0 = PhaseLedger { prefill_s: 0.1, decode_s: 0.4, ..PhaseLedger::default() };
        let l1 = PhaseLedger { replay_s: 0.25, stall_s: 0.05, ..PhaseLedger::default() };
        t.emit(0, TraceId(1), SpanKind::Retired { tokens: 4, queue_s: 0.5, ledger: l0 });
        t.emit(1, TraceId(2), SpanKind::Retired { tokens: 4, queue_s: 0.25, ledger: l1 });
        let s = attribution_rollup(&t.snapshot());
        assert!(s.contains("node 0: 1 retired | queue=0.5000s prefill=0.1000s"), "{s}");
        assert!(s.contains("node 1: 1 retired"), "{s}");
        assert!(s.contains("total : 2 retired | queue=0.7500s"), "{s}");
        assert!(s.contains("replay=0.2500s"), "{s}");
    }

    #[test]
    fn malformed_lines_are_rejected_loudly() {
        assert!(parse_journal("{\"type\":\"span\",\"node\":0}").is_err());
        assert!(parse_journal("{\"type\":\"mystery\"}").is_err());
        assert!(
            parse_journal(
                "{\"type\":\"span\",\"node\":0,\"seq\":0,\"round\":0,\"sim_s\":0.0,\
                 \"trace\":null,\"kind\":\"nonsense\"}"
            )
            .is_err()
        );
        assert!(parse_journal("").unwrap().events.is_empty());
    }
}
