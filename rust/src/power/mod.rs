//! Power/energy model and an `nvidia-smi`-like sampler.
//!
//! §4.4 measures decode token/W with nvidia-smi during inference. Our model:
//! board power = static floor + dynamic compute power (per-pipe activity ×
//! energy/op) + memory power (bytes/s × energy/byte), clipped to TDP by a
//! DVFS derate that also slows the kernel (GPU-Burn sits exactly at TDP).
//!
//! Energy coefficients are calibrated so that (a) a compute-saturated FP32
//! kernel on healthy GA100 silicon sits at TDP, (b) a bandwidth-saturated
//! decode sits at ~200 W of the 250 W TDP — the regime where the paper finds
//! CMP token/W ≈ A100 token/W.

pub mod model;
pub mod sampler;

pub use model::{PowerBreakdown, PowerModel};
pub use sampler::PowerSampler;
