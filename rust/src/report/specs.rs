//! Spec-sheet rendering (Tables 2-1…2-5) from the device registry.

use crate::device::DeviceSpec;
use crate::isa::class::InstClass;

/// Render the Tables 2-1…2-4 equivalent for one device.
pub fn spec_sheet(dev: &DeviceSpec) -> String {
    let mut s = String::new();
    s.push_str(&format!("=== {} ({}) ===\n", dev.name, dev.arch));
    s.push_str(&format!(
        "SMs {:>18}   CUDA cores {:>12}\n",
        dev.sms, dev.cuda_cores
    ));
    s.push_str(&format!(
        "base clock {:>7.0} MHz   boost clock {:>7.0} MHz\n",
        dev.base_clock_hz / 1e6,
        dev.boost_clock_hz / 1e6
    ));
    s.push_str(&format!(
        "L1/SM {:>9} KiB    L2 {:>14} MiB\n",
        dev.l1_bytes_per_sm / 1024,
        dev.mem.l2_bytes / (1 << 20)
    ));
    s.push_str(&format!(
        "memory {:>6} GiB {}   bandwidth {:>7.0} GB/s\n",
        dev.mem.capacity_bytes >> 30,
        dev.mem.kind,
        dev.mem.peak_bw / 1e9
    ));
    s.push_str(&format!(
        "PCIe {} x{}   TDP {:.0} W   released {}   ASP ${:.0}\n",
        dev.pcie.gen.name(),
        dev.pcie.lanes,
        dev.tdp_w,
        dev.released,
        dev.price_usd
    ));
    s.push_str(&format!(
        "theoretical: FP32 {:>6.2}  FP16 {:>6.2}  FP64 {:>6.3} TFLOPS  tensor-f16 {:>6.1}\n",
        dev.fp32_tflops(),
        dev.fp16_tflops(),
        dev.fp64_tflops(),
        dev.tensor_f16_tflops()
    ));
    if dev.throttle.is_crippled() {
        s.push_str("limiter: ");
        for (c, m) in dev.throttle.throttled_classes() {
            if m == 0.0 {
                s.push_str(&format!("{}=off ", c.name()));
            } else {
                s.push_str(&format!("{}=1/{:.0} ", c.name(), 1.0 / m));
            }
        }
        s.push('\n');
        s.push_str(&format!(
            "effective FP32 (FFMA) {:.3} TFLOPS — restored via -fmad=false: {:.2} TFLOPS\n",
            dev.fp32_tflops() * dev.throttle.mult(InstClass::Ffma),
            dev.fp32_tflops() / 2.0
        ));
    }
    s
}

/// All devices, Table 2-x style.
pub fn all_spec_sheets() -> String {
    crate::device::registry::all()
        .iter()
        .map(spec_sheet)
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::registry;

    #[test]
    fn sheet_contains_table_2_values() {
        let s = spec_sheet(&registry::cmp170hx());
        assert!(s.contains("CMP 170HX"));
        assert!(s.contains("SMs"));
        assert!(s.contains("1493"));
        assert!(s.contains("limiter:"));
        assert!(s.contains("FFMA=1/32"));
    }

    #[test]
    fn a100_sheet_has_no_limiter_line() {
        let s = spec_sheet(&registry::a100_pcie());
        assert!(!s.contains("limiter:"));
    }

    #[test]
    fn all_sheets_cover_registry() {
        let s = all_spec_sheets();
        for d in registry::all() {
            assert!(s.contains(d.name), "{}", d.name);
        }
    }
}
