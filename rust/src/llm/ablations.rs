//! Ablation sweeps over the §4 model's calibrated design choices
//! (DESIGN.md promises these for every knob the calibration leans on).
//!
//! Each sweep perturbs ONE parameter of the llama-bench decomposition and
//! reports how the paper-visible quantities move — the sensitivity
//! analysis that tells a reader which conclusions are robust to the
//! calibration and which are knife-edge.

use crate::device::registry;
use crate::isa::ir::KernelSource;
use crate::isa::pass::FmadPolicy;
use crate::llm::llamabench::LlamaBench;
use crate::llm::quant::{self, QuantFormat};

/// One ablation row: parameter value → (q2_k prefill speedup, q2_k decode
/// fraction of theoretical).
#[derive(Clone, Debug)]
pub struct AblationPoint {
    pub value: f64,
    pub q2_prefill_speedup: f64,
    pub q2_decode_fraction: f64,
}

fn q2_with(scale_fmas: f64, float_frac: f64) -> QuantFormat {
    QuantFormat {
        scale_fmas_per_block: scale_fmas,
        decode_float_frac: float_frac,
        ..quant::Q2_K
    }
}

/// Sweep the Q2_K scale-FMA density (the knob behind the 231% prefill
/// claim). The paper's number pins it near 10/block; the *ordering* of
/// speedups (q2 > q4 > q6 > q8) holds across the whole sweep.
pub fn sweep_scale_fmas(values: &[f64]) -> Vec<AblationPoint> {
    let bench = LlamaBench::default();
    let dev = registry::cmp170hx();
    values
        .iter()
        .map(|&v| {
            let q = q2_with(v, quant::Q2_K.decode_float_frac);
            let def = bench.run(&dev, &q, FmadPolicy::Fused);
            let nofma = bench.run(&dev, &q, FmadPolicy::Decomposed);
            AblationPoint {
                value: v,
                q2_prefill_speedup: nofma.prefill_tps / def.prefill_tps,
                q2_decode_fraction: def.decode_fraction(),
            }
        })
        .collect()
}

/// Sweep the decode float fraction (MMVQ's fp32 share) — the knob behind
/// the 39–78% decode band.
pub fn sweep_decode_float_frac(values: &[f64]) -> Vec<AblationPoint> {
    let bench = LlamaBench::default();
    let dev = registry::cmp170hx();
    values
        .iter()
        .map(|&v| {
            let q = q2_with(quant::Q2_K.scale_fmas_per_block, v);
            let def = bench.run(&dev, &q, FmadPolicy::Fused);
            let nofma = bench.run(&dev, &q, FmadPolicy::Decomposed);
            AblationPoint {
                value: v,
                q2_prefill_speedup: nofma.prefill_tps / def.prefill_tps,
                q2_decode_fraction: def.decode_fraction(),
            }
        })
        .collect()
}

/// The cuBLAS-boundary ablation: what *would* f32/f16 gain from noFMA if
/// their GEMMs were JIT-compiled instead of prebuilt? (Counterfactual for
/// §5.3's "modifying PyTorch faces significant challenges".)
pub fn counterfactual_jit_floats() -> Vec<(String, f64)> {
    let bench = LlamaBench::default();
    let dev = registry::cmp170hx();
    let mut rows = Vec::new();
    for base in [quant::F32, quant::F16] {
        let jit = QuantFormat {
            source: KernelSource::Jit,
            ..base
        };
        for (label, q) in [("lib (real)", base), ("jit (counterfactual)", jit)] {
            let def = bench.run(&dev, &q, FmadPolicy::Fused);
            let nofma = bench.run(&dev, &q, FmadPolicy::Decomposed);
            rows.push((
                format!("{} {}", q.name, label),
                nofma.prefill_tps / def.prefill_tps,
            ));
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_grows_monotonically_with_scale_fmas() {
        let pts = sweep_scale_fmas(&[2.0, 5.0, 10.0, 20.0]);
        for w in pts.windows(2) {
            assert!(
                w[1].q2_prefill_speedup > w[0].q2_prefill_speedup,
                "{pts:?}"
            );
        }
        // the paper's 231% needs scale_fmas in a plausible mid-range, not
        // an extreme corner
        assert!(pts[2].q2_prefill_speedup > 2.0 && pts[2].q2_prefill_speedup < 2.7);
    }

    #[test]
    fn decode_fraction_falls_as_float_share_rises() {
        let pts = sweep_decode_float_frac(&[0.05, 0.14, 0.3, 0.5]);
        for w in pts.windows(2) {
            assert!(
                w[1].q2_decode_fraction < w[0].q2_decode_fraction,
                "{pts:?}"
            );
        }
        // the paper's 39–78% band tolerates a ±2× float-share error
        assert!(pts[1].q2_decode_fraction > 0.39 && pts[1].q2_decode_fraction < 0.78);
    }

    #[test]
    fn cublas_boundary_is_what_blocks_float_gains() {
        let rows = counterfactual_jit_floats();
        let get = |pat: &str| {
            rows.iter()
                .find(|(l, _)| l.contains(pat))
                .map(|(_, s)| *s)
                .unwrap()
        };
        // real: no gain (Lib boundary). counterfactual JIT: f32 gets
        // *worse* — its GEMM runs on the scalar-half pipe, where
        // decomposition doubles instructions at an unchanged issue rate —
        // and f16 stays flat (packed-half mul/add dual-issues). This is a
        // stronger version of §5.3's conclusion: even if one could rebuild
        // PyTorch/cuBLAS with -fmad=false, the float paths have nothing to
        // recover; the gain lives entirely in the quantized kernels' fp32
        // scale math.
        assert!((get("f32 lib (real)") - 1.0).abs() < 1e-9);
        assert!((get("f16 lib (real)") - 1.0).abs() < 1e-9);
        assert!(get("f32 jit (counterfactual)") < 1.0);
        assert!((get("f16 jit (counterfactual)") - 1.0).abs() < 0.05);
    }
}
