//! Failure injection across the runtime/coordinator boundary: corrupted
//! artifacts, backpressure, and concurrent submission races.
//!
//! Tests skip (pass vacuously, with a note on stderr) when artifacts or a
//! live PJRT client are unavailable.

use std::time::Duration;

use cmphx::coordinator::batcher::BatchPolicy;
use cmphx::coordinator::scheduler::StepPolicy;
use cmphx::coordinator::{Server, ServerConfig};
use cmphx::isa::pass::FmadPolicy;
use cmphx::runtime::{ArtifactDir, ModelRuntime};

mod common;
use common::artifact_dir;

/// Copy the artifact dir with one entry corrupted.
fn corrupted_copy(src: &ArtifactDir, victim: &str, garbage: &str) -> ArtifactDir {
    let dst = std::env::temp_dir().join(format!("cmphx-corrupt-{victim}"));
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(&dst).unwrap();
    for entry in cmphx::runtime::artifacts::REQUIRED {
        std::fs::copy(src.path(entry), dst.join(entry)).unwrap();
    }
    std::fs::write(dst.join(victim), garbage).unwrap();
    ArtifactDir::open(&dst).unwrap()
}

#[test]
fn corrupted_hlo_text_is_a_clean_error() {
    let Some(src) = artifact_dir() else { return };
    let dir = corrupted_copy(&src, "decode.hlo.txt", "HloModule broken\nthis is not hlo");
    let err = ModelRuntime::load(&dir).err().expect("must fail").to_string();
    assert!(err.contains("decode.hlo.txt"), "{err}");
}

#[test]
fn corrupted_goldens_json_is_a_clean_error() {
    let Some(src) = artifact_dir() else { return };
    let dir = corrupted_copy(&src, "goldens.json", "{ not json !!");
    let err = format!("{:#}", ModelRuntime::load(&dir).err().expect("must fail"));
    assert!(!err.is_empty());
}

#[test]
fn server_start_surfaces_compile_failure() {
    let Some(src) = artifact_dir() else { return };
    let dir = corrupted_copy(&src, "prefill.hlo.txt", "HloModule broken ENTRY {}");
    let err = Server::start(dir, ServerConfig::default());
    assert!(err.is_err(), "server must not come up on a broken artifact");
}

#[test]
fn concurrent_submitters_all_get_served() {
    let config = ServerConfig {
        queue_depth: 64,
        batch: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            ..BatchPolicy::default()
        },
        step_policy: StepPolicy::RoundRobin,
        fmad: FmadPolicy::Decomposed,
        ..Default::default()
    };
    let Some(dir) = artifact_dir() else { return };
    let server = std::sync::Arc::new(Server::start(dir, config).unwrap());
    let mut handles = Vec::new();
    for t in 0..4 {
        let server = std::sync::Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            let mut tokens = 0usize;
            for i in 0..3 {
                let prompt: Vec<i32> = (1..=6).map(|x| (x * (t * 7 + i + 2)) % 500 + 1).collect();
                let rx = server.submit(prompt, 4).expect("submit");
                let resp = rx.recv_timeout(Duration::from_secs(180)).expect("recv");
                assert!(resp.ok(), "{:?}", resp.error);
                tokens += resp.tokens.len();
            }
            tokens
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 4 * 3 * 4);
}

#[test]
fn tiny_queue_applies_backpressure() {
    let config = ServerConfig {
        queue_depth: 1,
        batch: BatchPolicy {
            max_batch: 1,
            // long gather window so the engine stays occupied while we
            // flood the admission queue
            max_wait: Duration::from_millis(300),
            ..BatchPolicy::default()
        },
        step_policy: StepPolicy::RoundRobin,
        fmad: FmadPolicy::Decomposed,
        ..Default::default()
    };
    let Some(dir) = artifact_dir() else { return };
    let server = Server::start(dir, config).unwrap();
    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for _ in 0..32 {
        match server.submit(vec![1, 2, 3], 2) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                assert!(e.to_string().contains("backpressure"), "{e}");
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "flooding a depth-1 queue must shed load");
    for rx in accepted {
        let resp = rx.recv_timeout(Duration::from_secs(180)).unwrap();
        assert!(resp.ok());
    }
}
