//! Tiny argument parser: `cmd [positional…] [--key value] [--flag]`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    /// Every occurrence of each `--key value`, in order — repeatable
    /// options (`--tenant a:1 --tenant b:2`) keep all values; [`Args::opt`]
    /// reads the last one.
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        args.command = it.next().unwrap_or_else(|| "help".to_string());
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value | --key value | --flag
                if let Some((k, v)) = key.split_once('=') {
                    args.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    args.options.entry(key.to_string()).or_default().push(it.next().unwrap());
                } else {
                    args.flags.push(key.to_string());
                }
            } else if a.starts_with('-') && a.len() > 1 {
                bail!("short options are not supported: {a}");
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// The last value given for `--key` (repeats override, like most
    /// CLIs); [`Args::opt_all`] sees every occurrence.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|vs| vs.last()).map(String::as_str)
    }

    /// Every value given for a repeatable `--key`, in command-line order.
    pub fn opt_all(&self, key: &str) -> impl Iterator<Item = &str> {
        self.options.get(key).into_iter().flatten().map(String::as_str)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_command_positional_options_flags() {
        let a = parse("bench fp32 --device a100 --csv --n 5");
        assert_eq!(a.command, "bench");
        assert_eq!(a.pos(0), Some("fp32"));
        assert_eq!(a.opt("device"), Some("a100"));
        assert!(a.flag("csv"));
        assert_eq!(a.opt_usize("n", 1).unwrap(), 5);
    }

    #[test]
    fn equals_form_works() {
        let a = parse("serve --requests=12");
        assert_eq!(a.opt_usize("requests", 0).unwrap(), 12);
    }

    #[test]
    fn empty_argv_means_help() {
        let a = Args::parse(std::iter::empty()).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn trailing_flag_is_a_flag() {
        let a = parse("report --all");
        assert!(a.flag("all"));
        assert_eq!(a.opt("all"), None);
    }

    #[test]
    fn repeated_options_keep_every_value_and_opt_reads_the_last() {
        let a = parse("serve --tenant light:1 --tenant heavy:3 --batch 2 --batch 4");
        let tenants: Vec<&str> = a.opt_all("tenant").collect();
        assert_eq!(tenants, vec!["light:1", "heavy:3"]);
        assert_eq!(a.opt("tenant"), Some("heavy:3"));
        assert_eq!(a.opt_usize("batch", 1).unwrap(), 4);
        assert_eq!(a.opt_all("missing").count(), 0);
    }

    #[test]
    fn rejects_short_options() {
        assert!(Args::parse(vec!["x".into(), "-v".into()]).is_err());
    }

    #[test]
    fn defaults_apply_when_missing() {
        let a = parse("serve");
        assert_eq!(a.opt_usize("requests", 8).unwrap(), 8);
    }

    #[test]
    fn float_options_parse_and_default() {
        let a = parse("serve --affinity-bonus 3.5");
        assert_eq!(a.opt_f64("affinity-bonus", 2.0).unwrap(), 3.5);
        assert_eq!(a.opt_f64("missing", 2.0).unwrap(), 2.0);
        assert!(parse("serve --affinity-bonus=much").opt_f64("affinity-bonus", 2.0).is_err());
    }
}
