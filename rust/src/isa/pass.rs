//! The `-fmad=false` compiler pass.
//!
//! This is the paper's enabling technique (§2.2.2, credited to niconiconi's
//! blog): compile CUDA with `-fmad=false` (or OpenCL with
//! `#pragma OPENCL FP_CONTRACT OFF` + an `fma()` override) so the compiler
//! emits unfused MUL+ADD pairs instead of fused FFMA/DFMA instructions. On a
//! healthy GPU this *halves* attainable FLOPs (two issue slots per fused
//! op); on the CMP 170HX, whose limiter keys on the fused opcodes, it
//! trades a 2× instruction inflation for a 32× issue-rate recovery — a
//! net ≈16× speedup on FP32.
//!
//! The pass is a structural rewrite over [`Kernel`] bodies. It honours the
//! compiled-library boundary: kernels marked [`KernelSource::Lib`] (cuBLAS
//! et al.) ship prebuilt SASS and are returned unchanged.

use super::ir::{Kernel, KernelSource, Op, Stmt};

/// Whether fused multiply-add contraction is permitted at compile time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FmadPolicy {
    /// Default toolchain behaviour: contract `a*b+c` into fused FMA.
    Fused,
    /// `-fmad=false` / `FP_CONTRACT OFF`: every fused op becomes an unfused
    /// MUL followed by ADD (two instructions, double rounding).
    Decomposed,
}

impl FmadPolicy {
    pub fn name(self) -> &'static str {
        match self {
            FmadPolicy::Fused => "default",
            FmadPolicy::Decomposed => "noFMA",
        }
    }
}

/// Apply the fmad policy to a kernel, producing the kernel the device will
/// actually execute. `Fused` and `Lib`-sourced kernels pass through
/// untouched; `Decomposed` rewrites every fused-class op into its MUL+ADD
/// pair, preserving loop structure and op order.
pub fn apply_fmad(kernel: &Kernel, policy: FmadPolicy) -> Kernel {
    if policy == FmadPolicy::Fused || kernel.source == KernelSource::Lib {
        return kernel.clone();
    }
    let mut out = kernel.clone();
    out.name = format!("{}.nofma", kernel.name);
    out.body = rewrite(&kernel.body);
    out
}

fn rewrite(stmts: &[Stmt]) -> Vec<Stmt> {
    let mut out = Vec::with_capacity(stmts.len());
    for s in stmts {
        match s {
            Stmt::Op(op) => {
                if let Some((mul, add)) = op.class.decomposed() {
                    out.push(Stmt::Op(Op::new(mul, op.count)));
                    out.push(Stmt::Op(Op::new(add, op.count)));
                } else {
                    out.push(s.clone());
                }
            }
            Stmt::Loop { trips, body } => out.push(Stmt::Loop {
                trips: *trips,
                body: rewrite(body),
            }),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::class::InstClass::{self, *};
    use crate::isa::ir::Traffic;
    use crate::isa::mix::InstMix;
    use crate::testutil::{forall, Rng};

    fn jit_kernel(body: Vec<Stmt>) -> Kernel {
        Kernel::new("k", 1000, 128).with_body(body)
    }

    #[test]
    fn fused_policy_is_identity() {
        let k = jit_kernel(vec![Stmt::op(Ffma, 7)]);
        let out = apply_fmad(&k, FmadPolicy::Fused);
        assert_eq!(out.body, k.body);
    }

    #[test]
    fn decomposes_ffma_into_fmul_fadd() {
        let k = jit_kernel(vec![Stmt::op(Ffma, 7)]);
        let out = apply_fmad(&k, FmadPolicy::Decomposed);
        assert_eq!(
            out.body,
            vec![Stmt::op(Fmul, 7), Stmt::op(Fadd, 7)]
        );
    }

    #[test]
    fn recurses_into_loops() {
        let k = jit_kernel(vec![Stmt::looped(
            4,
            vec![Stmt::op(Dfma, 2), Stmt::op(Iadd, 1)],
        )]);
        let out = apply_fmad(&k, FmadPolicy::Decomposed);
        assert_eq!(
            out.body,
            vec![Stmt::looped(
                4,
                vec![Stmt::op(Dmul, 2), Stmt::op(Dadd, 2), Stmt::op(Iadd, 1)],
            )]
        );
    }

    #[test]
    fn lib_kernels_are_not_rewritten() {
        // cuBLAS boundary: prebuilt binaries ignore the compile flag. This
        // is the mechanism behind llama.cpp f16/f32 models showing no gain.
        let k = jit_kernel(vec![Stmt::op(Ffma, 7)]).with_source(KernelSource::Lib);
        let out = apply_fmad(&k, FmadPolicy::Decomposed);
        assert_eq!(out.body, k.body);
    }

    #[test]
    fn traffic_and_geometry_preserved() {
        let k = jit_kernel(vec![Stmt::op(Hfma2, 3)])
            .with_traffic(Traffic::coalesced(4096, 2048));
        let out = apply_fmad(&k, FmadPolicy::Decomposed);
        assert_eq!(out.threads, k.threads);
        assert_eq!(out.block, k.block);
        assert_eq!(out.traffic, k.traffic);
    }

    fn gen_body(rng: &mut Rng, depth: u32) -> Vec<Stmt> {
        let classes: &[InstClass] = &[Ffma, Dfma, Hfma, Hfma2, Fmul, Fadd, Imad, Dp4a, Ldg, Stg];
        let n = rng.range(1, 5);
        (0..n)
            .map(|_| {
                if depth < 3 && rng.chance(0.35) {
                    Stmt::looped(rng.range(1, 6), gen_body(rng, depth + 1))
                } else {
                    Stmt::op(*rng.pick(classes), rng.range(1, 20))
                }
            })
            .collect()
    }

    #[test]
    fn prop_pass_preserves_flops_and_removes_fused() {
        // Properties of the rewrite for arbitrary kernels:
        //   1. FLOP count is invariant (it's a semantic-preserving rewrite);
        //   2. the output contains zero fused-class instructions;
        //   3. instruction count grows by exactly the fused count;
        //   4. non-fused class counts are untouched.
        forall(0xFADED, 300, |rng: &mut Rng| {
            let k = jit_kernel(gen_body(rng, 0));
            let before = InstMix::from_kernel(&k);
            let after = InstMix::from_kernel(&apply_fmad(&k, FmadPolicy::Decomposed));
            assert_eq!(before.flops(), after.flops());
            assert_eq!(after.fused(), 0);
            assert_eq!(after.total(), before.total() + before.fused());
            for c in [Imad, Dp4a, Ldg, Stg] {
                assert_eq!(before.get(c), after.get(c));
            }
        });
    }

    #[test]
    fn prop_pass_is_idempotent() {
        forall(0x1D, 200, |rng: &mut Rng| {
            let k = jit_kernel(gen_body(rng, 0));
            let once = apply_fmad(&k, FmadPolicy::Decomposed);
            let twice = apply_fmad(&once, FmadPolicy::Decomposed);
            assert_eq!(once.body, twice.body);
        });
    }
}
