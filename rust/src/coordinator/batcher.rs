//! Admission policy for the continuous-batching engine.
//!
//! This module used to own a stop-the-world window batcher (gather requests
//! under a (size, wait) window, then serve that batch to completion). The
//! fleet engine replaced that loop with **continuous batching** — sequences
//! join the decode round whenever a KV slot frees — so the batcher is
//! reduced to the admission-policy value type consumed by
//! [`crate::coordinator::scheduler::plan_admission`] (the slot-join step)
//! and by the engine's cold-start gather.

use std::time::Duration;

/// Admission policy for a node's continuous-batching engine.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Concurrency cap: the most sequences that may share one card's
    /// decode round (bounded further by free KV slots at admission time).
    pub max_batch: usize,
    /// Cold-start gather window: how long an idle engine waits for company
    /// after the first request arrives before prefilling the round. Once
    /// the engine is busy, admission is non-blocking — arrivals join the
    /// next round immediately.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
        }
    }
}

impl BatchPolicy {
    /// The concurrency cap with a floor of one sequence — a zero cap would
    /// make an engine that can never admit anything.
    pub fn concurrency(&self) -> usize {
        self.max_batch.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_is_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.max_wait > Duration::ZERO);
        assert_eq!(p.concurrency(), p.max_batch);
    }

    #[test]
    fn zero_cap_is_floored_to_one() {
        let p = BatchPolicy { max_batch: 0, max_wait: Duration::ZERO };
        assert_eq!(p.concurrency(), 1);
    }
}
