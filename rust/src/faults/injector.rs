//! The runtime half of fault injection: a shared clock-and-cursor over a
//! [`FaultPlan`].
//!
//! Each node worker calls [`FaultInjector::begin_round`] exactly once per
//! engine round; the injector advances that node's round clock and
//! returns every scripted fault now due. Because the clock is the
//! worker's own loop counter, injection is deterministic per (seed, node,
//! round) and immune to scheduler jitter — the property the chaos smoke
//! matrix relies on to reproduce failures by seed.
//!
//! [`FaultKind::SwapInFailure`] is special: it *arms* rather than fires.
//! The armed count is consumed by the pager path at the next actual
//! swap-in ([`FaultInjector::take_swap_in_failure`]), so the fault lands
//! on a real host-pool restore no matter when one happens.

use std::sync::Mutex;

use super::plan::{FaultKind, FaultPlan};

struct NodeClock {
    /// (round, kind), sorted by round — this node's slice of the plan.
    script: Vec<(u64, FaultKind)>,
    cursor: usize,
    round: u64,
    armed_swap_failures: u32,
}

/// Shared fault scheduler, one per server run. Cheap when the plan is
/// empty (a single short mutex hold per round).
pub struct FaultInjector {
    nodes: Mutex<Vec<NodeClock>>,
}

impl FaultInjector {
    pub fn new(plan: &FaultPlan, nodes: usize) -> Self {
        FaultInjector {
            nodes: Mutex::new(
                (0..nodes)
                    .map(|n| NodeClock {
                        script: plan.for_node(n),
                        cursor: 0,
                        round: 0,
                        armed_swap_failures: 0,
                    })
                    .collect(),
            ),
        }
    }

    /// Advance `node`'s round clock and return the faults due. Events
    /// scheduled for rounds the node skipped (it was idle or stalled —
    /// its clock only ticks when its loop runs) fire on the next call
    /// rather than being lost.
    pub fn begin_round(&self, node: usize) -> Vec<FaultKind> {
        let mut nodes = self.nodes.lock().unwrap();
        let clock = &mut nodes[node];
        clock.round += 1;
        let mut due = Vec::new();
        while clock.cursor < clock.script.len() && clock.script[clock.cursor].0 <= clock.round {
            let kind = clock.script[clock.cursor].1.clone();
            clock.cursor += 1;
            if kind == FaultKind::SwapInFailure {
                clock.armed_swap_failures += 1;
            }
            due.push(kind);
        }
        due
    }

    /// Consume one armed swap-in failure for `node`, if any. Called by
    /// the worker at the moment it would restore a parked sequence from
    /// the host pool.
    pub fn take_swap_in_failure(&self, node: usize) -> bool {
        let mut nodes = self.nodes.lock().unwrap();
        let clock = &mut nodes[node];
        if clock.armed_swap_failures > 0 {
            clock.armed_swap_failures -= 1;
            true
        } else {
            false
        }
    }

    /// The node's current round clock (observability / tests).
    pub fn round(&self, node: usize) -> u64 {
        self.nodes.lock().unwrap()[node].round
    }
}

#[cfg(test)]
mod tests {
    use super::super::plan::FaultEvent;
    use super::*;

    fn plan() -> FaultPlan {
        FaultPlan::script(vec![
            FaultEvent { node: 0, round: 2, kind: FaultKind::TransientStall { rounds: 3 } },
            FaultEvent { node: 0, round: 2, kind: FaultKind::SwapInFailure },
            FaultEvent { node: 0, round: 5, kind: FaultKind::NodeDeath },
            FaultEvent { node: 1, round: 1, kind: FaultKind::LinkDowngrade { lanes: 1 } },
        ])
    }

    #[test]
    fn faults_fire_on_their_scripted_round_per_node() {
        let inj = FaultInjector::new(&plan(), 2);
        assert_eq!(inj.begin_round(0), vec![], "round 1 is clean");
        let due = inj.begin_round(0);
        assert_eq!(
            due,
            vec![FaultKind::TransientStall { rounds: 3 }, FaultKind::SwapInFailure],
            "both round-2 events fire together"
        );
        assert_eq!(inj.begin_round(0), vec![]);
        assert_eq!(inj.begin_round(0), vec![]);
        assert_eq!(inj.begin_round(0), vec![FaultKind::NodeDeath]);
        // node 1's clock is independent of node 0's five rounds
        assert_eq!(inj.begin_round(1), vec![FaultKind::LinkDowngrade { lanes: 1 }]);
        assert_eq!(inj.round(0), 5);
        assert_eq!(inj.round(1), 1);
    }

    #[test]
    fn swap_in_failures_arm_until_consumed() {
        let inj = FaultInjector::new(&plan(), 2);
        assert!(!inj.take_swap_in_failure(0), "nothing armed before round 2");
        inj.begin_round(0);
        inj.begin_round(0); // arms one failure
        assert!(!inj.take_swap_in_failure(1), "arming is per node");
        assert!(inj.take_swap_in_failure(0));
        assert!(!inj.take_swap_in_failure(0), "consumed exactly once");
    }

    #[test]
    fn every_event_fires_exactly_once_in_round_order() {
        let script = FaultPlan::script(vec![
            FaultEvent { node: 0, round: 3, kind: FaultKind::VramPageLoss { blocks: 1 } },
            FaultEvent { node: 0, round: 1, kind: FaultKind::VramPageLoss { blocks: 2 } },
        ]);
        let inj = FaultInjector::new(&script, 1);
        let mut fired = Vec::new();
        for _ in 0..6 {
            fired.extend(inj.begin_round(0));
        }
        assert_eq!(
            fired,
            vec![
                FaultKind::VramPageLoss { blocks: 2 },
                FaultKind::VramPageLoss { blocks: 1 },
            ]
        );
    }

    #[test]
    fn empty_plan_is_a_no_op() {
        let inj = FaultInjector::new(&FaultPlan::none(), 3);
        for node in 0..3 {
            for _ in 0..10 {
                assert!(inj.begin_round(node).is_empty());
            }
            assert!(!inj.take_swap_in_failure(node));
        }
    }
}
