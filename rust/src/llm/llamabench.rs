//! llama-bench equivalent: pp512 / tg128 over the six quant formats
//! (§4.2–§4.4), with the paper's A100-scaled theoretical overlays.
//!
//! Sweep shape: every cell of the 6-quant × 2-policy grid lowers its
//! prefill and decode kernels **once** ([`crate::sim::LoweredKernel`]) and
//! the whole grid runs as one batched [`crate::sim::batch`] sweep —
//! [`LlamaBench::run_all`] is the one-kernel-walk-per-cell path the report
//! figures, the coordinator overlay, and the fleet router all consume.

use crate::device::DeviceSpec;
use crate::isa::class::InstClass;
use crate::isa::pass::{apply_fmad, FmadPolicy};
use crate::sim::batch::{self, SweepJob};
use crate::sim::{simulate_lowered, KernelTiming, LoweredKernel, SimConfig};

use super::kernels::{
    self, decode_kernel, launch_overhead, prefill_kernel, readback_overhead,
    CUBLAS_FALLBACK_EFF, MMQ_ISSUE_EFF,
};
use super::model::ModelDesc;
use super::quant::{self, QuantFormat};

/// A100 llama-bench reference measurements for Qwen2.5-1.5B, reconstructed
/// from the paper's theoretical overlay bars (Graph 4-1 theoretical =
/// A100 × 70/108; Graph 4-2 theoretical = A100 × 1493/1555). Prefill rides
/// the A100's tensor cores (which the CMP cannot use — the paper's §4.2
/// explanation for the prefill gap); decode is bandwidth + launch bound.
/// `(quant, pp512 t/s, tg128 t/s)`.
pub const A100_REFERENCE: &[(&str, f64, f64)] = &[
    ("f32", 3755.5, 172.0),
    ("f16", 19045.0, 283.0),
    ("q8_0", 12589.6, 402.0),
    ("q6_k", 12231.8, 453.0),
    ("q4_k_m", 11668.0, 508.0),
    ("q2_k", 10531.3, 603.0),
];

/// §4.2/§4.3 scaling ratios.
pub const SM_RATIO: f64 = 70.0 / 108.0;
pub const BW_RATIO: f64 = 1493.0 / 1555.0;

fn a100_ref(quant: &QuantFormat) -> (f64, f64) {
    A100_REFERENCE
        .iter()
        .find(|(n, _, _)| *n == quant.name)
        .map(|&(_, pp, tg)| (pp, tg))
        .expect("quant in reference table")
}

/// One llama-bench run result (one quant × one fmad policy on one device).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub quant: &'static str,
    pub policy: FmadPolicy,
    /// Prompt processing, tokens/s (pp512).
    pub prefill_tps: f64,
    /// Text generation, tokens/s (tg128).
    pub decode_tps: f64,
    /// Paper-formula theoretical overlays (SM-scaled / BW-scaled A100).
    pub theoretical_prefill_tps: f64,
    pub theoretical_decode_tps: f64,
    /// Mean board power during decode, W (nvidia-smi style).
    pub decode_power_w: f64,
    /// Decode energy efficiency, tokens/s/W.
    pub tokens_per_watt: f64,
}

impl BenchResult {
    pub fn prefill_fraction(&self) -> f64 {
        self.prefill_tps / self.theoretical_prefill_tps
    }
    pub fn decode_fraction(&self) -> f64 {
        self.decode_tps / self.theoretical_decode_tps
    }
    /// The theoretical (A100-class) decode efficiency this card is
    /// compared against in Graph 4-3: BW-scaled A100 speed at the shared
    /// 250 W TDP.
    pub fn theoretical_tokens_per_watt(&self) -> f64 {
        self.theoretical_decode_tps / 250.0
    }
}

/// One (quant, policy) grid cell with its kernels lowered exactly once.
/// Reusable across any number of devices/configs — build with
/// [`LlamaBench::lower_cell`] (or the full grid via
/// [`LlamaBench::lower_grid`]).
#[derive(Clone, Debug)]
pub struct LoweredCell {
    pub quant: QuantFormat,
    pub policy: FmadPolicy,
    pub prefill: LoweredKernel,
    pub prefill_cfg: SimConfig,
    pub decode: LoweredKernel,
    pub decode_cfg: SimConfig,
}

/// The llama-bench driver.
pub struct LlamaBench {
    pub model: ModelDesc,
    pub prompt_tokens: u64,
    pub gen_tokens: u32,
}

impl Default for LlamaBench {
    fn default() -> Self {
        LlamaBench {
            model: ModelDesc::qwen25_15b(),
            prompt_tokens: 512,
            gen_tokens: 128,
        }
    }
}

impl LlamaBench {
    /// Engine config for one quant's prefill cell (public so benchmarks can
    /// replicate the exact sweep workload).
    pub fn prefill_config(quant: &QuantFormat) -> SimConfig {
        SimConfig {
            issue_efficiency: if quant.fmad_immune() {
                CUBLAS_FALLBACK_EFF
            } else {
                MMQ_ISSUE_EFF
            },
            ignore_occupancy: true,
            ..Default::default()
        }
    }

    /// Decode kernels are GEMV-class (streaming, no tiling) and sustain a
    /// higher issue fraction than the blocked GEMMs.
    pub fn decode_config() -> SimConfig {
        SimConfig {
            issue_efficiency: 0.7,
            ignore_occupancy: true,
            ..Default::default()
        }
    }

    /// Lower just the prefill kernel of one (quant, policy) cell.
    fn lower_prefill(&self, quant: &QuantFormat, policy: FmadPolicy) -> LoweredKernel {
        LoweredKernel::lower(&apply_fmad(
            &prefill_kernel(&self.model, quant, self.prompt_tokens),
            policy,
        ))
    }

    /// Lower just the decode kernel of one (quant, policy) cell, at the
    /// midpoint KV position.
    fn lower_decode(&self, quant: &QuantFormat, policy: FmadPolicy) -> LoweredKernel {
        let pos = self.gen_tokens / 2;
        LoweredKernel::lower(&apply_fmad(&decode_kernel(&self.model, quant, pos), policy))
    }

    /// Lower one (quant, policy) cell: both kernels walked exactly once.
    pub fn lower_cell(&self, quant: &QuantFormat, policy: FmadPolicy) -> LoweredCell {
        LoweredCell {
            quant: *quant,
            policy,
            prefill: self.lower_prefill(quant, policy),
            prefill_cfg: Self::prefill_config(quant),
            decode: self.lower_decode(quant, policy),
            decode_cfg: Self::decode_config(),
        }
    }

    /// Lower the full Graph 4-x grid (six quants × both policies), in the
    /// paper's order: quant-major, `Fused` before `Decomposed`.
    pub fn lower_grid(&self) -> Vec<LoweredCell> {
        let mut cells = Vec::with_capacity(quant::ALL.len() * 2);
        for q in quant::ALL {
            for policy in [FmadPolicy::Fused, FmadPolicy::Decomposed] {
                cells.push(self.lower_cell(q, policy));
            }
        }
        cells
    }

    /// Prefill tokens/s from a simulated prefill timing on `dev`.
    fn prefill_tps_from(&self, t: &KernelTiming, dev: &DeviceSpec) -> f64 {
        // per-batch launch overhead (amortized over 512 tokens) + readback
        let total =
            t.time_s + launch_overhead(&self.model) + readback_overhead(&self.model, &dev.pcie);
        self.prompt_tokens as f64 / total
    }

    /// Decode tokens/s and mean board power from a simulated decode timing.
    ///
    /// nvidia-smi-style decode power (Graph 4-3). Empirically calibrated
    /// residency model:
    ///   P = static + mem + κ·(issue rate, unpack-weighted) [+ boost]
    /// where the boost bonus models the DVFS governor pinning the card
    /// at its top clock/voltage point once the instruction stream's
    /// burst issue rate crosses a demand threshold — which the
    /// decomposed (noFMA) streams of the k-quants do and the throttled
    /// default streams never do. The result: noFMA decodes faster but
    /// *less efficiently* (the paper's §4.4 observation), while the
    /// default card never fills its envelope.
    fn decode_from(
        &self,
        decode: &LoweredKernel,
        t: &KernelTiming,
        dev: &DeviceSpec,
    ) -> (f64, f64) {
        let overhead = launch_overhead(&self.model) + readback_overhead(&self.model, &dev.pcie);
        let token_time = t.time_s + overhead;
        let tps = 1.0 / token_time;

        // The mix comes from the lowered kernel — no second IR walk.
        let mix = &decode.mix;
        // Integer unpack traffic lights up the operand-collector/register
        // paths disproportionately; weight it double.
        let weighted_insts = (mix.total() + mix.get(InstClass::Iadd)) as f64;
        const KAPPA: f64 = 3.0e-10; // W·s per weighted issue slot
        let issue_rate = weighted_insts / token_time;
        // Burst demand during the busy window decides the governor state.
        let busy = t.time_s.max(1e-9);
        let burst_rate = mix.total() as f64 / busy;
        let peak_core = dev.sms as f64 * dev.rates.fp32 * dev.boost_clock_hz;
        let boost_w = if burst_rate / peak_core > 0.12 { 25.0 } else { 0.0 };
        let mem_dyn = t.bytes * 62.0e-12 / token_time;
        let power = (dev.power.static_w + mem_dyn + KAPPA * issue_rate + boost_w).min(dev.tdp_w);
        (tps, power)
    }

    /// Assemble one cell's [`BenchResult`] from its simulated timings.
    fn assemble(
        &self,
        cell: &LoweredCell,
        prefill_t: &KernelTiming,
        decode_t: &KernelTiming,
        dev: &DeviceSpec,
    ) -> BenchResult {
        let (a100_pp, a100_tg) = a100_ref(&cell.quant);
        let prefill_tps = self.prefill_tps_from(prefill_t, dev);
        let (decode_tps, decode_power_w) = self.decode_from(&cell.decode, decode_t, dev);
        BenchResult {
            quant: cell.quant.name,
            policy: cell.policy,
            prefill_tps,
            decode_tps,
            theoretical_prefill_tps: a100_pp * SM_RATIO,
            theoretical_decode_tps: a100_tg * BW_RATIO,
            decode_power_w,
            tokens_per_watt: decode_tps / decode_power_w,
        }
    }

    /// Prefill speed (pp512), tokens/s. Lowers only the prefill kernel.
    pub fn prefill(&self, dev: &DeviceSpec, quant: &QuantFormat, policy: FmadPolicy) -> f64 {
        let lk = self.lower_prefill(quant, policy);
        let t = simulate_lowered(&lk, dev, &Self::prefill_config(quant));
        self.prefill_tps_from(&t, dev)
    }

    /// Decode speed (tg128) and mean power: averaged over the generation,
    /// evaluated at the midpoint KV position (the cache grows linearly and
    /// every term is ~linear in position). Lowers only the decode kernel.
    pub fn decode(&self, dev: &DeviceSpec, quant: &QuantFormat, policy: FmadPolicy) -> (f64, f64) {
        let lk = self.lower_decode(quant, policy);
        let t = simulate_lowered(&lk, dev, &Self::decode_config());
        self.decode_from(&lk, &t, dev)
    }

    /// Run one (quant, policy) cell of Graph 4-1/4-2/4-3. Both kernels are
    /// lowered once and simulated once.
    pub fn run(&self, dev: &DeviceSpec, quant: &QuantFormat, policy: FmadPolicy) -> BenchResult {
        let cell = self.lower_cell(quant, policy);
        let prefill_t = simulate_lowered(&cell.prefill, dev, &cell.prefill_cfg);
        let decode_t = simulate_lowered(&cell.decode, dev, &cell.decode_cfg);
        self.assemble(&cell, &prefill_t, &decode_t, dev)
    }

    /// The full grid the paper's Graphs 4-1…4-3 plot — six quants × two
    /// policies — as **one batched sweep**: 12 cells lowered once (24
    /// kernel walks total), then all 24 simulations fanned across worker
    /// threads. Results are ordered quant-major, `Fused` before
    /// `Decomposed`, and numerically identical to calling [`LlamaBench::run`]
    /// per cell.
    pub fn run_all(&self, dev: &DeviceSpec) -> Vec<BenchResult> {
        let cells = self.lower_grid();
        self.run_cells(&cells, dev)
    }

    /// Simulate pre-lowered cells on one device as a batched sweep.
    pub fn run_cells(&self, cells: &[LoweredCell], dev: &DeviceSpec) -> Vec<BenchResult> {
        // Jobs interleaved (prefill, decode) per cell — job-major output
        // keeps each cell's pair adjacent.
        let mut jobs = Vec::with_capacity(cells.len() * 2);
        for cell in cells {
            jobs.push(SweepJob { kernel: &cell.prefill, cfg: cell.prefill_cfg });
            jobs.push(SweepJob { kernel: &cell.decode, cfg: cell.decode_cfg });
        }
        let timings = batch::run_jobs_on(&jobs, dev);
        cells
            .iter()
            .zip(timings.chunks(2))
            .map(|(cell, pair)| self.assemble(cell, &pair[0], &pair[1], dev))
            .collect()
    }

    /// One (quant, policy) cell across many devices — the fleet-weighting
    /// sweep: kernels lowered once, `2 × devices` simulations batched.
    /// Results are ordered like `devices`.
    pub fn run_across(
        &self,
        devices: &[DeviceSpec],
        quant: &QuantFormat,
        policy: FmadPolicy,
    ) -> Vec<BenchResult> {
        let cell = self.lower_cell(quant, policy);
        let jobs = [
            SweepJob { kernel: &cell.prefill, cfg: cell.prefill_cfg },
            SweepJob { kernel: &cell.decode, cfg: cell.decode_cfg },
        ];
        // Job-major: [prefill×d0, prefill×d1, …, decode×d0, decode×d1, …].
        let timings = batch::run_jobs(&jobs, devices);
        let nd = devices.len();
        devices
            .iter()
            .enumerate()
            .map(|(d, dev)| self.assemble(&cell, &timings[d], &timings[nd + d], dev))
            .collect()
    }

    /// One quant across a heterogeneous fleet where every node carries its
    /// own fmad policy — the serving engine's per-card calibration. Cells
    /// are lowered once per distinct policy (at most two kernel walks per
    /// phase) and all `2 × nodes` simulations run as one batched
    /// [`batch::run_pairs`] sweep. Results are ordered like `nodes` and
    /// bit-identical to calling [`LlamaBench::run`] per node.
    pub fn run_nodes(
        &self,
        nodes: &[(DeviceSpec, FmadPolicy)],
        quant: &QuantFormat,
    ) -> Vec<BenchResult> {
        fn cell_for<'a>(
            fused: &'a Option<LoweredCell>,
            decomposed: &'a Option<LoweredCell>,
            p: FmadPolicy,
        ) -> &'a LoweredCell {
            match p {
                FmadPolicy::Fused => fused.as_ref().expect("fused cell lowered"),
                FmadPolicy::Decomposed => decomposed.as_ref().expect("decomposed cell lowered"),
            }
        }
        let fused = nodes
            .iter()
            .any(|(_, p)| *p == FmadPolicy::Fused)
            .then(|| self.lower_cell(quant, FmadPolicy::Fused));
        let decomposed = nodes
            .iter()
            .any(|(_, p)| *p == FmadPolicy::Decomposed)
            .then(|| self.lower_cell(quant, FmadPolicy::Decomposed));
        // Node-major pairs: [prefill×n0, decode×n0, prefill×n1, …].
        let pairs: Vec<(SweepJob<'_>, &DeviceSpec)> = nodes
            .iter()
            .flat_map(|(dev, p)| {
                let cell = cell_for(&fused, &decomposed, *p);
                [
                    (SweepJob { kernel: &cell.prefill, cfg: cell.prefill_cfg }, dev),
                    (SweepJob { kernel: &cell.decode, cfg: cell.decode_cfg }, dev),
                ]
            })
            .collect();
        let timings = batch::run_pairs(&pairs);
        nodes
            .iter()
            .zip(timings.chunks(2))
            .map(|((dev, p), pair)| {
                self.assemble(cell_for(&fused, &decomposed, *p), &pair[0], &pair[1], dev)
            })
            .collect()
    }

    /// VRAM check (§4.1: model chosen so all layers fit in 8 GB).
    pub fn fits(&self, dev: &DeviceSpec, quant: &QuantFormat) -> bool {
        self.model.fits(
            quant,
            (self.prompt_tokens + self.gen_tokens as u64) as u32,
            dev.mem.capacity_bytes,
        )
    }

    /// Per-step overheads, exposed for the perf report.
    pub fn overheads(&self, dev: &DeviceSpec) -> (f64, f64) {
        (
            launch_overhead(&self.model),
            readback_overhead(&self.model, &dev.pcie),
        )
    }
}

/// Convenience: quick accessor used by examples.
pub fn mmq_issue_efficiency() -> f64 {
    kernels::MMQ_ISSUE_EFF
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibration as cal;
    use crate::device::registry;
    use crate::llm::quant::*;

    fn bench() -> LlamaBench {
        LlamaBench::default()
    }

    fn cmp() -> DeviceSpec {
        registry::cmp170hx()
    }

    #[test]
    fn all_quants_fit_on_the_cmp() {
        let b = bench();
        let d = cmp();
        for q in ALL {
            assert!(b.fits(&d, q), "{}", q.name);
        }
    }

    #[test]
    fn float_models_show_no_nofma_prefill_gain() {
        // Graph 4-1: "f32/f16 models showed no performance gains".
        let b = bench();
        let d = cmp();
        for q in [F32, F16] {
            let def = b.prefill(&d, &q, FmadPolicy::Fused);
            let nofma = b.prefill(&d, &q, FmadPolicy::Decomposed);
            assert!(
                (nofma / def - 1.0).abs() < 1e-9,
                "{}: {def} vs {nofma}",
                q.name
            );
        }
    }

    #[test]
    fn nofma_prefill_speedup_grows_with_quantization_depth() {
        // Graph 4-1's ordering, peaking at Q2_K ≈ 231%.
        let b = bench();
        let d = cmp();
        let speedup = |q: &QuantFormat| {
            b.prefill(&d, q, FmadPolicy::Decomposed) / b.prefill(&d, q, FmadPolicy::Fused)
        };
        let s8 = speedup(&Q8_0);
        let s6 = speedup(&Q6_K);
        let s4 = speedup(&Q4_K_M);
        let s2 = speedup(&Q2_K);
        assert!(s8 > 1.1, "{s8}");
        assert!(s6 > s8, "{s6} vs {s8}");
        assert!(s4 > s6, "{s4} vs {s6}");
        assert!(s2 > s4, "{s2} vs {s4}");
        assert!(s2 > 2.0 && s2 < 2.7, "Q2_K ≈ 2.31×: {s2}");
    }

    #[test]
    fn prefill_nofma_lands_in_the_papers_band() {
        // §4.2: "prefill speeds only reached 14–45% of theoretical limits"
        // (noFMA). The CMP can't use tensor cores; the A100 reference can.
        let b = bench();
        let d = cmp();
        let (lo, hi) = cal::PREFILL_FRACTION_OF_THEORETICAL;
        for q in ALL {
            let r = b.run(&d, q, FmadPolicy::Decomposed);
            let f = r.prefill_fraction();
            assert!(
                f > lo - 0.02 && f < hi + 0.08,
                "{}: fraction {f} outside [{lo},{hi}]",
                q.name
            );
        }
    }

    #[test]
    fn decode_fractions_match_section_4_3() {
        // Default 39–78% of BW-scaled theoretical; noFMA 50–78%.
        let b = bench();
        let d = cmp();
        for q in ALL {
            let def = b.run(&d, q, FmadPolicy::Fused).decode_fraction();
            assert!(
                def > 0.35 && def < 0.88,
                "{} default fraction {def}",
                q.name
            );
        }
        for q in [Q8_0, Q6_K, Q4_K_M, Q2_K] {
            let nofma = b.run(&d, &q, FmadPolicy::Decomposed).decode_fraction();
            assert!(
                nofma > 0.48 && nofma < 0.88,
                "{} noFMA fraction {nofma}",
                q.name
            );
        }
    }

    #[test]
    fn nofma_boosts_quantized_decode() {
        let b = bench();
        let d = cmp();
        for q in [Q8_0, Q6_K, Q4_K_M, Q2_K] {
            let def = b.run(&d, &q, FmadPolicy::Fused).decode_tps;
            let nofma = b.run(&d, &q, FmadPolicy::Decomposed).decode_tps;
            assert!(nofma > def * 1.15, "{}: {def} → {nofma}", q.name);
        }
    }

    #[test]
    fn decode_is_ordered_by_model_bytes_once_restored() {
        // With noFMA the quantized kernels become memory-bound, so smaller
        // quants stream fewer bytes → faster decode. (At *default* the
        // crippled scale math inverts this — f16 beats q8_0, which the
        // paper's Graph 4-2 also shows.)
        let b = bench();
        let d = cmp();
        let tps: Vec<f64> = [F16, Q8_0, Q6_K, Q4_K_M, Q2_K]
            .iter()
            .map(|q| b.run(&d, q, FmadPolicy::Decomposed).decode_tps)
            .collect();
        for w in tps.windows(2) {
            assert!(w[1] > w[0] * 0.98, "{tps:?}");
        }
        // At *default*, crippled scale math drags q8_0 down to f16's level
        // despite streaming half the bytes (the paper's Graph 4-2 shows the
        // same compression of the default bars).
        let f16 = b.run(&d, &F16, FmadPolicy::Fused).decode_tps;
        let q8 = b.run(&d, &Q8_0, FmadPolicy::Fused).decode_tps;
        assert!((q8 / f16 - 1.0).abs() < 0.15, "{f16} vs {q8}");
    }

    #[test]
    fn efficiency_beats_theoretical_for_f32_f16_q8() {
        // Graph 4-3: "energy efficiency … outperforms its theoretical
        // efficiency in half of the scenarios (F32, F16, Q8)".
        let b = bench();
        let d = cmp();
        for q in [F32, F16, Q8_0] {
            let r = b.run(&d, &q, FmadPolicy::Fused);
            assert!(
                r.tokens_per_watt > r.theoretical_tokens_per_watt(),
                "{}: {} vs theoretical {}",
                q.name,
                r.tokens_per_watt,
                r.theoretical_tokens_per_watt()
            );
        }
    }

    #[test]
    fn nofma_reduces_efficiency_for_kquants() {
        // Graph 4-3: faster decode but worse tokens/W at Q6/Q4_K_M/Q2_K —
        // the boosted-clock residency costs more than the time it saves.
        let b = bench();
        let d = cmp();
        for q in [Q6_K, Q4_K_M, Q2_K] {
            let def = b.run(&d, &q, FmadPolicy::Fused);
            let nofma = b.run(&d, &q, FmadPolicy::Decomposed);
            assert!(nofma.decode_tps > def.decode_tps, "{}", q.name);
            assert!(
                nofma.tokens_per_watt < def.tokens_per_watt,
                "{}: noFMA t/W {} should drop below default {}",
                q.name,
                nofma.tokens_per_watt,
                def.tokens_per_watt
            );
        }
    }

    #[test]
    fn run_all_covers_the_full_grid() {
        let rows = bench().run_all(&cmp());
        assert_eq!(rows.len(), 12);
    }

    #[test]
    fn batched_grid_matches_per_cell_runs_exactly() {
        // The batched sweep must be numerically identical to the one-cell
        // path — same kernels, same configs, same math, just fewer IR
        // walks and more threads.
        let b = bench();
        let d = cmp();
        let batched = b.run_all(&d);
        let mut i = 0;
        for q in ALL {
            for policy in [FmadPolicy::Fused, FmadPolicy::Decomposed] {
                let single = b.run(&d, q, policy);
                let row = &batched[i];
                assert_eq!(row.quant, single.quant);
                assert_eq!(row.policy, single.policy);
                assert_eq!(row.prefill_tps.to_bits(), single.prefill_tps.to_bits());
                assert_eq!(row.decode_tps.to_bits(), single.decode_tps.to_bits());
                assert_eq!(
                    row.decode_power_w.to_bits(),
                    single.decode_power_w.to_bits()
                );
                i += 1;
            }
        }
    }

    #[test]
    fn run_nodes_matches_per_node_runs_with_mixed_policies() {
        // The fleet-calibration path: heterogeneous devices AND policies in
        // one sweep must be bit-identical to the sequential per-node runs.
        let b = bench();
        let nodes = [
            (registry::cmp170hx(), FmadPolicy::Decomposed),
            (registry::cmp90hx(), FmadPolicy::Fused),
            (registry::cmp170hx_x16(), FmadPolicy::Decomposed),
        ];
        let rows = b.run_nodes(&nodes, &Q8_0);
        assert_eq!(rows.len(), 3);
        for (row, (dev, policy)) in rows.iter().zip(nodes.iter()) {
            let single = b.run(dev, &Q8_0, *policy);
            assert_eq!(row.policy, *policy);
            assert_eq!(row.prefill_tps.to_bits(), single.prefill_tps.to_bits());
            assert_eq!(row.decode_tps.to_bits(), single.decode_tps.to_bits());
            assert_eq!(row.decode_power_w.to_bits(), single.decode_power_w.to_bits());
        }
    }

    #[test]
    fn run_across_matches_per_device_runs() {
        let b = bench();
        let devices = [registry::cmp170hx(), registry::cmp170hx_x16()];
        let across = b.run_across(&devices, &Q4_K_M, FmadPolicy::Decomposed);
        assert_eq!(across.len(), 2);
        for (row, dev) in across.iter().zip(devices.iter()) {
            let single = b.run(dev, &Q4_K_M, FmadPolicy::Decomposed);
            assert_eq!(row.decode_tps.to_bits(), single.decode_tps.to_bits());
            assert_eq!(row.prefill_tps.to_bits(), single.prefill_tps.to_bits());
        }
    }
}
