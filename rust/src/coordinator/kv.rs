//! KV-cache slot manager with VRAM accounting.
//!
//! The CMP 170HX's 8 GB ceiling is the binding constraint of §4.1/§6.2:
//! the slot manager admits at most `slots` concurrent sequences and tracks
//! the bytes a real deployment would pin (weights + per-slot KV), refusing
//! admissions that would not fit.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

/// Fixed-slot KV allocator.
#[derive(Debug)]
pub struct KvSlots {
    total: usize,
    free: BTreeSet<usize>,
    /// Device memory budget and static (weights) usage, bytes.
    vram_bytes: u64,
    weights_bytes: u64,
    per_slot_bytes: u64,
}

impl KvSlots {
    /// Build an allocator for `slots` sequences of `kv_bytes_per_slot`
    /// over a device with `vram_bytes`, `weights_bytes` of which are pinned
    /// by the model. Fails if the configuration cannot fit at all.
    pub fn new(
        slots: usize,
        kv_bytes_per_slot: u64,
        vram_bytes: u64,
        weights_bytes: u64,
    ) -> Result<Self> {
        let needed = weights_bytes + slots as u64 * kv_bytes_per_slot;
        if needed > vram_bytes {
            bail!(
                "{} slots need {} bytes but device has {} ({} for weights)",
                slots,
                needed,
                vram_bytes,
                weights_bytes
            );
        }
        Ok(KvSlots {
            total: slots,
            free: (0..slots).collect(),
            vram_bytes,
            weights_bytes,
            per_slot_bytes: kv_bytes_per_slot,
        })
    }

    /// Acquire a slot id, or `None` if all are busy.
    pub fn acquire(&mut self) -> Option<usize> {
        let id = self.free.iter().next().copied()?;
        self.free.remove(&id);
        Some(id)
    }

    /// Release a slot. Out-of-range ids and double-releases are rejected
    /// (they would silently corrupt `in_use`/`resident_bytes` accounting if
    /// the set insert were trusted blindly) — callers treat an `Err` as a
    /// coordinator logic bug.
    pub fn release(&mut self, id: usize) -> Result<()> {
        if id >= self.total {
            bail!("release of slot {id} out of range (capacity {})", self.total);
        }
        if !self.free.insert(id) {
            bail!("double release of slot {id}");
        }
        Ok(())
    }

    pub fn in_use(&self) -> usize {
        self.total - self.free.len()
    }

    /// Slots currently available for admission.
    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    pub fn capacity(&self) -> usize {
        self.total
    }

    /// Bytes currently resident (weights + active slots).
    pub fn resident_bytes(&self) -> u64 {
        self.weights_bytes + self.in_use() as u64 * self.per_slot_bytes
    }

    /// Headroom to the VRAM budget.
    pub fn headroom_bytes(&self) -> u64 {
        self.vram_bytes - self.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    fn slots(n: usize) -> KvSlots {
        KvSlots::new(n, 1 << 20, 8 << 30, 1 << 30).unwrap()
    }

    #[test]
    fn acquire_release_cycle() {
        let mut s = slots(2);
        let a = s.acquire().unwrap();
        let b = s.acquire().unwrap();
        assert_ne!(a, b);
        assert!(s.acquire().is_none());
        s.release(a).unwrap();
        assert_eq!(s.acquire(), Some(a));
    }

    #[test]
    fn double_release_is_rejected_without_corrupting_accounting() {
        let mut s = slots(2);
        let a = s.acquire().unwrap();
        let b = s.acquire().unwrap();
        s.release(a).unwrap();
        let err = s.release(a).unwrap_err().to_string();
        assert!(err.contains("double release"), "{err}");
        // the failed release must not have touched accounting
        assert_eq!(s.in_use(), 1);
        assert_eq!(s.free_slots(), 1);
        s.release(b).unwrap();
        assert_eq!(s.in_use(), 0);
    }

    #[test]
    fn out_of_range_release_is_rejected() {
        let mut s = slots(2);
        let a = s.acquire().unwrap();
        let err = s.release(7).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // accounting intact: the held slot is still held
        assert_eq!(s.in_use(), 1);
        s.release(a).unwrap();
    }

    #[test]
    fn rejects_configs_that_overflow_vram() {
        // 9 GB of KV on an 8 GB card.
        assert!(KvSlots::new(9, 1 << 30, 8 << 30, 1 << 30).is_err());
    }

    #[test]
    fn vram_accounting_tracks_active_slots() {
        let mut s = slots(4);
        assert_eq!(s.resident_bytes(), 1 << 30);
        let a = s.acquire().unwrap();
        assert_eq!(s.resident_bytes(), (1 << 30) + (1 << 20));
        s.release(a).unwrap();
        assert_eq!(s.headroom_bytes(), (8u64 << 30) - (1 << 30));
    }

    #[test]
    fn prop_never_leaks_or_duplicates_slots() {
        // Random acquire/release interleavings: the free+held sets always
        // partition [0, total).
        forall(0x510, 200, |rng: &mut Rng| {
            let n = rng.range(1, 8) as usize;
            let mut s = slots(n);
            let mut held: Vec<usize> = Vec::new();
            for _ in 0..64 {
                if rng.chance(0.5) {
                    if let Some(id) = s.acquire() {
                        assert!(!held.contains(&id), "duplicate slot {id}");
                        held.push(id);
                    } else {
                        assert_eq!(held.len(), n, "acquire failed with free slots");
                    }
                } else if !held.is_empty() {
                    let idx = rng.below(held.len() as u64) as usize;
                    s.release(held.swap_remove(idx)).unwrap();
                } else {
                    // nothing held: any release must be rejected cleanly
                    assert!(s.release(0).is_err());
                }
                assert_eq!(s.in_use(), held.len());
                assert_eq!(s.free_slots(), n - held.len());
            }
        });
    }
}
