//! L2 working-set model: estimate the hit rate a kernel's reads see, given
//! its resident working set vs the L2 capacity.
//!
//! The timing engine uses this to split read traffic between HBM and the L2
//! slice. The model is deliberately simple — a saturating-reuse curve — but
//! it captures the two cases that matter for the paper's workloads:
//! streaming kernels (working set ≫ L2, hit rate → 0, e.g. membench and
//! decode weight reads) and blocked GEMMs (tiles resident, hit rate high
//! for the reused operand).

/// Estimate an L2 hit rate for a kernel that reads `unique_bytes` of
/// distinct data `reuse` times each (reuse = total reads / unique bytes).
///
/// - If the unique set fits in L2, all re-reads hit: hit = (reuse-1)/reuse.
/// - If it doesn't fit, only the resident fraction of re-reads hit.
pub fn hit_rate(unique_bytes: u64, reuse: f64, l2_bytes: u64) -> f64 {
    assert!(reuse >= 1.0, "reuse must be >= 1, got {reuse}");
    if unique_bytes == 0 {
        return 0.0;
    }
    let resident = (l2_bytes as f64 / unique_bytes as f64).min(1.0);
    let rereads = (reuse - 1.0) / reuse; // fraction of reads that are re-reads
    rereads * resident
}

/// Convenience: hit rate for a streaming kernel (each byte touched once).
pub fn streaming() -> f64 {
    0.0
}

/// Hit rate for a blocked GEMM where one operand tile of `tile_bytes` is
/// reused `reuse` times from L2.
pub fn blocked_gemm(tile_bytes: u64, reuse: f64, l2_bytes: u64) -> f64 {
    hit_rate(tile_bytes, reuse, l2_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, forall, Rng};

    const L2: u64 = 8 << 20;

    #[test]
    fn single_touch_never_hits() {
        assert_eq!(hit_rate(1 << 30, 1.0, L2), 0.0);
        assert_eq!(streaming(), 0.0);
    }

    #[test]
    fn resident_set_hits_on_rereads() {
        // 1 MB set read 4 times: 3/4 of reads are re-reads, all hit.
        assert_close(hit_rate(1 << 20, 4.0, L2), 0.75, 1e-12);
    }

    #[test]
    fn oversized_set_hits_proportionally() {
        // 16 MB set in an 8 MB L2: half the re-reads hit.
        assert_close(hit_rate(16 << 20, 2.0, L2), 0.5 * 0.5, 1e-12);
    }

    #[test]
    fn prop_hit_rate_bounded_and_monotone_in_reuse() {
        forall(0x12, 300, |rng: &mut Rng| {
            let unique = rng.range(1, 1 << 34);
            let r1 = rng.f64_range(1.0, 64.0);
            let r2 = r1 + rng.f64_range(0.0, 64.0);
            let h1 = hit_rate(unique, r1, L2);
            let h2 = hit_rate(unique, r2, L2);
            assert!((0.0..=1.0).contains(&h1));
            assert!(h2 >= h1 - 1e-12, "more reuse must not lower hit rate");
        });
    }

    #[test]
    fn prop_hit_rate_monotone_in_l2_size() {
        forall(0x13, 300, |rng: &mut Rng| {
            let unique = rng.range(1, 1 << 34);
            let reuse = rng.f64_range(1.0, 16.0);
            let small = hit_rate(unique, reuse, 4 << 20);
            let large = hit_rate(unique, reuse, 40 << 20);
            assert!(large >= small - 1e-12);
        });
    }
}
