//! Transformer model descriptions (§4.1).

use super::quant::QuantFormat;

/// Architecture description of a decoder-only transformer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDesc {
    pub name: &'static str,
    pub layers: u32,
    pub hidden: u32,
    pub q_heads: u32,
    pub kv_heads: u32,
    pub head_dim: u32,
    pub ffn: u32,
    pub vocab: u32,
    /// Embeddings tied (Qwen2.5-1.5B ties lm_head to tok_embeddings).
    pub tied_embeddings: bool,
    pub max_ctx: u32,
}

impl ModelDesc {
    /// Qwen2.5-1.5B (§4.1): 28 layers, 12 Q heads / 2 KV heads (GQA),
    /// hidden 1536, ffn 8960, vocab 151936, tied embeddings, 32k context.
    pub fn qwen25_15b() -> Self {
        ModelDesc {
            name: "Qwen2.5-1.5B",
            layers: 28,
            hidden: 1536,
            q_heads: 12,
            kv_heads: 2,
            head_dim: 128,
            ffn: 8960,
            vocab: 151936,
            tied_embeddings: true,
            max_ctx: 32768,
        }
    }

    /// The tiny-Qwen the AOT artifacts implement (python/compile/model.py).
    /// Same architecture family, laptop-scale dimensions.
    pub fn tiny_qwen() -> Self {
        ModelDesc {
            name: "tiny-qwen",
            layers: 4,
            hidden: 256,
            q_heads: 8,
            kv_heads: 2,
            head_dim: 32,
            ffn: 704,
            vocab: 512,
            tied_embeddings: true,
            max_ctx: 256,
        }
    }

    /// Parameters in the attention + FFN + norm stacks (excluding
    /// embeddings) — what §4.1 quotes as "1.31B excluding embeddings".
    pub fn params_nonembed(&self) -> u64 {
        let h = self.hidden as u64;
        let qkv = h * (self.q_heads as u64 * self.head_dim as u64)
            + 2 * h * (self.kv_heads as u64 * self.head_dim as u64)
            // attention qkv bias (Qwen2 uses QKV bias)
            + (self.q_heads as u64 + 2 * self.kv_heads as u64) * self.head_dim as u64;
        let o = (self.q_heads as u64 * self.head_dim as u64) * h;
        let ffn = 3 * h * self.ffn as u64;
        let norms = 2 * h;
        self.layers as u64 * (qkv + o + ffn + norms) + h // final norm
    }

    /// Embedding parameters (tied: counted once).
    pub fn params_embed(&self) -> u64 {
        self.hidden as u64 * self.vocab as u64
    }

    /// Total parameters (§4.1 quotes 1.54B).
    pub fn params_total(&self) -> u64 {
        self.params_nonembed() + self.params_embed()
    }

    /// Multiply-accumulates per generated/processed token through the
    /// weight matrices (≈ params_nonembed; lm_head matvec added for decode,
    /// where every step must produce logits).
    pub fn macs_per_token(&self, include_lm_head: bool) -> u64 {
        let mut macs = self.params_nonembed();
        if include_lm_head {
            macs += self.params_embed();
        }
        macs
    }

    /// Attention-score MACs per token at context length `ctx`
    /// (QKᵀ + AV over GQA heads).
    pub fn attn_macs_per_token(&self, ctx: u32) -> u64 {
        2 * self.q_heads as u64 * self.head_dim as u64 * ctx as u64
    }

    /// KV-cache bytes per position (f16 K and V across layers).
    pub fn kv_bytes_per_pos(&self) -> u64 {
        2 * self.layers as u64 * self.kv_heads as u64 * self.head_dim as u64 * 2
    }

    /// Model weight bytes in a quant format (embeddings kept at f16 for
    /// quantized formats, as ggml does).
    pub fn weight_bytes(&self, quant: &QuantFormat) -> u64 {
        let body = quant.bytes_for(self.params_nonembed());
        let embed = if quant.bits_per_weight() >= 16.0 {
            quant.bytes_for(self.params_embed())
        } else {
            // ggml stores token embeddings at q8/f16 class precision
            self.params_embed()
        };
        body + embed
    }

    /// Can the model + a `ctx`-token KV cache live in `vram` bytes?
    /// Overhead covers activations, the logits buffer and ggml's compute
    /// workspace, which scales with context (attention score matrices).
    pub fn fits(&self, quant: &QuantFormat, ctx: u32, vram: u64) -> bool {
        let overhead = (512u64 << 20) + ctx as u64 * self.hidden as u64 * 4 * 16;
        self.weight_bytes(quant) + self.kv_bytes_per_pos() * ctx as u64 + overhead <= vram
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::quant;

    #[test]
    fn qwen_param_counts_match_the_model_card() {
        // §4.1: 1.54B total, 1.31B excluding embeddings.
        let m = ModelDesc::qwen25_15b();
        let nonembed = m.params_nonembed() as f64 / 1e9;
        let total = m.params_total() as f64 / 1e9;
        assert!((nonembed - 1.31).abs() < 0.04, "{nonembed}");
        assert!((total - 1.54).abs() < 0.04, "{total}");
    }

    #[test]
    fn gqa_shrinks_kv_cache_sixfold() {
        let m = ModelDesc::qwen25_15b();
        // 28 layers × 2 (K,V) × 2 heads × 128 dim × 2 B = 28 KiB/pos.
        assert_eq!(m.kv_bytes_per_pos(), 28 * 2 * 2 * 128 * 2);
        // An MHA equivalent (12 kv heads) would be 6× bigger.
        let mha = ModelDesc { kv_heads: 12, ..m };
        assert_eq!(mha.kv_bytes_per_pos(), 6 * m.kv_bytes_per_pos());
    }

    #[test]
    fn all_six_quants_fit_in_8gb_at_bench_context() {
        // §4.1's premise: the 1.5B model fits in 8 GB for every format
        // tested at llama-bench's default context.
        let m = ModelDesc::qwen25_15b();
        let vram = 8u64 << 30;
        for q in quant::ALL {
            assert!(m.fits(q, 640, vram), "{} should fit", q.name);
        }
        // but f32 does NOT fit at long context
        assert!(!m.fits(&quant::F32, 32768, vram));
    }

    #[test]
    fn decode_reads_lm_head_prefill_does_not() {
        let m = ModelDesc::qwen25_15b();
        assert!(m.macs_per_token(true) > m.macs_per_token(false));
        assert_eq!(
            m.macs_per_token(true) - m.macs_per_token(false),
            m.params_embed()
        );
    }

    #[test]
    fn tiny_qwen_is_tiny() {
        let t = ModelDesc::tiny_qwen();
        assert!(t.params_total() < 5_000_000, "{}", t.params_total());
    }
}
