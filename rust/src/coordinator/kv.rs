//! Paged KV-cache allocator with a radix-tree prefix index, a
//! three-tier (pinned / cached / free) page lifecycle, VRAM accounting,
//! prefix sharing, and copy-on-write.
//!
//! The CMP 170HX's 8 GB ceiling is the binding constraint of §4.1/§6.2.
//! The old fixed-slot manager reserved worst-case context
//! (`kv_bytes_per_pos × max_ctx`) for every admitted sequence, so a card
//! serving 4k-token contexts with ~1k-token mean generations wasted ~3/4
//! of its KV budget on positions that were never written. [`KvPager`]
//! instead hands out **blocks of N token positions** as a sequence
//! actually grows (vLLM-style paged attention, at the accounting level the
//! simulated deployment needs): admission pins only the prefill window,
//! each decode round grows the sequence by at most one block, and a grow
//! that cannot be satisfied signals the engine to preempt rather than
//! silently over-committing the device.
//!
//! # The three-tier page lifecycle
//!
//! Every physical block is in exactly one of three tiers, and the tiers
//! partition the budget (`pinned + cached + free == capacity`):
//!
//! - **Pinned** (`refs ≥ 1`): held by at least one live sequence. Never
//!   reclaimed — eviction works at sequence granularity through
//!   [`KvPager::release`], not by stealing pages out from under a holder.
//! - **Cached** (`refs == 0`, still linked in the prefix tree): the
//!   *reclaimable cache*. When the last holder of a content-addressed
//!   block lets go, the block is **not** freed — it is demoted to this
//!   tier, stamped by an LRU clock, and counted against the cached-bytes
//!   ledger. A returning user's next turn re-pins its entire conversation
//!   history from here (*resurrection*) instead of re-prefilling it —
//!   the difference between a cache that only exists while a sharer is
//!   live and one that makes millions of *distinct* conversations
//!   cache-effective on an 8 GB card.
//! - **Free**: in the allocator's pool. Cached blocks are *admissible*
//!   (the admission gate counts `free + cached`), but consuming one costs
//!   a **reclaim**: the LRU-oldest cached block is tree-unlinked and only
//!   then freed, strictly under allocation pressure. Reclaim never
//!   touches a pinned block.
//!
//! Private blocks (decode-written pages, CoW copies, diverged tails)
//! carry no tree link and free directly at refcount zero — only
//! content-addressed prompt blocks are worth retaining. The
//! [`KvPager::set_retention`] knob (`--no-kv-cache`) restores the old
//! free-at-refcount-zero behaviour as the ablation baseline.
//!
//! # The radix tree
//!
//! The pager is **content-aware** (vLLM's block-hash reuse): every block
//! admitted with prompt content carries a *chain hash* of all token
//! positions up to and including the ones it covers. Those hashes index a
//! [`RadixIndex`] — a radix tree over token chains where each node covers
//! one block-sized chunk, a parent→child edge extends the chain by one
//! chunk, and **one descent from the root yields the longest matching
//! prefix** (the old flat map probed chunk-by-chunk). Interior nodes
//! adapt their child layout by fanout, ART-style: a small sorted inline
//! array at low fanout spills to a hash table once a node's children
//! outgrow it (and shrinks back when they don't). Leaves — and every
//! interior node — hold the physical block reference for their chunk.
//!
//! [`KvPager::admit_prompt`] descends once, **pins** the matched run
//! (bumping refcounts, resurrecting any cached blocks in it) and
//! allocates + links only the fresh tail. The first write into a shared
//! block (a decode step growing into a partially-filled prompt tail)
//! triggers **copy-on-write**: the writer gets a private replacement and
//! the shared original stays valid for its other holders and in the
//! tree. [`KvPager::release`] demotes content-addressed blocks to the
//! cached tier at refcount zero; the tree is unlinked only by reclaim
//! (or divergence), so no tree path ever points at a freed block.
//!
//! [`HostPool`] accounts the host-RAM side of swap-based preemption:
//! evicted sequences whose KV is cheaper to move over the (crippled
//! x1/x4) PCIe link than to recompute park their pages there until
//! resume ([`crate::coordinator::scheduler::choose_preempt`] prices the
//! tradeoff with the §3 PCIe model). The cached tier credits that
//! pricing twice over: a victim's content-addressed pages survive its
//! release as cache, so they neither cross the link on swap-out
//! ([`KvPager::seq_swap_bytes`]) nor cost prefill on a recompute-resume
//! ([`KvPager::seq_survivor_blocks`]).
//!
//! Handles are generation-stamped: a released handle — or a handle whose
//! id was recycled by a later admission — is rejected on every operation
//! instead of silently corrupting another sequence's pages.

use std::collections::{HashMap, VecDeque};

use anyhow::{bail, Result};

/// Handle to one sequence's KV pages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqKv {
    id: usize,
    gen: u64,
}

/// One physical KV block: how many live sequences hold it, its node in
/// the prefix tree (`None` for private blocks — decode-written pages,
/// CoW copies, diverged tails), and — when `refs == 0` but the block is
/// retained — its LRU stamp in the cached tier.
#[derive(Clone, Copy, Debug, Default)]
struct Block {
    refs: u32,
    node: Option<usize>,
    cached_at: Option<u64>,
}

/// One live sequence's page table.
#[derive(Clone, Debug)]
struct SeqAlloc {
    /// Token positions this sequence may write (rounded up into blocks).
    positions: usize,
    /// Physical block ids, in position order. Shared blocks appear in
    /// several sequences' tables at once.
    blocks: Vec<usize>,
}

#[derive(Debug)]
struct PageEntry {
    gen: u64,
    alloc: Option<SeqAlloc>,
}

/// Cumulative prefix-cache counters (monotonic over the pager's life).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Prompt blocks served by pinning an already-resident block.
    pub hit_blocks: u64,
    /// Prompt blocks that had to be allocated fresh.
    pub miss_blocks: u64,
    /// Shared blocks privatized on first write (copy-on-write).
    pub cow_copies: u64,
    /// The subset of `hit_blocks` that were idle in the cached tier at
    /// pin time (resurrected by a returning conversation) rather than
    /// live-shared with another sequence.
    pub resurrected_blocks: u64,
    /// Cached blocks reclaimed (tree-unlinked, then freed) under
    /// allocation pressure.
    pub reclaimed_blocks: u64,
}

/// Chain hash: FNV-1a folded over the previous chunk's hash and this
/// chunk's token ids. Matching hashes at chunk *k* imply (collisions
/// aside) identical token content over **all** positions `0..=k·N` — the
/// causal-attention condition under which KV pages are interchangeable.
fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf29ce484222325;
    const FNV_PRIME: u64 = 0x100000001b3;
    let mut h = FNV_OFFSET;
    for b in prev.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    for t in tokens {
        for b in t.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Chain hashes for every block-sized chunk of a prefill window — the
/// exact keys [`KvPager::admit_prompt`] would descend on. Public so the
/// dispatcher can score nodes against the fleet [`PrefixDirectory`]
/// without touching any pager: the window construction is deterministic
/// ([`crate::runtime::ModelRuntime::padded_window`]), so dispatcher and
/// worker compute identical keys from the same prompt.
pub fn window_chain_hashes(window: &[i32], block_positions: usize) -> Vec<u64> {
    let mut hashes = Vec::with_capacity(window.len().div_ceil(block_positions.max(1)));
    let mut prev = 0u64;
    for chunk in window.chunks(block_positions.max(1)) {
        prev = chain_hash(prev, chunk);
        hashes.push(prev);
    }
    hashes
}

/// Fanout threshold at which a node's child table spills from the inline
/// sorted array to a hash map — the ART NODE4/NODE16 → NODE256 adaptation
/// at the two extremes this workload actually has (deep chains of fanout
/// ~1, plus a bushy first level where every distinct conversation forks).
const RADIX_INLINE_MAX: usize = 8;

/// Child table of one radix node, adaptive by fanout: linear scan over a
/// sorted-insertion-order inline array while small (cache-friendly, no
/// hashing), a hash map once fanout outgrows it. Shrinks back to inline
/// when removals drop it to half the threshold, so a node that briefly
/// fanned out does not stay heavyweight forever.
#[derive(Debug, Default)]
enum ChildTable {
    #[default]
    Empty,
    Inline(Vec<(u64, usize)>),
    Hashed(HashMap<u64, usize>),
}

impl ChildTable {
    fn get(&self, hash: u64) -> Option<usize> {
        match self {
            ChildTable::Empty => None,
            ChildTable::Inline(v) => v.iter().find(|&&(h, _)| h == hash).map(|&(_, n)| n),
            ChildTable::Hashed(m) => m.get(&hash).copied(),
        }
    }

    fn insert(&mut self, hash: u64, node: usize) {
        match self {
            ChildTable::Empty => *self = ChildTable::Inline(vec![(hash, node)]),
            ChildTable::Inline(v) => {
                debug_assert!(v.iter().all(|&(h, _)| h != hash), "duplicate child hash");
                v.push((hash, node));
                if v.len() > RADIX_INLINE_MAX {
                    let spilled: HashMap<u64, usize> = v.drain(..).collect();
                    *self = ChildTable::Hashed(spilled);
                }
            }
            ChildTable::Hashed(m) => {
                m.insert(hash, node);
            }
        }
    }

    fn remove(&mut self, hash: u64) {
        match self {
            ChildTable::Empty => {}
            ChildTable::Inline(v) => {
                v.retain(|&(h, _)| h != hash);
                if v.is_empty() {
                    *self = ChildTable::Empty;
                }
            }
            ChildTable::Hashed(m) => {
                m.remove(&hash);
                if m.len() <= RADIX_INLINE_MAX / 2 {
                    let kept: Vec<(u64, usize)> = m.drain().collect();
                    *self = ChildTable::Inline(kept);
                }
            }
        }
    }

    fn child_nodes(&self) -> Vec<usize> {
        match self {
            ChildTable::Empty => Vec::new(),
            ChildTable::Inline(v) => v.iter().map(|&(_, n)| n).collect(),
            ChildTable::Hashed(m) => m.values().copied().collect(),
        }
    }
}

/// One radix node: the chunk it covers (by chain hash — which already
/// encodes the full prefix, so the path to a node and its hash agree),
/// the physical block backing that chunk, and its adaptive child table.
#[derive(Debug)]
struct RadixNode {
    hash: u64,
    block: usize,
    /// `None` = depth-1 node (child of the root).
    parent: Option<usize>,
    children: ChildTable,
}

/// Radix tree over token chains: one node per resident content-addressed
/// block, edges extend the chain by one chunk, one descent = the longest
/// matching prefix. Arena-allocated; slots recycle through `free`.
#[derive(Debug, Default)]
struct RadixIndex {
    nodes: Vec<Option<RadixNode>>,
    free: Vec<usize>,
    root: ChildTable,
}

impl RadixIndex {
    /// Longest-prefix match in one descent: follow `hashes` from the root
    /// until the first missing edge, returning `(node, block)` per
    /// matched chunk in chain order.
    fn descend(&self, hashes: &[u64]) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut table = &self.root;
        for &h in hashes {
            match table.get(h) {
                Some(ni) => {
                    let node = self.nodes[ni].as_ref().expect("linked child is live");
                    out.push((ni, node.block));
                    table = &node.children;
                }
                None => break,
            }
        }
        out
    }

    /// Link a fresh chunk under `parent` (`None` = root). The caller
    /// guarantees the edge is absent — descent stopped there.
    fn insert(&mut self, parent: Option<usize>, hash: u64, block: usize) -> usize {
        let node = RadixNode { hash, block, parent, children: ChildTable::default() };
        let ni = match self.free.pop() {
            Some(ni) => {
                self.nodes[ni] = Some(node);
                ni
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        };
        match parent {
            Some(p) => self.nodes[p].as_mut().expect("parent is live").children.insert(hash, ni),
            None => self.root.insert(hash, ni),
        }
        ni
    }

    /// Detach the whole subtree rooted at `ni`, returning `(block id,
    /// chain hash)` per removed node (the root of the cut first). The
    /// caller owns the per-block consequences — a chain below a removed
    /// chunk can never be prefix-matched again, so the subtree goes with
    /// it — and the hashes feed the fleet-directory retraction delta.
    fn unlink(&mut self, ni: usize) -> Vec<(usize, u64)> {
        let (parent, hash) = {
            let n = self.nodes[ni].as_ref().expect("unlink target is live");
            (n.parent, n.hash)
        };
        match parent {
            Some(p) => {
                if let Some(pn) = self.nodes[p].as_mut() {
                    pn.children.remove(hash);
                }
            }
            None => self.root.remove(hash),
        }
        let mut removed = Vec::new();
        let mut stack = vec![ni];
        while let Some(i) = stack.pop() {
            let node = self.nodes[i].take().expect("subtree node is live");
            stack.extend(node.children.child_nodes());
            removed.push((node.block, node.hash));
            self.free.push(i);
        }
        removed
    }

    /// Chain depth of a live node: 1 for a depth-1 chunk (child of the
    /// root), growing along the parent chain. Shallow nodes are the
    /// shared system-prefix chunks every conversation descends through;
    /// deep nodes are one conversation's private tail.
    fn depth(&self, ni: usize) -> usize {
        let mut d = 1;
        let mut cur = self.nodes[ni].as_ref().expect("depth of a live node").parent;
        while let Some(p) = cur {
            d += 1;
            cur = self.nodes[p].as_ref().expect("live parent").parent;
        }
        d
    }

    /// Every registered chain hash (pinned and cached tiers alike) — the
    /// node's published view in the fleet [`PrefixDirectory`].
    fn hashes(&self) -> Vec<u64> {
        self.nodes.iter().flatten().map(|n| n.hash).collect()
    }
}

/// Victim selection for cached-tier reclaim under allocation pressure.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReclaimPolicy {
    /// Strict LRU over demotion order. Blind to tree shape: a released
    /// sequence demotes its blocks shallow-first, so the LRU-oldest
    /// cached block is often a *shared system-prefix chunk* — and
    /// reclaiming it strands (and frees) every deeper chain behind it.
    #[default]
    Lru,
    /// Depth-first: reclaim the deepest cached chain block (ties broken
    /// toward the LRU-older stamp). Deep blocks are one conversation's
    /// private tail — losing one costs that conversation's last chunk —
    /// while shallow system-prefix blocks, which every tenant's next
    /// request would hit, survive pressure longest.
    Depth,
}

/// Paged KV block allocator for one card.
#[derive(Debug)]
pub struct KvPager {
    block_positions: usize,
    bytes_per_pos: u64,
    total_blocks: usize,
    /// Distinct physical blocks with at least one holder (the pinned tier).
    allocated: usize,
    /// Blocks in the reclaimable-cache tier (refs == 0, tree-linked).
    cached: usize,
    active: usize,
    /// Device memory budget and static (weights) usage, bytes.
    vram_bytes: u64,
    weights_bytes: u64,
    /// Physical block table; slots are recycled through `free_slots`.
    blocks: Vec<Block>,
    free_slots: Vec<usize>,
    /// Radix tree over token chains; nodes reference resident blocks in
    /// the pinned or cached tier — never a freed one.
    index: RadixIndex,
    /// LRU clock over the cached tier: (stamp, block) in demotion order,
    /// with lazy invalidation (an entry is live iff the block's
    /// `cached_at` still equals the stamp).
    lru: VecDeque<(u64, usize)>,
    lru_tick: u64,
    /// Retain content-addressed blocks at refcount zero (the cached
    /// tier). Off = the refcount-zero-frees ablation (`--no-kv-cache`).
    retain: bool,
    /// Victim selection under reclaim pressure (`--reclaim-policy`).
    reclaim: ReclaimPolicy,
    /// Chain hashes unlinked from the prefix tree since the last
    /// [`KvPager::take_retracted`] — the worker's retraction delta for
    /// the fleet [`PrefixDirectory`], so affine routing stops chasing
    /// reclaimed history before the next full republish.
    retracted_chains: Vec<u64>,
    entries: Vec<PageEntry>,
    free_ids: Vec<usize>,
    stats: PrefixStats,
}

impl KvPager {
    /// Build a pager over a device with `vram_bytes`, `weights_bytes` of
    /// which are pinned by the model; everything left is carved into
    /// blocks of `block_positions × bytes_per_pos`. Fails when the
    /// geometry cannot yield even one block.
    pub fn new(
        block_positions: usize,
        bytes_per_pos: u64,
        vram_bytes: u64,
        weights_bytes: u64,
    ) -> Result<Self> {
        if block_positions == 0 {
            bail!("KV block size must be at least one position");
        }
        if bytes_per_pos == 0 {
            bail!("KV bytes per position must be nonzero");
        }
        if weights_bytes > vram_bytes {
            bail!("weights ({weights_bytes} bytes) exceed device VRAM ({vram_bytes} bytes)");
        }
        let block_bytes = block_positions as u64 * bytes_per_pos;
        let total_blocks = ((vram_bytes - weights_bytes) / block_bytes) as usize;
        if total_blocks == 0 {
            bail!("no headroom for even one {block_bytes}-byte KV block after weights");
        }
        Ok(KvPager {
            block_positions,
            bytes_per_pos,
            total_blocks,
            allocated: 0,
            cached: 0,
            active: 0,
            vram_bytes,
            weights_bytes,
            blocks: Vec::new(),
            free_slots: Vec::new(),
            index: RadixIndex::default(),
            lru: VecDeque::new(),
            lru_tick: 0,
            retain: true,
            reclaim: ReclaimPolicy::default(),
            retracted_chains: Vec::new(),
            entries: Vec::new(),
            free_ids: Vec::new(),
            stats: PrefixStats::default(),
        })
    }

    /// Toggle cache-beyond-refcount retention. Off restores the old
    /// free-at-refcount-zero behaviour — the `--no-kv-cache` ablation
    /// baseline. Turning retention off on a warm pager reclaims the
    /// whole cached tier immediately.
    pub fn set_retention(&mut self, retain: bool) {
        self.retain = retain;
        if !retain {
            while self.cached > 0 {
                self.reclaim_lru();
            }
        }
    }

    /// Select the reclaim victim policy (`--reclaim-policy lru|depth`).
    pub fn set_reclaim_policy(&mut self, policy: ReclaimPolicy) {
        self.reclaim = policy;
    }

    /// Drain the chain hashes unlinked from the prefix tree since the
    /// last call — reclaims, divergence, retention flips. The worker
    /// folds these into its per-round (and mid-stall) directory delta as
    /// retractions; chains re-admitted since unlinking are re-added by
    /// the same delta's resident diff, so over-retraction is safe.
    pub fn take_retracted(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.retracted_chains)
    }

    /// Cap the block pool below the VRAM-derived total (a test/ops knob:
    /// force page pressure without faking device specs). Only valid with
    /// no live sequences; the cached tier is reclaimed to make the cap
    /// meaningful.
    pub fn limit_blocks(&mut self, cap: usize) -> Result<()> {
        if cap == 0 {
            bail!("KV block budget must be at least one block");
        }
        if self.allocated > 0 {
            bail!("cannot shrink the block pool with live sequences");
        }
        while self.cached > 0 {
            self.reclaim_lru();
        }
        self.total_blocks = self.total_blocks.min(cap);
        Ok(())
    }

    /// Permanently retire up to `n` blocks from the **free** pool — the
    /// VRAM-page-loss fault model. Cached blocks are reclaimed to cover
    /// the loss when the free pool alone cannot; live sequences are never
    /// touched (their pages are, by definition, the ones still readable).
    /// The card just gets smaller, and the admission gate sees the
    /// shrunken capacity immediately. Returns how many blocks were
    /// actually lost, which can be less than `n` when free + cached is
    /// nearly empty.
    pub fn lose_blocks(&mut self, n: usize) -> usize {
        let lose = n.min(self.free_blocks() + self.cached);
        self.ensure_free(lose);
        for _ in 0..lose {
            // Retire a concrete free slot when one exists so the id can
            // never be recycled; blocks never materialized in `blocks`
            // are retired by the capacity cut alone.
            self.free_slots.pop();
        }
        self.total_blocks -= lose;
        lose
    }

    /// Blocks needed to hold `positions` token positions (at least one —
    /// every live sequence owns a page).
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.max(1).div_ceil(self.block_positions)
    }

    /// Allocate one private physical block with `refs = 1`. The caller
    /// must have ensured a free slot exists ([`KvPager::ensure_free`]).
    fn alloc_block(&mut self) -> usize {
        debug_assert!(self.free_blocks() > 0, "alloc without ensure_free");
        let id = match self.free_slots.pop() {
            Some(id) => id,
            None => {
                self.blocks.push(Block::default());
                self.blocks.len() - 1
            }
        };
        self.blocks[id] = Block { refs: 1, node: None, cached_at: None };
        self.allocated += 1;
        id
    }

    /// Allocate one block and link it into the prefix tree as `hash`
    /// under `parent` (`None` = a depth-1 chunk). Returns the block and
    /// its tree node.
    fn alloc_chain_block(&mut self, parent: Option<usize>, hash: u64) -> (usize, usize) {
        let id = self.alloc_block();
        let ni = self.index.insert(parent, hash, id);
        self.blocks[id].node = Some(ni);
        (id, ni)
    }

    /// Pin one resident block: bump its refcount, resurrecting it out of
    /// the cached tier when idle. Returns true when the pin was a
    /// resurrection (the block had no live holder).
    fn pin_block(&mut self, id: usize) -> bool {
        let b = &mut self.blocks[id];
        b.refs += 1;
        if b.cached_at.take().is_some() {
            self.cached -= 1;
            self.allocated += 1;
            return true;
        }
        false
    }

    /// Drop one holder of a physical block. At refcount zero a
    /// tree-linked block **demotes to the cached tier** (LRU-stamped,
    /// still matchable) when retention is on; otherwise — private blocks
    /// always, every block under `--no-kv-cache` — it is freed, taking
    /// its tree subtree with it. Returns true when the block was
    /// actually freed.
    fn unref_block(&mut self, id: usize) -> bool {
        let b = &mut self.blocks[id];
        assert!(b.refs > 0, "refcount underflow on KV block {id}");
        b.refs -= 1;
        if b.refs > 0 {
            return false;
        }
        if self.retain && b.node.is_some() {
            let stamp = self.lru_tick;
            self.lru_tick += 1;
            self.blocks[id].cached_at = Some(stamp);
            self.lru.push_back((stamp, id));
            self.cached += 1;
            self.allocated -= 1;
            return false;
        }
        if let Some(ni) = self.blocks[id].node {
            self.unlink_tree(ni);
        }
        self.free_slots.push(id);
        self.allocated -= 1;
        true
    }

    /// Detach the subtree rooted at tree node `ni`. Pinned blocks in the
    /// subtree lose only their registration (their pages are untouched
    /// and their holders unaffected); cached blocks are freed on the
    /// spot — a cached block's sole purpose is future matching, and an
    /// unreachable one can never match again. Returns blocks freed.
    fn unlink_tree(&mut self, ni: usize) -> usize {
        let mut freed = 0;
        for (id, hash) in self.index.unlink(ni) {
            // Every unlinked chain vanishes from `index_hashes`, so it
            // must vanish from the fleet directory too — buffered here
            // for the worker's next retraction delta.
            self.retracted_chains.push(hash);
            let b = &mut self.blocks[id];
            b.node = None;
            if b.refs == 0 && b.cached_at.take().is_some() {
                self.cached -= 1;
                self.free_slots.push(id);
                self.stats.reclaimed_blocks += 1;
                freed += 1;
            }
        }
        freed
    }

    /// Reclaim the LRU-oldest cached block (tree-unlink, then free),
    /// along with any cached blocks stranded in its subtree. Returns
    /// blocks freed — zero when the cached tier is empty.
    fn reclaim_lru(&mut self) -> usize {
        while let Some((stamp, id)) = self.lru.pop_front() {
            if self.blocks[id].cached_at != Some(stamp) {
                continue; // stale entry: resurrected or already reclaimed
            }
            let ni = self.blocks[id].node.expect("cached blocks are tree-linked");
            return self.unlink_tree(ni);
        }
        0
    }

    /// Reclaim the deepest cached chain block (LRU-older stamp breaks
    /// depth ties), then its stranded subtree. A deep block is a leaf or
    /// near-leaf — one conversation's private tail — so the cut is
    /// surgical where LRU's shallow cut takes the whole chain behind a
    /// shared prefix chunk. Returns blocks freed.
    fn reclaim_deep(&mut self) -> usize {
        let mut victim: Option<(usize, u64, usize)> = None; // (depth, stamp, id)
        for &(stamp, id) in &self.lru {
            if self.blocks[id].cached_at != Some(stamp) {
                continue; // stale entry: resurrected or already reclaimed
            }
            let ni = self.blocks[id].node.expect("cached blocks are tree-linked");
            let depth = self.index.depth(ni);
            let deeper = match victim {
                None => true,
                Some((d, s, _)) => depth > d || (depth == d && stamp < s),
            };
            if deeper {
                victim = Some((depth, stamp, id));
            }
        }
        let Some((_, _, id)) = victim else {
            return 0;
        };
        let ni = self.blocks[id].node.expect("victim is tree-linked");
        self.unlink_tree(ni)
    }

    /// Reclaim one victim under the configured policy.
    fn reclaim_one(&mut self) -> usize {
        match self.reclaim {
            ReclaimPolicy::Lru => self.reclaim_lru(),
            ReclaimPolicy::Depth => self.reclaim_deep(),
        }
    }

    /// Reclaim cached blocks until the free pool holds `need` — the only
    /// place cache is given back, and strictly under allocation
    /// pressure. Callers gate on [`KvPager::available_blocks`] first, so
    /// this cannot fall short.
    fn ensure_free(&mut self, need: usize) {
        while self.free_blocks() < need && self.cached > 0 {
            self.reclaim_one();
        }
    }

    fn new_handle(&mut self, positions: usize, blocks: Vec<usize>) -> SeqKv {
        let id = match self.free_ids.pop() {
            Some(id) => id,
            None => {
                self.entries.push(PageEntry { gen: 0, alloc: None });
                self.entries.len() - 1
            }
        };
        let entry = &mut self.entries[id];
        entry.gen += 1;
        entry.alloc = Some(SeqAlloc { positions: positions.max(1), blocks });
        self.active += 1;
        SeqKv { id, gen: entry.gen }
    }

    /// Admit a sequence holding `positions` positions (the prefill
    /// window) on private, content-less blocks, or `None` when free +
    /// cached cannot cover it. The prefix-blind path — what a disabled
    /// prefix cache uses.
    pub fn admit(&mut self, positions: usize) -> Option<SeqKv> {
        let need = self.blocks_for(positions);
        if need > self.available_blocks() {
            return None;
        }
        self.ensure_free(need);
        let blocks: Vec<usize> = (0..need).map(|_| self.alloc_block()).collect();
        Some(self.new_handle(positions, blocks))
    }

    /// Admit a sequence whose prefill window holds exactly `window`
    /// (prompt plus deterministic padding): one radix-tree descent yields
    /// the longest resident prefix — live-shared *or* idle in the cached
    /// tier — and the matched run is **pinned** (refcount bumped, cached
    /// blocks resurrected) instead of allocated. The remaining chunks are
    /// allocated fresh and linked into the tree for future admissions —
    /// including a trailing partial chunk, whose content is still
    /// deterministic. Returns the handle and the number of pinned
    /// (cache-hit) blocks, or `None` when free + reclaimable cannot cover
    /// the fresh tail. On `None` nothing is pinned, allocated, or
    /// reclaimed.
    pub fn admit_prompt(&mut self, window: &[i32]) -> Option<(SeqKv, usize)> {
        if window.is_empty() {
            return self.admit(0).map(|kv| (kv, 0));
        }
        let hashes = window_chain_hashes(window, self.block_positions);
        // One descent: the longest matching prefix, all tiers.
        let matched = self.index.descend(&hashes);
        let resurrect =
            matched.iter().filter(|&&(_, b)| self.blocks[b].cached_at.is_some()).count();
        let fresh = hashes.len() - matched.len();
        // Cached blocks we are about to resurrect are not reclaimable
        // for this admission's own tail — exclude them from the budget.
        if fresh > self.free_blocks() + (self.cached - resurrect) {
            return None;
        }
        // Commit: pin the run first (so reclaim for the tail can never
        // take a block the run needs), then allocate + link the tail.
        for &(_, b) in &matched {
            self.pin_block(b);
        }
        let hits = matched.len();
        let mut parent = matched.last().map(|&(ni, _)| ni);
        let mut blocks: Vec<usize> = matched.iter().map(|&(_, b)| b).collect();
        for &h in &hashes[hits..] {
            self.ensure_free(1);
            let (id, ni) = self.alloc_chain_block(parent, h);
            blocks.push(id);
            parent = Some(ni);
        }
        self.stats.hit_blocks += hits as u64;
        self.stats.resurrected_blocks += resurrect as u64;
        self.stats.miss_blocks += fresh as u64;
        Some((self.new_handle(window.len(), blocks), hits))
    }

    /// Grow a sequence to `positions`. `Ok(true)` when the sequence now
    /// owns every page up to `positions` (including the no-op case);
    /// `Ok(false)` when free + reclaimable cannot cover the growth — the
    /// caller's cue to preempt or stall. Nothing changes on `Ok(false)`.
    /// `Err` marks a coordinator logic bug (stale handle).
    ///
    /// Growth writes positions `cur..positions`, and sequences only ever
    /// append — so the sole block that can be *re*-written is a
    /// partially-filled tail. A shared tail (refs > 1) triggers
    /// **copy-on-write**: the writer takes a private replacement block
    /// (costing one extra page this round) and unpins the original, which
    /// stays valid for its other holders and in the tree. A
    /// privately-held tail is simply unlinked (a partial chunk is always
    /// a tree leaf), since its content is about to diverge from its hash.
    pub fn grow(&mut self, seq: SeqKv, positions: usize) -> Result<bool> {
        let (cur, owned) = {
            let a = self.alloc(seq)?;
            (a.positions, a.blocks.len())
        };
        if positions <= cur {
            return Ok(true);
        }
        let tail_written = cur % self.block_positions != 0;
        let tail_id = if tail_written {
            Some(self.entries[seq.id].alloc.as_ref().expect("checked live").blocks[owned - 1])
        } else {
            None
        };
        let cow = tail_id.is_some_and(|id| self.blocks[id].refs > 1);
        let fresh = self.blocks_for(positions) - owned + cow as usize;
        if fresh > self.available_blocks() {
            return Ok(false);
        }
        self.ensure_free(fresh);
        if let Some(id) = tail_id {
            if cow {
                let copy = self.alloc_block();
                self.unref_block(id);
                let alloc = self.entries[seq.id].alloc.as_mut().expect("checked live");
                *alloc.blocks.last_mut().expect("tail exists") = copy;
                self.stats.cow_copies += 1;
            } else if let Some(ni) = self.blocks[id].node {
                self.unlink_tree(ni);
            }
        }
        let add = self.blocks_for(positions) - owned;
        let new_blocks: Vec<usize> = (0..add).map(|_| self.alloc_block()).collect();
        let alloc = self.entries[seq.id].alloc.as_mut().expect("checked live");
        alloc.blocks.extend(new_blocks);
        alloc.positions = positions;
        Ok(true)
    }

    /// Release a sequence's pages (retirement or preemption); returns the
    /// number of blocks actually freed. With retention on this is the
    /// eviction-demotes-to-cache path: content-addressed blocks whose
    /// last holder lets go move to the cached tier (freed count excludes
    /// them) and only private pages free immediately. Stale handles —
    /// double release, or reuse after the id was recycled — are rejected
    /// without touching the accounting.
    pub fn release(&mut self, seq: SeqKv) -> Result<usize> {
        self.alloc(seq)?;
        let entry = &mut self.entries[seq.id];
        let alloc = entry.alloc.take().expect("checked live");
        // Invalidate every outstanding copy of this handle immediately.
        entry.gen += 1;
        let mut freed = 0;
        for &id in &alloc.blocks {
            if self.unref_block(id) {
                freed += 1;
            }
        }
        self.active -= 1;
        self.free_ids.push(seq.id);
        Ok(freed)
    }

    fn alloc(&self, seq: SeqKv) -> Result<&SeqAlloc> {
        let Some(entry) = self.entries.get(seq.id) else {
            bail!("KV handle {} out of range", seq.id);
        };
        if entry.gen != seq.gen || entry.alloc.is_none() {
            bail!("stale KV handle {} (released or recycled)", seq.id);
        }
        Ok(entry.alloc.as_ref().expect("checked above"))
    }

    /// Positions a live sequence currently owns pages for.
    pub fn seq_positions(&self, seq: SeqKv) -> Result<usize> {
        Ok(self.alloc(seq)?.positions)
    }

    /// Blocks a live sequence holds (shared blocks counted once per
    /// holder).
    pub fn seq_blocks(&self, seq: SeqKv) -> Result<usize> {
        Ok(self.alloc(seq)?.blocks.len())
    }

    /// Device bytes backing one sequence's pages, shared blocks included.
    pub fn seq_bytes(&self, seq: SeqKv) -> Result<u64> {
        Ok(self.seq_blocks(seq)? as u64 * self.block_bytes())
    }

    /// Device bytes only this sequence holds (refs == 1) — the
    /// tier-blind footprint probe.
    pub fn seq_private_bytes(&self, seq: SeqKv) -> Result<u64> {
        let alloc = self.alloc(seq)?;
        let private = alloc
            .blocks
            .iter()
            .filter(|&&id| self.blocks[id].refs == 1)
            .count();
        Ok(private as u64 * self.block_bytes())
    }

    /// Device bytes a swap must actually move: blocks that would vanish
    /// from the card when this sequence releases. Shared blocks (refs >
    /// 1) stay resident for their other holders, and — with retention on
    /// — sole-held *content-addressed* blocks stay too, demoted to the
    /// cached tier, where a prefix-aware re-admission pins them again on
    /// restore. Neither crosses the link; only private pages (decode
    /// tails, CoW copies) do. The swap-vs-recompute pricer's
    /// cached-survivor credit lives here.
    pub fn seq_swap_bytes(&self, seq: SeqKv) -> Result<u64> {
        let alloc = self.alloc(seq)?;
        let moved = alloc
            .blocks
            .iter()
            .filter(|&&id| {
                let b = &self.blocks[id];
                b.refs == 1 && !(self.retain && b.node.is_some())
            })
            .count();
        Ok(moved as u64 * self.block_bytes())
    }

    /// How many of a sequence's first `first` blocks (its prompt window)
    /// other live sequences also hold (refs > 1). Kept tier-blind; the
    /// eviction pricer uses [`KvPager::seq_survivor_blocks`], which also
    /// credits the cached tier.
    pub fn seq_shared_blocks(&self, seq: SeqKv, first: usize) -> Result<usize> {
        let alloc = self.alloc(seq)?;
        Ok(alloc
            .blocks
            .iter()
            .take(first)
            .filter(|&&id| self.blocks[id].refs > 1)
            .count())
    }

    /// How many of a sequence's first `first` blocks (its prompt window)
    /// survive this sequence's release: live-shared with another holder,
    /// or — with retention on — content-addressed and therefore demoted
    /// to the cached tier instead of freed. Those blocks would be
    /// prefix-cache hits on a recompute-resume, so the eviction chooser
    /// prices the recompute side with the same credit the resume path
    /// applies.
    pub fn seq_survivor_blocks(&self, seq: SeqKv, first: usize) -> Result<usize> {
        let alloc = self.alloc(seq)?;
        Ok(alloc
            .blocks
            .iter()
            .take(first)
            .filter(|&&id| {
                let b = &self.blocks[id];
                b.refs > 1 || (self.retain && b.node.is_some())
            })
            .count())
    }

    /// How many new sequences of `positions` the pager could admit right
    /// now — the admission gate of continuous batching. Counts free and
    /// reclaimable-cached pages (cached pages are admissible at the
    /// price of a reclaim); conservative for prompts whose prefixes are
    /// resident (those pin instead of allocating).
    pub fn admissible(&self, positions: usize) -> usize {
        self.available_blocks() / self.blocks_for(positions)
    }

    /// Read-only probe: how many leading blocks of `window` are resident
    /// right now — one radix descent, counting the cached tier (a
    /// warm-but-idle conversation is exactly what resurrection serves).
    /// Nothing is pinned — the prefix-aware admission gate uses this to
    /// discount a queued prompt's page bill before deciding to pop it,
    /// and a stale answer only costs a conservative decision, never
    /// correctness (admission re-descends under the same lock).
    pub fn resident_prefix_blocks(&self, window: &[i32]) -> usize {
        self.index.descend(&window_chain_hashes(window, self.block_positions)).len()
    }

    /// Every chain hash currently linked in the prefix tree — pinned
    /// *and* cached tiers, so affinity routing sees warm-but-idle cards —
    /// the node's published view in the fleet [`PrefixDirectory`]. A
    /// snapshot: by the time a route lands the set may have shrunk
    /// (reclaim), which is why admission re-checks and a stale hit
    /// degrades to a miss.
    pub fn index_hashes(&self) -> Vec<u64> {
        self.index.hashes()
    }

    /// Truly-free blocks — allocatable without reclaiming cache.
    pub fn free_blocks(&self) -> usize {
        self.total_blocks - self.allocated - self.cached
    }

    /// Blocks an admission could consume: free plus reclaimable-cached.
    pub fn available_blocks(&self) -> usize {
        self.total_blocks - self.allocated
    }

    /// Blocks idle in the reclaimable-cache tier.
    pub fn cached_blocks(&self) -> usize {
        self.cached
    }

    /// The cached-bytes ledger: device bytes held by the reclaimable
    /// tier (counted inside [`KvPager::resident_bytes`] — cache occupies
    /// real VRAM until reclaimed).
    pub fn cached_bytes(&self) -> u64 {
        self.cached as u64 * self.block_bytes()
    }

    /// Distinct physical blocks with live holders (the pinned tier).
    pub fn used_blocks(&self) -> usize {
        self.allocated
    }

    pub fn capacity_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Token positions per block.
    pub fn block_positions(&self) -> usize {
        self.block_positions
    }

    /// The longest single sequence the whole pool could hold.
    pub fn max_positions(&self) -> usize {
        self.total_blocks * self.block_positions
    }

    /// Live sequences holding pages.
    pub fn active_seqs(&self) -> usize {
        self.active
    }

    /// Cumulative prefix-cache counters.
    pub fn prefix_stats(&self) -> PrefixStats {
        self.stats
    }

    fn block_bytes(&self) -> u64 {
        self.block_positions as u64 * self.bytes_per_pos
    }

    /// Bytes currently resident (weights + pinned pages + cached pages —
    /// sharing means this can be far below the sum of per-sequence
    /// footprints, while the cached tier keeps VRAM occupied until
    /// reclaimed).
    pub fn resident_bytes(&self) -> u64 {
        self.weights_bytes + (self.allocated + self.cached) as u64 * self.block_bytes()
    }

    /// Headroom to the VRAM budget.
    pub fn headroom_bytes(&self) -> u64 {
        self.vram_bytes - self.resident_bytes()
    }

    /// What the replaced fixed-slot allocator would have admitted over the
    /// same VRAM: worst-case reservation of `max_ctx` positions per
    /// sequence. Kept as the paged-vs-fixed comparison baseline for
    /// benches and acceptance tests.
    pub fn fixed_slot_capacity(&self, max_ctx: usize) -> usize {
        let per_slot = self.bytes_per_pos * max_ctx.max(1) as u64;
        ((self.vram_bytes - self.weights_bytes) / per_slot) as usize
    }

    #[cfg(test)]
    fn block_refs(&self, id: usize) -> u32 {
        self.blocks[id].refs
    }

    #[cfg(test)]
    fn block_cached(&self, id: usize) -> bool {
        self.blocks[id].cached_at.is_some()
    }

    #[cfg(test)]
    fn seq_block_ids(&self, seq: SeqKv) -> Vec<usize> {
        self.alloc(seq).expect("live handle").blocks.clone()
    }

    #[cfg(test)]
    fn index_entries(&self) -> Vec<usize> {
        self.index.nodes.iter().flatten().map(|n| n.block).collect()
    }

    #[cfg(test)]
    fn root_children_hashed(&self) -> bool {
        matches!(self.index.root, ChildTable::Hashed(_))
    }
}

/// Host-RAM pool for swap-based preemption: evicted sequences whose KV is
/// cheaper to move over PCIe than to recompute park their pages here
/// until resume. Pure byte accounting — in the simulated deployment the
/// "pages" are the sequence's retained [`crate::runtime::DecodeState`].
#[derive(Clone, Copy, Debug)]
pub struct HostPool {
    capacity: u64,
    used: u64,
}

impl HostPool {
    pub fn new(capacity_bytes: u64) -> Self {
        HostPool { capacity: capacity_bytes, used: 0 }
    }

    /// Reserve `bytes` for a swapped-out sequence; false when the pool
    /// cannot hold it (the caller falls back to drop-and-recompute).
    pub fn try_reserve(&mut self, bytes: u64) -> bool {
        if self.used + bytes > self.capacity {
            return false;
        }
        self.used += bytes;
        true
    }

    /// Return a swapped sequence's bytes (resume or terminal failure).
    pub fn release(&mut self, bytes: u64) {
        debug_assert!(bytes <= self.used, "host pool release underflow");
        self.used = self.used.saturating_sub(bytes);
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity
    }
}

/// Fleet-level chain-hash prefix directory: each node publishes the chain
/// hashes its [`KvPager`]'s radix tree holds resident — pinned *and*
/// cached tiers, so a warm-but-idle card still attracts its returning
/// users — and the dispatcher scores candidate nodes by how deep a new
/// prompt's hash chain matches ([`crate::coordinator::router::Fleet::route_affine`]).
///
/// Publishing is **delta-based**: a worker sends only the chains added
/// and retracted since its last round ([`PrefixDirectory::publish_delta`]),
/// against an epoch stamp. The epoch bumps whenever the directory-side
/// set is invalidated wholesale ([`PrefixDirectory::clear`] on node
/// death); a delta against a stale epoch is refused and the worker full-
/// publishes once ([`PrefixDirectory::publish`]) to resynchronize. This
/// keeps the per-round cost O(churn), not O(resident blocks).
///
/// The directory is deliberately a *hint*, not a lease: entries can
/// outlive a reclaim between a publish and the route that read it. That
/// is safe by construction — the worker's [`KvPager::admit_prompt`]
/// re-descends its own live tree under its own lock, so a stale hit
/// simply admits with fewer (or zero) pinned blocks: a plain miss and a
/// full prefill, never an error. Nothing in the data plane trusts the
/// directory.
#[derive(Debug)]
pub struct PrefixDirectory {
    published: std::sync::Mutex<Vec<NodeSet>>,
}

#[derive(Debug, Default)]
struct NodeSet {
    epoch: u64,
    set: std::collections::HashSet<u64>,
}

impl PrefixDirectory {
    pub fn new(nodes: usize) -> Self {
        PrefixDirectory {
            published: std::sync::Mutex::new((0..nodes).map(|_| NodeSet::default()).collect()),
        }
    }

    /// Replace `node`'s published set with a fresh full snapshot
    /// ([`KvPager::index_hashes`]) — the resynchronization path after an
    /// epoch mismatch, and the first publish. Returns the epoch the
    /// snapshot was installed under, which subsequent deltas must carry.
    pub fn publish(&self, node: usize, hashes: Vec<u64>) -> u64 {
        let mut p = self.published.lock().unwrap();
        match p.get_mut(node) {
            Some(ns) => {
                ns.set.clear();
                ns.set.extend(hashes);
                ns.epoch
            }
            None => 0,
        }
    }

    /// Apply a chain-set delta for `node`: `added` since the last round,
    /// `retracted` since the last round. Returns false — applying
    /// nothing — when `epoch` does not match the directory's (the set
    /// was cleared by a death/recovery since the worker last synced);
    /// the caller must full-publish to resynchronize.
    pub fn publish_delta(&self, node: usize, epoch: u64, added: &[u64], retracted: &[u64]) -> bool {
        let mut p = self.published.lock().unwrap();
        let Some(ns) = p.get_mut(node) else {
            return false;
        };
        if ns.epoch != epoch {
            return false;
        }
        for h in retracted {
            ns.set.remove(h);
        }
        ns.set.extend(added.iter().copied());
        true
    }

    /// The epoch `node`'s published set currently lives under.
    pub fn epoch(&self, node: usize) -> u64 {
        let p = self.published.lock().unwrap();
        p.get(node).map(|ns| ns.epoch).unwrap_or(0)
    }

    /// Drop a dead node's entries immediately — its VRAM is gone, so
    /// routing toward its published chains would be pure loss. Bumps the
    /// epoch, so any in-flight delta stream from the (possibly revived)
    /// worker is refused until it full-publishes.
    pub fn clear(&self, node: usize) {
        let mut p = self.published.lock().unwrap();
        if let Some(ns) = p.get_mut(node) {
            ns.set.clear();
            ns.epoch += 1;
        }
    }

    /// Per-node matched-prefix depth for one prompt's hash chain: how
    /// many *leading* hashes each node has published. Matching stops at
    /// the first gap, mirroring [`KvPager::admit_prompt`] — a resident
    /// block behind a missing one is unreachable prefix-wise.
    pub fn match_depths(&self, hashes: &[u64]) -> Vec<usize> {
        let p = self.published.lock().unwrap();
        p.iter()
            .map(|ns| hashes.iter().take_while(|h| ns.set.contains(h)).count())
            .collect()
    }

    /// Nodes the directory tracks.
    pub fn nodes(&self) -> usize {
        self.published.lock().unwrap().len()
    }

    #[cfg(test)]
    fn snapshot(&self, node: usize) -> std::collections::HashSet<u64> {
        self.published.lock().unwrap()[node].set.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    /// 4-position blocks of 1 KiB/pos over 8 MiB with 1 MiB of weights:
    /// (8 - 1) MiB / 4 KiB = 1792 blocks.
    fn pager() -> KvPager {
        KvPager::new(4, 1 << 10, 8 << 20, 1 << 20).unwrap()
    }

    #[test]
    fn admit_grow_release_cycle_tracks_blocks() {
        let mut p = pager();
        assert_eq!(p.capacity_blocks(), 1792);
        let a = p.admit(6).unwrap(); // 2 blocks
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.active_seqs(), 1);
        // growth inside the last owned block allocates nothing
        assert!(p.grow(a, 7).unwrap());
        assert!(p.grow(a, 8).unwrap());
        assert_eq!(p.used_blocks(), 2);
        // crossing the block boundary allocates exactly one block
        assert!(p.grow(a, 9).unwrap());
        assert_eq!(p.used_blocks(), 3);
        // shrinking requests are no-ops
        assert!(p.grow(a, 2).unwrap());
        assert_eq!(p.seq_positions(a).unwrap(), 9);
        // private (content-less) blocks free for real — there is nothing
        // to cache
        assert_eq!(p.release(a).unwrap(), 3);
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.cached_blocks(), 0);
        assert_eq!(p.active_seqs(), 0);
    }

    #[test]
    fn grow_past_the_pool_fails_without_side_effects() {
        let mut p = pager();
        let a = p.admit(4).unwrap();
        let hog = p.admit(1792 * 4 - 4).unwrap(); // everything else
        assert_eq!(p.free_blocks(), 0);
        assert!(!p.grow(a, 5).unwrap(), "no pages left");
        assert_eq!(p.seq_positions(a).unwrap(), 4, "failed grow must not move");
        assert_eq!(p.used_blocks(), 1792);
        p.release(hog).unwrap();
        assert!(p.grow(a, 5).unwrap(), "freed pages make growth succeed");
        p.release(a).unwrap();
    }

    #[test]
    fn stale_handles_are_rejected_without_corrupting_accounting() {
        let mut p = pager();
        let a = p.admit(4).unwrap();
        let b = p.admit(4).unwrap();
        p.release(a).unwrap();
        let err = p.release(a).unwrap_err().to_string();
        assert!(err.contains("stale"), "{err}");
        assert_eq!(p.used_blocks(), 1);
        // the id is recycled by the next admission; the old handle must
        // still be dead even though the slot is live again
        let c = p.admit(4).unwrap();
        assert!(p.grow(a, 8).is_err());
        assert!(p.release(a).is_err());
        assert_eq!(p.used_blocks(), 2);
        // out-of-range ids are rejected too
        let bogus = SeqKv { id: 999, gen: 1 };
        assert!(p.release(bogus).unwrap_err().to_string().contains("out of range"));
        p.release(b).unwrap();
        p.release(c).unwrap();
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn rejects_impossible_geometries() {
        // weights alone overflow the card
        assert!(KvPager::new(4, 1 << 10, 1 << 20, 2 << 20).is_err());
        // headroom smaller than one block
        assert!(KvPager::new(1024, 1 << 20, (1 << 30) + 1, 1 << 30).is_err());
        // degenerate parameters
        assert!(KvPager::new(0, 1 << 10, 8 << 20, 0).is_err());
        assert!(KvPager::new(4, 0, 8 << 20, 0).is_err());
    }

    #[test]
    fn vram_accounting_tracks_pages() {
        let mut p = pager();
        assert_eq!(p.resident_bytes(), 1 << 20);
        let a = p.admit(5).unwrap(); // 2 blocks of 4 KiB
        assert_eq!(p.resident_bytes(), (1 << 20) + 2 * (4 << 10));
        assert_eq!(p.seq_bytes(a).unwrap(), 2 * (4 << 10));
        p.release(a).unwrap();
        assert_eq!(p.headroom_bytes(), (8 << 20) - (1 << 20));
    }

    #[test]
    fn limit_blocks_caps_the_pool() {
        let mut p = pager();
        p.limit_blocks(3).unwrap();
        assert_eq!(p.capacity_blocks(), 3);
        assert_eq!(p.max_positions(), 12);
        assert_eq!(p.admissible(4), 3);
        let a = p.admit(12).unwrap();
        assert!(p.admit(1).is_none());
        assert!(p.limit_blocks(2).is_err(), "cannot shrink under live pages");
        assert!(p.limit_blocks(0).is_err());
        p.release(a).unwrap();
        // a cap above the total is a no-op
        p.limit_blocks(usize::MAX).unwrap();
        assert_eq!(p.capacity_blocks(), 3);
    }

    #[test]
    fn lose_blocks_shrinks_only_the_free_pool() {
        let mut p = pager();
        p.limit_blocks(10).unwrap();
        let a = p.admit(12).unwrap(); // 3 blocks live
        assert_eq!(p.free_blocks(), 7);
        // a VRAM fault burns 4 free pages: capacity shrinks, the live
        // sequence is untouched
        assert_eq!(p.lose_blocks(4), 4);
        assert_eq!(p.capacity_blocks(), 6);
        assert_eq!(p.free_blocks(), 3);
        assert_eq!(p.seq_positions(a).unwrap(), 12);
        assert!(p.grow(a, 16).unwrap(), "survivors can still grow");
        // losses clamp to the free pool — live pages are never taken
        assert_eq!(p.lose_blocks(100), 2);
        assert_eq!(p.capacity_blocks(), 4);
        assert_eq!(p.free_blocks(), 0);
        assert_eq!(p.lose_blocks(1), 0, "nothing free left to lose");
        // released pages come back into the (smaller) pool and recycle
        assert_eq!(p.release(a).unwrap(), 4);
        assert_eq!(p.free_blocks(), 4);
        let b = p.admit(16).unwrap();
        assert_eq!(p.used_blocks(), 4);
        p.release(b).unwrap();
        assert_eq!(p.used_blocks() + p.free_blocks(), p.capacity_blocks());
    }

    #[test]
    fn lose_blocks_reclaims_cache_to_cover_the_loss() {
        let mut p = pager();
        p.limit_blocks(4).unwrap();
        let (a, _) = p.admit_prompt(&window(0, 8, 1)).unwrap(); // 2 blocks
        p.release(a).unwrap();
        assert_eq!(p.cached_blocks(), 2);
        assert_eq!(p.free_blocks(), 2);
        // losing 3 pages must dip into the cached tier: the chain is
        // reclaimed (tree-unlinked) to cover the loss
        assert_eq!(p.lose_blocks(3), 3);
        assert_eq!(p.capacity_blocks(), 1);
        assert_eq!(p.cached_blocks(), 0, "cache reclaimed to cover the loss");
        assert_eq!(p.free_blocks(), 1);
        assert!(p.index_entries().is_empty());
        let b = p.admit(4).unwrap();
        p.release(b).unwrap();
    }

    #[test]
    fn paged_admits_strictly_more_than_fixed_slots_at_long_context() {
        // The §4.1 accounting on a CMP 170HX: Qwen2.5-1.5B KV bytes/pos
        // (2 · 28 layers · 2 kv_heads · 128 head_dim · f16 = 28672 B) on
        // an 8 GB card with ~2 GB of q8_0 weights, serving 4096-token
        // contexts whose mean sequence (prompt + generation) is 1024
        // positions — context 4× the mean, the acceptance operating point.
        let mut p = KvPager::new(16, 28_672, 8 << 30, 2 << 30).unwrap();
        let max_ctx = 4096;
        let mean_seq = 1024;
        let fixed = p.fixed_slot_capacity(max_ctx);
        let paged = p.admissible(mean_seq);
        assert!(fixed > 0);
        assert!(
            paged > fixed,
            "paged {paged} must beat fixed-slot {fixed} at equal VRAM"
        );
        // ~4× is the arithmetic expectation when reservations are 4× the
        // mean; block rounding costs a little
        assert!(paged >= 3 * fixed, "paged {paged} vs fixed {fixed}");
        // and the pager actually delivers that concurrency within budget
        let held: Vec<SeqKv> = (0..paged).map(|_| p.admit(mean_seq).unwrap()).collect();
        assert!(p.resident_bytes() <= 8 << 30);
        assert_eq!(p.active_seqs(), paged);
        for h in held {
            p.release(h).unwrap();
        }
    }

    /// A padded prefill window: `shared` common tokens then `salt`-unique
    /// filler up to `len` (models a shared system prompt + per-user tail).
    fn window(shared: usize, len: usize, salt: i32) -> Vec<i32> {
        (0..len)
            .map(|i| if i < shared { i as i32 + 1 } else { salt * 10_000 + i as i32 })
            .collect()
    }

    #[test]
    fn identical_prompts_share_every_block() {
        let mut p = pager(); // 4-position blocks
        let w = window(8, 8, 0); // two full blocks
        let (a, hits_a) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits_a, 0);
        assert_eq!(p.used_blocks(), 2);
        let (b, hits_b) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits_b, 2, "the second identical prompt pins both blocks");
        assert_eq!(p.used_blocks(), 2, "no new physical blocks");
        assert_eq!(p.seq_block_ids(a), p.seq_block_ids(b));
        assert_eq!(
            p.prefix_stats(),
            PrefixStats { hit_blocks: 2, miss_blocks: 2, ..Default::default() }
        );
        // releases unpin; the last holder demotes to the cached tier
        // instead of freeing — the conversation may come back
        assert_eq!(p.release(a).unwrap(), 0, "shared blocks survive the first release");
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.release(b).unwrap(), 0, "content blocks demote, not free");
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.cached_blocks(), 2);
        assert_eq!(p.index_entries().len(), 2, "cached blocks stay matchable");
        // the ablation arm frees for real
        p.set_retention(false);
        assert_eq!(p.cached_blocks(), 0);
        assert_eq!(p.free_blocks(), p.capacity_blocks());
        assert!(p.index_entries().is_empty(), "reclaimed blocks leave the tree");
    }

    #[test]
    fn shared_prefix_pins_only_the_common_run() {
        let mut p = pager();
        // 12-position windows sharing the first 8 positions (2 of 3 blocks)
        let (a, _) = p.admit_prompt(&window(8, 12, 1)).unwrap();
        let (b, hits) = p.admit_prompt(&window(8, 12, 2)).unwrap();
        assert_eq!(hits, 2);
        assert_eq!(p.used_blocks(), 4, "3 + 1 fresh tail, not 6");
        let (ia, ib) = (p.seq_block_ids(a), p.seq_block_ids(b));
        assert_eq!(&ia[..2], &ib[..2]);
        assert_ne!(ia[2], ib[2]);
        assert_eq!(p.block_refs(ia[0]), 2);
        assert_eq!(p.block_refs(ia[2]), 1);
        // the eviction chooser's survivability probe: 2 of a's 3 blocks
        // (and both of its first 2, the "prompt window") are shared
        assert_eq!(p.seq_shared_blocks(a, 3).unwrap(), 2);
        assert_eq!(p.seq_shared_blocks(a, 1).unwrap(), 1);
        // …and with retention on, even a's private tail is a survivor
        // (it demotes to cache on release), so a swap moves nothing
        assert_eq!(p.seq_survivor_blocks(a, 3).unwrap(), 3);
        assert_eq!(p.seq_swap_bytes(a).unwrap(), 0);
        assert_eq!(p.seq_private_bytes(a).unwrap(), 4 << 10);
        assert_eq!(p.seq_bytes(a).unwrap(), 3 * (4 << 10));
        p.release(b).unwrap();
        assert_eq!(p.cached_blocks(), 1, "b's private tail went to cache");
        assert_eq!(p.seq_shared_blocks(a, 3).unwrap(), 0, "sole holder shares nothing");
        assert_eq!(p.seq_private_bytes(a).unwrap(), p.seq_bytes(a).unwrap());
        p.release(a).unwrap();
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.cached_blocks(), 4);
        // the ablation arm: nothing survives a release, swaps move
        // every sole-held page
        p.set_retention(false);
        let (c, _) = p.admit_prompt(&window(8, 12, 3)).unwrap();
        assert_eq!(p.seq_survivor_blocks(c, 3).unwrap(), 0);
        assert_eq!(p.seq_swap_bytes(c).unwrap(), 3 * (4 << 10));
        p.release(c).unwrap();
    }

    #[test]
    fn growing_into_a_shared_tail_copies_on_write() {
        let mut p = pager();
        // 6-position windows: one full block + a shared partial tail
        let w = window(6, 6, 0);
        let (a, _) = p.admit_prompt(&w).unwrap();
        let (b, hits) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits, 2, "the deterministic partial tail is shareable too");
        assert_eq!(p.used_blocks(), 2);
        let tail = p.seq_block_ids(a)[1];
        assert_eq!(p.block_refs(tail), 2);
        // a's first decode write lands inside the shared tail → CoW
        assert!(p.grow(a, 7).unwrap());
        assert_eq!(p.prefix_stats().cow_copies, 1);
        assert_eq!(p.used_blocks(), 3, "one private replacement allocated");
        let a_tail = p.seq_block_ids(a)[1];
        assert_ne!(a_tail, tail, "writer got a private copy");
        assert_eq!(p.block_refs(tail), 1, "b still holds the original");
        assert_eq!(p.seq_block_ids(b)[1], tail);
        // the original stays registered: a third identical prompt re-pins it
        let (c, hits_c) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits_c, 2);
        assert_eq!(p.block_refs(tail), 2);
        // a sole-holder hashed tail is unregistered (not copied) on write
        p.release(c).unwrap();
        assert!(p.grow(b, 8).unwrap());
        assert_eq!(p.prefix_stats().cow_copies, 1, "no copy when refs == 1");
        let (_, hits_d) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits_d, 1, "the diverged tail no longer matches");
        p.release(a).unwrap();
        p.release(b).unwrap();
    }

    #[test]
    fn cow_respects_the_free_pool() {
        let mut p = pager();
        p.limit_blocks(3).unwrap();
        let w = window(6, 6, 0);
        let (a, _) = p.admit_prompt(&w).unwrap();
        let (b, _) = p.admit_prompt(&w).unwrap(); // pins both of a's blocks
        let hog = p.admit(1).unwrap(); // takes the last free block
        assert_eq!(p.free_blocks(), 0);
        // a's first write needs a CoW replacement block that does not
        // exist: the grow must refuse and change nothing.
        let before = p.seq_block_ids(a);
        assert!(!p.grow(a, 7).unwrap());
        assert_eq!(p.seq_block_ids(a), before);
        assert_eq!(p.seq_positions(a).unwrap(), 6);
        assert_eq!(p.prefix_stats().cow_copies, 0);
        p.release(hog).unwrap();
        assert!(p.grow(a, 7).unwrap(), "freed pages make the CoW succeed");
        assert_eq!(p.prefix_stats().cow_copies, 1);
        assert_eq!(p.seq_positions(b).unwrap(), 6, "the other holder is untouched");
        p.release(a).unwrap();
        p.release(b).unwrap();
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn cow_can_reclaim_cache_for_its_replacement_block() {
        // Same shape as above, but the last free page is held by the
        // cached tier instead of a hog: the CoW must reclaim it rather
        // than refuse.
        let mut p = pager();
        p.limit_blocks(3).unwrap();
        let w = window(6, 6, 0);
        let (a, _) = p.admit_prompt(&w).unwrap();
        let (b, _) = p.admit_prompt(&w).unwrap();
        let (idle, _) = p.admit_prompt(&window(0, 4, 9)).unwrap();
        p.release(idle).unwrap(); // demotes: 1 cached, 0 free
        assert_eq!(p.free_blocks(), 0);
        assert_eq!(p.cached_blocks(), 1);
        assert!(p.grow(a, 7).unwrap(), "cached pages are reclaimable for CoW");
        assert_eq!(p.prefix_stats().cow_copies, 1);
        assert_eq!(p.prefix_stats().reclaimed_blocks, 1);
        assert_eq!(p.cached_blocks(), 0);
        p.release(a).unwrap();
        p.release(b).unwrap();
    }

    #[test]
    fn demoted_blocks_resurrect_for_returning_users() {
        let mut p = pager();
        let w = window(0, 8, 7); // one user's distinct 2-block history
        let (a, h0) = p.admit_prompt(&w).unwrap();
        assert_eq!(h0, 0);
        let ids = p.seq_block_ids(a);
        assert_eq!(p.release(a).unwrap(), 0, "content blocks demote instead of freeing");
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.cached_blocks(), 2);
        assert_eq!(p.cached_bytes(), 2 * (4 << 10));
        assert_eq!(p.free_blocks(), 1792 - 2);
        assert_eq!(p.available_blocks(), 1792, "cached pages stay admissible");
        assert_eq!(p.resident_prefix_blocks(&w), 2, "warm but idle");
        // the returning user re-pins its entire history
        let (b, hits) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits, 2);
        assert_eq!(p.seq_block_ids(b), ids, "the same physical pages come back");
        assert_eq!(p.cached_blocks(), 0);
        assert_eq!(p.used_blocks(), 2);
        let s = p.prefix_stats();
        assert_eq!(s.resurrected_blocks, 2, "hits came from the cached tier");
        assert_eq!(s.hit_blocks, 2);
        assert_eq!(s.miss_blocks, 2);
        // the --no-kv-cache ablation frees at refcount zero: no comeback
        p.set_retention(false);
        assert_eq!(p.release(b).unwrap(), 2);
        assert_eq!(p.resident_prefix_blocks(&w), 0);
        let (c, hits_c) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits_c, 0, "the baseline re-prefills everything");
        p.release(c).unwrap();
    }

    #[test]
    fn reclaim_is_lru_and_never_touches_pinned() {
        let mut p = pager();
        p.limit_blocks(6).unwrap();
        // two idle conversations demoted in age order: wa older than wb
        let wa = window(0, 8, 1);
        let wb = window(0, 8, 2);
        let (a, _) = p.admit_prompt(&wa).unwrap();
        let (b, _) = p.admit_prompt(&wb).unwrap();
        p.release(a).unwrap();
        p.release(b).unwrap();
        assert_eq!(p.cached_blocks(), 4);
        // a live sequence pins the remaining free pages
        let live = p.admit(8).unwrap();
        assert_eq!(p.free_blocks(), 0);
        // pressure for 2 more pages reclaims the LRU-oldest chain only
        let hog = p.admit(8).unwrap();
        assert_eq!(p.prefix_stats().reclaimed_blocks, 2);
        assert_eq!(p.resident_prefix_blocks(&wa), 0, "oldest chain reclaimed");
        assert_eq!(p.resident_prefix_blocks(&wb), 2, "newer chain survives");
        assert_eq!(p.seq_positions(live).unwrap(), 8, "pinned pages untouched");
        assert_eq!(p.used_blocks(), 4);
        assert_eq!(p.cached_blocks(), 2);
        // more pressure takes the rest of the cache — never a pinned page
        let hog2 = p.admit(8).unwrap();
        assert_eq!(p.cached_blocks(), 0);
        assert_eq!(p.resident_prefix_blocks(&wb), 0);
        assert_eq!(p.used_blocks(), 6);
        assert!(p.admit(1).is_none(), "only pinned pages remain");
        p.release(live).unwrap();
        p.release(hog).unwrap();
        p.release(hog2).unwrap();
    }

    #[test]
    fn lru_entries_go_stale_on_resurrection() {
        let mut p = pager();
        p.limit_blocks(4).unwrap();
        let w1 = window(0, 4, 1); // one block each
        let w2 = window(0, 4, 2);
        let (a, _) = p.admit_prompt(&w1).unwrap();
        p.release(a).unwrap(); // w1 demoted first…
        let (b, _) = p.admit_prompt(&w2).unwrap();
        p.release(b).unwrap(); // …then w2
        let (a2, hits) = p.admit_prompt(&w1).unwrap();
        assert_eq!(hits, 1);
        p.release(a2).unwrap(); // w1 re-demoted: now *newer* than w2
        // pressure for 3 pages: the stale head entry for w1 must be
        // skipped and w2 — the true LRU — reclaimed instead
        let hog = p.admit(12).unwrap();
        assert_eq!(p.resident_prefix_blocks(&w2), 0, "w2 was truly oldest");
        assert_eq!(p.resident_prefix_blocks(&w1), 1, "the resurrected chain is recent");
        p.release(hog).unwrap();
    }

    #[test]
    fn adaptive_root_fanout_spills_to_hash_and_shrinks_back() {
        let mut p = pager();
        // 9 distinct one-block conversations: the root's child table
        // must spill past the inline node
        for salt in 0..9 {
            let (h, _) = p.admit_prompt(&window(0, 4, 100 + salt)).unwrap();
            p.release(h).unwrap();
        }
        assert!(p.root_children_hashed(), "fanout 9 spills the inline table");
        assert_eq!(p.cached_blocks(), 9);
        // draining the cache shrinks the table back below the spill point
        p.set_retention(false);
        assert_eq!(p.cached_blocks(), 0);
        assert!(!p.root_children_hashed(), "low fanout shrinks back to inline");
        assert!(p.index_entries().is_empty());
    }

    #[test]
    fn returning_user_workload_hits_radix_cache_acceptance() {
        // The serve_radix_cache acceptance point, pinned analytically
        // like PR 5's: 8 distinct users share a 2-block system prompt,
        // chat once, go idle, and return for a second turn. With
        // retention on, every returning turn re-pins its entire turn-1
        // history from the cached tier; the --no-kv-cache ablation
        // (refcount-zero-frees) re-prefills everything but the
        // still-live-shared system prompt. ≥1.5× fleet prefix hits and
        // strictly less prefill work (the goodput proxy at fixed
        // demand) are the acceptance bars.
        let users = 8;
        let (shared, len) = (8usize, 32usize); // 2 system + 6 private blocks
        let run = |retain: bool| -> PrefixStats {
            let mut p = pager();
            p.set_retention(retain);
            for _turn in 0..2 {
                let held: Vec<SeqKv> = (0..users)
                    .map(|u| p.admit_prompt(&window(shared, len, u as i32)).unwrap().0)
                    .collect();
                for h in held {
                    p.release(h).unwrap();
                }
            }
            p.prefix_stats()
        };
        let cached = run(true);
        let baseline = run(false);
        // baseline: each turn hits only the live-shared system prompt
        // (7 followers × 2 blocks); the cached arm's second turn hits
        // all 8 blocks for all 8 users, 50 of them resurrections (the
        // first returner resurrects the system prompt too).
        assert_eq!(baseline.hit_blocks, 28);
        assert_eq!(baseline.resurrected_blocks, 0);
        assert_eq!(baseline.miss_blocks, 100);
        assert_eq!(cached.hit_blocks, 78);
        assert_eq!(cached.resurrected_blocks, 50);
        assert_eq!(cached.miss_blocks, 50);
        assert!(
            cached.hit_blocks as f64 >= 1.5 * baseline.hit_blocks as f64,
            "radix cache {} vs baseline {} prefix hits",
            cached.hit_blocks,
            baseline.hit_blocks
        );
        assert!(
            cached.miss_blocks < baseline.miss_blocks,
            "strictly less prefill work = strictly better goodput at fixed demand"
        );
    }

    #[test]
    fn prefix_cached_admission_hits_the_acceptance_multiplier() {
        // The ISSUE 5 acceptance point: Qwen2.5-1.5B q8_0 on a CMP 170HX
        // (8 GiB, 1,625,610,592 bytes of weights → 15181 16-position
        // blocks), ctx 4096, 1024-position mean sequences, all sharing a
        // 512-position system prompt. The paged baseline admits
        // ⌊15181/64⌋ = 237; with prefix sharing the 32 prompt blocks are
        // resident once and each later admission allocates only its 32
        // private blocks: 1 + ⌊(15181 − 64)/32⌋ = 473 — ≥ 1.5× (≈2×) the
        // PR 3 baseline. Recorded as `serve_prefix_cache` in
        // BENCH_sim_throughput.json.
        use crate::device::registry;
        use crate::llm::model::ModelDesc;
        use crate::llm::quant;
        let model = ModelDesc::qwen25_15b();
        let dev = registry::cmp170hx();
        let mut p = KvPager::new(
            16,
            model.kv_bytes_per_pos(),
            dev.mem.capacity_bytes,
            model.weight_bytes(&quant::Q8_0),
        )
        .unwrap();
        let (mean_seq, shared) = (1024usize, 512usize);
        let baseline = p.admissible(mean_seq);
        assert_eq!(baseline, 237, "the PR 3 serve_concurrency operating point");
        let mut held = Vec::new();
        while let Some((kv, _)) = p.admit_prompt(&window(shared, mean_seq, held.len() as i32)) {
            held.push(kv);
        }
        let shared_blocks = shared / 16;
        let per_seq = mean_seq / 16;
        let analytic = 1 + (p.capacity_blocks() - per_seq) / (per_seq - shared_blocks);
        assert_eq!(held.len(), analytic, "admission must match the analytic point");
        assert_eq!(held.len(), 473);
        assert!(
            held.len() as f64 >= 1.5 * baseline as f64,
            "prefix-cached {} vs paged {baseline}",
            held.len()
        );
        assert!(p.resident_bytes() <= dev.mem.capacity_bytes);
        for kv in held {
            p.release(kv).unwrap();
        }
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn host_pool_reserves_and_releases() {
        let mut pool = HostPool::new(100);
        assert!(pool.try_reserve(60));
        assert!(!pool.try_reserve(50), "over-capacity reservation refused");
        assert!(pool.try_reserve(40));
        assert_eq!(pool.used_bytes(), 100);
        pool.release(60);
        assert_eq!(pool.used_bytes(), 40);
        assert!(pool.try_reserve(60));
        assert_eq!(pool.capacity_bytes(), 100);
    }

    #[test]
    fn prop_host_pool_conserves_bytes_under_faulty_swap_interleavings() {
        // Shadow-model property for the swap path's host-RAM accounting:
        // random interleavings of swap-out (reserve), swap-in (release),
        // and *failed* swap-in (the fault injector corrupts the parked
        // pages; the worker releases the reservation exactly once and
        // falls back to recompute). Invariants after every step: used
        // bytes equal the sum of outstanding reservations (bytes
        // conserved, no double-free), used never exceeds capacity, and a
        // refused reservation changes nothing.
        forall(0xFA117, 200, |rng: &mut Rng| {
            let capacity = rng.range(1, 1 << 20);
            let mut pool = HostPool::new(capacity);
            let mut outstanding: Vec<u64> = Vec::new(); // shadow reservations
            for _ in 0..120 {
                match rng.below(3) {
                    0 => {
                        // swap-out: park a sequence's private KV bytes
                        let bytes = rng.range(0, capacity + capacity / 4);
                        let before = pool.used_bytes();
                        if pool.try_reserve(bytes) {
                            outstanding.push(bytes);
                        } else {
                            assert!(before + bytes > capacity, "refusal must mean overflow");
                            assert_eq!(pool.used_bytes(), before, "refused reserve moved bytes");
                        }
                    }
                    1 => {
                        // swap-in: the resume path restores and releases
                        if let Some(i) =
                            (!outstanding.is_empty()).then(|| rng.below(outstanding.len() as u64))
                        {
                            pool.release(outstanding.swap_remove(i as usize));
                        }
                    }
                    _ => {
                        // failed swap-in: the reservation is released once
                        // (never twice) and the sequence recomputes; from
                        // the pool's view this is indistinguishable from a
                        // clean swap-in, which is exactly the invariant —
                        // the fault path must not invent or leak bytes.
                        if let Some(i) =
                            (!outstanding.is_empty()).then(|| rng.below(outstanding.len() as u64))
                        {
                            pool.release(outstanding.swap_remove(i as usize));
                        }
                    }
                }
                let expect: u64 = outstanding.iter().sum();
                assert_eq!(pool.used_bytes(), expect, "pool drifted from shadow ledger");
                assert!(pool.used_bytes() <= pool.capacity_bytes());
            }
            for bytes in outstanding.drain(..) {
                pool.release(bytes);
            }
            assert_eq!(pool.used_bytes(), 0, "draining all reservations must zero the pool");
        });
    }

    #[test]
    fn prop_pages_always_partition_the_budget() {
        // Port of the fixed-slot allocator's never-leaks property to
        // random admit/grow/preempt/resume interleavings: live
        // allocations plus the free pool always partition the block
        // budget, and resident bytes never exceed VRAM. (Private blocks
        // only — the cached tier stays empty on this path.)
        forall(0x9A6ED, 150, |rng: &mut Rng| {
            let bp = rng.range(1, 8) as usize;
            let total = rng.range(2, 40) as usize;
            let bytes_per_pos = 64u64;
            let block_bytes = bp as u64 * bytes_per_pos;
            let weights = 1u64 << 10;
            let vram = weights + total as u64 * block_bytes + rng.below(block_bytes);
            let mut p = KvPager::new(bp, bytes_per_pos, vram, weights).unwrap();
            assert_eq!(p.capacity_blocks(), total);
            // (handle, positions) shadow model; parked holds preempted
            // sequences' positions awaiting resume
            let mut held: Vec<(SeqKv, usize)> = Vec::new();
            let mut parked: Vec<usize> = Vec::new();
            for _ in 0..96 {
                match rng.below(4) {
                    0 => {
                        // admit a fresh sequence
                        let pos = rng.range(1, 4 * bp as u64) as usize;
                        match p.admit(pos) {
                            Some(h) => held.push((h, pos)),
                            None => assert!(p.free_blocks() < pos.div_ceil(bp)),
                        }
                    }
                    1 => {
                        // grow a live sequence (a decode round)
                        if let Some(i) =
                            (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                        {
                            let target = held[i].1 + rng.range(0, 2 * bp as u64) as usize;
                            let before = p.used_blocks();
                            if p.grow(held[i].0, target).unwrap() {
                                held[i].1 = held[i].1.max(target);
                            } else {
                                assert_eq!(p.used_blocks(), before, "failed grow moved");
                            }
                        }
                    }
                    2 => {
                        // preempt: KV dropped, sequence parked for resume
                        if let Some(i) =
                            (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                        {
                            let (h, pos) = held.swap_remove(i);
                            let freed = p.release(h).unwrap();
                            assert_eq!(freed, pos.max(1).div_ceil(bp));
                            assert!(p.release(h).is_err(), "double release must fail");
                            parked.push(pos);
                        }
                    }
                    _ => {
                        // resume: re-admit at the parked length (the
                        // recompute path re-grows to where it left off)
                        if let Some(i) =
                            (!parked.is_empty()).then(|| rng.below(parked.len() as u64) as usize)
                        {
                            let pos = parked[i];
                            if let Some(h) = p.admit(pos) {
                                parked.swap_remove(i);
                                held.push((h, pos));
                            } else {
                                assert!(p.free_blocks() < pos.max(1).div_ceil(bp));
                            }
                        }
                    }
                }
                // invariants after every step
                let expect: usize = held.iter().map(|&(_, pos)| pos.max(1).div_ceil(bp)).sum();
                assert_eq!(p.used_blocks(), expect);
                assert_eq!(p.cached_blocks(), 0, "private pages never enter the cache");
                assert_eq!(p.used_blocks() + p.free_blocks(), p.capacity_blocks());
                assert!(p.resident_bytes() <= vram);
                assert_eq!(p.active_seqs(), held.len());
                assert_eq!(p.admissible(bp), p.free_blocks());
            }
            for (h, _) in held {
                p.release(h).unwrap();
            }
            assert_eq!(p.used_blocks(), 0);
        });
    }

    #[test]
    fn prop_shared_prefix_refcounts_and_tree_never_dangle() {
        // The release-path property, extended to three tiers: random
        // interleavings of shared-prefix admit / CoW grow / release
        // against a shadow model of per-sequence block tables. After
        // every step: each block's refcount equals the number of live
        // holders (so it can never underflow), every block the tree
        // points at is pinned or cached (never freed), pinned + cached +
        // free partitions the budget, and admission bills exactly its
        // fresh pages plus resurrections.
        forall(0xC0FFEE, 120, |rng: &mut Rng| {
            let bp = rng.range(1, 6) as usize;
            let total = rng.range(4, 48) as usize;
            let weights = 1u64 << 10;
            let vram = weights + total as u64 * (bp as u64 * 64);
            let mut p = KvPager::new(bp, 64, vram, weights).unwrap();
            // a small pool of prompt families: windows share a prefix
            // within a family, so admissions pin each other's blocks
            let families: Vec<(usize, usize)> = (0..3)
                .map(|_| {
                    let len = rng.range(1, 4 * bp as u64) as usize;
                    (rng.range(0, len as u64 + 1) as usize, len)
                })
                .collect();
            let mut held: Vec<(SeqKv, Vec<usize>, usize)> = Vec::new(); // handle, shadow blocks, positions
            for _ in 0..80 {
                match rng.below(4) {
                    0 | 1 => {
                        // admit from a random family with a random salt
                        // (small salt range → frequent identical prompts)
                        let (shared, len) = *rng.pick(&families);
                        let salt = rng.range(0, 3) as i32;
                        let w = window(shared, len, salt);
                        let avail_before = p.available_blocks();
                        let stats_before = p.prefix_stats();
                        if let Some((h, hits)) = p.admit_prompt(&w) {
                            let ids = p.seq_block_ids(h);
                            assert_eq!(ids.len(), len.max(1).div_ceil(bp));
                            assert!(hits <= ids.len());
                            let resurrected = (p.prefix_stats().resurrected_blocks
                                - stats_before.resurrected_blocks)
                                as usize;
                            assert_eq!(
                                avail_before - p.available_blocks(),
                                ids.len() - hits + resurrected,
                                "admission must bill fresh pages plus resurrections"
                            );
                            held.push((h, ids, len));
                        } else {
                            assert!(p.available_blocks() < len.max(1).div_ceil(bp));
                        }
                    }
                    2 => {
                        // grow (may CoW a shared tail)
                        if let Some(i) =
                            (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                        {
                            let target = held[i].2 + rng.range(0, 2 * bp as u64) as usize;
                            if p.grow(held[i].0, target).unwrap() {
                                held[i].2 = held[i].2.max(target);
                                held[i].1 = p.seq_block_ids(held[i].0);
                            }
                        }
                    }
                    _ => {
                        // release a random holder (demotes content blocks)
                        if let Some(i) =
                            (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                        {
                            let (h, _, _) = held.swap_remove(i);
                            p.release(h).unwrap();
                            assert!(p.release(h).is_err(), "double release must fail");
                        }
                    }
                }
                // shadow-model invariants
                let mut refs: std::collections::HashMap<usize, u32> =
                    std::collections::HashMap::new();
                for (_, ids, _) in &held {
                    for &id in ids {
                        *refs.entry(id).or_default() += 1;
                    }
                }
                for (&id, &expect) in &refs {
                    assert_eq!(p.block_refs(id), expect, "refcount drifted on block {id}");
                }
                assert_eq!(p.used_blocks(), refs.len(), "distinct held blocks == pinned");
                assert_eq!(
                    p.used_blocks() + p.cached_blocks() + p.free_blocks(),
                    p.capacity_blocks(),
                    "pinned + cached + free must partition the budget"
                );
                assert_eq!(p.cached_bytes(), p.cached_blocks() as u64 * (bp as u64 * 64));
                for id in p.index_entries() {
                    assert!(
                        refs.contains_key(&id) || p.block_cached(id),
                        "tree points at freed block {id}"
                    );
                }
            }
            for (h, _, _) in held {
                p.release(h).unwrap();
            }
            assert_eq!(p.used_blocks(), 0);
            // every surviving tree entry is cached; dropping retention
            // reclaims them all and returns the full budget
            p.set_retention(false);
            assert_eq!(p.cached_blocks(), 0);
            assert!(p.index_entries().is_empty());
            assert_eq!(p.free_blocks(), p.capacity_blocks());
        });
    }

    #[test]
    fn prop_radix_descend_matches_flat_map_for_live_blocks() {
        // The tentpole shadow model: for live blocks the tree must be
        // exactly the old flat chain-hash map. With retention off the
        // new pager IS the old one — one descent must equal
        // chunk-by-chunk probing of a shadow HashMap, and the registered
        // hash set must match it key-for-key. With retention on the
        // tree may only know *more* (the cached tier); it must still
        // contain every live chain.
        forall(0x12AD1C, 150, |rng: &mut Rng| {
            let bp = rng.range(1, 6) as usize;
            let total = rng.range(8, 48) as usize;
            let weights = 1u64 << 10;
            let vram = weights + total as u64 * (bp as u64 * 64);
            let mut p = KvPager::new(bp, 64, vram, weights).unwrap();
            let retain = rng.below(2) == 0;
            p.set_retention(retain);
            let families: Vec<(usize, usize)> = (0..3)
                .map(|_| {
                    let len = rng.range(1, 4 * bp as u64) as usize;
                    (rng.range(0, len as u64 + 1) as usize, len)
                })
                .collect();
            // the shadow: chain hash → live holders, exactly the old index
            let mut flat: std::collections::HashMap<u64, u32> = std::collections::HashMap::new();
            let mut held: Vec<(SeqKv, Vec<u64>)> = Vec::new();
            for _ in 0..80 {
                if rng.below(3) < 2 {
                    let (shared, len) = *rng.pick(&families);
                    let w = window(shared, len, rng.range(0, 3) as i32);
                    let hashes = window_chain_hashes(&w, bp);
                    let flat_depth = hashes.iter().take_while(|h| flat.contains_key(h)).count();
                    let tree_depth = p.resident_prefix_blocks(&w);
                    if retain {
                        assert!(tree_depth >= flat_depth, "tree lost a live chain");
                    } else {
                        assert_eq!(tree_depth, flat_depth, "descent != flat-map probing");
                    }
                    if let Some((h, hits)) = p.admit_prompt(&w) {
                        assert_eq!(hits, tree_depth, "admission must pin the probed depth");
                        for hash in &hashes {
                            *flat.entry(*hash).or_default() += 1;
                        }
                        held.push((h, hashes));
                    }
                } else if let Some(i) =
                    (!held.is_empty()).then(|| rng.below(held.len() as u64) as usize)
                {
                    let (h, hashes) = held.swap_remove(i);
                    p.release(h).unwrap();
                    for hash in hashes {
                        let holders = flat.get_mut(&hash).expect("released chain was live");
                        *holders -= 1;
                        if *holders == 0 {
                            flat.remove(&hash);
                        }
                    }
                }
                let tree: std::collections::HashSet<u64> = p.index_hashes().into_iter().collect();
                for hash in flat.keys() {
                    assert!(tree.contains(hash), "live chain hash missing from the tree");
                }
                if !retain {
                    assert_eq!(tree.len(), flat.len(), "retention off must free at refs zero");
                }
                assert_eq!(
                    p.used_blocks() + p.cached_blocks() + p.free_blocks(),
                    p.capacity_blocks()
                );
            }
            for (h, _) in held {
                p.release(h).unwrap();
            }
            assert_eq!(p.used_blocks(), 0);
        });
    }

    #[test]
    fn directory_scores_matched_prefix_depth_per_node() {
        let mut p0 = pager();
        let mut p1 = pager();
        // node 0 holds the 8-shared family; node 1 holds a disjoint one
        let (a, _) = p0.admit_prompt(&window(8, 12, 1)).unwrap();
        let (b, _) = p1.admit_prompt(&window(0, 12, 9)).unwrap();
        let dir = PrefixDirectory::new(2);
        assert_eq!(dir.nodes(), 2);
        dir.publish(0, p0.index_hashes());
        dir.publish(1, p1.index_hashes());
        // a sibling of node 0's family matches its 2 shared blocks there
        // and nothing on node 1
        let w = window(8, 12, 2);
        let hashes = window_chain_hashes(&w, p0.block_positions());
        assert_eq!(dir.match_depths(&hashes), vec![2, 0]);
        // the exact resident prompt matches all 3 of its blocks
        let exact = window_chain_hashes(&window(8, 12, 1), p0.block_positions());
        assert_eq!(dir.match_depths(&exact), vec![3, 0]);
        // and the probe agrees with what admission would report
        assert_eq!(p0.resident_prefix_blocks(&w), 2);
        assert_eq!(p1.resident_prefix_blocks(&w), 0);
        // a released conversation still attracts its returning user:
        // the cached tier stays published (warm-but-idle cards win)
        p0.release(a).unwrap();
        dir.publish(0, p0.index_hashes());
        assert_eq!(dir.match_depths(&exact), vec![3, 0], "cached chains stay routable");
        // clearing a dead node zeroes its depths without touching others
        dir.clear(0);
        assert_eq!(dir.match_depths(&exact), vec![0, 0]);
        p1.release(b).unwrap();
    }

    #[test]
    fn delta_publishes_reconstruct_the_full_directory_exactly() {
        // 8b: a worker publishing only per-round adds/retracts must land
        // the directory exactly where full snapshots would.
        let full = PrefixDirectory::new(1);
        let delta = PrefixDirectory::new(1);
        let epoch = delta.publish(0, vec![]);
        let mut resident: Vec<u64> = Vec::new();
        for round in 0u64..50 {
            // deterministic churn: two chains admitted per round, the
            // oldest reclaimed from round 5 on
            let added = vec![round * 2, round * 2 + 1];
            let retracted: Vec<u64> =
                if round >= 5 { vec![resident.remove(0), resident.remove(0)] } else { vec![] };
            resident.extend(&added);
            full.publish(0, resident.clone());
            assert!(delta.publish_delta(0, epoch, &added, &retracted));
            assert_eq!(delta.snapshot(0), full.snapshot(0), "delta stream drifted");
        }
        // a node death bumps the epoch: in-flight deltas are refused and
        // apply nothing until the worker resynchronizes with one full
        // publish
        delta.clear(0);
        assert!(!delta.publish_delta(0, epoch, &[1], &[]), "stale epoch refused");
        assert!(delta.snapshot(0).is_empty(), "refused delta applied nothing");
        let epoch2 = delta.publish(0, resident.clone());
        assert_ne!(epoch, epoch2, "clear must bump the epoch");
        assert_eq!(delta.epoch(0), epoch2);
        assert!(delta.publish_delta(0, epoch2, &[999], &[]));
        assert!(delta.snapshot(0).contains(&999));
        // out-of-range nodes refuse deltas instead of panicking
        assert!(!delta.publish_delta(9, epoch2, &[], &[]));
    }

    #[test]
    fn stale_directory_entry_degrades_to_a_plain_miss() {
        // The dispatcher/directory race: node 0 publishes its resident
        // chains, then loses them (here: the --no-kv-cache ablation
        // frees at refcount zero; with retention on the same race needs
        // a reclaim) before the affinity-routed request lands. The route
        // was taken on a stale entry — admission must degrade to a plain
        // miss (re-prefill), never error, and the directory heals on the
        // next publish.
        let mut p = pager();
        p.set_retention(false);
        let w = window(8, 8, 0);
        let (a, _) = p.admit_prompt(&w).unwrap();
        let dir = PrefixDirectory::new(1);
        dir.publish(0, p.index_hashes());
        let hashes = window_chain_hashes(&w, p.block_positions());
        assert_eq!(dir.match_depths(&hashes), vec![2], "published while resident");
        // evict between publish and dispatch
        p.release(a).unwrap();
        assert_eq!(
            dir.match_depths(&hashes),
            vec![2],
            "directory is a stale hint by design"
        );
        assert_eq!(p.resident_prefix_blocks(&w), 0, "the pager knows better");
        // the routed request admits anyway: zero hits, fresh pages, no error
        let (b, hits) = p.admit_prompt(&w).unwrap();
        assert_eq!(hits, 0, "stale hit must become a plain miss");
        assert_eq!(p.used_blocks(), 2);
        // republish reflects reality again
        dir.publish(0, p.index_hashes());
        assert_eq!(dir.match_depths(&hashes), vec![2]);
        p.release(b).unwrap();
        dir.publish(0, p.index_hashes());
        assert_eq!(dir.match_depths(&hashes), vec![0]);
    }

    #[test]
    fn prop_two_node_fabric_directory_and_pools_never_dangle() {
        // The fabric-wide extension of the shared-prefix property, now
        // with the cached tier in play: two pagers (cards), one fleet
        // PrefixDirectory, one shared HostPool. Random interleavings of
        // affinity-routed admit / CoW grow / swap-out / cross-node
        // migration (swap-in on the *other* card) / release / cache
        // flush, with publishes interleaved at random (so the directory
        // is routinely stale). Invariants after every step: each pager's
        // tree never points at a freed block, admission pins exactly the
        // probed depth (a reclaimed chain never resurrects), the
        // cached-bytes ledger never double-counts, the three tiers
        // partition each budget, the shared host pool's bytes equal the
        // outstanding parked reservations, and admitting via a stale
        // directory route never errors.
        forall(0xFAB51C, 100, |rng: &mut Rng| {
            let bp = rng.range(1, 6) as usize;
            let total = rng.range(6, 40) as usize;
            let weights = 1u64 << 10;
            let vram = weights + total as u64 * (bp as u64 * 64);
            let mut pagers = [
                KvPager::new(bp, 64, vram, weights).unwrap(),
                KvPager::new(bp, 64, vram, weights).unwrap(),
            ];
            let dir = PrefixDirectory::new(2);
            let mut host = HostPool::new(rng.range(1, 1 << 16));
            // live: (node, handle, shadow ids, positions); parked: (home
            // node at swap time, reserved bytes, family, len, salt)
            let mut live: Vec<(usize, SeqKv, Vec<usize>, usize)> = Vec::new();
            let mut parked: Vec<(usize, u64, usize, usize, i32)> = Vec::new();
            let families: Vec<(usize, usize)> = (0..3)
                .map(|_| {
                    let len = rng.range(1, 4 * bp as u64) as usize;
                    (rng.range(0, len as u64 + 1) as usize, len)
                })
                .collect();
            for _ in 0..80 {
                match rng.below(7) {
                    0 | 1 => {
                        // affinity-routed admit: pick the node with the
                        // deeper published match (possibly stale)
                        let fi = rng.below(families.len() as u64) as usize;
                        let (shared, len) = families[fi];
                        let salt = rng.range(0, 3) as i32;
                        let w = window(shared, len, salt);
                        let depths = dir.match_depths(&window_chain_hashes(&w, bp));
                        let node = if depths[1] > depths[0] { 1 } else { 0 };
                        let probed = pagers[node].resident_prefix_blocks(&w);
                        if let Some((h, hits)) = pagers[node].admit_prompt(&w) {
                            // stale routes degrade: hits are exactly what
                            // the live tree held — a reclaimed chain can
                            // never resurrect, and it is never an error
                            assert_eq!(hits, probed, "node {node} resurrected a reclaimed chain");
                            assert!(hits <= len.max(1).div_ceil(bp));
                            let ids = pagers[node].seq_block_ids(h);
                            live.push((node, h, ids, len));
                        }
                    }
                    2 => {
                        // grow (may CoW)
                        if let Some(i) =
                            (!live.is_empty()).then(|| rng.below(live.len() as u64) as usize)
                        {
                            let target = live[i].3 + rng.range(0, 2 * bp as u64) as usize;
                            let node = live[i].0;
                            if pagers[node].grow(live[i].1, target).unwrap() {
                                live[i].3 = live[i].3.max(target);
                                live[i].2 = pagers[node].seq_block_ids(live[i].1);
                            }
                        }
                    }
                    3 => {
                        // swap-out: park a live sequence in the shared
                        // host pool, moving only the bytes the cached
                        // tier and live sharers cannot keep resident
                        if let Some(i) =
                            (!live.is_empty()).then(|| rng.below(live.len() as u64) as usize)
                        {
                            let (node, h, len) = (live[i].0, live[i].1, live[i].3);
                            let bytes = pagers[node].seq_swap_bytes(h).unwrap();
                            if host.try_reserve(bytes) {
                                live.swap_remove(i);
                                pagers[node].release(h).unwrap();
                                let fi = rng.below(families.len() as u64) as usize;
                                let (shared, _) = families[fi];
                                parked.push((node, bytes, shared.min(len), len, 0));
                            }
                        }
                    }
                    4 => {
                        // migrate/resume: restore a parked sequence onto a
                        // random card — possibly NOT its home (the
                        // cross-node path); the host reservation is
                        // released exactly once either way
                        if let Some(i) =
                            (!parked.is_empty()).then(|| rng.below(parked.len() as u64) as usize)
                        {
                            let (_, bytes, shared, len, salt) = parked[i];
                            let dst = rng.below(2) as usize;
                            let w = window(shared, len, salt);
                            if let Some((h, _)) = pagers[dst].admit_prompt(&w) {
                                parked.swap_remove(i);
                                host.release(bytes);
                                let ids = pagers[dst].seq_block_ids(h);
                                live.push((dst, h, ids, len));
                            }
                        }
                    }
                    5 => {
                        // release, or republish a random node's snapshot
                        if rng.below(2) == 0 {
                            let node = rng.below(2) as usize;
                            dir.publish(node, pagers[node].index_hashes());
                        } else if let Some(i) =
                            (!live.is_empty()).then(|| rng.below(live.len() as u64) as usize)
                        {
                            let (node, h, _, _) = live.swap_remove(i);
                            pagers[node].release(h).unwrap();
                        }
                    }
                    _ => {
                        // reclaim-pressure flush: retention off drains the
                        // whole cached tier (every reclaim path at once),
                        // then back on — reclaimed chains must be gone
                        // from descent and never come back
                        let node = rng.below(2) as usize;
                        pagers[node].set_retention(false);
                        assert_eq!(pagers[node].cached_blocks(), 0);
                        pagers[node].set_retention(true);
                    }
                }
                // invariants: per-node tier partition + tree integrity +
                // shared-pool byte conservation
                for (node, pager) in pagers.iter().enumerate() {
                    let mut refs: std::collections::HashMap<usize, u32> =
                        std::collections::HashMap::new();
                    for (n, _, ids, _) in &live {
                        if *n == node {
                            for &id in ids {
                                *refs.entry(id).or_default() += 1;
                            }
                        }
                    }
                    for (&id, &expect) in &refs {
                        assert_eq!(pager.block_refs(id), expect, "node {node} refcount drift");
                    }
                    assert_eq!(pager.used_blocks(), refs.len());
                    assert_eq!(
                        pager.used_blocks() + pager.cached_blocks() + pager.free_blocks(),
                        pager.capacity_blocks(),
                        "node {node} tiers must partition the budget"
                    );
                    assert_eq!(
                        pager.cached_bytes(),
                        pager.cached_blocks() as u64 * (bp as u64 * 64),
                        "node {node} cached-bytes ledger double-counted"
                    );
                    for id in pager.index_entries() {
                        assert!(
                            refs.contains_key(&id) || pager.block_cached(id),
                            "node {node} tree points at freed block {id}"
                        );
                    }
                }
                let expect: u64 = parked.iter().map(|&(_, b, _, _, _)| b).sum();
                assert_eq!(host.used_bytes(), expect, "host pool drifted from parked ledger");
                assert!(host.used_bytes() <= host.capacity_bytes());
            }
            for (node, h, _, _) in live {
                pagers[node].release(h).unwrap();
            }
            for (_, bytes, _, _, _) in parked {
                host.release(bytes);
            }
            assert_eq!(host.used_bytes(), 0);
            for pager in pagers.iter_mut() {
                assert_eq!(pager.used_blocks(), 0);
                pager.set_retention(false);
                assert_eq!(pager.cached_blocks(), 0);
                assert_eq!(pager.free_blocks(), pager.capacity_blocks());
            }
        });
    }

    #[test]
    fn reclaim_retracts_dropped_chains_for_the_directory() {
        // Regression: a cache-tier reclaim unlinks chains from the radix
        // tree, but nothing carried the retraction to the fleet
        // PrefixDirectory — affine routing kept chasing history that was
        // gone until the next full republish. The pager now buffers every
        // unlinked hash for the worker's retraction delta.
        let mut p = pager();
        p.limit_blocks(2).unwrap();
        let w = window(0, 8, 1); // 2 blocks
        let hashes = window_chain_hashes(&w, 4);
        let (a, _) = p.admit_prompt(&w).unwrap();
        p.release(a).unwrap();
        assert_eq!(p.cached_blocks(), 2);
        // The worker's round-top publish: full snapshot, drain the buffer.
        let dir = PrefixDirectory::new(1);
        let epoch = dir.publish(0, p.index_hashes());
        p.take_retracted();
        assert_eq!(dir.match_depths(&hashes), vec![2]);
        // Pressure from an unrelated admission reclaims the cached chain.
        let (b, _) = p.admit_prompt(&window(0, 8, 2)).unwrap();
        let retracted = p.take_retracted();
        assert_eq!(retracted.len(), 2, "both dropped chunks must be retracted");
        assert!(hashes.iter().all(|h| retracted.contains(h)));
        assert!(dir.publish_delta(0, epoch, &[], &retracted));
        assert_eq!(
            dir.match_depths(&hashes),
            vec![0],
            "the directory must stop advertising the reclaimed chain"
        );
        assert!(p.take_retracted().is_empty(), "drain is one-shot");
        p.release(b).unwrap();
    }

    #[test]
    fn divergence_and_retention_flips_buffer_retractions_too() {
        let mut p = pager();
        let (a, _) = p.admit_prompt(&window(0, 6, 1)).unwrap(); // 2 blocks, partial tail
        p.take_retracted();
        // growing into the privately-held partial tail diverges it from
        // its hash: the tail chunk unlinks and must be retracted
        assert!(p.grow(a, 7).unwrap());
        assert_eq!(p.take_retracted().len(), 1);
        p.release(a).unwrap();
        // flipping retention off reclaims the whole cached tier at once
        let before = p.cached_blocks();
        assert!(before > 0);
        p.set_retention(false);
        assert_eq!(p.take_retracted().len(), before);
    }

    #[test]
    fn depth_policy_reclaims_the_tail_and_keeps_the_prefix() {
        // One idle 3-chunk conversation fills the (capped) card. Release
        // demotes its blocks shallow-first, so under LRU the *prefix*
        // chunk is the oldest entry — and reclaiming it strands the whole
        // chain: three blocks die to find one page. Depth picks the tail
        // chunk instead: one surgical block, the reusable prefix survives.
        let run = |policy: ReclaimPolicy| {
            let mut p = pager();
            p.limit_blocks(3).unwrap();
            p.set_reclaim_policy(policy);
            let (a, _) = p.admit_prompt(&window(0, 12, 1)).unwrap(); // 3-chunk chain
            p.release(a).unwrap();
            assert_eq!((p.cached_blocks(), p.free_blocks()), (3, 0));
            // one unrelated block's worth of pressure
            let c = p.admit(4).unwrap();
            let survivors = p.resident_prefix_blocks(&window(0, 12, 1));
            let freed = p.prefix_stats().reclaimed_blocks;
            p.release(c).unwrap();
            (survivors, freed)
        };
        assert_eq!(run(ReclaimPolicy::Lru), (0, 3), "LRU cuts shallow: whole chain dies");
        assert_eq!(run(ReclaimPolicy::Depth), (2, 1), "depth cuts the tail: prefix survives");
    }

    #[test]
    fn depth_keeps_the_shared_system_prefix_warm_across_tenants() {
        // Two conversations behind one shared 4-token system prefix, all
        // idle in the cached tier. Depth pressure eats private tails
        // (deepest, then LRU-older on ties) before ever touching the
        // chunk both tenants' next requests would hit.
        let mut p = pager();
        p.limit_blocks(4).unwrap();
        p.set_reclaim_policy(ReclaimPolicy::Depth);
        let (a, _) = p.admit_prompt(&window(4, 12, 1)).unwrap(); // shared + 2 private
        let (b, _) = p.admit_prompt(&window(4, 8, 2)).unwrap(); // shared + 1 private
        p.release(a).unwrap();
        p.release(b).unwrap();
        assert_eq!((p.cached_blocks(), p.free_blocks()), (4, 0));
        // first pressure block: a's depth-3 tail is the unique deepest
        let c = p.admit(4).unwrap();
        assert_eq!(p.resident_prefix_blocks(&window(4, 12, 1)), 2);
        assert_eq!(p.resident_prefix_blocks(&window(4, 8, 2)), 2);
        // second: both depth-2 tails tie; a's was demoted first, so it goes
        let d = p.admit(4).unwrap();
        assert_eq!(p.resident_prefix_blocks(&window(4, 12, 1)), 1);
        assert_eq!(p.resident_prefix_blocks(&window(4, 8, 2)), 2);
        // third: b's tail goes — the shared prefix is the last survivor
        let e = p.admit(4).unwrap();
        assert_eq!(p.resident_prefix_blocks(&window(4, 8, 2)), 1);
        assert_eq!(p.cached_blocks(), 1, "the system prefix outlives all its tails");
        p.release(c).unwrap();
        p.release(d).unwrap();
        p.release(e).unwrap();
    }
}
