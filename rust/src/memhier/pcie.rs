//! PCIe host-link model.
//!
//! The CMP 170HX ships with a **PCIe 1.1 x4** electrical interface (Table
//! 2-1) — mining needs almost no host bandwidth, so NVIDIA depopulated the
//! coupling capacitors. Appendix Ex.2.2 notes the x16 pads exist and could
//! be repopulated; [`PcieLink::with_lanes`] models that mod. The test
//! platform itself connects through OCuLink (§2.2), which caps at x4 — the
//! model composes both ends by taking the min.

/// PCIe generation: per-lane raw rate and encoding overhead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PcieGen {
    Gen1,
    Gen2,
    Gen3,
    Gen4,
}

impl PcieGen {
    /// Raw per-lane signalling rate, GT/s.
    pub fn gtps(self) -> f64 {
        match self {
            PcieGen::Gen1 => 2.5,
            PcieGen::Gen2 => 5.0,
            PcieGen::Gen3 => 8.0,
            PcieGen::Gen4 => 16.0,
        }
    }

    /// Encoding efficiency (8b/10b for gen1/2, 128b/130b after).
    pub fn encoding_eff(self) -> f64 {
        match self {
            PcieGen::Gen1 | PcieGen::Gen2 => 0.8,
            PcieGen::Gen3 | PcieGen::Gen4 => 128.0 / 130.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PcieGen::Gen1 => "1.1",
            PcieGen::Gen2 => "2.0",
            PcieGen::Gen3 => "3.0",
            PcieGen::Gen4 => "4.0",
        }
    }
}

/// A host link: generation × lane count, with protocol efficiency for
/// payload transfers (TLP headers, flow control ≈ 80–85% of line rate).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PcieLink {
    pub gen: PcieGen,
    pub lanes: u32,
    /// Payload fraction of line rate after TLP/DLLP overhead.
    pub protocol_eff: f64,
}

impl PcieLink {
    pub fn new(gen: PcieGen, lanes: u32) -> Self {
        PcieLink {
            gen,
            lanes,
            protocol_eff: 0.82,
        }
    }

    /// The CMP 170HX's stock link (Table 2-1).
    pub fn cmp170hx_stock() -> Self {
        Self::new(PcieGen::Gen1, 4)
    }

    /// Ex.2.2's capacitor mod: same gen, x16 lanes.
    pub fn cmp170hx_x16_mod() -> Self {
        Self::new(PcieGen::Gen1, 16)
    }

    /// Change lane count (returns a new link).
    pub fn with_lanes(mut self, lanes: u32) -> Self {
        self.lanes = lanes;
        self
    }

    /// Theoretical unidirectional bandwidth, bytes/s (line rate × encoding).
    pub fn theoretical_bw(&self) -> f64 {
        self.gen.gtps() * 1e9 * self.gen.encoding_eff() * self.lanes as f64 / 8.0
    }

    /// Achieved unidirectional payload bandwidth, bytes/s.
    pub fn achieved_bw(&self) -> f64 {
        self.theoretical_bw() * self.protocol_eff
    }

    /// Achieved bidirectional aggregate (full duplex).
    pub fn bidir_bw(&self) -> f64 {
        2.0 * self.achieved_bw()
    }

    /// Time to move `bytes` one way, including a fixed DMA setup latency.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        const DMA_SETUP_S: f64 = 10e-6;
        DMA_SETUP_S + bytes as f64 / self.achieved_bw()
    }

    /// Compose with the host-side link (OCuLink adapter): the narrower and
    /// slower of the two ends governs.
    pub fn through(&self, host: &PcieLink) -> PcieLink {
        let gen = if self.gen.gtps() <= host.gen.gtps() { self.gen } else { host.gen };
        PcieLink {
            gen,
            lanes: self.lanes.min(host.lanes),
            protocol_eff: self.protocol_eff.min(host.protocol_eff),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn stock_link_is_about_one_gbps() {
        // PCIe 1.1 x4: 2.5 GT/s × 0.8 × 4 / 8 = 1.0 GB/s theoretical —
        // matching Graph EX.2's theoretical line.
        let l = PcieLink::cmp170hx_stock();
        assert_close(l.theoretical_bw(), 1.0e9, 1e-9);
        assert!(l.achieved_bw() < 1.0e9 && l.achieved_bw() > 0.75e9);
    }

    #[test]
    fn x16_mod_quadruples_bandwidth() {
        let stock = PcieLink::cmp170hx_stock();
        let modded = PcieLink::cmp170hx_x16_mod();
        assert_close(modded.theoretical_bw() / stock.theoretical_bw(), 4.0, 1e-12);
    }

    #[test]
    fn bidir_is_double_unidir() {
        let l = PcieLink::cmp170hx_stock();
        assert_close(l.bidir_bw(), 2.0 * l.achieved_bw(), 1e-12);
    }

    #[test]
    fn through_oculink_takes_the_min() {
        // x16 card through an x4 OCuLink gen4 host: lanes limited by host,
        // gen limited by the card.
        let card = PcieLink::cmp170hx_x16_mod();
        let host = PcieLink::new(PcieGen::Gen4, 4);
        let eff = card.through(&host);
        assert_eq!(eff.lanes, 4);
        assert_eq!(eff.gen, PcieGen::Gen1);
    }

    #[test]
    fn transfer_time_includes_setup() {
        let l = PcieLink::cmp170hx_stock();
        assert!(l.transfer_time(0) >= 10e-6);
        let big = l.transfer_time(1 << 30);
        assert!(big > 1.0, "1 GiB over ~0.8 GB/s takes over a second: {big}");
    }

    #[test]
    fn gen3_uses_128b130b() {
        assert!(PcieGen::Gen3.encoding_eff() > 0.98);
        assert_close(PcieGen::Gen1.encoding_eff(), 0.8, 1e-12);
    }
}
