//! One generator per paper graph/table.

use crate::bench::{gpuburn, membench, mixbench, openclbench, pciebench, torchgemm, Precision};
use crate::bench_harness::{Row, Table};
use crate::calibration as cal;
use crate::device::{registry, DeviceSpec};
use crate::isa::pass::FmadPolicy;
use crate::llm::llamabench::LlamaBench;
use crate::market::sales::{estimate_sales, Scenario};

fn flops_suite(dev: &DeviceSpec, precision: Precision, title: &str, unit: &'static str) -> Table {
    let mut t = Table::new(title, unit);
    let integer = precision.integer();
    let value = |r: &crate::bench::ToolResult| if integer { r.tiops() } else { r.tflops() };

    let torch = torchgemm::run(dev, precision);
    t.push(Row::new("PyTorch-CUDA", value(&torch)));
    for policy in [FmadPolicy::Fused, FmadPolicy::Decomposed] {
        let ocl = openclbench::peak(dev, precision, policy);
        t.push(Row::new(
            format!("OpenCL-benchmark ({})", policy.name()),
            value(&ocl),
        ));
        let mb = mixbench::peak(dev, precision, policy);
        t.push(Row::new(
            format!("Mixbench-CUDA ({})", policy.name()),
            value(&mb),
        ));
    }
    let burn = gpuburn::run(dev, precision);
    t.push(Row::new("GPU-Burn-CUDA", value(&burn)));
    t
}

/// Graph 3-1 — FP32 TFLOPS across the six tool/policy bars.
pub fn graph_3_1() -> Table {
    let dev = registry::cmp170hx();
    let mut t = flops_suite(&dev, Precision::Fp32, "Graph 3-1: CMP 170HX FP32", "TFLOPS");
    // attach paper values to the canonical bars
    for r in t.rows.iter_mut() {
        if r.label.contains("default") || r.label.contains("PyTorch") || r.label.contains("Burn") {
            r.paper = Some(cal::FP32_DEFAULT_TFLOPS.value);
        } else if r.label.contains("noFMA") {
            r.paper = Some(cal::FP32_NOFMA_TFLOPS.value);
        }
    }
    t.push(
        Row::new("Theoretical Perf.", dev.fp32_tflops())
            .paper(cal::FP32_THEORETICAL_TFLOPS.value),
    );
    t
}

/// Graph 3-2 — FP16: the half2 tools against the scalar-half tools.
pub fn graph_3_2() -> Table {
    let dev = registry::cmp170hx();
    let mut t = Table::new("Graph 3-2: CMP 170HX FP16", "TFLOPS");
    t.push(
        Row::new("PyTorch-CUDA (scalar half)", torchgemm::run(&dev, Precision::Fp16Scalar).tflops())
            .paper(cal::FP16_SCALAR_TFLOPS.value),
    );
    for policy in [FmadPolicy::Fused, FmadPolicy::Decomposed] {
        t.push(
            Row::new(
                format!("OpenCL-benchmark half2 ({})", policy.name()),
                openclbench::peak(&dev, Precision::Fp16Half2, policy).tflops(),
            )
            .paper(cal::FP16_HALF2_TFLOPS.value),
        );
        t.push(Row::new(
            format!("Mixbench-CUDA half2 ({})", policy.name()),
            mixbench::peak(&dev, Precision::Fp16Half2, policy).tflops(),
        ));
    }
    t.push(
        Row::new("GPU-Burn-CUDA (scalar half)", gpuburn::run(&dev, Precision::Fp16Scalar).tflops())
            .paper(cal::FP16_SCALAR_TFLOPS.value),
    );
    t.push(
        Row::new("Theoretical Perf.", dev.fp16_tflops()).paper(cal::FP16_THEORETICAL_TFLOPS.value),
    );
    t
}

/// Graph 3-3 — FP64.
pub fn graph_3_3() -> Table {
    let dev = registry::cmp170hx();
    let mut t = flops_suite(&dev, Precision::Fp64, "Graph 3-3: CMP 170HX FP64", "TFLOPS");
    for r in t.rows.iter_mut() {
        if r.label.contains("default") || r.label.contains("PyTorch") || r.label.contains("Burn") {
            r.paper = Some(cal::FP64_DEFAULT_TFLOPS.value);
        } else if r.label.contains("noFMA") {
            r.paper = Some(cal::FP64_NOFMA_TFLOPS.value);
            r.note = "noFMA makes FP64 *worse*".into();
        }
    }
    t.push(
        Row::new("Theoretical Perf.", dev.fp64_tflops()).paper(cal::FP64_THEORETICAL_TFLOPS.value),
    );
    t
}

/// Graph 3-4 — INT32 TIOPs.
pub fn graph_3_4() -> Table {
    let dev = registry::cmp170hx();
    let mut t = Table::new("Graph 3-4: CMP 170HX INT32", "TIOPs");
    t.push(
        Row::new(
            "OpenCL-benchmark",
            openclbench::peak(&dev, Precision::Int32, FmadPolicy::Fused).tiops(),
        )
        .paper(cal::INT32_OPENCL_TIOPS.value),
    );
    t.push(
        Row::new(
            "Mixbench-CUDA",
            mixbench::peak(&dev, Precision::Int32, FmadPolicy::Fused).tiops(),
        )
        .paper(cal::INT32_CUDA_TIOPS.value)
        .note("lower launch pressure (§3.4)"),
    );
    t.push(Row::new(
        "Theoretical Perf.",
        dev.theoretical_class_rate(crate::isa::InstClass::Imad),
    ));
    t
}

/// Graph 3-5 — memory bandwidth.
pub fn graph_3_5() -> Table {
    let dev = registry::cmp170hx();
    let mut t = Table::new("Graph 3-5: CMP 170HX memory bandwidth", "GB/s");
    for r in membench::graph_3_5(&dev) {
        let mut row = Row::new(r.case.clone(), r.gbps());
        if r.case.contains("read") && r.case.contains("Coalesced") {
            row = row.paper(cal::MEMBW_COALESCED_GBPS.value);
        }
        t.push(row);
    }
    t.push(
        Row::new("Theoretical Perf.", dev.mem.peak_bw / 1e9)
            .paper(cal::MEMBW_THEORETICAL_GBPS.value),
    );
    t
}

/// The llama-bench grid, simulated once as a batched sweep. Returned
/// quant-major with `Fused` before `Decomposed` — `chunks(2)` walks it in
/// paper order. Each §4 figure consumes one of these instead of re-running
/// (and re-lowering) the whole grid per row.
fn llama_grid(dev: &DeviceSpec) -> Vec<crate::llm::llamabench::BenchResult> {
    LlamaBench::default().run_all(dev)
}

/// Graph 4-1 — llama-bench prefill speeds across quants/policies with the
/// SM-scaled A100 theoretical overlay.
pub fn graph_4_1() -> Table {
    let dev = registry::cmp170hx();
    let mut t = Table::new(
        "Graph 4-1: llama-bench prefill (Qwen2.5-1.5B, pp512)",
        "tokens/s",
    );
    for pair in llama_grid(&dev).chunks(2) {
        for r in pair {
            t.push(
                Row::new(format!("{} ({})", r.quant, r.policy.name()), r.prefill_tps).note(
                    format!("{:.0}% of theoretical", 100.0 * r.prefill_fraction()),
                ),
            );
        }
        t.push(Row::new(
            format!("{} (Theoretical Perf.)", pair[0].quant),
            pair[0].theoretical_prefill_tps,
        ));
    }
    t
}

/// Graph 4-2 — decode speeds with the BW-scaled overlay.
pub fn graph_4_2() -> Table {
    let dev = registry::cmp170hx();
    let mut t = Table::new(
        "Graph 4-2: llama-bench decode (Qwen2.5-1.5B, tg128)",
        "tokens/s",
    );
    for pair in llama_grid(&dev).chunks(2) {
        for r in pair {
            t.push(
                Row::new(format!("{} ({})", r.quant, r.policy.name()), r.decode_tps).note(
                    format!("{:.0}% of theoretical", 100.0 * r.decode_fraction()),
                ),
            );
        }
        t.push(Row::new(
            format!("{} (Theoretical Perf.)", pair[0].quant),
            pair[0].theoretical_decode_tps,
        ));
    }
    t
}

/// Graph 4-3 — decode power efficiency (tokens/s/W).
pub fn graph_4_3() -> Table {
    let dev = registry::cmp170hx();
    let mut t = Table::new("Graph 4-3: decode power efficiency", "tokens/s/W");
    for pair in llama_grid(&dev).chunks(2) {
        for r in pair {
            t.push(
                Row::new(format!("{} ({})", r.quant, r.policy.name()), r.tokens_per_watt)
                    .note(format!("{:.0} W", r.decode_power_w)),
            );
        }
        t.push(Row::new(
            format!("{} (theoretical A100-class)", pair[0].quant),
            pair[0].theoretical_tokens_per_watt(),
        ));
    }
    t
}

/// Graph EX.1 — INT8 dp4a.
pub fn graph_ex1() -> Table {
    let dev = registry::cmp170hx();
    let mut t = Table::new("Graph EX.1: CMP 170HX INT8 (dp4a)", "TIOPs");
    t.push(
        Row::new(
            "OpenCL-benchmark",
            openclbench::peak(&dev, Precision::Int8, FmadPolicy::Fused).tiops(),
        )
        .paper(cal::INT8_OPENCL_TIOPS.value),
    );
    t.push(
        Row::new(
            "Mixbench-CUDA",
            mixbench::peak(&dev, Precision::Int8, FmadPolicy::Fused).tiops(),
        )
        .paper(cal::INT8_CUDA_TIOPS.value),
    );
    t
}

/// Graph EX.2 — PCIe bandwidth, stock x4 vs the x16 capacitor mod.
pub fn graph_ex2() -> Table {
    let dev = registry::cmp170hx();
    let mut t = Table::new("Graph EX.2: CMP 170HX PCIe bandwidth", "GB/s");
    for r in pciebench::graph_ex2(&dev) {
        let mut row = Row::new(r.case.clone(), r.gbps);
        if r.case.contains("stock") && r.case.contains("send") {
            row = row.note(format!("theoretical {:.2} GB/s", r.theoretical_gbps));
        }
        t.push(row);
    }
    t
}

/// Table 1-1 — prices and FP16 TFLOPS of the CMP family.
pub fn table_1_1() -> Table {
    let mut t = Table::new("Table 1-1: CMP family prices & FP16", "TFLOPS");
    let devices = [
        registry::cmp30hx(),
        registry::cmp40hx(),
        registry::cmp50hx(),
        registry::cmp90hx(),
        registry::cmp170hx(),
    ];
    for (dev, &(name, _price, fp16)) in devices.iter().zip(cal::TABLE_1_1) {
        t.push(
            Row::new(name, dev.fp16_tflops())
                .paper(fp16)
                .note(format!("ASP ${:.0}", dev.price_usd)),
        );
    }
    t
}

/// Table 1-2 — sales-volume scenarios.
pub fn table_1_2() -> Table {
    let mut t = Table::new("Table 1-2: estimated CMP sales", "units");
    for (scenario, (paper_total, _)) in Scenario::all().iter().zip(cal::TABLE_1_2_TOTALS.iter()) {
        let est = estimate_sales(cal::CMP_REVENUE_USD, scenario);
        for (model, _asp, units) in &est.rows {
            t.push(Row::new(format!("{model} (scenario {})", est.scenario), *units));
        }
        t.push(
            Row::new(format!("Whole (scenario {})", est.scenario), est.total_units)
                .paper(*paper_total),
        );
    }
    t
}

/// Every figure, in paper order (the `report --all` payload).
pub fn all_figures() -> Vec<Table> {
    vec![
        table_1_1(),
        table_1_2(),
        graph_3_1(),
        graph_3_2(),
        graph_3_3(),
        graph_3_4(),
        graph_3_5(),
        graph_4_1(),
        graph_4_2(),
        graph_4_3(),
        graph_ex1(),
        graph_ex2(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_figure_renders_nonempty() {
        for t in all_figures() {
            assert!(!t.rows.is_empty(), "{}", t.title);
            assert!(t.render().contains(&t.title));
        }
    }

    #[test]
    fn figure_3_1_reproduces_within_tolerance() {
        let t = graph_3_1();
        let worst = t.worst_deviation().unwrap();
        assert!(worst < 0.12, "worst deviation {worst}");
    }

    #[test]
    fn table_1_2_totals_are_exact() {
        let t = table_1_2();
        let worst = t.worst_deviation().unwrap();
        assert!(worst < 0.01, "{worst}");
    }

    #[test]
    fn headline_restore_visible_in_graph_3_1() {
        let t = graph_3_1();
        let default = t
            .rows
            .iter()
            .find(|r| r.label.contains("OpenCL") && r.label.contains("default"))
            .unwrap()
            .measured;
        let nofma = t
            .rows
            .iter()
            .find(|r| r.label.contains("OpenCL") && r.label.contains("noFMA"))
            .unwrap()
            .measured;
        assert!(nofma / default > 15.0);
    }
}
