//! Quickstart: the paper's headline in a few lines of API.
//!
//! Run: `cargo run --release --example quickstart`

use cmphx::bench::{membench, openclbench, Precision};
use cmphx::device::registry;
use cmphx::isa::ir::MemPattern;
use cmphx::isa::pass::FmadPolicy;
use cmphx::report::specs;

fn main() {
    // 1. The subject: a CMP 170HX as shipped (limiter engaged).
    let dev = registry::cmp170hx();
    println!("{}", specs::spec_sheet(&dev));

    // 2. FP32 as the card ships: ~1/32 of its silicon.
    let crippled = openclbench::peak(&dev, Precision::Fp32, FmadPolicy::Fused);
    // 3. FP32 with the community workaround (-fmad=false).
    let restored = openclbench::peak(&dev, Precision::Fp32, FmadPolicy::Decomposed);

    println!(
        "FP32 default : {:>7.3} TFLOPS   (paper: ~0.39 — beats only a 2007 Tesla C870)",
        crippled.tflops()
    );
    println!(
        "FP32 noFMA   : {:>7.3} TFLOPS   (paper: ~6.2 — a free Tesla P6)",
        restored.tflops()
    );
    println!(
        "restore      : {:>7.1}×         (abstract claims >15×)",
        restored.tflops() / crippled.tflops()
    );

    // 4. And the part NVIDIA couldn't throttle: memory bandwidth.
    let bw = membench::run(&dev, membench::Dir::Read, MemPattern::Coalesced);
    let a100 = membench::run(
        &registry::a100_pcie(),
        membench::Dir::Read,
        MemPattern::Coalesced,
    );
    println!(
        "bandwidth    : {:>7.0} GB/s     ({:.0}% of an A100 — the reuse thesis)",
        bw.gbps(),
        100.0 * bw.gbps() / a100.gbps()
    );
}
