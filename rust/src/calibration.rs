//! Paper-reported values — the calibration targets.
//!
//! Every number the paper's graphs/tables report that we reproduce lives
//! here, with its source figure, so tests and EXPERIMENTS.md compare
//! simulator output against a single authoritative table. Where the paper's
//! prose and its own graphs disagree (it happens — soundness band 0), the
//! chosen value and the discrepancy are documented.

/// One calibration point: paper-reported value + tolerance for our
/// reproduction (relative).
#[derive(Clone, Copy, Debug)]
pub struct Target {
    pub id: &'static str,
    pub figure: &'static str,
    pub value: f64,
    pub rtol: f64,
    pub note: &'static str,
}

/// Graph 3-1 — FP32 TFLOPS on the CMP 170HX.
pub const FP32_DEFAULT_TFLOPS: Target = Target {
    id: "fp32.default",
    figure: "Graph 3-1",
    value: 0.39,
    rtol: 0.06,
    note: "≈1/32 of 12.63 theoretical; beats only Tesla C870 (0.346)",
};
pub const FP32_NOFMA_TFLOPS: Target = Target {
    id: "fp32.nofma",
    figure: "Graph 3-1",
    value: 6.2,
    rtol: 0.04,
    note: "-fmad=false recovers half of theoretical; ≈ Tesla P6",
};
pub const FP32_THEORETICAL_TFLOPS: Target = Target {
    id: "fp32.theoretical",
    figure: "Table 2-4",
    value: 12.63,
    rtol: 0.005,
    note: "boost FP32",
};
/// The abstract's headline: ">15× the original capability".
pub const FP32_RESTORE_FACTOR_MIN: f64 = 15.0;

/// Graph 3-2 — FP16.
pub const FP16_HALF2_TFLOPS: Target = Target {
    id: "fp16.half2",
    figure: "Graph 3-2",
    value: 49.0,
    rtol: 0.05,
    note: "OpenCL half2 path ≈ RTX 4080 FP16 (non-tensor); FMA status irrelevant",
};
pub const FP16_SCALAR_TFLOPS: Target = Target {
    id: "fp16.scalar",
    figure: "Graph 3-2",
    value: 6.3,
    rtol: 0.06,
    note: "PyTorch/GPU-Burn scalar-half path (no half2 vectorization)",
};
pub const FP16_THEORETICAL_TFLOPS: Target = Target {
    id: "fp16.theoretical",
    figure: "Table 2-4",
    value: 50.53,
    rtol: 0.005,
    note: "boost FP16 (non-tensor)",
};

/// Graph 3-3 — FP64.
pub const FP64_DEFAULT_TFLOPS: Target = Target {
    id: "fp64.default",
    figure: "Graph 3-3",
    value: 0.19,
    rtol: 0.08,
    note: "graph shows 0.18–0.20 ≈ theoretical/32; prose claims 1/64 — we calibrate to the graph (DESIGN.md §3)",
};
pub const FP64_NOFMA_TFLOPS: Target = Target {
    id: "fp64.nofma",
    figure: "Graph 3-3",
    value: 0.099,
    rtol: 0.10,
    note: "noFMA halves FP64: unfused f64 ops are throttled too and there are 2× of them",
};
pub const FP64_THEORETICAL_TFLOPS: Target = Target {
    id: "fp64.theoretical",
    figure: "Table 2-4",
    value: 6.317,
    rtol: 0.005,
    note: "boost FP64",
};

/// Graph 3-4 — INT32 (TIOPs). Uncrippled; OpenCL slightly above CUDA.
pub const INT32_OPENCL_TIOPS: Target = Target {
    id: "int32.opencl",
    figure: "Graph 3-4",
    value: 12.3,
    rtol: 0.06,
    note: "≈97% of 12.63 theoretical IMAD rate",
};
pub const INT32_CUDA_TIOPS: Target = Target {
    id: "int32.cuda",
    figure: "Graph 3-4",
    value: 11.7,
    rtol: 0.06,
    note: "mixbench at 1024 iters underpressures the GPU (paper §3.4)",
};

/// Graph 3-5 — memory bandwidth (GB/s).
pub const MEMBW_COALESCED_GBPS: Target = Target {
    id: "membw.coalesced",
    figure: "Graph 3-5",
    value: 1314.0,
    rtol: 0.05,
    note: "≈88% of 1493 GB/s peak — fully retained",
};
pub const MEMBW_THEORETICAL_GBPS: Target = Target {
    id: "membw.theoretical",
    figure: "Table 2-3",
    value: 1493.0,
    rtol: 0.005,
    note: "HBM2e 4096-bit @ 2916 MT/s",
};

/// Graph EX.1 — INT8 dp4a (TIOPs).
pub const INT8_OPENCL_TIOPS: Target = Target {
    id: "int8.opencl",
    figure: "Graph EX.1",
    value: 25.13,
    rtol: 0.05,
    note: "dp4a uncrippled, ≈ peak of the half-rate dp4a pipe",
};
pub const INT8_CUDA_TIOPS: Target = Target {
    id: "int8.cuda",
    figure: "Graph EX.1",
    value: 21.77,
    rtol: 0.06,
    note: "CUDA path at lower launch pressure",
};

/// Graph EX.2 — PCIe (GB/s).
pub const PCIE_STOCK_THEORETICAL_GBPS: Target = Target {
    id: "pcie.stock.theoretical",
    figure: "Graph EX.2",
    value: 1.0,
    rtol: 0.01,
    note: "PCIe 1.1 x4",
};

/// §4 — llama-bench shape targets (ratios, not absolute t/s).
/// Prefill noFMA/default speedup per quant (Graph 4-1; Q2_K "231% of
/// original rate", f32/f16 "no performance gains").
pub const PREFILL_NOFMA_SPEEDUP: &[(&str, f64, f64)] = &[
    // (quant, speedup, rtol)
    ("f32", 1.00, 0.02),
    ("f16", 1.00, 0.02),
    ("q8_0", 1.45, 0.15),
    ("q6_k", 1.60, 0.15),
    ("q4_k_m", 1.70, 0.15),
    ("q2_k", 2.31, 0.10),
];
/// Prefill reaches 14–45% of the SM-scaled A100 theoretical (§4.2, noFMA).
pub const PREFILL_FRACTION_OF_THEORETICAL: (f64, f64) = (0.14, 0.45);
/// Decode reaches 39–78% of the BW-scaled A100 theoretical by default and
/// 50–78% with noFMA (§4.3).
pub const DECODE_FRACTION_DEFAULT: (f64, f64) = (0.39, 0.78);
pub const DECODE_FRACTION_NOFMA: (f64, f64) = (0.50, 0.78);

/// §4.2/§4.3 scaling rules.
pub const SM_RATIO_CMP_OVER_A100: f64 = 70.0 / 108.0;
pub const BW_RATIO_CMP_OVER_A100: f64 = 1493.0 / 1555.0;

/// Table 1-1 — CMP family prices and FP16 TFLOPS.
pub const TABLE_1_1: &[(&str, f64, f64)] = &[
    // (model, 2021 avg price USD midpoint-range, FP16 TFLOPS)
    ("CMP 30HX", 750.0, 10.05),
    ("CMP 40HX", 650.0, 15.21),
    ("CMP 50HX", 800.0, 22.15),
    ("CMP 90HX", 1550.0, 21.89),
    ("CMP 170HX", 4500.0, 50.53),
];

/// Table 1-2 — revenue-split scenarios (percent of $550M per model, in
/// Table 1-1 row order) and the resulting sales estimates.
pub const SCENARIO_A: [f64; 5] = [15.0, 25.0, 25.0, 20.0, 15.0];
pub const SCENARIO_B: [f64; 5] = [25.0, 30.0, 20.0, 15.0, 10.0];
pub const SCENARIO_C: [f64; 5] = [10.0, 15.0, 20.0, 25.0, 30.0];
pub const CMP_REVENUE_USD: f64 = 550e6;
/// Paper's whole-market sales estimates per scenario (Table 1-2).
pub const TABLE_1_2_TOTALS: [(f64, f64); 3] = [
    (582_714.0, 0.01),
    (640_127.0, 0.01),
    (463_133.0, 0.01),
];

/// Check a simulated value against a target.
pub fn check(target: &Target, measured: f64) -> bool {
    ((measured - target.value) / target.value).abs() <= target.rtol
}

/// All scalar targets, for the `report` subcommand.
pub fn all_targets() -> Vec<&'static Target> {
    vec![
        &FP32_DEFAULT_TFLOPS,
        &FP32_NOFMA_TFLOPS,
        &FP32_THEORETICAL_TFLOPS,
        &FP16_HALF2_TFLOPS,
        &FP16_SCALAR_TFLOPS,
        &FP16_THEORETICAL_TFLOPS,
        &FP64_DEFAULT_TFLOPS,
        &FP64_NOFMA_TFLOPS,
        &FP64_THEORETICAL_TFLOPS,
        &INT32_OPENCL_TIOPS,
        &INT32_CUDA_TIOPS,
        &MEMBW_COALESCED_GBPS,
        &MEMBW_THEORETICAL_GBPS,
        &INT8_OPENCL_TIOPS,
        &INT8_CUDA_TIOPS,
        &PCIE_STOCK_THEORETICAL_GBPS,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_accepts_within_tolerance() {
        assert!(check(&FP32_DEFAULT_TFLOPS, 0.39));
        assert!(check(&FP32_DEFAULT_TFLOPS, 0.40));
        assert!(!check(&FP32_DEFAULT_TFLOPS, 0.5));
    }

    #[test]
    fn restore_factor_is_consistent_with_targets() {
        assert!(FP32_NOFMA_TFLOPS.value / FP32_DEFAULT_TFLOPS.value > FP32_RESTORE_FACTOR_MIN);
    }

    #[test]
    fn scenarios_sum_to_hundred_percent() {
        for s in [SCENARIO_A, SCENARIO_B, SCENARIO_C] {
            let sum: f64 = s.iter().sum();
            assert!((sum - 100.0).abs() < 1e-9);
        }
    }

    #[test]
    fn all_targets_have_positive_values() {
        for t in all_targets() {
            assert!(t.value > 0.0 && t.rtol > 0.0, "{}", t.id);
        }
    }
}
