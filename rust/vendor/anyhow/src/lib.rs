//! Vendored minimal `anyhow`: the subset of the real crate's API this
//! repository uses, reimplemented over `std` only. The offline build image
//! has no crates.io access, so the dependency is satisfied by this local
//! path crate instead. Semantics intentionally mirror upstream:
//!
//! - [`Error`] is an opaque error with a context chain; `{}` displays the
//!   outermost message, `{:#}` the whole chain joined by `": "`, and
//!   `{:?}` the chain in "Caused by" form;
//! - any `std::error::Error + Send + Sync + 'static` converts into it via
//!   `?` (the source chain is captured);
//! - [`Context`] adds context to `Result` and `Option` values;
//! - [`anyhow!`], [`bail!`] and [`ensure!`] build/return ad-hoc errors.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: an outermost message plus the chain of causes beneath
/// it (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The chain of messages, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, outermost first, like anyhow.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket conversion
// coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding context to fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an ad-hoc [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an ad-hoc error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_outer_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("opening config");
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("layer").unwrap_err();
        assert_eq!(format!("{e:#}"), "layer: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        let some: Option<u32> = Some(7);
        assert_eq!(some.context("unused").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", f(50).unwrap_err()), "x too big: 50");
        let e = anyhow!("plain {}", 1);
        assert_eq!(format!("{e}"), "plain 1");
    }

    #[test]
    fn root_cause_and_chain() {
        let e = Error::from(io_err()).context("mid").context("outer");
        assert_eq!(e.root_cause(), "gone");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "mid", "gone"]);
    }
}
